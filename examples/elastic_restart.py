"""Cross-mesh elastic resume demo: train sharded over 8 devices, 'lose'
half the machine twice, and auto-resume each time on a mesh rebuilt from
the surviving devices — the flat optimizer shards re-shard onto the new
mesh from the checkpoint manifest, and the stateless data pipeline replays
the exact batches, so the final params match an uninterrupted run.

    python examples/elastic_restart.py        (simulates 8 CPU devices)
"""
import os

# append (not setdefault): the demo requires 8 simulated devices even when
# the environment already carries XLA flags
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8"
                           ).strip()

import dataclasses
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.gpt2 import GPT2_TINY
from repro.data import DataConfig, make_source
from repro.launch.mesh import make_mesh
from repro.launch.train import compile_train_step
from repro.models import get_model
from repro.train import TrainerConfig, checkpoint as ckpt, make_engine
from repro.train.elastic import run_resumable

# fp32 compute: the only cross-mesh difference is then collective reduction
# order (fp32 ulps), so the resumed run tracks the uninterrupted one exactly
# (bf16 forward rounding would amplify mesh changes chaotically)
cfg = dataclasses.replace(GPT2_TINY, dtype="float32")
tc = TrainerConfig(optimizer="sophia_g", peak_lr=8e-4, total_steps=24,
                   warmup_steps=2, hess_interval=5, hess_subbatch=4)
src = make_source(DataConfig(seq_len=32, global_batch=8,
                             vocab_size=cfg.vocab_size, seed=0))
ckpt_dir = tempfile.mkdtemp(prefix="elastic_demo_")
TOTAL = 24
sample = {k: jax.numpy.asarray(v) for k, v in src.batch_at(0).items()}
params_shape = jax.eval_shape(lambda k: get_model(cfg).init_params(cfg, k),
                              jax.random.PRNGKey(0))
layout_meta = dict(make_engine(tc).describe(params_shape))

ctx = {"devices": list(jax.devices()), "crashes": 2}


def setup():
    # data-parallel-only meshes: per-device model math is identical on any
    # device count, so the resumed trajectory tracks the uninterrupted one
    # to reduction-order noise (a TP axis would change matmul tilings)
    n = len(ctx["devices"])
    mesh = make_mesh((n, 1), ("data", "model"), devices=ctx["devices"]) \
        if n > 1 else None
    sjit, init_fn, ssh, bsh = compile_train_step(cfg, tc, mesh, sample)
    ctx.update(sjit=sjit, init_fn=init_fn, ssh=ssh, bsh=bsh)


def make_state():
    setup()
    state = ctx["init_fn"](jax.random.PRNGKey(0))
    return jax.device_put(state, ctx["ssh"]) if ctx["ssh"] is not None \
        else state


def restore_latest():
    if ckpt.latest_step(ckpt_dir) is None:
        return None
    setup()
    like = jax.eval_shape(ctx["init_fn"], jax.random.PRNGKey(0))
    state, step = ckpt.restore_resharded(ckpt_dir, like, shardings=ctx["ssh"],
                                         expect_layout=layout_meta)
    print(f"  [resume] from step {step} onto {len(ctx['devices'])} device(s)")
    return state, step


def run(state, start):
    for t in range(start, TOTAL):
        batch = {k: jax.numpy.asarray(v) for k, v in src.batch_at(t).items()}
        if ctx["bsh"] is not None:
            batch = jax.device_put(batch, ctx["bsh"])
        flag = jax.numpy.asarray(t % tc.hess_interval == 0)
        state, _ = ctx["sjit"](state, batch, flag)
        if (t + 1) % 6 == 0 and t + 1 < TOTAL:
            ckpt.save(ckpt_dir, t + 1, state, extra=layout_meta)
            if ctx["crashes"] > 0 and len(ctx["devices"]) > 1:
                ctx["crashes"] -= 1
                ctx["devices"] = ctx["devices"][
                    :max(1, len(ctx["devices"]) // 2)]
                print(f"  [boom] lost half the machine after step {t + 1}; "
                      f"{len(ctx['devices'])} device(s) survive")
                raise RuntimeError("node failure")
    return state


state = run_resumable(make_state, run, restore_latest, max_restarts=5)

# verify against an uninterrupted run on the full 8-device mesh
ctx.update(devices=list(jax.devices()), crashes=0)
clean = run(make_state(), 0)
a = jax.flatten_util.ravel_pytree(jax.device_get(state.params))[0]
b = jax.flatten_util.ravel_pytree(jax.device_get(clean.params))[0]
err = float(abs(np.asarray(a) - np.asarray(b)).max())
print(f"max |resumed(8->4->2) - uninterrupted(8)| = {err:.2e}  "
      f"(exact resume: {err < 1e-4})")
shutil.rmtree(ckpt_dir, ignore_errors=True)
