"""Fault tolerance demo: train, 'crash', auto-resume from the latest
checkpoint, finish — final params are bit-identical to an uninterrupted
run (stateless data pipeline + full optimizer-state checkpointing).

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.gpt2 import GPT2_TINY
from repro.data import DataConfig, make_source
from repro.train import TrainerConfig, checkpoint as ckpt, train_loop
from repro.train.elastic import run_resumable

cfg = GPT2_TINY
tc = TrainerConfig(optimizer="sophia_g", peak_lr=8e-4, total_steps=24,
                   warmup_steps=2, hess_interval=5, hess_subbatch=4)
src = make_source(DataConfig(seq_len=32, global_batch=4,
                             vocab_size=cfg.vocab_size, seed=0))
ckpt_dir = tempfile.mkdtemp(prefix="elastic_demo_")
TOTAL = 24
crashes = {"remaining": 2}


def make_state():
    from repro.train import make_train_fns
    init_fn, *_ = make_train_fns(cfg, tc)
    return init_fn(jax.random.PRNGKey(0))


def restore_latest():
    step = ckpt.latest_step(ckpt_dir)
    if step is None:
        return None
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        make_state())
    state, step = ckpt.restore(ckpt_dir, like)
    print(f"  [resume] from step {step}")
    return state, step


def run(state, start):
    for t in range(start, TOTAL, 6):
        state, hist = train_loop(cfg, tc, src, num_steps=min(6, TOTAL - t),
                                 state=state, start_step=t)
        ckpt.save(ckpt_dir, t + 6, state)
        if crashes["remaining"] > 0 and t + 6 < TOTAL:
            crashes["remaining"] -= 1
            print(f"  [boom] simulated node failure after step {t + 6}")
            raise RuntimeError("node failure")
    return state


state = run_resumable(make_state, run, restore_latest, max_restarts=5)

# verify against an uninterrupted run
clean, _ = train_loop(cfg, tc, src, num_steps=TOTAL)
a = jax.flatten_util.ravel_pytree(state.params)[0]
b = jax.flatten_util.ravel_pytree(clean.params)[0]
err = float(abs(np.asarray(a) - np.asarray(b)).max())
print(f"max |resumed - uninterrupted| = {err:.2e}  (exact resume: {err < 1e-5})")
shutil.rmtree(ckpt_dir, ignore_errors=True)
