"""Batched serving example: prefill + KV-cache decode across families.

    PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config
from repro.models import get_model
from repro.serve import generate

for arch in ("yi-6b", "rwkv6-7b", "recurrentgemma-2b"):
    cfg = get_config(arch, smoke=True)  # reduced configs for CPU
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                cfg.vocab_size)
    t0 = time.time()
    out = generate(cfg, params, prompt, max_new=24, temperature=0.8)
    print(f"{arch:20s} ({cfg.family:8s}) 4x24 tokens in "
          f"{time.time() - t0:5.1f}s   first row: {out[0, :8].tolist()}")
