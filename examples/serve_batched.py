"""Continuous-batching serving example: mixed-length requests stream
through slot-based engines across three model families.

    PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.serve import Request, ServeEngine

# (prompt_len, max_new): deliberately ragged — the engine admits each
# request into a free slot, chunk-prefills it alongside in-flight decodes,
# and retires it the moment its budget is spent.
REQUESTS = [(5, 18), (17, 6), (9, 12), (24, 4), (3, 20), (12, 9)]

for arch in ("yi-6b", "rwkv6-7b", "recurrentgemma-2b"):
    cfg = get_config(arch, smoke=True)  # reduced configs for CPU
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, n_slots=2, cache_len=48, page_len=8,
                      steps_per_tick=4, seed=0)
    for i, (sp, mn) in enumerate(REQUESTS):
        toks = jax.random.randint(jax.random.PRNGKey(1 + i), (sp,), 0,
                                  cfg.vocab_size)
        eng.submit(Request(uid=i, tokens=np.asarray(toks), max_new=mn,
                           temperature=0.8))
    t0 = time.time()
    results = {r.uid: r for r in eng.run()}
    stats = eng.stats()
    total = sum(len(r.tokens) for r in results.values())
    print(f"{arch:20s} ({cfg.family:8s}) {len(REQUESTS)} reqs / {total} "
          f"tokens in {time.time() - t0:5.1f}s  "
          f"util={stats['slot_utilization']:.2f}  "
          f"first req: {results[0].tokens[:8]}")
