"""End-to-end driver: pre-train a GPT-2 with Sophia vs AdamW — the paper's
headline experiment at CPU-tractable scale.

Default: a ~10M-param GPT-2 (the paper's 30M-class protocol scaled down for
a CPU container) for a few hundred steps, comparing AdamW @ T against
Sophia-G @ T/2 — the paper's eq. (14) criterion.

    PYTHONPATH=src python examples/train_gpt2.py            # reduced
    PYTHONPATH=src python examples/train_gpt2.py --full     # gpt2-small 125M
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.gpt2 import GPT2_SMALL, _gpt2
from repro.data import DataConfig, make_source
from repro.models import get_model
from repro.train import TrainerConfig, train_loop

import jax.numpy as jnp
import numpy as np


def val_loss(cfg, state, seed=999):
    model = get_model(cfg)
    src = make_source(DataConfig(seq_len=128, global_batch=8,
                                 vocab_size=cfg.vocab_size, seed=seed))
    ls = []
    for b in range(4):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(b).items()}
        ls.append(float(model.loss_fn(cfg, state.params, batch)[0]))
    return float(np.mean(ls))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="gpt2-small (125M) — hours on CPU")
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    cfg = GPT2_SMALL if args.full else _gpt2("gpt2-10m", 256, 6, 8, ctx=128,
                                             vocab=2048)
    T = args.steps
    src = make_source(DataConfig(seq_len=128, global_batch=8,
                                 vocab_size=cfg.vocab_size, seed=0))

    print(f"== AdamW, budget T={T} (schedule pinned to T) ==")
    tc = TrainerConfig(optimizer="adamw", peak_lr=1e-3, total_steps=T,
                       warmup_steps=T // 20, weight_decay=0.1)
    st_adam, hist = train_loop(cfg, tc, src, num_steps=T)
    adam = val_loss(cfg, st_adam)
    print(f"AdamW val loss @ {T}: {adam:.4f}")

    print(f"== Sophia-G, budget T/2={T // 2} ==")
    tc = TrainerConfig(optimizer="sophia_g", peak_lr=8e-4,
                       total_steps=T // 2, warmup_steps=T // 40,
                       weight_decay=0.2, hess_interval=10, hess_subbatch=4)
    st_soph, hist = train_loop(cfg, tc, src, num_steps=T // 2)
    soph = val_loss(cfg, st_soph)
    print(f"Sophia-G val loss @ {T // 2}: {soph:.4f}")

    print(f"eq.(14) 2x-speedup criterion met: {soph <= adam} "
          f"(Sophia@T/2 {soph:.4f} vs AdamW@T {adam:.4f})")


if __name__ == "__main__":
    main()
