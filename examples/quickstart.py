"""Quickstart: train a small LM with Sophia-G in ~40 lines of public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.gpt2 import GPT2_TINY
from repro.data import DataConfig, make_source
from repro.train import TrainerConfig, train_loop

# 1. pick a model config (any of the 10 assigned archs work: repro.configs)
cfg = GPT2_TINY

# 2. configure the optimizer — Sophia-G (Algorithm 3, GNB estimator).
#    The paper's recipe: gamma tuned for 10-50% unclipped coordinates,
#    lr ~ 0.8x your AdamW lr, Hessian refresh every k=10 steps on a
#    reduced sub-batch.
tc = TrainerConfig(
    optimizer="sophia_g",
    peak_lr=8e-4,
    total_steps=150,
    warmup_steps=10,
    weight_decay=0.2,
    gamma=0.05,
    hess_interval=10,
    hess_subbatch=4,
)

# 3. point it at data (synthetic stream here; memmap token files for real)
src = make_source(DataConfig(seq_len=64, global_batch=8,
                             vocab_size=cfg.vocab_size, seed=0))

# 4. train
state, history = train_loop(cfg, tc, src, num_steps=150)

print(f"loss: {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")
print(f"sophia clip fraction (tune gamma so this is 0.5-0.9): "
      f"{history[-1]['sophia_clip_fraction']:.2f}")
assert history[-1]["loss"] < history[0]["loss"]
