"""Unit tests: Sophia (Algorithm 3) semantics, exactly as pseudo-coded."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apply_updates, sophia, sophia_g, sophia_h
from repro.core.sophia import scale_by_sophia


def _manual_sophia_run(grads_seq, hhat_seq, lr, beta1, beta2, gamma, eps, wd,
                       k, theta0):
    """Direct transcription of Algorithm 3 (numpy)."""
    theta = np.array(theta0, dtype=np.float64)
    m = np.zeros_like(theta)
    h = np.zeros_like(theta)
    out = []
    for t, g in enumerate(grads_seq):
        m = beta1 * m + (1 - beta1) * np.asarray(g)
        if t % k == 0:
            h = beta2 * h + (1 - beta2) * np.asarray(hhat_seq[t])
        theta = theta - lr * wd * theta                     # line 12
        u = np.clip(m / np.maximum(gamma * h, eps), -1, 1)  # line 13
        theta = theta - lr * u
        out.append(theta.copy())
    return out


def test_matches_algorithm3_pseudocode():
    rng = np.random.default_rng(0)
    d = 16
    T, k = 20, 5
    grads = [rng.normal(size=d).astype(np.float32) for _ in range(T)]
    hhats = [np.abs(rng.normal(size=d)).astype(np.float32) for _ in range(T)]
    lr, b1, b2, gamma, eps, wd = 0.01, 0.96, 0.99, 0.05, 1e-12, 0.2

    opt = sophia(lr, beta1=b1, beta2=b2, gamma=gamma, eps=eps,
                 weight_decay=wd)
    theta = jnp.zeros((d,)) + 1.0
    state = opt.init(theta)
    ours = []
    for t in range(T):
        if t % k == 0:
            state = opt.update_hessian(jnp.asarray(hhats[t]), state)
        updates, state = opt.update(jnp.asarray(grads[t]), state, theta)
        theta = apply_updates(theta, updates)
        ours.append(np.asarray(theta))

    ref = _manual_sophia_run(grads, hhats, lr, b1, b2, gamma, eps, wd, k,
                             np.ones(d))
    for t in range(T):
        np.testing.assert_allclose(ours[t], ref[t], rtol=2e-5, atol=2e-6)


def test_negative_curvature_falls_back_to_sign():
    """h < 0 => update is exactly -lr * sign(m) (SignSGD backup)."""
    opt = sophia(0.1, beta1=0.0, weight_decay=0.0)
    theta = jnp.array([1.0, -1.0, 2.0])
    state = opt.init(theta)
    state = opt.update_hessian(jnp.array([-5.0, -1e-3, -100.0]), state)
    g = jnp.array([0.3, -0.7, 1e-4])
    updates, state = opt.update(g, state, theta)
    np.testing.assert_allclose(np.asarray(updates),
                               -0.1 * np.sign(np.asarray(g)), rtol=1e-6)


def test_clip_bounds_worst_case_update():
    opt = sophia(1.0, beta1=0.0, weight_decay=0.0)
    theta = jnp.zeros((8,))
    state = opt.init(theta)
    state = opt.update_hessian(jnp.full((8,), 1e-8), state)  # tiny curvature
    updates, _ = opt.update(jnp.ones((8,)) * 100.0, state, theta)
    assert float(jnp.max(jnp.abs(updates))) <= 1.0 + 1e-6


def test_gamma_rescaling_identity():
    """eta*clip(m/max(gamma h, eps),1) == (eta/gamma)*clip(m/max(h,eps/gamma),gamma)."""
    rng = np.random.default_rng(1)
    m = jnp.asarray(rng.normal(size=32).astype(np.float32))
    h = jnp.asarray(np.abs(rng.normal(size=32)).astype(np.float32))
    eta, gamma, eps = 0.3, 0.05, 1e-12
    lhs = eta * jnp.clip(m / jnp.maximum(gamma * h, eps), -1, 1)
    rhs = (eta / gamma) * jnp.clip(m / jnp.maximum(h, eps / gamma),
                                   -gamma, gamma)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-5)


def test_clip_fraction_telemetry():
    core = scale_by_sophia(gamma=1.0)
    theta = {"a": jnp.ones((10,)), "b": jnp.ones((10,))}
    state = core.init(theta)
    h = {"a": jnp.full((10,), 1e6), "b": jnp.full((10,), 1e-9)}
    state = state._replace(h=jax.tree.map(lambda x: x / (1 - 0.99), h))
    g = {"a": jnp.ones((10,)), "b": jnp.ones((10,))}
    _, state = core.update(g, state, theta)
    # "a" has huge curvature (never clips), "b" tiny (always clips)
    assert abs(float(state.clip_fraction) - 0.5) < 1e-6


def test_sophia_h_g_defaults():
    assert sophia_h(1e-3) is not None  # gamma=0.01 path
    assert sophia_g(1e-3) is not None  # gamma=0.05 path


def test_hessian_ema_line9():
    opt = sophia(0.1, beta2=0.9)
    theta = jnp.zeros((4,))
    state = opt.init(theta)
    state = opt.update_hessian(jnp.full((4,), 2.0), state)
    np.testing.assert_allclose(np.asarray(state.h), 0.1 * 2.0, rtol=1e-6)
    state = opt.update_hessian(jnp.full((4,), 1.0), state)
    np.testing.assert_allclose(np.asarray(state.h), 0.9 * 0.2 + 0.1, rtol=1e-6)
    assert int(state.hess_count) == 2
