"""Estimator correctness: Hutchinson unbiasedness, GNB = diag Gauss-Newton."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (empirical_fisher_estimator, exact_diag_hessian,
                        gnb_estimator, hutchinson_estimator)


def test_exact_diag_hessian_analytic():
    def f(p):
        return 2.0 * p["x"][0] ** 2 + 0.5 * p["x"][1] ** 2 \
            + p["x"][0] * p["x"][1] + jnp.sum(p["y"] ** 4)

    p = {"x": jnp.array([1.0, 2.0]), "y": jnp.array([1.0, -1.0])}
    d = exact_diag_hessian(f, p)
    np.testing.assert_allclose(np.asarray(d["x"]), [4.0, 1.0], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(d["y"]), [12.0, 12.0], rtol=1e-5)


def test_hutchinson_unbiased():
    """E[u * Hu] = diag(H) on a non-diagonal quadratic."""
    A = jnp.array([[3.0, 1.0, 0.0], [1.0, 2.0, 0.5], [0.0, 0.5, 0.25]])

    def f(p):
        return 0.5 * p @ A @ p

    p = jnp.array([1.0, -2.0, 0.5])
    keys = jax.random.split(jax.random.PRNGKey(0), 4000)
    ests = jax.vmap(lambda k: hutchinson_estimator(f, p, k))(keys)
    mean = np.asarray(ests.mean(0))
    np.testing.assert_allclose(mean, np.diag(np.asarray(A)),
                               rtol=0.15, atol=0.05)


def _softmax_model():
    """Linear softmax classifier: f(W, x) = W x, CE loss."""
    V, D, B = 5, 3, 8
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32)) * 0.5
    X = jnp.asarray(rng.normal(size=(B, D)).astype(np.float32))
    return W, X, V, D, B


def _exact_gn_diag(W, X):
    """diag of J^T S J for the linear softmax model, summed over batch/B.

    For f = W x: d f_v / d W_{v d} = x_d, so
    GN[v,d] = mean_b S_b[v,v] * x_{b,d}^2 with S = diag(p) - p p^T.
    """
    logits = X @ W.T
    p = jax.nn.softmax(logits, axis=-1)          # (B, V)
    s_diag = p * (1 - p)                         # (B, V)
    return jnp.einsum("bv,bd->vd", s_diag, X ** 2) / X.shape[0]


def test_gnb_matches_exact_gauss_newton_diag():
    W, X, V, D, B = _softmax_model()

    def logits_fn(W_):
        return X @ W_.T                          # (B, V)

    keys = jax.random.split(jax.random.PRNGKey(1), 3000)
    est = jax.vmap(lambda k: gnb_estimator(logits_fn, W, k))(keys)
    mean = np.asarray(est.mean(0))
    exact = np.asarray(_exact_gn_diag(W, X))
    np.testing.assert_allclose(mean, exact, rtol=0.2, atol=0.01)


def test_gnb_is_psd():
    W, X, *_ = _softmax_model()

    def logits_fn(W_):
        return X @ W_.T

    est = gnb_estimator(logits_fn, W, jax.random.PRNGKey(2))
    assert float(jnp.min(est)) >= 0.0  # B * g*g is non-negative by construction


def test_empirical_fisher_uses_true_labels():
    """E-F (Fig 8b ablation) differs from GNB: no label resampling."""
    W, X, V, D, B = _softmax_model()
    y = jnp.zeros((B,), jnp.int32)

    def loss_fn(W_):
        logits = X @ W_.T
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

    ef = empirical_fisher_estimator(loss_fn, W, B)
    assert ef.shape == W.shape
    assert float(jnp.min(ef)) >= 0.0


def test_gnb_mask_excludes_padding():
    W, X, V, D, B = _softmax_model()

    def logits_fn(W_):
        return X @ W_.T

    mask = jnp.array([1.0] * 4 + [0.0] * 4)
    est = gnb_estimator(logits_fn, W, jax.random.PRNGKey(3), mask=mask)
    assert est.shape == W.shape
    assert bool(jnp.all(jnp.isfinite(est)))
