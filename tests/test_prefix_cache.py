"""Shared-prefix KV page reuse: host index semantics, engine integration
(token identity warm vs cold, across families and KV dtypes), eviction
under pool pressure, and the one-program compilation invariant.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.serve import PrefixCache, Request, ServeEngine
from repro.serve.prefix_cache import ROOT, chunk_key

pytestmark = pytest.mark.serve

PAGE = 8


def _chunks(seed, n, lo=0, hi=512):
    rng = np.random.default_rng(seed)
    return [rng.integers(lo, hi, PAGE).astype(np.int32) for _ in range(n)]


# ---------------------------------------------------------------- host index
def test_chain_hash_commits_to_full_prefix():
    """Identical chunks under different parents are different pages."""
    c = _chunks(0, 1)[0]
    assert chunk_key(ROOT, c) != chunk_key(chunk_key(ROOT, c), c)


def test_insert_lookup_roundtrip_and_refcounts():
    pc = PrefixCache(pool_pages=8, page_len=PAGE)
    chunks = _chunks(1, 3)
    tokens = np.concatenate(chunks)
    key = ROOT
    for c in chunks:
        node, fresh = pc.insert(key, c)
        assert fresh
        key = node.key
    chain = pc.lookup(tokens, max_pages=3)
    assert [n.pool_idx for n in chain] == [0, 1, 2]
    # a diverging third chunk only matches the first two pages
    other = np.concatenate(chunks[:2] + _chunks(2, 1))
    assert len(pc.lookup(other, max_pages=3)) == 2
    assert pc.hits == 2 and pc.lookups == 2 and pc.pages_reused == 5
    # re-inserting an existing chain entry is not fresh and re-acquires
    node, fresh = pc.insert(ROOT, chunks[0])
    assert not fresh and node.refcount == 2
    pc.release([node])
    assert node.refcount == 1


def test_eviction_is_lru_and_leaf_only():
    pc = PrefixCache(pool_pages=2, page_len=PAGE)
    a, _ = pc.insert(ROOT, _chunks(3, 1)[0])
    b, _ = pc.insert(a.key, _chunks(4, 1)[0])
    pc.release([a, b])
    # pool full; a is older but interior (has a child) -> b must go
    c, fresh = pc.insert(ROOT, _chunks(5, 1)[0])
    assert fresh and c.pool_idx == b.pool_idx
    assert pc.evictions == 1 and a.key in pc.nodes and b.key not in pc.nodes


def test_insert_fails_when_everything_is_held():
    pc = PrefixCache(pool_pages=1, page_len=PAGE)
    a, _ = pc.insert(ROOT, _chunks(6, 1)[0])   # held: refcount 1
    node, fresh = pc.insert(ROOT, _chunks(7, 1)[0])
    assert node is None and not fresh
    pc.release([a])
    node, fresh = pc.insert(ROOT, _chunks(7, 1)[0])
    assert fresh  # evictable now


def test_double_release_raises():
    pc = PrefixCache(pool_pages=1, page_len=PAGE)
    a, _ = pc.insert(ROOT, _chunks(8, 1)[0])
    pc.release([a])
    with pytest.raises(RuntimeError):
        pc.release([a])


# ------------------------------------------------------------ engine paths
def _engine_case(arch, kv_dtype, tag, **eng_kw):
    """Uniquely-named config so each case gets fresh compiled-fn caches
    (the _cache_size() == 1 asserts must not see other tests' entries)."""
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, name=f"{cfg.name}-pfx-{tag}")
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))

    shared = np.asarray(jax.random.randint(
        jax.random.PRNGKey(42), (4 * PAGE + 3,), 0, cfg.vocab_size))

    def requests():
        out = []
        for i in range(5):
            tail = np.asarray(jax.random.randint(
                jax.random.PRNGKey(100 + i), (3 + 2 * i,), 0,
                cfg.vocab_size))
            out.append(Request(uid=i, tokens=np.concatenate([shared, tail]),
                               max_new=5))
        return out

    kw = dict(n_slots=2, cache_len=64, page_len=PAGE, steps_per_tick=4,
              kv_dtype=kv_dtype)
    kw.update(eng_kw)
    cold = ServeEngine(cfg, params, **kw)
    for r in requests():
        cold.submit(r)
    cold_out = {r.uid: r.tokens for r in cold.run()}
    warm = ServeEngine(cfg, params, prefix_cache=True, **kw)
    for r in requests():
        warm.submit(r)
    warm_out = {r.uid: r.tokens for r in warm.run()}
    return cold, warm, cold_out, warm_out


@pytest.mark.parametrize("arch,kv_dtype", [
    ("yi-6b", "bf16"), ("yi-6b", "int8"),
    ("deepseek-moe-16b", "bf16"), ("deepseek-moe-16b", "int8"),
])
def test_warm_tokens_identical_to_cold(arch, kv_dtype):
    """Greedy decode over restored pages is token-identical to a cold
    prefill — pages are bit-copies, chunk boundaries are unchanged, and
    int8 writes are deterministic — for a dense and a MoE family in both
    KV dtypes.  Exactly one prefill and one decode program either way."""
    cold, warm, cold_out, warm_out = _engine_case(
        arch, kv_dtype, f"{arch[:4]}-{kv_dtype}")
    assert cold_out == warm_out
    s = warm.stats()
    assert s["prefix_hit_rate"] > 0 and s["prefix_pages_reused"] >= 4
    for eng in (cold, warm):
        assert eng._prefill_jit._cache_size() == 1
        assert eng._burst_jit._cache_size() == 1


def test_identity_survives_eviction_pressure():
    """Two alternating 3-page prefix chains contend for a 4-page pool:
    every switch evicts the other chain leaf-first, but the surviving
    root page still re-hits.  Reuse degrades under pressure but never
    corrupts — outputs stay identical to cold."""
    cfg = get_config("yi-6b", smoke=True)
    cfg = dataclasses.replace(cfg, name=cfg.name + "-pfx-evict")
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prefixes = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(40 + p), (3 * PAGE,), 0, cfg.vocab_size))
        for p in range(2)]

    def requests():
        return [Request(uid=i, tokens=np.concatenate(
            [prefixes[i % 2], np.asarray(jax.random.randint(
                jax.random.PRNGKey(100 + i), (4,), 0, cfg.vocab_size))]),
            max_new=4) for i in range(6)]

    kw = dict(n_slots=2, cache_len=48, page_len=PAGE, steps_per_tick=4)
    outs = {}
    for mode in ("cold", "warm"):
        eng = ServeEngine(cfg, params, prefix_cache=(mode == "warm"),
                          prefix_pool_pages=4, **kw)
        res = []
        for r in requests():           # sequential: full drain per request
            eng.submit(r)
            res += eng.run()
            eng.results.clear()
        outs[mode] = {r.uid: r.tokens for r in res}
        if mode == "warm":
            s = eng.stats()
            assert s["prefix_evictions"] > 0
            assert s["prefix_pool_used"] <= 4
            assert s["prefix_pages_reused"] > 0
    assert outs["cold"] == outs["warm"]


def test_prefix_cache_rejects_unpaged_families():
    cfg = get_config("recurrentgemma-2b", smoke=True)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="paged KV"):
        ServeEngine(cfg, params, prefix_cache=True)


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "rwkv6-7b"])
def test_int8_kv_rejected_for_stateful_families(arch):
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              kv_dtype="int8")
    model = get_model(cfg)
    with pytest.raises(ValueError):
        model.init_slots(cfg, 2, 32)


def test_kv_byte_model_matches_live_state():
    """launch/roofline's capacity model equals jax.Array.nbytes of the
    engine state for both dtypes, and int8 fits >= 1.7x slots in the
    bf16 budget once E = n_kv_heads * head_dim is large enough."""
    from repro.launch.roofline import (kv_cache_slot_bytes,
                                      kv_slots_at_budget)

    cfg = get_config("yi-6b", smoke=True)
    cfg = dataclasses.replace(cfg, name=cfg.name + "-pfx-bytes",
                              head_dim=32)
    model = get_model(cfg)
    C = 64
    for kvd in ("bf16", "int8"):
        c = dataclasses.replace(cfg, kv_dtype=kvd)
        state = get_model(c).init_slots(c, 3, C)
        measured = sum(l.nbytes for l in jax.tree.leaves(state))
        assert measured == 3 * kv_cache_slot_bytes(c, C)
    budget = 4 * kv_cache_slot_bytes(cfg, C, kv_dtype="bf16")
    assert kv_slots_at_budget(cfg, C, budget, kv_dtype="int8") >= 7
    del model
