"""End-to-end system tests: train -> checkpoint -> crash -> resume -> serve,
plus the sharded-lowering path in a subprocess with host devices."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # excluded from the fast tier-1 default

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_train_checkpoint_resume_serve(tmp_path):
    """The full lifecycle on a tiny model."""
    from repro.configs.gpt2 import GPT2_TINY
    from repro.data import DataConfig, make_source
    from repro.serve import generate
    from repro.train import TrainerConfig, checkpoint as ckpt, train_loop

    cfg = GPT2_TINY
    tc = TrainerConfig(optimizer="sophia_g", peak_lr=1e-3, total_steps=30,
                       warmup_steps=3, hess_interval=5, hess_subbatch=4)
    src = make_source(DataConfig(seq_len=32, global_batch=4,
                                 vocab_size=cfg.vocab_size, seed=0))
    state, hist = train_loop(cfg, tc, src, num_steps=10)
    ckpt.save(str(tmp_path), 10, state)

    # "crash": restore and continue
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    state2, step = ckpt.restore(str(tmp_path), like)
    assert step == 10
    state2, hist2 = train_loop(cfg, tc, src, num_steps=5, state=state2,
                               start_step=step)
    assert np.isfinite(hist2[-1]["loss"])

    # serve from the trained weights
    prompt = jnp.zeros((2, 4), jnp.int32)
    out = generate(cfg, state2.params, prompt, max_new=4)
    assert out.shape == (2, 4)


def test_run_resumable_retries():
    from repro.train.elastic import run_resumable

    calls = {"n": 0}

    def make_state():
        return {"x": 0}

    def restore_latest():
        return ({"x": 5}, 5) if calls["n"] > 0 else None

    def run(state, start):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("node failure")
        return (state, start)

    state, start = run_resumable(make_state, run, restore_latest,
                                 max_restarts=5)
    assert calls["n"] == 3
    assert start == 5  # resumed from checkpoint after first failure


def test_straggler_detector():
    from repro.train.elastic import StragglerDetector
    det = StragglerDetector(alpha=0.2, z_thresh=3.0, warmup=3)
    for _ in range(20):
        det.observe(1.0 + np.random.default_rng(0).normal() * 1e-3)
    assert det.observe(10.0) is True
    assert det.flagged >= 1


def test_preemption_guard():
    from repro.train.elastic import PreemptionGuard
    g = PreemptionGuard(install=False)
    assert not g.requested
    g.request()
    assert g.requested


def test_sharded_train_step_with_collectives(tmp_path):
    """Lower + compile + RUN a sharded Sophia train step on 8 host devices;
    assert collectives appear and loss is finite (mini dry-run integration).
    """
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, {json.dumps(os.path.abspath(SRC))})
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.gpt2 import GPT2_TINY as cfg
        from repro.data import DataConfig, make_source
        from repro.distributed.sharding import (batch_specs, partition_params,
                                                set_activation_mesh)
        from repro.launch.mesh import make_mesh
        from repro.launch.dryrun import state_partition_specs
        from repro.train import TrainerConfig, make_train_fns

        mesh = make_mesh((4, 2), ("data", "model"))
        set_activation_mesh(mesh)
        tc = TrainerConfig(optimizer="sophia_g", peak_lr=1e-3,
                           total_steps=100, warmup_steps=2, hess_subbatch=4)
        init_fn, train_step = make_train_fns(cfg, tc)
        state = init_fn(jax.random.PRNGKey(0))
        pspecs = partition_params(state.params, mesh, fsdp=True)
        sspecs = state_partition_specs(state, pspecs)
        ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        state = jax.device_put(state, ns(sspecs))
        src = make_source(DataConfig(seq_len=32, global_batch=8,
                                     vocab_size=cfg.vocab_size))
        batch = {{k: jnp.asarray(v) for k, v in src.batch_at(0).items()}}
        bspecs = batch_specs(batch, mesh)
        batch = jax.device_put(batch, ns(bspecs))
        step = jax.jit(train_step,
                       in_shardings=(ns(sspecs), ns(bspecs), None),
                       out_shardings=(ns(sspecs), None))
        flag = jnp.asarray(True)  # refresh branch exercised under sharding
        lowered = step.lower(state, batch, flag)
        compiled = lowered.compile()
        txt = compiled.as_text()
        assert ("all-reduce" in txt or "all-gather" in txt), "no collectives!"
        state, metrics = compiled(state, batch, flag)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), loss
        print("SHARDED_OK", loss)
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=600)
    assert "SHARDED_OK" in r.stdout, (r.stdout[-1000:], r.stderr[-3000:])
