"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.sophia_update import (adamw_fused_block, hessian_ema_block,
                                         sophia_fused_block)

HYPER = dict(lr=3e-4, beta1=0.96, gamma=0.05, eps=1e-12, weight_decay=0.2)


def _rand(key, shape, scale=1.0, positive=False):
    x = jax.random.normal(key, shape, jnp.float32) * scale
    return jnp.abs(x) if positive else x


@pytest.mark.parametrize("n,block", [
    (256, 256), (512, 256), (1000, 256), (4096, 1024),
    (128 * 1024, 128 * 1024), (3 * 128 * 1024, 128 * 1024),
])
def test_sophia_fused_shapes(n, block):
    ks = jax.random.split(jax.random.PRNGKey(n), 4)
    p, g = _rand(ks[0], (n,)), _rand(ks[1], (n,), 0.1)
    m, h = _rand(ks[2], (n,), 0.1), _rand(ks[3], (n,), 0.01, positive=True)
    rp, rm, rc = ref.sophia_fused_ref(p, m, h, g, **HYPER)
    tp, tm, cf = ops.sophia_fused_apply({"w": p}, {"w": m}, {"w": h},
                                        {"w": g}, block=block, **HYPER)
    np.testing.assert_allclose(np.asarray(tp["w"]), np.asarray(rp),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(tm["w"]), np.asarray(rm),
                               rtol=1e-5, atol=1e-7)
    assert abs(float(cf) - float(rc) / n) < 1e-6


@pytest.mark.parametrize("shape", [(64,), (8, 128), (4, 16, 32), (3, 5, 7)])
def test_sophia_fused_nd_shapes(shape):
    ks = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 4)
    p, g = _rand(ks[0], shape), _rand(ks[1], shape, 0.1)
    m, h = _rand(ks[2], shape, 0.1), _rand(ks[3], shape, 0.01, positive=True)
    rp, rm, _ = ref.sophia_fused_ref(p, m, h, g, **HYPER)
    tp, tm, _ = ops.sophia_fused_apply({"w": p}, {"w": m}, {"w": h},
                                       {"w": g}, block=128, **HYPER)
    np.testing.assert_allclose(np.asarray(tp["w"]), np.asarray(rp),
                               rtol=1e-5, atol=1e-6)


def test_sophia_fused_negative_curvature():
    """Negative h -> sign fallback must survive the kernel unchanged."""
    n = 256
    p = jnp.ones((n,))
    m = jnp.linspace(-1, 1, n)
    h = -jnp.ones((n,))
    g = jnp.zeros((n,))
    rp, rm, _ = ref.sophia_fused_ref(p, m, h, g, **HYPER)
    tp, tm, _ = ops.sophia_fused_apply({"w": p}, {"w": m}, {"w": h},
                                       {"w": g}, block=256, **HYPER)
    np.testing.assert_allclose(np.asarray(tp["w"]), np.asarray(rp), rtol=1e-6)


@pytest.mark.parametrize("n,block", [(1000, 256), (4096, 512)])
def test_hessian_ema_kernel(n, block):
    ks = jax.random.split(jax.random.PRNGKey(n + 1), 2)
    h = _rand(ks[0], (n,), positive=True)
    e = _rand(ks[1], (n,), positive=True)
    r = ref.hessian_ema_ref(h, 240.0 * e, beta2=0.99)
    t = ops.hessian_ema_apply({"w": h}, {"w": e}, beta2=0.99, scale=240.0,
                              block=block)
    np.testing.assert_allclose(np.asarray(t["w"]), np.asarray(r), rtol=1e-5)


@pytest.mark.parametrize("n,block,step", [(777, 128, 1.0), (4096, 1024, 100.0)])
def test_adamw_fused_kernel(n, block, step):
    ks = jax.random.split(jax.random.PRNGKey(n + 2), 4)
    p, m, g = (_rand(k, (n,)) for k in ks[:3])
    v = _rand(ks[3], (n,), positive=True)
    kw = dict(lr=1e-3, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.1)
    r = ref.adamw_fused_ref(p, m, v, g, step=step, **kw)
    t = ops.adamw_fused_apply({"w": p}, {"w": m}, {"w": v}, {"w": g},
                              step=step, block=block, **kw)
    for i in range(3):
        np.testing.assert_allclose(np.asarray(t[i]["w"]), np.asarray(r[i]),
                                   rtol=2e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=2048),
    seed=st.integers(min_value=0, max_value=2**30),
    gamma=st.floats(min_value=1e-3, max_value=1.0),
    lr=st.floats(min_value=1e-5, max_value=1.0),
)
def test_sophia_fused_property(n, seed, gamma, lr):
    """Property: kernel == oracle for arbitrary sizes/hypers; update bounded."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    p, g = _rand(ks[0], (n,)), _rand(ks[1], (n,))
    m = _rand(ks[2], (n,))
    h = _rand(ks[3], (n,))  # mixed-sign curvature
    hyper = dict(lr=lr, beta1=0.96, gamma=gamma, eps=1e-12, weight_decay=0.0)
    rp, rm, _ = ref.sophia_fused_ref(p, m, h, g, **hyper)
    tp, tm, _ = ops.sophia_fused_apply({"w": p}, {"w": m}, {"w": h},
                                       {"w": g}, block=256, **hyper)
    np.testing.assert_allclose(np.asarray(tp["w"]), np.asarray(rp),
                               rtol=1e-4, atol=1e-6)
    # invariant: |delta theta| <= lr (wd=0), up to one fp32 ulp of theta
    # (fl(p - lr*u) - p rounds by <= ulp(p)/2)
    delta = np.asarray(tp["w"]) - np.asarray(p)
    ulp = np.float32(1.2e-7) * max(1.0, float(np.max(np.abs(p))))
    assert np.max(np.abs(delta)) <= lr * (1 + 1e-5) + ulp
