"""Flat-buffer optimizer engine: layout round-trip, fused-vs-reference
parity across Hessian refreshes, bf16 state, telemetry agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (OptimizerEngine, build_layout, ravel_shards,
                               unravel_shards)

SOPHIA_HYPERS = dict(beta1=0.96, beta2=0.99, gamma=0.05, eps=1e-12,
                     weight_decay=0.2, clip_threshold=1.0)


def _params(key, *, dtype=jnp.float32):
    """Deliberately awkward leaf sizes: nothing is a block multiple."""
    ks = jax.random.split(key, 4)
    return {
        "emb": jax.random.normal(ks[0], (13, 7), dtype),
        "blocks": [
            {"w": jax.random.normal(ks[1], (5, 11), dtype),
             "b": jnp.zeros((11,), dtype)},
            {"w": jax.random.normal(ks[2], (11, 3), dtype),
             "b": jnp.zeros((3,), dtype)},
        ],
        "scale": jax.random.normal(ks[3], (), dtype),  # scalar leaf
    }


def _grads_like(params, key, scale=0.1):
    leaves, treedef = jax.tree.flatten(params)
    ks = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [
        jax.random.normal(k, l.shape, jnp.float32) * scale
        for k, l in zip(ks, leaves)])


def _engines(optimizer, hypers, **kw):
    ref = OptimizerEngine(optimizer, hypers=hypers, backend="reference", **kw)
    fused = OptimizerEngine(optimizer, hypers=hypers, backend="pallas", **kw)
    return ref, fused


# ---------------------------------------------------------------------------
# layout


def test_layout_roundtrip_mixed_dtypes():
    p = _params(jax.random.PRNGKey(0))
    p["half"] = jnp.arange(37, dtype=jnp.bfloat16)  # second dtype shard
    lay = build_layout(p, block=64)
    assert lay.n_shards == 2
    assert all(s % 64 == 0 for s in lay.shard_sizes)
    assert lay.n_params == sum(x.size for x in jax.tree.leaves(p))
    shards = ravel_shards(lay, p)
    assert [s.dtype for s in shards] == list(lay.shard_dtypes)
    back = unravel_shards(lay, shards)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_layout_pad_is_tail_only():
    p = _params(jax.random.PRNGKey(1))
    lay = build_layout(p, block=128)
    (shard,) = ravel_shards(lay, p)
    used = lay.shard_used[0]
    assert shard.shape[0] == lay.shard_sizes[0]
    np.testing.assert_array_equal(np.asarray(shard[used:]), 0.0)


# ---------------------------------------------------------------------------
# multi-step parity: grad steps + Hessian-EMA refreshes interleaved


def _run_sophia(engine, *, steps=16, k=5, state_dtype=None, seed=0):
    """Sophia schedule over >= 3 Hessian intervals (refresh at 0, 5, 10, 15).

    Estimates come from a synthetic ghat^2-style positive tree with a folded
    batch scale, exactly like the trainer's GNB path."""
    key = jax.random.PRNGKey(seed)
    params = _params(key)
    state = engine.init(params)
    clip_fracs = []
    for t in range(steps):
        kt = jax.random.fold_in(key, t)
        if t % k == 0:
            est = jax.tree.map(jnp.square,
                               _grads_like(params, jax.random.fold_in(kt, 1)))
            state = engine.update_hessian(state, est, scale=240.0,
                                          params=params)
        grads = _grads_like(params, kt)
        lr = 3e-4 * (1.0 + 0.1 * t)
        params, state = engine.step(state, params, grads, lr)
        clip_fracs.append(float(state.clip_fraction))
    return params, state, clip_fracs


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16"])
def test_sophia_fused_matches_reference_across_refreshes(state_dtype):
    sdt = jnp.bfloat16 if state_dtype == "bfloat16" else jnp.float32
    ref, fused = _engines("sophia_g", SOPHIA_HYPERS, block=128,
                          state_dtype=sdt)
    p1, s1, cf1 = _run_sophia(ref)
    p2, s2, cf2 = _run_sophia(fused)
    assert int(s1.hess_count) == int(s2.hess_count) == 4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    for a, b in zip(s1.m, s2.m):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
    for a, b in zip(s1.h, s2.h):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
    # in-kernel clip telemetry must agree step by step
    np.testing.assert_allclose(cf1, cf2, rtol=1e-6, atol=1e-7)


def test_clip_fraction_counts_only_real_params():
    """Telemetry denominator is true param count; padding never clips."""
    ref, fused = _engines("sophia_g", dict(SOPHIA_HYPERS, gamma=1e3),
                          block=128)
    params = _params(jax.random.PRNGKey(3))
    n = sum(x.size for x in jax.tree.leaves(params))
    for eng in (ref, fused):
        state = eng.init(params)
        # tiny h, huge m -> every real coordinate hits the clip
        est = jax.tree.map(lambda x: jnp.full(x.shape, 1e-8), params)
        state = eng.update_hessian(state, est, scale=1.0, params=params)
        grads = jax.tree.map(lambda x: jnp.full(x.shape, 100.0), params)
        _, state = eng.step(state, params, grads, 1e-3)
        assert abs(float(state.clip_fraction) - 1.0) < 1e-6, eng.backend
        # padded shard is larger than n: fraction uses n, not padded size
        assert state.m[0].shape[0] > n


@pytest.mark.parametrize("optimizer,hypers", [
    ("adamw", dict(beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.1)),
    ("lion", dict(beta1=0.95, beta2=0.98, weight_decay=0.2)),
    ("signgd", dict(beta1=0.96, weight_decay=0.0)),
    ("sgd", dict(momentum=0.9)),
])
def test_baseline_families_fused_matches_reference(optimizer, hypers):
    ref, fused = _engines(optimizer, hypers, block=128)
    key = jax.random.PRNGKey(7)
    p1 = p2 = _params(key)
    s1, s2 = ref.init(p1), fused.init(p2)
    for t in range(5):
        g = _grads_like(p1, jax.random.fold_in(key, t))
        p1, s1 = ref.step(s1, p1, g, 1e-3)
        p2, s2 = fused.step(s2, p2, g, 1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_adahessian_squared_refresh_parity():
    hypers = dict(beta1=0.92, beta2=0.99, eps=1e-8, weight_decay=0.0)
    ref, fused = _engines("adahessian", hypers, block=128)
    key = jax.random.PRNGKey(11)
    p1 = p2 = _params(key)
    s1, s2 = ref.init(p1), fused.init(p2)
    for t in range(6):
        est = _grads_like(p1, jax.random.fold_in(key, 100 + t), scale=1.0)
        s1 = ref.update_hessian(s1, est, scale=1.0, params=p1)
        s2 = fused.update_hessian(s2, est, scale=1.0, params=p2)
        g = _grads_like(p1, jax.random.fold_in(key, t))
        p1, s1 = ref.step(s1, p1, g, 1e-3)
        p2, s2 = fused.step(s2, p2, g, 1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    for a, b in zip(s1.h, s2.h):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# engine invariants


def test_state_stays_flat_and_padded_region_is_fixed_point():
    ref = OptimizerEngine("sophia_g", hypers=SOPHIA_HYPERS, block=128)
    params = _params(jax.random.PRNGKey(5))
    state = ref.init(params)
    used = ref.layout(params).shard_used[0]
    for t in range(4):
        est = jax.tree.map(jnp.square,
                           _grads_like(params, jax.random.PRNGKey(50 + t)))
        state = ref.update_hessian(state, est, scale=32.0, params=params)
        grads = _grads_like(params, jax.random.PRNGKey(t))
        params, state = ref.step(state, params, grads, 1e-3)
        assert state.m[0].ndim == 1  # never unraveled
        np.testing.assert_array_equal(np.asarray(state.m[0][used:]), 0.0)
        np.testing.assert_array_equal(np.asarray(state.h[0][used:]), 0.0)


def test_engine_under_jit_with_traced_lr_and_scale():
    fused = OptimizerEngine("sophia_g", hypers=SOPHIA_HYPERS, block=128)
    params = _params(jax.random.PRNGKey(9))
    state = fused.init(params)

    @jax.jit
    def one(params, state, grads, est, lr, scale):
        state = fused.update_hessian(state, est, scale=scale, params=params)
        return fused.step(state, params, grads, lr)

    grads = _grads_like(params, jax.random.PRNGKey(10))
    est = jax.tree.map(jnp.square, grads)
    p2, s2 = one(params, state, grads, est, jnp.float32(1e-3),
                 jnp.float32(240.0))
    assert int(s2.count) == 1 and int(s2.hess_count) == 1
    assert all(np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree.leaves(p2))


def test_lion_has_no_curvature_slot():
    eng = OptimizerEngine("lion", hypers=dict(beta1=0.95, beta2=0.98,
                                              weight_decay=0.2))
    state = eng.init(_params(jax.random.PRNGKey(0)))
    assert state.h == ()
    assert not eng.hessian_aware


def test_layout_manifest_is_json_ready():
    import json
    eng = OptimizerEngine("sophia_g", hypers=SOPHIA_HYPERS, block=256)
    man = eng.describe(_params(jax.random.PRNGKey(0)))
    txt = json.dumps(man)
    assert "shards" in man and man["block"] == 256
    assert man["n_params"] == sum(s["used"] for s in man["shards"])
    assert json.loads(txt) == man
