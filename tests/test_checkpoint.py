"""Checkpointing: roundtrip, atomicity, async, GC, elastic re-shard."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16)),
                       "b": jnp.zeros((16,))},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    s = _state()
    ckpt.save(str(tmp_path), 100, s)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s)
    restored, step = ckpt.restore(str(tmp_path), like)
    assert step == 100
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(s["params"]["w"]))
    assert int(restored["step"]) == 7


def test_latest_step_and_gc(tmp_path):
    s = _state()
    for st in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), st, s, keep=3)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert len([d for d in kept if d.startswith("step_")]) == 3


def test_async_save(tmp_path):
    s = _state()
    ckpt.save(str(tmp_path), 42, s, async_=True)
    ckpt.wait_for_pending()
    assert ckpt.latest_step(str(tmp_path)) == 42


def test_incomplete_save_is_invisible(tmp_path):
    """A tmp dir without manifest never counts as a checkpoint."""
    os.makedirs(tmp_path / ".tmp-step_00000009")
    os.makedirs(tmp_path / "step_00000011")  # no manifest -> incomplete
    assert ckpt.latest_step(str(tmp_path)) is None
    ckpt.save(str(tmp_path), 12, _state())
    assert ckpt.latest_step(str(tmp_path)) == 12


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), _state())


@pytest.mark.slow
def test_elastic_reshard_across_meshes(tmp_path):
    """Save under a (2,2) mesh, restore under (4,1) — in a subprocess with
    4 host devices (elastic re-scaling path)."""
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, {json.dumps(os.path.join(os.path.dirname(__file__), '..', 'src'))})
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.train import checkpoint as ckpt
        from repro.launch.mesh import make_mesh

        mesh_a = make_mesh((2, 2), ("data", "model"))
        w = jnp.arange(64.0).reshape(8, 8)
        w_a = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
        ckpt.save({json.dumps(str(tmp_path))}, 5, {{"w": w_a}})

        mesh_b = make_mesh((4, 1), ("data", "model"))
        sh_b = {{"w": NamedSharding(mesh_b, P(None, "data"))}}
        like = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
        restored, step = ckpt.restore({json.dumps(str(tmp_path))}, like,
                                      shardings=sh_b)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
        assert restored["w"].sharding.mesh.shape["data"] == 4
        print("ELASTIC_OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=300)
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]


def test_resume_exact_with_stateless_data(tmp_path):
    """Crash-resume reproduces the exact same trajectory."""
    from repro.configs.gpt2 import GPT2_TINY
    from repro.data import DataConfig, make_source
    from repro.train import TrainerConfig, make_train_fns, train_loop

    tc = TrainerConfig(optimizer="adamw", peak_lr=1e-3, total_steps=20,
                       warmup_steps=2, seed=3)
    src = make_source(DataConfig(seq_len=32, global_batch=4,
                                 vocab_size=GPT2_TINY.vocab_size, seed=3))
    # uninterrupted run: 8 steps
    s_full, _ = train_loop(GPT2_TINY, tc, src, num_steps=8)
    # interrupted: 5 steps, checkpoint, restore, 3 more
    s_mid, _ = train_loop(GPT2_TINY, tc, src, num_steps=5)
    ckpt.save(str(tmp_path), 5, s_mid)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        s_mid)
    s_res, step = ckpt.restore(str(tmp_path), like)
    s_done, _ = train_loop(GPT2_TINY, tc, src, num_steps=3, state=s_res,
                           start_step=step)
    a = jax.flatten_util.ravel_pytree(s_full.params)[0]
    b = jax.flatten_util.ravel_pytree(s_done.params)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
