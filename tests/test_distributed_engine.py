"""The 8-device simulation tier + elastic-machinery regressions.

Fast tests cover the device-count-invariance core of the in-collective
compressor and the StragglerDetector / run_resumable fixes.  The slow tier
launches subprocesses with ``--xla_force_host_platform_device_count=8``
(tests/_distributed_driver.py) and asserts the property the scale story
rests on: the engine produces the same training trajectory on 1 device and
8, with and without int8 gradient compression, and an 8->4-device elastic
restore resumes with bit-identical optimizer state.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import build_layout
from repro.distributed.compression import (GradCompressor, _quantize,
                                           compressed_bytes)
from repro.train.elastic import MeshDegraded, StragglerDetector, run_resumable

DRIVER = os.path.join(os.path.dirname(__file__), "_distributed_driver.py")


# ---------------------------------------------------------------------------
# fast: compressor device-count invariance


def test_quantize_segment_invariance():
    """The rounding decision is a function of (seed, global element index)
    only: quantizing a shard whole equals quantizing block-aligned segments
    with their global offsets — the property that makes 1-device and
    N-device compressed trajectories identical."""
    n, seg = 2048, 512
    x = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 2.0
    seed = jnp.uint32(1234)
    _, _, whole = _quantize(x, 256, seed)
    parts = [np.asarray(_quantize(x[i * seg:(i + 1) * seg], 256, seed,
                                  offset=i * seg)[2])
             for i in range(n // seg)]
    np.testing.assert_array_equal(np.asarray(whole), np.concatenate(parts))


def test_allreduce_shards_error_feedback():
    """Mesh-less flat path: deq + new error reconstructs input (+ carried
    error), and the residual feeds the next round."""
    params = {"w": jnp.zeros((1000,)), "b": jnp.zeros((17,))}
    lay = build_layout(params, block=256)
    comp = GradCompressor(block=256)
    state = comp.init_shards(lay)
    assert all(float(jnp.abs(e).sum()) == 0.0 for e in state.error)
    g_sh = tuple(jax.random.normal(jax.random.PRNGKey(i + 1), (s,))
                 for i, s in enumerate(lay.shard_sizes))
    deq, state2 = comp.allreduce_shards(g_sh, state, jax.random.PRNGKey(9),
                                        mesh=None)
    for g, d, e in zip(g_sh, deq, state2.error):
        # stochastic rounding: reconstruction to ~1 fp32 ulp of the inputs
        tol = np.spacing(np.maximum(np.abs(np.asarray(g)),
                                    np.abs(np.asarray(d)))) * 2
        assert np.all(np.abs(np.asarray(d + e - g)) <= tol + 1e-12)
    deq2, state3 = comp.allreduce_shards(g_sh, state2, jax.random.PRNGKey(10),
                                         mesh=None)
    # carried error changes the quantization input, hence the residual
    assert any(not np.allclose(np.asarray(a), np.asarray(b))
               for a, b in zip(state2.error, state3.error))


def test_allreduce_shards_accepts_none_rng():
    """rng=None selects deterministic round-to-nearest all the way down
    (regression: the per-shard seed decorrelation xor used to TypeError on
    None instead of preserving _quantize's documented rng-less mode)."""
    params = {"w": jnp.zeros((600,))}
    lay = build_layout(params, block=256)
    comp = GradCompressor(block=256)
    g_sh = tuple(jax.random.normal(jax.random.PRNGKey(3), (s,))
                 for s in lay.shard_sizes)
    deq1, _ = comp.allreduce_shards(g_sh, comp.init_shards(lay), None,
                                    mesh=None)
    deq2 = comp.allreduce_shards_stateless(g_sh, None, mesh=None)
    for g, a, b in zip(g_sh, deq1, deq2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # nearest rounding: |deq - g| <= scale/2 <= max|block|/254
        assert float(jnp.max(jnp.abs(a - g))) <= \
            float(jnp.max(jnp.abs(g))) / 254 + 1e-12


def test_wire_bytes_formula():
    """Per-shard wire bytes = n int8 payload + 4 bytes per 256-block scale,
    and the layout-level accounting agrees with compressed_bytes."""
    params = {"w": jnp.zeros((100_000,)), "b": jnp.zeros((300,))}
    lay = build_layout(params, block=256)
    comp = GradCompressor(block=256)
    wire = comp.wire_bytes(lay)
    for n, b in zip(lay.shard_sizes, wire):
        assert b == n + 4 * (-(-n // 256))
    shards = tuple(jnp.zeros((n,), jnp.float32) for n in lay.shard_sizes)
    assert sum(wire) == compressed_bytes(shards)
    assert sum(wire) < 4 * sum(lay.shard_sizes) / 3.5  # ~4x vs fp32


# ---------------------------------------------------------------------------
# fast: elastic-machinery regressions


def test_straggler_warmup_excludes_baseline():
    """Regression: the baseline sample used to count toward warmup, making
    the detector eligible to flag one deviation-sample early."""
    det = StragglerDetector(alpha=0.1, z_thresh=3.0, warmup=3)
    det.observe(1.0)                      # baseline
    assert det.n == 0                     # not a deviation sample
    det.observe(1.0)
    det.observe(1.0)
    # 3rd deviation sample: n == warmup, still warming up — the old
    # counting (n included the baseline) flagged exactly here
    assert det.observe(50.0) is False
    assert det.flagged == 0

    det2 = StragglerDetector(alpha=0.1, z_thresh=3.0, warmup=3)
    for _ in range(4):                    # baseline + 3 deviation samples
        det2.observe(1.0)
    assert det2.observe(50.0) is True     # n == 4 > warmup: flags
    assert det2.flagged == 1


def test_run_resumable_retries_before_first_checkpoint():
    """Regression: a raising restore_latest (no checkpoint written yet)
    used to kill the retry loop before the first attempt."""
    calls = {"run": 0, "restore": 0}

    def make_state():
        return {"fresh": True}

    def restore_latest():
        calls["restore"] += 1
        raise FileNotFoundError("no checkpoints yet")

    def run(state, start):
        calls["run"] += 1
        if calls["run"] < 3:
            raise RuntimeError("failure before any checkpoint")
        return state, start

    state, start = run_resumable(make_state, run, restore_latest,
                                 max_restarts=5)
    assert calls["run"] == 3
    assert start == 0 and state == {"fresh": True}
    assert calls["restore"] == 3  # attempted (and survived) every time


def test_run_resumable_mesh_degrade_is_a_free_retry():
    """Deliberate checkpoint-and-reconfigure (MeshDegraded) must not
    consume the restart budget — a run that degrades 8->4->2 would
    otherwise exhaust max_restarts before any real failure happened."""
    calls = {"run": 0}

    def run(state, start):
        calls["run"] += 1
        if calls["run"] < 4:
            raise MeshDegraded("straggler; shrinking mesh")
        return "done"

    # max_restarts=0: any *failure* would raise immediately
    assert run_resumable(lambda: {}, run, lambda: None,
                         max_restarts=0) == "done"
    assert calls["run"] == 4


def test_run_resumable_does_not_mask_corrupt_restore():
    """A restore_latest raising anything other than FileNotFoundError
    (layout mismatch, corrupt leaves) must propagate: silently starting
    fresh would overwrite the checkpoints it failed to read."""
    def restore_latest():
        raise ValueError("checkpoint flat-shard layout mismatch")

    with pytest.raises(ValueError, match="layout mismatch"):
        run_resumable(lambda: {}, lambda s, t: s, restore_latest,
                      max_restarts=5)


# ---------------------------------------------------------------------------
# slow: the 8-device subprocess tier


def _run_driver(*args, timeout=1200):
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, DRIVER, *args], capture_output=True,
                       text=True, timeout=timeout, env=env)
    for line in reversed(r.stdout.strip().splitlines()):
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise AssertionError(f"driver produced no RESULT\n"
                         f"stdout: {r.stdout[-2000:]}\n"
                         f"stderr: {r.stderr[-4000:]}")


@pytest.mark.slow
@pytest.mark.parametrize("opt,compress,compress_hess", [
    ("sophia_g", False, False), ("sophia_g", True, False),
    ("sophia_g", True, True),  # estimator grad rides the int8 collective
    ("adamw", False, False), ("adamw", True, False),
])
def test_one_vs_eight_device_loss_parity(opt, compress, compress_hess):
    """Identical seed -> step-for-step loss parity between a 1-device and
    an 8-device mesh, across >= 2 Hessian-refresh intervals.  Compression
    must not break parity: quantization happens on the reduced shard with
    position-keyed rounding, so the compressed trajectory is the same
    function of the data on any device count.  The compress_hess case runs
    the stateless int8 collective *inside* the lax.cond refresh branch —
    the one genuinely new shard_map/cond interaction of the unified
    stepper."""
    out = _run_driver("--mode", "parity", "--opt", opt,
                      "--compress", str(int(compress)),
                      "--compress-hess", str(int(compress_hess)))
    l1, l8 = out["losses_1"], out["losses_8"]
    assert len(l1) == len(l8) >= 7
    assert all(np.isfinite(l1)) and all(np.isfinite(l8))
    # fp32-compute model: the only cross-mesh difference is collective
    # reduction order (fp32 ulps/step, mildly amplified by the trajectory)
    np.testing.assert_allclose(l8, l1, rtol=2e-4, atol=2e-4)
    # unified stepper: the refresh flag is traced, so a full run (hot steps
    # AND refresh steps) compiles exactly ONE program per mesh
    assert out["programs_1"] == 1 and out["programs_8"] == 1, \
        (out["programs_1"], out["programs_8"])
    if compress:
        for n, b in zip(out["shard_sizes"], out["wire_bytes"]):
            assert b == n + 4 * (-(-n // 256))
        assert sum(out["wire_bytes"]) == out["compressed_bytes"]


@pytest.mark.slow
def test_one_vs_eight_device_loss_parity_bucketed():
    """The bucketed overlapped reduction (distributed/overlap.py) keeps
    1-vs-8-device parity: per-bucket segmentation is 256*ndev-aligned and
    noise/scales key on global element index, so bucketing changes neither
    the wire math nor its device-count invariance."""
    out = _run_driver("--mode", "parity", "--opt", "sophia_g",
                      "--compress", "1", "--bucket-elems", "16384")
    l1, l8 = out["losses_1"], out["losses_8"]
    assert len(l1) == len(l8) >= 7
    assert all(np.isfinite(l1)) and all(np.isfinite(l8))
    np.testing.assert_allclose(l8, l1, rtol=2e-4, atol=2e-4)
    assert out["programs_1"] == 1 and out["programs_8"] == 1


@pytest.mark.slow
def test_hlo_peak_comm_buffer_bucketed():
    """Peak-comm-buffer regression audit on the COMPILED 8-device program:
    bucketing must cap the int8 gradient gather at O(bucket) bytes where
    the monolithic path gathers O(shard) — the satellite fix for
    allreduce_shards peak memory.  (fp32 reduce-scatter feeds stay
    O(n/devices) in both.)"""
    out = _run_driver("--mode", "hlo", "--bucket-elems", "16384")
    be = out["bucket_elems"]
    mono = out["monolithic"]["max"].get("all-gather", {}).get("s8", 0)
    buck = out["bucketed"]["max"].get("all-gather", {}).get("s8", 0)
    assert mono > 0 and buck > 0, out
    # the monolithic gather's buffer is the whole (largest) shard's int8
    # payload; bucketed must be capped by the bucket size
    assert mono >= max(out["shard_sizes"]) // 8  # operand: per-device seg
    assert buck <= be, (buck, be)
    assert buck < mono, (buck, mono)
    # same wire bytes overall: bucketing splits collectives, it must not
    # add traffic (scales excluded: counted under f32 alongside params
    # gathers, asserted via totals staying within a few percent)
    s_mono = out["monolithic"]["sum"]["total"]
    s_buck = out["bucketed"]["sum"]["total"]
    assert abs(s_buck - s_mono) <= 0.05 * s_mono, (s_mono, s_buck)


@pytest.mark.slow
def test_elastic_restore_8_to_4_devices(tmp_path):
    """Train 6 steps on 8 devices, checkpoint, restore onto a 4-device
    mesh: params/m/h bit-identical after the re-shard, and the loss keeps
    falling through the next Hessian refresh on the smaller mesh."""
    out = _run_driver("--mode", "elastic", "--ckpt-dir", str(tmp_path))
    ident = out["bit_identical"]
    assert ident["params"] and ident["m"] and ident["h"] and ident["step"], \
        ident
    before, after = out["losses_before"], out["losses_after"]
    assert all(np.isfinite(before)) and all(np.isfinite(after))
    # continuation picks up where the 8-device run left off...
    assert abs(after[0] - before[-1]) < 0.25
    # ...and keeps improving monotonically (small slack for step noise)
    for a, b in zip(after, after[1:]):
        assert b < a + 0.02, (a, b)
    assert after[-1] < after[0]
    # the shrunken mesh also compiled exactly one program
    assert out["programs_4"] == 1, out["programs_4"]
