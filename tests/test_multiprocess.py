"""The 2-process ``jax.distributed`` tier (real multi-process, CPU/gloo).

Everything here launches REAL separate OS processes that form a jax
distributed runtime over localhost — the multi-host scale-out path of
launch/train.py and distributed/overlap.py, not the single-process
8-device simulation of tests/test_distributed_engine.py.

Pinned properties:
  * 2-process / single-process loss parity <= 3e-6 at the same global
    device count, with the bucketed int8 collective on — multi-process
    changes the transport, never the math;
  * node-loss resume: a checkpoint written collectively by 2 processes
    restores into 1 surviving process (the relaunch path NodeLoss
    documents) and training continues monotonically.
"""
import json
import os
import socket
import subprocess
import sys

import pytest

DRIVER = os.path.join(os.path.dirname(__file__), "_multiprocess_driver.py")


def _free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _launch(*args, port=None, nproc=1, env_extra=None, timeout=1200):
    """Launch nproc copies of the driver (one per process-id), wait for
    all, and parse process 0's RESULT line."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)  # no inherited forced device counts
    if env_extra:
        env.update(env_extra)
    common = [sys.executable, DRIVER, *args]
    if nproc > 1:
        common += ["--port", str(port), "--num-processes", str(nproc)]
    procs = [subprocess.Popen(common + (["--process-id", str(pid)]
                                        if nproc > 1 else []),
                              stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True, env=env)
             for pid in range(nproc)]
    outs = [p.communicate(timeout=timeout) for p in procs]
    for p, (so, se) in zip(procs, outs):
        assert p.returncode == 0, \
            f"driver rc={p.returncode}\nstdout: {so[-2000:]}\n" \
            f"stderr: {se[-4000:]}"
    for line in reversed(outs[0][0].strip().splitlines()):
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise AssertionError(f"no RESULT from process 0\n"
                         f"stdout: {outs[0][0][-2000:]}\n"
                         f"stderr: {outs[0][1][-4000:]}")


@pytest.mark.slow
def test_two_process_loss_parity():
    """2 processes x 1 device vs 1 process x 2 simulated devices: same
    global device count, same mesh shape, same seed -> per-step losses
    agree to <= 3e-6 (fp32; the transport — gloo cross-process vs
    in-process — is the only difference)."""
    two = _launch("--steps", "6", "--bucket-elems", "8192",
                  port=_free_port(), nproc=2)
    one = _launch("--steps", "6", "--bucket-elems", "8192",
                  "--force-devices", "2", nproc=1)
    assert two["process_count"] == 2 and two["global_devices"] == 2
    assert one["process_count"] == 1 and one["global_devices"] == 2
    assert len(two["losses"]) == 6
    for a, b in zip(one["losses"], two["losses"]):
        assert abs(a - b) <= 3e-6, (one["losses"], two["losses"])


@pytest.mark.slow
def test_node_loss_resume(tmp_path):
    """A checkpoint written collectively by 2 processes restores into ONE
    surviving process — the post-NodeLoss relaunch — and the continued
    trajectory stays monotone through the next Hessian refresh."""
    ckpt_dir = str(tmp_path / "ckpt")
    before = _launch("--steps", "4", "--bucket-elems", "8192",
                     "--ckpt-dir", ckpt_dir,
                     port=_free_port(), nproc=2)
    assert before["manifest_digest"]
    # the survivor: 1 process, 1 device — a smaller mesh than wrote the
    # checkpoint (the flat-shard layout is mesh-independent)
    after = _launch("--steps", "4", "--resume", "--ckpt-dir", ckpt_dir,
                    nproc=1)
    assert after["start"] == 4, after
    assert len(after["losses"]) == 4
    # resumed trajectory continues the descent, not a restart spike
    assert min(after["losses"]) < min(before["losses"]), (before, after)
    assert max(after["losses"]) < before["losses"][0] + 0.05, (before, after)
