"""Per-arch smoke tests (reduced configs, one train step, shapes + no NaN)
and model-level consistency (decode == forward, chunked == full)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, get_config
from repro.models import ModelConfig, get_model


def _batch_for(cfg, B=2, S=64, key=0):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.patch_embed_input:
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, 8, cfg.d_model))
        batch["mask"] = jnp.concatenate(
            [jnp.zeros((B, 8)), jnp.ones((B, S - 8))], axis=1)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(key + 2), (B, S, cfg.d_model))
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_forward_and_train_step(arch):
    """Instantiate the reduced config, one forward + one Sophia-G train
    step on CPU; assert output shapes and no NaNs."""
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, B=2, S=64)

    loss, metrics = model.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss)), arch

    out = model.forward(cfg, params, batch["tokens"],
                        **({"frames": batch["frames"]}
                           if cfg.family == "encdec" else {}))
    logits = out[0]
    assert logits.shape == (2, 64, cfg.padded_vocab), (arch, logits.shape)
    assert bool(jnp.all(jnp.isfinite(logits))), arch

    # one optimizer step end-to-end
    from repro.core import apply_updates, sophia_g
    opt = sophia_g(1e-3)
    ostate = opt.init(params)
    grads = jax.grad(lambda p: model.loss_fn(cfg, p, batch)[0])(params)
    updates, ostate = opt.update(grads, ostate, params)
    params2 = apply_updates(params, updates)
    loss2, _ = model.loss_fn(cfg, params2, batch)
    assert np.isfinite(float(loss2)), arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_param_count_within_assignment(arch):
    """Full config's analytic size matches the assigned id (+-20%)."""
    targets = {
        "qwen1.5-110b": 110e9, "yi-6b": 6e9, "gemma2-9b": 9e9,
        "stablelm-1.6b": 1.6e9, "qwen2-vl-7b": 7e9, "rwkv6-7b": 7e9,
        "llama4-maverick-400b-a17b": 400e9, "deepseek-moe-16b": 16e9,
        "seamless-m4t-medium": 0.55e9, "recurrentgemma-2b": 2.7e9,
    }
    n = get_config(arch).param_count()
    assert 0.8 * targets[arch] <= n <= 1.25 * targets[arch], (arch, n)


def test_moe_capacity_drops_overflow():
    """Tokens beyond expert capacity are dropped, not misrouted."""
    from repro.models.moe import _slots_in_group
    e = jnp.array([0, 0, 0, 1, 0, 1, 2, 0], jnp.int32)
    slots = np.asarray(_slots_in_group(e))
    # slot = rank within expert
    assert slots.tolist() == [0, 1, 2, 0, 3, 1, 0, 4]


def test_moe_aux_loss_balanced_vs_skewed():
    """Aux loss is minimized by a uniform router."""
    cfg = get_config("deepseek-moe-16b", smoke=True)
    from repro.models.moe import init_moe, moe_ffn
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    _, aux = moe_ffn(p, x, cfg)
    assert np.isfinite(float(aux)) and float(aux) >= 0.0


def test_gemma2_softcap_bounds_logits():
    cfg = get_config("gemma2-9b", smoke=True)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    # scale params up to force big logits; softcap must bound them
    params = jax.tree.map(lambda x: x * 10.0, params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    logits, _ = model.forward(cfg, params, toks)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_logit_softcap + 1e-3


def test_local_window_masks_differ():
    """gemma2 alternating local/global layers attend differently."""
    from repro.models.transformer import layer_windows
    cfg = get_config("gemma2-9b", smoke=True)
    w = np.asarray(layer_windows(cfg, 64))
    assert (w[0] == cfg.local_window) and (w[1] > 1e6)


def test_mrope_sections_rotate_differently():
    from repro.models.layers import apply_rope
    B, S, H, hd = 1, 8, 2, 16
    x = jnp.ones((B, S, H, hd))
    pos2d = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    pos3d = jnp.stack([pos2d, pos2d * 0, pos2d * 2], axis=1)  # (B,3,S)
    a = apply_rope(x, pos2d)
    b = apply_rope(x, pos3d, mrope_sections=(2, 3, 3))
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_attention_temperature_trick():
    """Fig 7b baseline trick: per-layer inverse-index scaling is wired."""
    from repro.models.transformer import layer_scales
    cfg = get_config("stablelm-1.6b", smoke=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "attn_temperature_by_layer": True})
    s = np.asarray(layer_scales(cfg))
    np.testing.assert_allclose(s, 1.0 / (1 + np.arange(cfg.n_layers)))


# --------------------------------------------------------------------------
# decode == forward consistency (serving correctness)


def test_dense_decode_matches_forward():
    cfg = get_config("yi-6b", smoke=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    full, _ = model.forward(cfg, params, toks)
    cache = model.init_cache(cfg, 2, 16)
    outs = []
    for t in range(16):
        lg, cache = model.decode_step(cfg, params, cache, toks[:, t:t + 1], t)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_rwkv_chunked_matches_scan():
    from repro.models import rwkv as R
    B, S, H, K = 2, 96, 3, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, K)) * 0.5 for i in range(3))
    logw = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) - 1.0),
                    -4.0, -1e-6)
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    st = jax.random.normal(jax.random.PRNGKey(9), (B, H, K, K)) * 0.1
    o1, s1 = R.wkv_scan(r, k, v, logw, u, st)
    o2, s2 = R.wkv_chunked(r, k, v, logw, u, st)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_griffin_decode_matches_forward():
    cfg = get_config("recurrentgemma-2b", smoke=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size)
    full, _ = model.forward(cfg, params, toks)
    state = model.init_cache(cfg, 2)
    outs = []
    for t in range(24):
        lg, state = model.decode_step(cfg, params, state, toks[:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_chunked_attention_matches_full_with_window():
    from repro.models.layers import (chunked_attention, full_attention,
                                     init_attention)
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      dtype="float32")
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 64))
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    for window in (None, 32):
        a = full_attention(p, x, cfg, pos, window=window)
        b = chunked_attention(p, x, cfg, pos, window=window, kv_block=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
