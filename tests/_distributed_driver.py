"""Subprocess driver for the 8-device simulation tier.

Run by tests/test_distributed_engine.py with
``--xla_force_host_platform_device_count=8`` so the SPMD engine path (mesh
shard_map compression, FSDP flat shards, cross-mesh restore) executes on
real (simulated) devices.  Prints one JSON object on the last line.

Modes:
    parity   — identical seed, 1-device vs 8-device mesh: step-for-step
               losses for a given optimizer, with/without int8 compression,
               plus the compressed wire-bytes accounting per flat shard and
               the jit-cache size (the unified stepper must compile exactly
               one program per mesh even as the refresh flag flips).
               ``--bucket-elems`` routes the compressed reduction through
               the bucketed overlapped pipeline (distributed/overlap.py).
    elastic  — train 6 steps on an 8-device mesh, checkpoint, restore onto
               a 4-device mesh, report bit-identity of params/m/h and the
               continued loss trajectory through the next Hessian refresh.
    hlo      — compile the 8-device train step monolithic vs bucketed and
               report per-(kind, dtype) MAX single-collective buffer bytes:
               the peak-comm-buffer regression audit (the int8 gather must
               shrink from O(shard) to O(bucket)).
"""
import os

# append (not overwrite): inherited XLA flags — determinism/debug knobs set
# by CI or the developer — must keep applying inside the subprocess
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8"
                           ).strip()

import argparse
import dataclasses
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gpt2 import GPT2_TINY
from repro.core import hessian_aware_optimizer
from repro.data import DataConfig, make_source
from repro.distributed.compression import GradCompressor, compressed_bytes
from repro.launch.mesh import make_mesh
from repro.launch.train import compile_train_step  # the production wiring
from repro.train import TrainerConfig, checkpoint as ckpt, make_engine

# fp32 compute: parity across meshes is then limited only by collective
# reduction order (fp32 ulps), not bf16 forward rounding chaos
CFG = dataclasses.replace(GPT2_TINY, dtype="float32")
STEPS = 8
HESS_INTERVAL = 3  # refreshes at t = 0, 3, 6  ->  >= 2 full intervals


def _tc(opt, compress, compress_hess=False, bucket_elems=None):
    return TrainerConfig(optimizer=opt, peak_lr=1e-3, total_steps=100,
                         warmup_steps=2, hess_interval=HESS_INTERVAL,
                         hess_subbatch=4, compress_grads=compress,
                         compress_hess=compress_hess,
                         comm_bucket_elems=bucket_elems, seed=0)


def _mesh(n_dev):
    if n_dev == 1:
        return None
    return make_mesh((n_dev, 1), ("data", "model"),
                     devices=jax.devices()[:n_dev])


def _source():
    return make_source(DataConfig(seq_len=32, global_batch=8,
                                  vocab_size=CFG.vocab_size, seed=0))


def _setup(tc, mesh):
    """The production driver's jit/sharding wiring (launch.train), so the
    parity tier validates what actually runs, not a test-local copy."""
    sample = {k: jnp.asarray(v) for k, v in _source().batch_at(0).items()}
    train_step, init_fn, ssh, bsh = compile_train_step(CFG, tc, mesh, sample)
    state = init_fn(jax.random.PRNGKey(0))
    if ssh is not None:
        state = jax.device_put(state, ssh)
    return train_step, init_fn, state, ssh, bsh


def _trajectory(n_dev, opt, compress, compress_hess=False, steps=STEPS,
                bucket_elems=None):
    tc = _tc(opt, compress, compress_hess, bucket_elems)
    mesh = _mesh(n_dev)
    train_step, _, state, _, bsh = _setup(tc, mesh)
    src = _source()
    needs_hess = hessian_aware_optimizer(opt)
    losses = []
    for t in range(steps):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(t).items()}
        if bsh is not None:
            batch = jax.device_put(batch, bsh)
        flag = jnp.asarray(needs_hess and t % HESS_INTERVAL == 0)
        state, metrics = train_step(state, batch, flag)
        losses.append(float(metrics["loss"]))
    # the unified-stepper contract: flipping the refresh flag across a full
    # run must never grow the jit cache — exactly ONE program per mesh
    return losses, state, train_step._cache_size()


def parity(args):
    be = args.bucket_elems
    l1, _, progs1 = _trajectory(1, args.opt, args.compress,
                                bool(args.compress_hess), bucket_elems=be)
    l8, s8, progs8 = _trajectory(8, args.opt, args.compress,
                                 bool(args.compress_hess), bucket_elems=be)
    out = {"losses_1": l1, "losses_8": l8,
           "programs_1": progs1, "programs_8": progs8}
    if args.compress:
        lay = make_engine(_tc(args.opt, True)).layout(
            jax.device_get(s8.params))
        comp = GradCompressor()
        out["shard_sizes"] = [int(n) for n in lay.shard_sizes]
        out["wire_bytes"] = [int(b) for b in comp.wire_bytes(lay)]
        out["compressed_bytes"] = int(compressed_bytes(
            tuple(jnp.zeros((n,), jnp.float32) for n in lay.shard_sizes)))
    return out


def elastic(args):
    tc = _tc("sophia_g", False)
    train_step, _, state, _, bsh = _setup(tc, _mesh(8))
    src = _source()
    losses_before = []
    for t in range(6):
        batch = jax.device_put(
            {k: jnp.asarray(v) for k, v in src.batch_at(t).items()}, bsh)
        state, metrics = train_step(state, batch,
                                    jnp.asarray(t % HESS_INTERVAL == 0))
        losses_before.append(float(metrics["loss"]))

    layout_meta = make_engine(tc).describe(jax.device_get(state.params))
    ckpt.save(args.ckpt_dir, 6, state, extra=layout_meta)
    saved = jax.device_get(state)  # host snapshot for bit-identity check

    # "lose" half the machine: rebuild the production wiring on a 4-device
    # mesh and re-shard the checkpoint onto it
    train_step, init_fn, _, ssh, bsh4 = _setup(tc, _mesh(4))
    like = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    state4, start = ckpt.restore_resharded(args.ckpt_dir, like, shardings=ssh,
                                           expect_layout=layout_meta)
    restored = jax.device_get(state4)

    def bit_identical(a, b):
        return all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    ident = {
        "params": bit_identical(saved.params, restored.params),
        "m": bit_identical(saved.opt_state.m, restored.opt_state.m),
        "h": bit_identical(saved.opt_state.h, restored.opt_state.h),
        "step": int(start) == 6,
    }

    losses_after = []
    for t in range(start, start + 5):  # through the refreshes at t=6 and 9
        batch = jax.device_put(
            {k: jnp.asarray(v) for k, v in src.batch_at(t).items()}, bsh4)
        state4, metrics = train_step(state4, batch,
                                     jnp.asarray(t % HESS_INTERVAL == 0))
        losses_after.append(float(metrics["loss"]))
    return {"bit_identical": ident, "losses_before": losses_before,
            "losses_after": losses_after,
            "programs_4": train_step._cache_size()}


def hlo(args):
    """Compile (don't run) the 8-device step monolithic vs bucketed and
    audit peak single-collective buffer bytes by (kind, dtype)."""
    from repro.launch.roofline import (collective_buffer_bytes,
                                       collective_bytes)
    mesh = _mesh(8)
    sample = {k: jnp.asarray(v) for k, v in _source().batch_at(0).items()}
    be = args.bucket_elems or 16 * 1024
    out = {"bucket_elems": be}
    for label, bucket in (("monolithic", 0), ("bucketed", be)):
        tc = _tc("sophia_g", True, bucket_elems=bucket)
        train_step, init_fn, ssh, bsh = compile_train_step(CFG, tc, mesh,
                                                           sample)
        state = jax.device_put(init_fn(jax.random.PRNGKey(0)), ssh)
        batch = jax.device_put(sample, bsh)
        txt = train_step.lower(state, batch,
                               jnp.asarray(False)).compile().as_text()
        out[label] = {"max": collective_buffer_bytes(txt),
                      "sum": collective_bytes(txt)}
    lay = make_engine(_tc("sophia_g", True)).layout(
        jax.eval_shape(init_fn, jax.random.PRNGKey(0)).params)
    out["shard_sizes"] = [int(n) for n in lay.shard_sizes]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["parity", "elastic", "hlo"],
                    required=True)
    ap.add_argument("--opt", default="sophia_g")
    ap.add_argument("--compress", type=int, default=0)
    ap.add_argument("--compress-hess", type=int, default=0)
    ap.add_argument("--bucket-elems", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    out = {"parity": parity, "elastic": elastic, "hlo": hlo}[args.mode](args)
    print("RESULT " + json.dumps(out))


if __name__ == "__main__":
    main()
