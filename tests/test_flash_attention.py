"""Training-path flash attention vs the closed-form oracles.

Parity contracts (interpret mode on CPU):

  * forward, backward (custom_vjp) and jvp (custom_jvp twin) match
    ``kernels/ref.py``'s oracles to <= 3e-6 in fp32 across
    causal x window x softcap x q_offset x GQA, including cases whose
    grids cross >= 2 block boundaries in BOTH axes and both schedules;
  * bf16 sits at ~1 ulp (accumulation-order straddling);
  * the Hutchinson route (jvp-of-grad) crosses the custom_jvp rule —
    asserted through the trace-time KERNEL_CALLS counters;
  * the model-level routes agree: flash == full == chunked through the
    same projection weights, including window + softcap + q_offset.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import (INTERPRET_CELL_CAP,
                                           _clamp_interpret_grid, _fit_block,
                                           attention_hbm_bytes_train_flash,
                                           attention_hbm_bytes_unfused,
                                           flash_attention)
from repro.kernels.fused_ce import KERNEL_CALLS
from repro.kernels.ref import (flash_attention_grads_ref,
                               flash_attention_jvp_ref, flash_attention_ref)

F32_TOL = 3e-6
# bf16 mantissa is 8 bits: one output-rounding ulp is a 2**-8 relative
# flip wherever accumulation order straddles a rounding boundary
BF16_RTOL = 2.0 / 256
BF16_ATOL = 2e-5


def _qkv(key, B, H, Hkv, Sq, Sk, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), dtype) * 0.5
    k = jax.random.normal(ks[1], (B, Hkv, Sk, hd), dtype) * 0.5
    v = jax.random.normal(ks[2], (B, Hkv, Sk, hd), dtype) * 0.5
    return q, k, v


# case matrix: every entry runs forward, backward AND jvp parity.
# (1,2,1,192,192) with bq=bk=64 crosses two block boundaries in both grid
# axes (3x3 blocks); the q_offset case has Sq != Sk on uneven blocks.
CASES = [
    # B, H, Hkv, Sq, Sk, hd, bq, bk, causal, window, softcap, qoff, sched
    (1, 2, 1, 192, 192, 32, 64, 64, True, None, None, 0, None),
    (1, 2, 1, 192, 192, 32, 64, 64, True, 48, None, 0, "skip"),
    (1, 2, 1, 192, 192, 32, 64, 64, True, None, 20.0, 0, None),
    (1, 2, 2, 128, 192, 32, 32, 64, True, 80, 8.0, 64, "skip"),
    (1, 4, 1, 96, 160, 32, 32, 32, False, None, None, 0, "dense"),
    (2, 2, 1, 128, 128, 64, 64, 64, True, None, None, 0, "dense"),
]


def _run_parity(B, H, Hkv, Sq, Sk, hd, bq, bk, causal, window, softcap,
                qoff, sched, dtype=jnp.float32, use_jvp=False,
                atol=F32_TOL, rtol=0.0):
    q, k, v = _qkv(jax.random.PRNGKey(Sq + Sk + hd), B, H, Hkv, Sq, Sk, hd,
                   dtype)
    kw = dict(causal=causal, window=window, softcap=softcap, q_offset=qoff)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk, schedule=sched,
                          use_jvp=use_jvp, **kw)
    ref, _ = flash_attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol,
                               rtol=rtol)

    g = jax.random.normal(jax.random.PRNGKey(7), out.shape, dtype) * 0.5

    def f(q, k, v):
        o = flash_attention(q, k, v, block_q=bq, block_k=bk, schedule=sched,
                            use_jvp=use_jvp, **kw)
        return (o.astype(jnp.float32) * g.astype(jnp.float32)).sum()

    dq, dk, dv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    rq, rk, rv = flash_attention_grads_ref(q, k, v, g, **kw)
    for got, want, name in ((dq, rq, "dq"), (dk, rk, "dk"), (dv, rv, "dv")):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=atol, rtol=rtol, err_msg=name)

    tq, tk, tv = _qkv(jax.random.PRNGKey(11), B, H, Hkv, Sq, Sk, hd, dtype)
    _, do = jax.jvp(
        lambda q, k, v: flash_attention(q, k, v, block_q=bq, block_k=bk,
                                        schedule=sched, use_jvp=True, **kw),
        (q, k, v), (tq, tk, tv))
    do_ref = flash_attention_jvp_ref(q, k, v, tq, tk, tv, **kw)
    np.testing.assert_allclose(np.asarray(do, np.float32),
                               np.asarray(do_ref, np.float32),
                               atol=atol, rtol=rtol)


@pytest.mark.parametrize(
    "B,H,Hkv,Sq,Sk,hd,bq,bk,causal,window,softcap,qoff,sched", CASES)
def test_flash_fwd_bwd_jvp_match_oracle(B, H, Hkv, Sq, Sk, hd, bq, bk,
                                        causal, window, softcap, qoff,
                                        sched):
    _run_parity(B, H, Hkv, Sq, Sk, hd, bq, bk, causal, window, softcap,
                qoff, sched)


def test_flash_bf16_parity():
    """bf16 fwd stays at fp32-level error (fp32 accumulators); grads sit
    ~1 ulp out where accumulation order straddles a rounding boundary."""
    _run_parity(1, 2, 1, 192, 192, 32, 64, 64, True, 48, 20.0, 0, None,
                dtype=jnp.bfloat16, atol=BF16_ATOL, rtol=BF16_RTOL)


def test_flash_traced_window():
    """A traced window (per-layer windows ride through lax.scan) takes the
    scalar-prefetch path and matches the static-window result exactly."""
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 2, 1, 192, 192, 32)
    static = flash_attention(q, k, v, window=48, block_q=64, block_k=64)
    traced = jax.jit(
        lambda w: flash_attention(q, k, v, window=w, block_q=64,
                                  block_k=64))(jnp.asarray(48, jnp.int32))
    np.testing.assert_allclose(np.asarray(traced), np.asarray(static),
                               atol=F32_TOL)


def test_flash_schedules_agree():
    """"skip" (clamped index maps + band guard) == "dense" (mask only)."""
    q, k, v = _qkv(jax.random.PRNGKey(5), 1, 2, 1, 192, 192, 32)
    for window in (None, 48):
        a = flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                            schedule="skip")
        b = flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                            schedule="dense")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=F32_TOL)


def test_kernel_calls_counters():
    """Trace-time counters: fwd / bwd kernels fire under grad, the
    custom_jvp rule fires under jvp-of-grad (the Hutchinson route) —
    the no-silent-fallback assertion the trainer tests reuse."""
    q, k, v = _qkv(jax.random.PRNGKey(0), 1, 2, 1, 64, 64, 32)
    before = {k_: KERNEL_CALLS.get(k_, 0) for k_ in
              ("attn_fwd", "attn_bwd_dq", "attn_bwd_dkv", "attn_jvp_rule")}
    jax.grad(lambda q: flash_attention(q, k, v).astype(jnp.float32).sum())(q)
    assert KERNEL_CALLS["attn_fwd"] > before["attn_fwd"]
    assert KERNEL_CALLS["attn_bwd_dq"] > before["attn_bwd_dq"]
    assert KERNEL_CALLS["attn_bwd_dkv"] > before["attn_bwd_dkv"]

    def f(q):
        return flash_attention(q, k, v, use_jvp=True).astype(
            jnp.float32).sum()

    u = jnp.ones_like(q)
    jax.jvp(jax.grad(f), (q,), (u,))
    assert KERNEL_CALLS["attn_jvp_rule"] > before["attn_jvp_rule"]


def test_jvp_crosses_layer_scan():
    """Forward-over-reverse through ``lax.scan`` (the transformer layer
    loop): linearization inlines the known side of a staged custom_jvp
    call, so the rule must be Pallas-free — this is the exact composition
    Hutchinson's HVP runs."""
    B, H, Hkv, S, hd = 1, 2, 1, 64, 16
    q0, k, v = _qkv(jax.random.PRNGKey(0), B, H, Hkv, S, S, hd)
    u = jnp.ones_like(q0)

    def f(q):
        def body(x, w):
            return flash_attention(x, k, v, window=w, use_jvp=True), None
        x, _ = jax.lax.scan(body, q, jnp.array([48, 64], jnp.int32))
        return x.astype(jnp.float32).sum()

    def f_ref(q):
        def body(x, w):
            return flash_attention_ref(x, k, v, window=w)[0], None
        x, _ = jax.lax.scan(body, q, jnp.array([48, 64], jnp.int32))
        return x.astype(jnp.float32).sum()

    _, hvp = jax.jvp(jax.grad(f), (q0,), (u,))
    _, hvp_ref = jax.jvp(jax.grad(f_ref), (q0,), (u,))
    np.testing.assert_allclose(np.asarray(hvp), np.asarray(hvp_ref),
                               atol=1e-5)


def test_interpret_grid_clamp():
    """Interpret grids are clamped to <= INTERPRET_CELL_CAP cells (the
    unrolled emulation is ~ms per cell) by growing blocks, preferring the
    axis with more blocks; the B*H outer product alone may exceed the cap
    (best effort)."""
    bq, bk = _clamp_interpret_grid(512, 512, 64, 64, outer=1)
    assert (512 // bq) * (512 // bk) <= INTERPRET_CELL_CAP
    # already small grids are untouched
    assert _clamp_interpret_grid(128, 128, 64, 64, outer=1) == (64, 64)
    # huge outer product: blocks max out at the axis length
    bq, bk = _clamp_interpret_grid(256, 256, 64, 64, outer=1024)
    assert bq == 256 and bk == 256
    assert _fit_block(192, 128) == 96  # largest divisor <= want
    # and a clamped end-to-end call still matches the oracle
    q, k, v = _qkv(jax.random.PRNGKey(9), 1, 1, 1, 512, 512, 16)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref, _ = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=F32_TOL)


def test_byte_models_ordering():
    """The train-path analytic floor: flash < unfused at any real length,
    and the unfused term grows ~quadratically."""
    B, H, Hkv, hd = 8, 12, 4, 128
    for S in (2048, 8192):
        assert attention_hbm_bytes_train_flash(B, H, Hkv, S, hd) < \
            attention_hbm_bytes_unfused(B, H, S, hd)
    r = (attention_hbm_bytes_unfused(B, H, 8192, hd)
         / attention_hbm_bytes_unfused(B, H, 2048, hd))
    assert 8 < r <= 16


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30), s_blocks=st.integers(1, 4),
       causal=st.booleans(), windowed=st.booleans())
def test_flash_property(seed, s_blocks, causal, windowed):
    S = 64 * s_blocks
    window = 40 if (windowed and causal) else None
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1, 2, 1, S, S, 64)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64)
    ref, _ = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-6)
    # rows are convex combinations of v rows: output bounded by v range
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4


# ---------------------------------------------------------------------------
# model-level routes (models/layers.py dispatch)


def _layer_cfg(**kw):
    from repro.models.common import ModelConfig
    base = dict(name="t", family="dense", n_layers=1, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_train_attention_routes_agree():
    """flash == full == chunked through the same projections, including
    sliding window + softcap + non-zero q_offset (the chunked-prefill
    continuation case)."""
    from repro.models.layers import init_attention, train_attention
    cfg = _layer_cfg(attn_logit_softcap=8.0)
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 64))
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    # window >= qoff + 1 in the offset cases keeps every query row's
    # in-window key set non-empty against the S-long KV chunk: on a fully
    # masked row flash (like the oracle) outputs 0 while full/chunked's
    # all -1e30 softmax degenerates to uniform — a row real prefill
    # continuations never produce (their KV always covers the window)
    for window, qoff in ((None, 0), (32, 0), (96, 64), (None, 64)):
        pos_o = pos + qoff
        outs = {impl: train_attention(p, x, cfg, pos_o, window=window,
                                      q_offset=qoff, impl=impl)
                for impl in ("full", "chunked", "flash")}
        np.testing.assert_allclose(
            np.asarray(outs["chunked"]), np.asarray(outs["full"]),
            rtol=1e-5, atol=1e-5, err_msg=f"chunked w={window} q0={qoff}")
        np.testing.assert_allclose(
            np.asarray(outs["flash"]), np.asarray(outs["full"]),
            rtol=1e-5, atol=1e-5, err_msg=f"flash w={window} q0={qoff}")


def test_train_attention_grads_agree():
    from repro.models.layers import init_attention, train_attention
    cfg = _layer_cfg()
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 64))
    pos = jnp.broadcast_to(jnp.arange(128)[None], (1, 128))

    def loss(p, impl):
        return (train_attention(p, x, cfg, pos, window=48,
                                impl=impl) ** 2).sum()

    g_full = jax.grad(loss)(p, "full")
    g_flash = jax.grad(loss)(p, "flash")
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_flash)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-5, atol=2e-5)


def test_set_train_attn_impl_round_trip():
    from repro.models.layers import get_train_attn_impl, set_train_attn_impl
    prev = get_train_attn_impl()
    try:
        set_train_attn_impl("flash")
        assert get_train_attn_impl() == "flash"
        with pytest.raises(AssertionError):
            set_train_attn_impl("nope")
    finally:
        set_train_attn_impl(prev)


def test_train_attention_cross_kv_override():
    """Cross-attention (kv_override) reaches the flash kernel bidirectional
    (no rope on q, raw kv) and matches the full path."""
    from repro.models.layers import _qkv as qkv_proj
    from repro.models.layers import init_attention, train_attention
    cfg = _layer_cfg()
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64))
    mem = jax.random.normal(jax.random.PRNGKey(2), (1, 96, 64))
    pos = jnp.broadcast_to(jnp.arange(64)[None], (1, 64))
    _, mk, mv = qkv_proj(p, mem, cfg)
    kv = (mk, mv)
    a = train_attention(p, x, cfg, pos, causal=False, kv_override=kv,
                        impl="full")
    b = train_attention(p, x, cfg, pos, causal=False, kv_override=kv,
                        impl="flash")
    np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                               rtol=1e-5, atol=1e-5)
