"""Flash-attention Pallas kernel vs the plain-softmax oracle
(interpret mode; shape x GQA x causality sweep + hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref


def _qkv(key, B, H, Hkv, S, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype) * 0.5
    k = jax.random.normal(ks[1], (B, Hkv, S, hd), dtype) * 0.5
    v = jax.random.normal(ks[2], (B, Hkv, S, hd), dtype) * 0.5
    return q, k, v


@pytest.mark.parametrize("B,H,Hkv,S,hd,bq,bk", [
    (1, 2, 2, 128, 64, 64, 64),     # MHA
    (2, 4, 2, 128, 64, 64, 32),     # GQA 2:1
    (1, 8, 1, 256, 64, 128, 128),   # MQA
    (1, 2, 2, 128, 128, 128, 64),   # head_dim 128
    (2, 2, 1, 64, 32, 64, 64),      # single q block
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(B, H, Hkv, S, hd, bq, bk, causal):
    q, k, v = _qkv(jax.random.PRNGKey(S + hd), B, H, Hkv, S, hd)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    q, k, v = _qkv(jax.random.PRNGKey(0), 1, 2, 2, 128, 64, jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30),
       s_blocks=st.integers(1, 4),
       causal=st.booleans())
def test_flash_property(seed, s_blocks, causal):
    S = 64 * s_blocks
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1, 2, 1, S, 64)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-5, atol=5e-5)
    # rows are convex combinations of v rows: output bounded by v range
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4
