"""Graceful degradation when ``hypothesis`` is not installed.

Mixed test modules (shape sweeps + property tests) import ``given`` /
``settings`` / ``st`` from here instead of from ``hypothesis`` directly, so
a missing dependency skips the property tests instead of killing collection
for the whole module (the per-test equivalent of
``pytest.importorskip("hypothesis")``).  Modules that are *entirely*
property-based call ``pytest.importorskip`` at module level instead.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # degrade: decorated tests become skips
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies`` during collection."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def placeholder():
                pass  # pragma: no cover
            placeholder.__name__ = f.__name__
            placeholder.__doc__ = f.__doc__
            return placeholder
        return deco
