"""The unified curvature pipeline: one compiled train step, flat-shard
estimators, fused update+refresh.

Covers the three contracts the refactor rests on:

  * trajectory parity — the single flag-gated step reproduces the
    pre-refactor two-program loop (grad step vs grad step + out-of-band
    ``update_hessian``) across >= 3 Hessian-refresh intervals, for the
    reference AND Pallas backends, fp32 AND bf16 optimizer state;
  * fused equivalence — ``engine.step_with_refresh`` == ``update_hessian``
    followed by ``step_shards`` (flag set) and == plain ``step_shards``
    (flag clear), both backends;
  * compilation — flipping the refresh flag never grows the jit cache
    (exactly one program), and the lowered step's refresh branch contains
    no rank-1 pad ops (the flat estimators ravel once through the layout;
    the CI fast tier runs this).
"""
import dataclasses
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gpt2 import GPT2_TINY
from repro.core import (clip_by_global_norm, gnb_estimator_sq_flat,
                        gnb_ghat_flat_from_loss, hutchinson_estimator_flat,
                        subsample_batch)
from repro.core.engine import OptimizerEngine
from repro.data import DataConfig, make_source
from repro.models import get_model
from repro.train import TrainerConfig, make_engine, make_schedule, \
    make_train_fns, train_loop
from repro.train.trainer import RNG_TAG_HESS, _fold_rng

SOPHIA_HYPERS = dict(beta1=0.96, beta2=0.99, gamma=0.05, eps=1e-12,
                     weight_decay=0.2, clip_threshold=1.0)

# fp32 compute: parity between the fused sweep and the two-pass refresh is
# then limited by op reassociation ulps, not bf16 forward rounding
CFG32 = dataclasses.replace(GPT2_TINY, dtype="float32")


def _src(B=8, S=32, seed=0):
    return make_source(DataConfig(seq_len=S, global_batch=B,
                                  vocab_size=GPT2_TINY.vocab_size, seed=seed))


def _tc(**kw):
    base = dict(optimizer="sophia_g", peak_lr=5e-4, total_steps=64,
                warmup_steps=4, hess_interval=5, hess_subbatch=4, seed=0)
    base.update(kw)
    return TrainerConfig(**base)


# ---------------------------------------------------------------------------
# engine-level fused equivalence


def _params(key, *, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {"w": jax.random.normal(ks[0], (37, 5), dtype),
            "b": jnp.zeros((11,), dtype),
            "s": jax.random.normal(ks[1], (), dtype)}


def _grads_like(params, key, scale=0.1):
    leaves, treedef = jax.tree.flatten(params)
    ks = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [
        jax.random.normal(k, l.shape, jnp.float32) * scale
        for k, l in zip(ks, leaves)])


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("optimizer,hypers", [
    ("sophia_g", SOPHIA_HYPERS),
    ("adahessian", dict(beta1=0.92, beta2=0.99, eps=1e-8, weight_decay=0.1)),
])
@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16"])
def test_step_with_refresh_matches_two_pass(backend, optimizer, hypers,
                                            state_dtype):
    """Fused update+refresh == update_hessian -> step (flag on) and
    == plain step (flag off), over interleaved steps, to <= 3e-6."""
    sdt = jnp.bfloat16 if state_dtype == "bfloat16" else jnp.float32
    eng = OptimizerEngine(optimizer, hypers=hypers, backend=backend,
                          block=128, state_dtype=sdt)
    key = jax.random.PRNGKey(0)
    p_fused = p_two = _params(key)
    s_fused, s_two = eng.init(p_fused), eng.init(p_two)
    lay = eng.layout(p_fused)
    for t in range(16):  # refreshes at t = 0, 5, 10, 15 -> 3 full intervals
        kt = jax.random.fold_in(key, t)
        refresh = t % 5 == 0
        est_sh = tuple(jnp.square(e) for e in
                       eng.ravel_grads(p_fused,
                                       _grads_like(p_fused,
                                                   jax.random.fold_in(kt, 1))))
        g = _grads_like(p_fused, kt)
        g_sh = eng.ravel_grads(p_fused, g)
        lr = 1e-3 * (1.0 + 0.1 * t)

        p_fused, s_fused = eng.step_with_refresh(
            s_fused, p_fused, g_sh, lr, est_sh, 240.0,
            jnp.asarray(refresh))

        if refresh:  # flat shards accepted directly by update_hessian
            s_two = eng.update_hessian(s_two, est_sh, scale=240.0,
                                       params=p_two)
        p_two, s_two = eng.step_shards(s_two, p_two, g_sh, lr)

        assert int(s_fused.count) == int(s_two.count) == t + 1
        assert int(s_fused.hess_count) == int(s_two.hess_count)
        for a, b in zip(jax.tree.leaves(p_fused), jax.tree.leaves(p_two)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-6, atol=3e-6)
        for a, b in zip(s_fused.m + s_fused.h, s_two.m + s_two.h):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-6, atol=3e-6)
        np.testing.assert_allclose(float(s_fused.clip_fraction),
                                   float(s_two.clip_fraction), atol=1e-7)


def test_step_with_refresh_rejects_non_hessian_families():
    eng = OptimizerEngine("lion", hypers=dict(beta1=0.95, beta2=0.98,
                                              weight_decay=0.1))
    p = _params(jax.random.PRNGKey(0))
    s = eng.init(p)
    g_sh = eng.ravel_grads(p, p)
    with pytest.raises(ValueError, match="hessian-aware"):
        eng.step_with_refresh(s, p, g_sh, 1e-3, g_sh, 1.0, jnp.asarray(True))


# ---------------------------------------------------------------------------
# flat estimators agree with the pytree originals


def test_flat_estimators_match_tree_estimators():
    from repro.core import gnb_estimator_sq, hutchinson_estimator
    from repro.core.engine import ravel_shards

    model = get_model(CFG32)
    params = model.init_params(CFG32, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in _src(B=4).batch_at(0).items()}
    eng = OptimizerEngine("sophia_g", hypers=SOPHIA_HYPERS)
    lay = eng.layout(params)
    rng = jax.random.PRNGKey(7)

    def lf(p):
        return model.logits_fn(CFG32, p, batch)

    sq_tree, b1 = gnb_estimator_sq(lf, params, rng)
    sq_flat, b2 = gnb_estimator_sq_flat(lf, params, rng, lay)
    assert float(b1) == float(b2)
    ref = ravel_shards(lay, sq_tree, dtype=jnp.float32)
    for a, b in zip(ref, sq_flat):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-8)

    # Hutchinson draws its probe per shard, not per leaf: same estimator
    # family (u * Hu, u ~ N(0,I)), different stream — check statistics by
    # construction instead: finite, correct layout, zero on the pad tail
    def sf(p):
        return model.loss_fn(CFG32, p, batch)[0]

    hz = hutchinson_estimator_flat(sf, params, rng, lay)
    assert len(hz) == lay.n_shards
    for e, size, used in zip(hz, lay.shard_sizes, lay.shard_used):
        assert e.shape == (size,) and e.dtype == jnp.float32
        assert np.all(np.isfinite(np.asarray(e)))
        np.testing.assert_array_equal(np.asarray(e[used:]), 0.0)
    # the tree-space estimator exists for the same loss: sanity anchor that
    # the flat one is the same order of magnitude per coordinate
    ht = ravel_shards(lay, hutchinson_estimator(sf, params, rng),
                      dtype=jnp.float32)
    assert 0.1 < (np.mean(np.abs(np.asarray(hz[0])))
                  / max(np.mean(np.abs(np.asarray(ht[0]))), 1e-12)) < 10.0


# ---------------------------------------------------------------------------
# trainer-level trajectory parity vs the pre-refactor two-program loop


def _two_program_loop(cfg, tc, src, steps):
    """The PRE-refactor trainer, reconstructed from public pieces: two
    separate programs (plain grad step / grad step preceded by an
    out-of-band ``update_hessian`` on the estimator sub-batch), sharing the
    unified step's RNG stream derivation AND its loss-impl routing
    (``fused_loss`` -> fused hot path, in-sweep GNB draw, fused-JVP HVP)
    so the trajectories are comparable."""
    model = get_model(cfg)
    engine = make_engine(tc)
    schedule = make_schedule(tc)
    clipper = clip_by_global_norm(tc.grad_clip)
    li = "fused" if tc.fused_loss else None
    # same attention routing as make_train_fns: fused_attn -> flash on the
    # grad path, the custom_jvp twin on the Hutchinson HVP
    ai = (tc.attn_impl if tc.attn_impl != "auto"
          else ("flash" if tc.fused_attn else "auto"))
    hvp_ai = "flash_jvp" if ai == "flash" else ai

    def loss_fn(params, batch):
        return model.loss_fn(cfg, params, batch, loss_impl=li, attn_impl=ai)

    def grad_step(state, batch):
        (loss, _), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        grads, clip_state = clipper.update(grads, state.clip_state)
        g_sh = engine.ravel_grads(state.params, grads)
        lr = schedule(state.opt_state.count)
        params, opt_state = engine.step_shards(state.opt_state, state.params,
                                               g_sh, lr)
        return state._replace(step=state.step + 1, params=params,
                              opt_state=opt_state, clip_state=clip_state), \
            loss

    def hess_step(state, batch):
        rng = _fold_rng(state, RNG_TAG_HESS)
        sub = subsample_batch(batch, tc.hess_subbatch)
        lay = engine.layout(state.params)
        if tc.estimator == "gnb":
            if tc.fused_loss:
                g_sh, scale = gnb_ghat_flat_from_loss(
                    lambda p: model.sampled_loss_fn(cfg, p, sub, rng,
                                                    loss_impl="fused",
                                                    attn_impl=ai),
                    state.params, lay)
                est_sh = tuple(g * g for g in g_sh)
            else:
                est_sh, scale = gnb_estimator_sq_flat(
                    lambda p: model.logits_fn(cfg, p, sub, attn_impl=ai),
                    state.params, rng, lay, mask=sub.get("mask"))
        else:
            hvp_impl = "fused_jvp" if tc.fused_loss else "chunked"
            est_sh = hutchinson_estimator_flat(
                lambda p: model.loss_fn(cfg, p, sub, loss_impl=hvp_impl,
                                        attn_impl=hvp_ai)[0],
                state.params, rng, lay)
            scale = 1.0
        opt_state = engine.update_hessian(state.opt_state, est_sh,
                                          scale=scale, params=state.params)
        return grad_step(state._replace(opt_state=opt_state), batch)

    grad_step = jax.jit(grad_step)
    hess_step = jax.jit(hess_step)
    init_fn, _ = make_train_fns(cfg, tc)
    state = init_fn(jax.random.PRNGKey(tc.seed))
    losses = []
    for t in range(steps):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(t).items()}
        fn = hess_step if t % tc.hess_interval == 0 else grad_step
        state, loss = fn(state, batch)
        losses.append(float(loss))
    return state, losses


@pytest.mark.parametrize("fused_kernel", [False, True])
@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16"])
def test_unified_step_matches_two_program_loop(fused_kernel, state_dtype):
    """16 steps, k=5 (refreshes at 0/5/10/15 -> 3 full intervals): the
    unified flag-gated step tracks the two-program loop to <= 3e-6."""
    _check_unified_vs_two_program(
        _tc(fused_kernel=fused_kernel, state_dtype=state_dtype))


def test_unified_step_matches_two_program_loop_hutchinson():
    """Same parity for the Hutchinson estimator (per-shard probe draws are
    shared by both loops, so trajectories line up exactly).  Tightened:
    with the HVP crossing the fused CE through its custom_jvp rule in BOTH
    loops, the old cross-program chunked-CE fusion wobble (which put a
    blanket 2e-3 on every coordinate) is gone — the estimator branch runs
    the identical kernel sequence, so all but a vanishing fraction of
    coordinates now sit at 3e-6.  What remains above it is not HVP drift
    but clip-flip amplification: an ulp-level program difference flips
    Sophia's clip on a coordinate at exactly rho, which then walks
    ~lr*rho per step.  Contract: >= 99.99% of coordinates within 3e-6,
    ALL within the old 2e-3."""
    s_two, s_uni = _check_unified_vs_two_program(_tc(estimator="hutchinson"))
    a = np.asarray(jax.flatten_util.ravel_pytree(s_two.params)[0])
    b = np.asarray(jax.flatten_util.ravel_pytree(s_uni.params)[0])
    bad = np.abs(b - a) > (3e-6 + 1e-5 * np.abs(a))
    assert bad.mean() <= 1e-4, \
        f"{bad.sum()} / {bad.size} coordinates beyond 3e-6"


def test_unified_step_matches_two_program_loop_fused_attn():
    """Trajectory parity with the flash-attention train path (the
    ``fused_attn=True`` default): 16 steps over 3 full Hessian-refresh
    intervals, Hutchinson estimator — the HVP crosses the attention
    custom_jvp rule AND the fused-CE jvp rule, with no chunked fallback
    (KERNEL_CALLS: the chunked/full jnp paths never trace)."""
    from repro.kernels.fused_ce import KERNEL_CALLS
    tc = _tc(estimator="hutchinson", fused_attn=True)
    KERNEL_CALLS.clear()
    s_two, s_uni = _check_unified_vs_two_program(tc)
    assert KERNEL_CALLS.get("attn_fwd", 0) > 0
    assert KERNEL_CALLS.get("attn_bwd_dq", 0) > 0
    assert KERNEL_CALLS.get("attn_bwd_dkv", 0) > 0
    assert KERNEL_CALLS.get("attn_jvp_rule", 0) > 0, \
        "Hutchinson HVP fell back off the flash custom_jvp twin"
    a = np.asarray(jax.flatten_util.ravel_pytree(s_two.params)[0])
    b = np.asarray(jax.flatten_util.ravel_pytree(s_uni.params)[0])
    bad = np.abs(b - a) > (3e-6 + 1e-5 * np.abs(a))
    assert bad.mean() <= 1e-4, \
        f"{bad.sum()} / {bad.size} coordinates beyond 3e-6"


def _check_unified_vs_two_program(tc, atol=2e-3, rtol=1e-2):
    src = _src()
    steps = 16
    s_two, l_two = _two_program_loop(CFG32, tc, src, steps)
    s_uni, hist = train_loop(CFG32, tc, src, num_steps=steps)
    assert int(s_uni.opt_state.hess_count) == \
        int(s_two.opt_state.hess_count) == 4
    # the two loops are DIFFERENT XLA programs (one cond'd program vs two
    # separate jits): fp32 op reassociation differs by ulps per step and 16
    # Sophia steps (clip nonlinearity) amplify that on a handful of
    # coordinates — the strict <= 3e-6 contract lives at the engine level
    # (test_step_with_refresh_matches_two_pass), where the computation is
    # identical op for op
    np.testing.assert_allclose([h["loss"] for h in hist], l_two,
                               rtol=1e-4, atol=1e-5)
    # atol 2e-3: the two programs fuse the loss sweep differently, so the
    # estimator's grad drifts by ulps more than the old whole-logits path
    # — enough to flip the clip on a coordinate sitting exactly at rho,
    # which then walks ~lr*rho per step (~1e-3 over 16 steps on a handful
    # of coordinates).  The Hutchinson caller additionally asserts the
    # 99.99%-within-3e-6 quantile (see its docstring).
    a = jax.flatten_util.ravel_pytree(s_two.params)[0]
    b = jax.flatten_util.ravel_pytree(s_uni.params)[0]
    np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                               rtol=rtol, atol=atol)
    for x, y in zip(s_two.opt_state.m + s_two.opt_state.h,
                    s_uni.opt_state.m + s_uni.opt_state.h):
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(x, np.float32),
                                   rtol=rtol, atol=atol)
    return s_two, s_uni


# ---------------------------------------------------------------------------
# compilation contracts (the fast-tier CI checks)


def test_unified_step_compiles_one_program():
    """Flipping the traced refresh flag must not grow the jit cache."""
    tc = _tc()
    init_fn, train_step = make_train_fns(GPT2_TINY, tc)
    step = jax.jit(train_step)
    state = init_fn(jax.random.PRNGKey(0))
    src = _src()
    for t in range(3):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(t).items()}
        state, _ = step(state, batch, jnp.asarray(t % 2 == 0))
    assert step._cache_size() == 1


@pytest.mark.parametrize("estimator", ["gnb", "hutchinson"])
def test_refresh_branch_hlo_has_no_rank1_pads(estimator):
    """The lowered unified step (BOTH cond branches are in the HLO of a
    traced-flag program) must contain no rank-1 f32 pad ops: the flat
    estimators ravel once through the layout — the tail pad is a constant
    concatenate operand, never a per-leaf pad — and the hot path kept the
    engine's pad-free contract."""
    tc = _tc(estimator=estimator)
    init_fn, train_step = make_train_fns(GPT2_TINY, tc)
    state_shape = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    batch = {k: jax.ShapeDtypeStruct(jnp.asarray(v).shape,
                                     jnp.asarray(v).dtype)
             for k, v in _src().batch_at(0).items()}
    txt = jax.jit(train_step).lower(
        state_shape, batch, jax.ShapeDtypeStruct((), jnp.bool_)).as_text()
    pads = re.findall(r"stablehlo\.pad[^\n]*tensor<\d+xf32>", txt)
    assert not pads, pads[:5]


def test_grad_accum_metrics_match_unaccumulated():
    """Satellite regression: aux metrics used to be dropped (aux=0, ce from
    the last microbatch only) on the accumulation path."""
    src = _src(B=8)
    h1 = train_loop(CFG32, _tc(grad_accum=1, optimizer="adamw"), src,
                    num_steps=2)[1]
    h2 = train_loop(CFG32, _tc(grad_accum=4, optimizer="adamw"), src,
                    num_steps=2)[1]
    for a, b in zip(h1, h2):
        assert set(a) == set(b)
        np.testing.assert_allclose(b["ce"], a["ce"], rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(b["aux"], a["aux"], rtol=2e-3, atol=1e-4)
        np.testing.assert_allclose(b["loss"], a["loss"], rtol=2e-3,
                                   atol=2e-3)


def test_rng_streams_are_domain_separated():
    """Satellite regression: the compression stream used to be
    ``fold_in(rng, step + 2**20)`` — identical to the estimator stream
    ``fold_in(rng, step)`` once step >= 2**20."""
    from repro.train.trainer import (RNG_TAG_COMPRESS, RNG_TAG_HESS,
                                     RNG_TAG_HESS_COMPRESS)
    from repro.train.train_state import TrainState

    def at(step, tag):
        st = TrainState(step=jnp.asarray(step, jnp.int32), params=(),
                        opt_state=(), clip_state=(),
                        rng=jax.random.PRNGKey(0))
        return np.asarray(_fold_rng(st, tag))

    tags = (RNG_TAG_HESS, RNG_TAG_COMPRESS, RNG_TAG_HESS_COMPRESS)
    seen = set()
    for step in (0, 1, (1 << 20), (1 << 20) + 1, (1 << 21)):
        for tag in tags:
            key = at(step, tag).tobytes()
            assert key not in seen, (step, tag)
            seen.add(key)


def test_compress_hess_trains():
    """Stateless int8 compression of the estimator sub-batch gradient keeps
    the run healthy (mesh-less path: identical math on the whole shard)."""
    src = _src()
    tc = _tc(compress_grads=True, compress_hess=True)
    state, hist = train_loop(GPT2_TINY, tc, src, num_steps=12)
    assert int(state.opt_state.hess_count) == 3
    assert np.isfinite(hist[-1]["loss"])
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.1
