"""Additional unit coverage: schedules, layers, sharding rules, RG-LRU
oracle, optimizer chain, fused-AdamW trainer parity."""
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import apply_updates, chain, clip_by_global_norm, global_norm
from repro.core.schedule import (inverse_sqrt, linear_warmup_cosine,
                                 linear_warmup_linear_decay)
from repro.core.baselines import adamw
from repro.models.layers import apply_rope, cross_entropy, rms_norm, _softcap


# --------------------------------------------------------------------------
# schedules (paper protocol)


def test_cosine_schedule_endpoints():
    s = linear_warmup_cosine(3e-4, total_steps=1000, warmup_steps=100,
                             final_lr_ratio=0.05)
    assert float(s(0)) == 0.0
    np.testing.assert_allclose(float(s(100)), 3e-4, rtol=1e-5)
    np.testing.assert_allclose(float(s(1000)), 0.05 * 3e-4, rtol=1e-4)
    # monotone decay after warmup
    vals = [float(s(t)) for t in range(100, 1000, 100)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_linear_and_invsqrt_schedules():
    lin = linear_warmup_linear_decay(1e-3, 100, warmup_steps=10)
    assert float(lin(100)) <= 1e-8
    isq = inverse_sqrt(1e-3, warmup_steps=100)
    np.testing.assert_allclose(float(isq(400)), 1e-3 / 2, rtol=1e-5)


# --------------------------------------------------------------------------
# layers


def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
    r = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(r), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i - j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))

    def dot_at(i, j):
        qi = apply_rope(q, jnp.full((1, 1), i))
        kj = apply_rope(k, jnp.full((1, 1), j))
        return float(jnp.sum(qi * kj))

    np.testing.assert_allclose(dot_at(3, 1), dot_at(7, 5), rtol=1e-4)


def test_softcap_bounds():
    x = jnp.linspace(-1e4, 1e4, 101)
    y = _softcap(x, 50.0)
    assert float(jnp.max(jnp.abs(y))) <= 50.0
    # near-identity for small logits
    np.testing.assert_allclose(float(_softcap(jnp.asarray(0.5), 50.0)), 0.5,
                               atol=1e-3)


def test_cross_entropy_matches_manual():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 8))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, 8)
    ce = float(cross_entropy(logits, labels))
    lp = jax.nn.log_softmax(logits, -1)
    manual = -np.take_along_axis(np.asarray(lp),
                                 np.asarray(labels)[..., None], -1).mean()
    np.testing.assert_allclose(ce, manual, rtol=1e-6)


def test_rms_norm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32)) * 7.0
    y = rms_norm(x, jnp.zeros((32,)))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-2)


# --------------------------------------------------------------------------
# sharding rule table (mock mesh — pure logic)


def _mock_mesh(data=4, model=2, pod=None):
    names = (("pod",) if pod else ()) + ("data", "model")
    shape = {"data": data, "model": model}
    if pod:
        shape["pod"] = pod
    return SimpleNamespace(axis_names=names, shape=shape)


def test_param_rules_basic():
    from repro.distributed.sharding import _spec_for
    mesh = _mock_mesh()
    # wq (stacked): [layers, D, H*hd] -> (None, data, model)
    s = _spec_for("['layers']['attn']['wq']", (8, 64, 32), 1, mesh, True)
    assert s == P(None, "data", "model")
    # embedding: vocab over model, d over data
    s = _spec_for("['embed']['tok']", (128, 64), 0, mesh, True)
    assert s == P("model", "data")
    # norm scale: replicated
    s = _spec_for("['layers']['ln1']['scale']", (8, 64), 1, mesh, True)
    assert s == P()


def test_param_rules_divisibility_fallback():
    from repro.distributed.sharding import _spec_for
    mesh = _mock_mesh(data=4, model=16)
    # H*hd = 24 not divisible by 16 -> that dim replicated
    s = _spec_for("['attn']['wq']", (64, 24), 0, mesh, True)
    assert s == P("data")


def test_param_rules_multipod_composite_axis():
    from repro.distributed.sharding import _spec_for
    mesh = _mock_mesh(data=4, model=2, pod=2)
    s = _spec_for("['mlp']['w_up']", (64, 32), 0, mesh, True)
    assert s == P(("pod", "data"), "model")


def test_no_fsdp_replicates_data_dim():
    from repro.distributed.sharding import _spec_for
    mesh = _mock_mesh()
    s = _spec_for("['attn']['wq']", (64, 32), 0, mesh, False)
    assert s == P(None, "model")


# --------------------------------------------------------------------------
# RG-LRU associative scan vs naive loop oracle


def test_rg_lru_matches_loop():
    from repro.models.griffin import rg_lru
    B, S, W = 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    u = jax.random.normal(ks[0], (B, S, W))
    rg = jax.nn.sigmoid(jax.random.normal(ks[1], (B, S, W)))
    ig = jax.nn.sigmoid(jax.random.normal(ks[2], (B, S, W)))
    lam = jnp.linspace(2.0, 5.0, W)
    h0 = jax.random.normal(ks[3], (B, W))
    ys, last = rg_lru(u, rg, ig, lam, h0)

    log_a = 8.0 * rg * jax.nn.log_sigmoid(lam)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1 - jnp.exp(2 * log_a), 0, 1)) * (ig * u)
    h = h0
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        np.testing.assert_allclose(np.asarray(ys[:, t]), np.asarray(h),
                                   rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(last), np.asarray(h), rtol=2e-5,
                               atol=2e-5)


# --------------------------------------------------------------------------
# rwkv decay clamp


def test_rwkv_decay_clamped():
    from repro.models.rwkv import _decay, LOG_DECAY_CLAMP
    tm = {"w0": jnp.array([10.0, -10.0]), "wa": jnp.zeros((2, 64)),
          "wb": jnp.zeros((64, 2))}
    lw = _decay(tm, jnp.zeros((1, 1, 2)))
    assert float(jnp.min(lw)) >= -LOG_DECAY_CLAMP
    assert float(jnp.max(lw)) <= -1e-7


# --------------------------------------------------------------------------
# core utilities


def test_chain_composition_and_global_norm():
    opt = chain(clip_by_global_norm(1.0), adamw(1e-2))
    p = {"w": jnp.ones((4,))}
    s = opt.init(p)
    g = {"w": jnp.full((4,), 100.0)}
    u, s = opt.update(g, s, p)
    assert np.isfinite(float(global_norm(u)))
    p2 = apply_updates(p, u)
    assert p2["w"].dtype == p["w"].dtype


def test_adamw_fused_trainer_parity():
    from repro.configs.gpt2 import GPT2_TINY
    from repro.data import DataConfig, make_source
    from repro.train import TrainerConfig, train_loop

    src = make_source(DataConfig(seq_len=32, global_batch=4,
                                 vocab_size=GPT2_TINY.vocab_size, seed=0))
    kw = dict(optimizer="adamw", peak_lr=1e-3, total_steps=40,
              warmup_steps=2, weight_decay=0.1, seed=0)
    s1, _ = train_loop(GPT2_TINY, TrainerConfig(**kw), src, num_steps=5)
    s2, _ = train_loop(GPT2_TINY, TrainerConfig(fused_kernel=True, **kw),
                       src, num_steps=5)
    a = jax.flatten_util.ravel_pytree(s1.params)[0]
    b = jax.flatten_util.ravel_pytree(s2.params)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-2,
                               atol=5e-3)


# --------------------------------------------------------------------------
# hlo_analysis collective parsing on a fixed module


def test_collective_parse_fixed_module():
    from repro.launch.hlo_analysis import analyze_hlo
    hlo = """
ENTRY %main (a: f32[16,32]) -> f32[16,32] {
  %a = f32[16,32]{1,0} parameter(0)
  %ar = f32[16,32]{1,0} all-reduce(%a), to_apply=%add
  ROOT %r = f32[16,32]{1,0} add(%ar, %a)
}
"""
    acc = analyze_hlo(hlo)
    assert acc["coll"]["all-reduce"] == 16 * 32 * 4
    assert acc["coll_total"] == 2048
