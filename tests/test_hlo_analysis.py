"""The HLO cost analyzer vs ground truth (unrolled graphs / analytics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo, _numel, _type_bytes


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_type_bytes():
    assert _type_bytes("f32[16,32]{1,0}") == 16 * 32 * 4
    assert _type_bytes("bf16[8]") == 16
    assert _type_bytes("(f32[4], s32[2,2])") == 16 + 16
    assert _type_bytes("pred[10]") == 10


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, b)
    acc = analyze_hlo(c.as_text())
    assert abs(acc["flops"] - 2 * 64 * 128 * 256) / (2 * 64 * 128 * 256) < 0.05


def test_scan_trip_count_multiplies():
    """Scan flops scale linearly with layer count (the XLA bug we fix)."""
    def make(n):
        ws = jax.ShapeDtypeStruct((n, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((32, 64), jnp.float32)

        def f(w, x):
            def body(h, wl):
                return jnp.tanh(h @ wl), None
            h, _ = jax.lax.scan(body, x, w)
            return h.sum()
        return analyze_hlo(_compile(jax.grad(f), ws, x).as_text())["flops"]

    f4, f8 = make(4), make(8)
    assert 1.8 < f8 / f4 < 2.2, (f4, f8)


def test_scan_matches_unrolled():
    def make(n, scan):
        ws = jax.ShapeDtypeStruct((n, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((32, 64), jnp.float32)

        def f(w, x):
            if scan:
                def body(h, wl):
                    return jnp.tanh(h @ wl), None
                h, _ = jax.lax.scan(body, x, w)
            else:
                h = x
                for i in range(n):
                    h = jnp.tanh(h @ w[i])
            return h.sum()
        return analyze_hlo(_compile(jax.grad(f), ws, x).as_text())["flops"]

    s, u = make(6, True), make(6, False)
    assert abs(s - u) / u < 0.25, (s, u)


def test_nested_scans():
    """Inner scan's trips multiply through the outer scan."""
    def f(w, x):
        def outer(h, wl):
            def inner(h2, _):
                return jnp.tanh(h2 @ wl), None
            h2, _ = jax.lax.scan(inner, h, None, length=3)
            return h2, None
        h, _ = jax.lax.scan(outer, x, w)
        return h.sum()

    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    acc = analyze_hlo(_compile(f, ws, x).as_text())
    expect = 2 * 32 * 64 * 64 * 3 * 4  # dot flops x inner x outer
    assert 0.8 < acc["flops"] / expect < 1.3, (acc["flops"], expect)
