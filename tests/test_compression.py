"""int8 gradient compression: error bounds, error feedback, wire size."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.distributed.compression import (GradCompressor, _quantize,
                                           compressed_bytes)


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    _, scale, deq = _quantize(x, 256, jax.random.PRNGKey(1))
    err = np.abs(np.asarray(deq - x))
    # error per element <= scale (one quantization bin, stochastic rounding)
    bound = np.repeat(np.asarray(scale)[:, 0], 256)[:1000]
    assert np.all(err <= bound + 1e-7)


def test_stochastic_rounding_unbiased():
    x = jnp.full((256,), 0.3)
    keys = jax.random.split(jax.random.PRNGKey(2), 500)
    deqs = jax.vmap(lambda k: _quantize(x, 256, k)[2])(keys)
    np.testing.assert_allclose(float(deqs.mean()), 0.3, atol=5e-3)


def test_error_feedback_carries_residual():
    comp = GradCompressor(block=64)
    g = {"w": jax.random.normal(jax.random.PRNGKey(3), (100,))}
    st_ = comp.init(g)
    deq, st2 = comp.roundtrip(g, st_, jax.random.PRNGKey(4))
    resid = np.asarray(st2.error["w"])
    np.testing.assert_allclose(resid, np.asarray(g["w"]) - np.asarray(deq["w"]),
                               atol=1e-6)


def test_error_feedback_preserves_signal_over_time():
    """Sum of dequantized grads tracks sum of true grads (EF property)."""
    comp = GradCompressor(block=64)
    true_sum = np.zeros(64)
    deq_sum = np.zeros(64)
    st_ = comp.init({"w": jnp.zeros((64,))})
    for i in range(50):
        g = {"w": jnp.asarray(np.random.default_rng(i).normal(size=64) * 0.01)}
        deq, st_ = comp.roundtrip(g, st_, jax.random.PRNGKey(i))
        true_sum += np.asarray(g["w"])
        deq_sum += np.asarray(deq["w"])
    # residual is bounded by one quantization step, not growing with T
    resid = np.abs(true_sum - deq_sum)
    assert np.max(resid) < 0.01, np.max(resid)


def test_wire_bytes_4x_smaller_than_fp32():
    g = {"w": jnp.zeros((1 << 20,))}
    wire = compressed_bytes(g, block=256)
    fp32 = (1 << 20) * 4
    assert wire < fp32 / 3.5


@settings(max_examples=20, deadline=None)
@given(n=st.integers(min_value=1, max_value=5000),
       scale=st.floats(min_value=1e-6, max_value=1e3),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_quantize_property(n, scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale
    _, _, deq = _quantize(x, 256, jax.random.PRNGKey(seed + 1))
    rel = float(jnp.max(jnp.abs(deq - x)) / (jnp.max(jnp.abs(x)) + 1e-12))
    assert rel <= 1.0 / 127 + 1e-3  # one int8 bin of the block max


@settings(max_examples=20, deadline=None, derandomize=True)
@given(n=st.integers(min_value=1, max_value=4000),
       mag=st.floats(min_value=1e-4, max_value=1e3),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_quantize_nearest_error_at_most_half_scale(n, mag, seed):
    """rng=None selects round-to-nearest: per-element dequantization error
    is bounded by scale/2 (one half of a quantization bin)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * mag
    _, scale, deq = _quantize(x, 256, None)
    bound = np.repeat(np.asarray(scale)[:, 0], 256)[:n] / 2
    assert np.all(np.abs(np.asarray(deq - x)) <= bound * (1 + 1e-6) + 1e-12)


@settings(max_examples=8, deadline=None, derandomize=True)
@given(v=st.floats(min_value=0.05, max_value=0.95))
def test_stochastic_rounding_unbiased_in_expectation(v):
    """Fixed-seed mean test: E[deq] == x under stochastic rounding.  The
    block max pins scale = 1.0, so every other element sits at fractional
    bin position v and must round up with probability exactly v."""
    x = jnp.concatenate([jnp.full((255,), v), jnp.full((1,), 127.0)])
    seeds = jnp.arange(400, dtype=jnp.int32)
    deqs = jax.vmap(lambda s: _quantize(x, 256, s)[2])(seeds)
    mean = float(deqs[:, :255].mean())
    assert abs(mean - v) < 8e-3  # 5 sigma of the 400x255-sample mean


@settings(max_examples=20, deadline=None, derandomize=True)
@given(n=st.integers(min_value=1, max_value=2000),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_error_feedback_residual_exactly_reconstructs(n, seed):
    """Round-to-nearest residual is *exact* in fp32: deq != 0 implies
    deq/2 <= |x| <= 2|deq| (Sterbenz), so x - deq carries no rounding and
    dequant + residual reconstructs the fp32 input bit-for-bit — the
    error-feedback loop loses nothing."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 3.0
    _, _, deq = _quantize(x, 256, None)
    resid = x - deq  # what the compressor stores as error feedback
    np.testing.assert_array_equal(np.asarray(deq + resid), np.asarray(x))


def test_quantize_core_is_shared_with_kv_cache():
    """The quantizer the serve tier's int8 KV cache uses (repro.quant) is
    the SAME object compression imports — the hypothesis properties above
    cover both consumers.  Deterministic mode (rng=None, what the KV path
    uses) keeps the tighter half-bin bound."""
    from repro import quant

    assert _quantize is quant._quantize
    x = jax.random.normal(jax.random.PRNGKey(7), (512,)) * 2.0
    _, scale, deq = quant._quantize(x, 128, None)
    err = np.abs(np.asarray(deq - x))
    bound = np.repeat(np.asarray(scale)[:, 0], 128) / 2
    assert np.all(err <= bound + 1e-7)
