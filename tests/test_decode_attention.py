"""Pallas decode-attention kernel vs its pure-jnp oracle, and the kernel
wired through the model decode path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.ref import decode_attention_ref
from repro.models import get_model
from repro.models.layers import set_decode_attn_impl

pytestmark = pytest.mark.serve

TOL = 3e-6


def _rand(N, H, Hkv, C, hd, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (N, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (N, C, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (N, C, Hkv, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("N,H,Hkv,C,hd,page", [
    (3, 4, 2, 32, 16, 8),     # GQA
    (2, 2, 1, 64, 8, 16),     # MQA
    (4, 8, 8, 16, 32, 16),    # MHA, single page
    (1, 4, 4, 48, 64, 8),     # non-power-of-two page count
])
def test_kernel_matches_oracle(N, H, Hkv, C, hd, page):
    q, k, v = _rand(N, H, Hkv, C, hd)
    pos = (jnp.arange(N, dtype=jnp.int32) * 7 + 3) % C
    got = decode_attention_pallas(q, k, v, pos, page_len=page)
    want = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=TOL)


def test_kernel_ring_wraparound():
    """Positions beyond C: the ring has wrapped; stale entries must mask."""
    N, H, Hkv, C, hd = 2, 4, 2, 16, 16
    q, k, v = _rand(N, H, Hkv, C, hd, seed=1)
    pos = jnp.array([C + 3, 5 * C + 11], jnp.int32)
    got = decode_attention_pallas(q, k, v, pos, page_len=8)
    want = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=TOL)


@pytest.mark.parametrize("window", [4, 12])
def test_kernel_sliding_window(window):
    N, H, Hkv, C, hd = 2, 4, 1, 32, 16
    q, k, v = _rand(N, H, Hkv, C, hd, seed=2)
    pos = jnp.array([9, 27], jnp.int32)
    got = decode_attention_pallas(q, k, v, pos, page_len=8, window=window)
    want = decode_attention_ref(q, k, v, pos, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=TOL)


def test_kernel_softcap_and_traced_window():
    N, H, Hkv, C, hd = 2, 4, 2, 32, 16
    q, k, v = _rand(N, H, Hkv, C, hd, seed=3)
    pos = jnp.array([6, 30], jnp.int32)
    got = jax.jit(lambda *a: decode_attention_pallas(
        *a[:-1], window=a[-1], page_len=8, softcap=50.0))(q, k, v, pos,
                                                          jnp.int32(10))
    want = decode_attention_ref(q, k, v, pos, window=10, softcap=50.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=TOL)


def test_unwritten_slots_fully_masked():
    """A slot at position 0 attends only to its own just-written token even
    when the rest of the ring holds garbage."""
    N, H, Hkv, C, hd = 2, 2, 2, 16, 8
    q, k, v = _rand(N, H, Hkv, C, hd, seed=4)
    pos = jnp.array([0, 0], jnp.int32)
    got = decode_attention_pallas(q, k, v, pos, page_len=8)
    # only index 0 is valid -> output is exactly v[:, 0]
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(v[:, 0].astype(got.dtype)),
                               atol=TOL)


@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_kernel_int8_matches_dequantized_ref(dt):
    """Kernel vs oracle on the SAME int8 cache + scales: both dequantize
    page-by-page with one rounding into the compute dtype, so the bound
    stays as tight as the bf16 case.  Positions cross >= 2 page
    boundaries (page_len=8, pos up to 41)."""
    from repro.quant import quantize_kv

    N, H, Hkv, C, hd = 3, 4, 2, 48, 16
    q, k, v = _rand(N, H, Hkv, C, hd, seed=5)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    pos = jnp.array([17, 41, 30], jnp.int32)
    got = decode_attention_pallas(q.astype(dt), kq, vq, pos, page_len=8,
                                  k_scale=ks, v_scale=vs)
    want = decode_attention_ref(q.astype(dt), kq, vq, pos, k_scale=ks,
                                v_scale=vs)
    atol = TOL if dt == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_kernel_int8_parity_vs_unquantized_oracle(dt):
    """End-to-end quantization error: int8 kernel vs the bf16-oracle on
    the ORIGINAL unquantized cache stays within 1e-2 (the serve-tier
    acceptance bound), again crossing multiple page boundaries."""
    from repro.quant import quantize_kv

    N, H, Hkv, C, hd = 2, 4, 2, 64, 32
    q, k, v = _rand(N, H, Hkv, C, hd, seed=6)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    pos = jnp.array([63, 37], jnp.int32)
    got = decode_attention_pallas(q.astype(dt), kq, vq, pos, page_len=16,
                                  k_scale=ks, v_scale=vs)
    want = decode_attention_ref(q.astype(dt), k.astype(dt), v.astype(dt),
                                pos)
    assert float(np.max(np.abs(np.asarray(got, np.float32)
                               - np.asarray(want, np.float32)))) <= 1e-2


def test_quantize_kv_roundtrip_within_half_bin():
    """Deterministic round-to-nearest: |deq - x| <= scale/2 per token."""
    from repro.quant import quantize_kv

    k = jax.random.normal(jax.random.PRNGKey(9), (2, 32, 2, 16)) * 4.0
    kq, ks = quantize_kv(k)
    deq = np.asarray(kq, np.float32) * np.asarray(ks)[..., None, None]
    err = np.abs(deq - np.asarray(k, np.float32))
    assert np.all(err <= np.asarray(ks)[..., None, None] / 2 + 1e-6)


@pytest.mark.parametrize("arch", ["yi-6b", "gemma2-9b",
                                  "recurrentgemma-2b"])
def test_decode_slots_pallas_matches_xla(arch):
    """The kernel wired through decode_slots reproduces the jnp path
    (dense RoPE/GQA, gemma2 softcap + alternating windows, griffin ring)."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    N, C = 3, 32
    state = model.init_slots(cfg, N, C)
    toks = jax.random.randint(jax.random.PRNGKey(1), (N, 1), 0,
                              cfg.vocab_size)
    pos = jnp.array([0, 3, 17], jnp.int32)
    lg_x, st_x = model.decode_slots(cfg, params, state, toks, pos)
    set_decode_attn_impl("pallas")
    try:
        lg_p, st_p = model.decode_slots(cfg, params, state, toks, pos)
    finally:
        set_decode_attn_impl("xla")
    np.testing.assert_allclose(np.asarray(lg_x), np.asarray(lg_p),
                               rtol=1e-5, atol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5), st_x, st_p)


def test_decode_slots_pallas_matches_xla_int8():
    """Same wiring check with an int8 KV cache: the kernel's in-register
    dequant (scale planes streamed per page) reproduces the XLA read
    path's full-cache dequant.  State compares with the same tolerance as
    the bf16 case — the write path is shared code, but XLA may fuse the
    K/V projection differently per consumer (an ulp in a scale)."""
    cfg = dataclasses.replace(get_config("yi-6b", smoke=True),
                              dtype="float32", kv_dtype="int8")
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    N, C = 3, 32
    state = model.init_slots(cfg, N, C)
    toks = jax.random.randint(jax.random.PRNGKey(1), (N, 1), 0,
                              cfg.vocab_size)
    pos = jnp.array([0, 3, 17], jnp.int32)
    lg_x, st_x = model.decode_slots(cfg, params, state, toks, pos)
    set_decode_attn_impl("pallas")
    try:
        lg_p, st_p = model.decode_slots(cfg, params, state, toks, pos)
    finally:
        set_decode_attn_impl("xla")
    np.testing.assert_allclose(np.asarray(lg_x), np.asarray(lg_p),
                               rtol=1e-5, atol=1e-5)

    def cmp(a, b):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.int8:   # one quantization step of slack
            assert np.max(np.abs(a.astype(np.int32)
                                 - b.astype(np.int32))) <= 1
        else:
            np.testing.assert_allclose(a.astype(np.float32),
                                       b.astype(np.float32),
                                       rtol=1e-5, atol=1e-5)
    jax.tree.map(cmp, st_x, st_p)
