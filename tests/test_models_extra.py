"""Deeper model invariants: window masking, M-RoPE decode, MoE gating,
token shift, embed scaling, encdec cross-attention."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ModelConfig, get_model


def test_local_window_actually_masks():
    """A token beyond the window cannot influence a local layer's output."""
    from repro.models.layers import full_attention, init_attention
    cfg = ModelConfig(name="w", family="dense", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=64,
                      dtype="float32")
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32))
    pos = jnp.broadcast_to(jnp.arange(32)[None], (1, 32))
    base = full_attention(p, x, cfg, pos, window=8)
    # perturb token 0; positions >= 8 must be unaffected
    x2 = x.at[:, 0].add(100.0)
    pert = full_attention(p, x2, cfg, pos, window=8)
    np.testing.assert_allclose(np.asarray(base[:, 8:]),
                               np.asarray(pert[:, 8:]), atol=1e-5)
    assert not np.allclose(np.asarray(base[:, 1:8]), np.asarray(pert[:, 1:8]))


def test_causality():
    """Future tokens never influence past logits (all families)."""
    for arch in ("yi-6b", "rwkv6-7b", "recurrentgemma-2b"):
        cfg = get_config(arch, smoke=True)
        cfg = dataclasses.replace(cfg, dtype="float32")
        model = get_model(cfg)
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                                  cfg.vocab_size)
        out1 = model.forward(cfg, params, toks)[0]
        toks2 = toks.at[0, -1].set((toks[0, -1] + 7) % cfg.vocab_size)
        out2 = model.forward(cfg, params, toks2)[0]
        np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                                   np.asarray(out2[:, :-1]),
                                   atol=2e-4, err_msg=arch)


def test_moe_topk_gates_normalized():
    from repro.models.moe import init_moe, moe_ffn_gspmd
    cfg = get_config("deepseek-moe-16b", smoke=True)
    # with capacity ample and experts = identity-ish, the combined output
    # magnitude tracks the input (gates sum to 1 after renorm)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = moe_ffn_gspmd(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(float(jnp.abs(out).sum()))


def test_moe_every_other_layer_structure():
    cfg = get_config("llama4-maverick-400b-a17b", smoke=True)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    groups = params["layers"]
    assert "dense" in groups and "moe" in groups
    n_groups = jax.tree.leaves(groups["dense"])[0].shape[0]
    assert n_groups == cfg.n_layers // 2
    assert groups["moe"]["moe"]["w_gate"].shape[1] == cfg.n_experts


def test_rwkv_token_shift():
    from repro.models.rwkv import _token_shift
    x = jnp.arange(12.0).reshape(1, 4, 3)
    prev = jnp.full((1, 3), -1.0)
    y = _token_shift(x, prev)
    np.testing.assert_array_equal(np.asarray(y[0, 0]), [-1, -1, -1])
    np.testing.assert_array_equal(np.asarray(y[0, 1:]), np.asarray(x[0, :-1]))


def test_gemma_embed_scaling():
    from repro.models.layers import embed, init_embedding
    cfg = get_config("gemma2-9b", smoke=True)
    p = init_embedding(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((1, 4), jnp.int32)
    x = embed(p, toks, cfg)
    raw = p["tok"][0]
    np.testing.assert_allclose(
        np.asarray(x[0, 0], np.float32),
        np.asarray(raw * np.sqrt(cfg.d_model), np.float32), rtol=1e-2)


def test_encdec_cross_attention_sees_encoder():
    """Changing the source frames changes decoder logits."""
    cfg = get_config("seamless-m4t-medium", smoke=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                              cfg.vocab_size)
    f1 = jax.random.normal(jax.random.PRNGKey(2), (1, 12, cfg.d_model))
    f2 = f1 + 1.0
    l1 = model.forward(cfg, params, toks, frames=f1)[0]
    l2 = model.forward(cfg, params, toks, frames=f2)[0]
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_vlm_patch_injection_changes_output():
    cfg = get_config("qwen2-vl-7b", smoke=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                              cfg.vocab_size)
    pe1 = jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model))
    l1 = model.forward(cfg, params, toks, patch_embeds=pe1)[0]
    l2 = model.forward(cfg, params, toks, patch_embeds=pe1 * 2.0)[0]
    assert not np.allclose(np.asarray(l1), np.asarray(l2))
    # text-only positions past the patches still get token embeddings
    assert bool(jnp.all(jnp.isfinite(l1)))


def test_padded_vocab_lane_aligned():
    for arch in ("seamless-m4t-medium", "yi-6b"):
        cfg = get_config(arch)
        assert cfg.padded_vocab % 128 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab - cfg.vocab_size < 128


def test_griffin_pattern_counts():
    from repro.models.griffin import n_groups, n_tail
    cfg = get_config("recurrentgemma-2b")
    assert 3 * n_groups(cfg) + n_tail(cfg) == cfg.n_layers == 26
    assert n_tail(cfg) == 2  # 8 groups of (rec,rec,attn) + 2 tail rec
