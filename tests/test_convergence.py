"""Reproduction of the paper's motivating toy (Fig 2) + theory (Sec 4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apply_updates, exact_diag_hessian, sophia
from repro.core.baselines import sgd, signgd

pytestmark = pytest.mark.slow  # excluded from the fast tier-1 default


def paper_toy_loss(theta):
    """Footnote 1: L1 sharp, L2 flat."""
    t1, t2 = theta[0], theta[1]
    L1 = 8 * (t1 - 1) ** 2 * (1.3 * t1 ** 2 + 2 * t1 + 1)
    L2 = 0.5 * (t2 - 4) ** 2
    return L1 + L2


def _run(update_fn, theta0, steps):
    theta = jnp.asarray(theta0, jnp.float32)
    for _ in range(steps):
        theta = update_fn(theta)
    return theta


def test_toy_2d_paper_fig2():
    """Sophia-style clipped-Newton beats GD/SignGD/Newton on the paper toy.

    Start in the global basin's NEGATIVE-curvature region (L1'' < 0 for
    t1 in (0, ~0.4)): Newton runs uphill to the local max at t1 = 0,
    Sophia's clip falls back to sign steps, crosses into the convex
    valley, then Newton-converges to the minimum (1, 4).
    """
    theta0 = [0.23, 0.0]  # 0.23: SignGD's 0.1-steps can't land exactly on 1
    steps = 50
    grad = jax.grad(paper_toy_loss)

    # GD: lr limited by the sharpness at the minimum (L1''(1) ~ 69)
    gd = _run(lambda t: t - 0.01 * grad(t), theta0, steps)
    # SignGD (simplified Adam)
    sg = _run(lambda t: t - 0.1 * jnp.sign(grad(t)), theta0, steps)

    # vanilla Newton: converges to the local MAX at t1 = 0
    def newton_step(t):
        h = exact_diag_hessian(paper_toy_loss, t)
        return t - grad(t) / h

    nw = _run(newton_step, theta0, steps)

    # Sophia (deterministic, exact diagonal Hessian, per-coord clip) — eq (4)
    def sophia_step(t):
        h = exact_diag_hessian(paper_toy_loss, t)
        u = jnp.clip(grad(t) / jnp.maximum(h, 1e-12), -1.0, 1.0)
        return t - 0.5 * u

    so = _run(sophia_step, theta0, steps)

    l_gd = float(paper_toy_loss(gd))
    l_sg = float(paper_toy_loss(sg))
    l_so = float(paper_toy_loss(so))
    # Sophia reaches (1, 4); GD crawls in the flat dim; SignGD bounces
    assert l_so < 1e-3, l_so
    assert l_so < l_gd and l_so < l_sg
    np.testing.assert_allclose(np.asarray(so), [1.0, 4.0], atol=0.05)
    # Newton is trapped at the sharp-dim local max (t1 ~ 0, loss ~ 8 + flat)
    assert abs(float(nw[0])) < 0.05


@pytest.mark.parametrize("kappa", [1e2, 1e6])
def test_condition_number_free_convergence(kappa):
    """Thm 4.3 flavor: clipped-Newton steps don't grow with kappa."""
    mu = 1.0

    def loss(t):
        return 0.5 * (kappa * t[0] ** 2 + mu * t[1] ** 2)

    grad = jax.grad(loss)
    h = jnp.array([kappa, mu])
    theta = jnp.array([1.0, 1.0])
    steps = 0
    while float(loss(theta)) > 1e-8 and steps < 200:
        u = jnp.clip(grad(theta) / jnp.maximum(h, 1e-12), -10.0, 10.0)
        theta = theta - 0.5 * u
        steps += 1
    # Newton-with-clip converges linearly regardless of conditioning
    assert steps <= 40, (kappa, steps)


def test_signgd_depends_on_condition_number():
    """Thm D.12: SignGD's steps scale with sqrt(beta/mu)."""
    def steps_to(eps, kappa, lr):
        def loss(t):
            return 0.5 * (kappa * t[0] ** 2 + t[1] ** 2)
        grad = jax.grad(loss)
        t = jnp.array([0.0, jnp.sqrt(2.0 / 1.0)])  # flat-dim init
        for i in range(10000):
            if float(loss(t)) <= eps:
                return i
            t = t - lr * jnp.sign(grad(t))
        return 10000

    # lr must shrink like 1/sqrt(kappa) to converge in the sharp dim,
    # making flat-dim progress linear in sqrt(kappa)
    s_small = steps_to(1e-2, 1e2, lr=np.sqrt(8 * 1e-2 / 1e2))
    s_large = steps_to(1e-2, 1e4, lr=np.sqrt(8 * 1e-2 / 1e4))
    assert s_large > 5 * s_small, (s_small, s_large)


def test_sophia_trains_tiny_lm():
    """End-to-end: Sophia-G reduces LM loss on synthetic data quickly."""
    from repro.configs.gpt2 import GPT2_TINY
    from repro.data import DataConfig, make_source
    from repro.train import TrainerConfig, train_loop

    cfg = GPT2_TINY
    tc = TrainerConfig(optimizer="sophia_g", peak_lr=1e-3, total_steps=60,
                       warmup_steps=5, hess_interval=10, hess_subbatch=4,
                       grad_clip=1.0, seed=0)
    src = make_source(DataConfig(seq_len=64, global_batch=8,
                                 vocab_size=cfg.vocab_size, seed=0))
    _, hist = train_loop(cfg, tc, src, num_steps=60)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.5, (first, last)
