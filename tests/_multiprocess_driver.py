"""Subprocess driver for the 2-process ``jax.distributed`` tier.

Run by tests/test_multiprocess.py.  Unlike tests/_distributed_driver.py
(one process simulating 8 devices), every invocation here is ONE process
of a real multi-process jax runtime on CPU (gloo collectives): the test
launches N copies with the same ``--port`` and distinct ``--process-id``,
they form a (num_devices, 1) mesh spanning the processes, and run the
production ``compile_train_step`` wiring with int8-compressed bucketed
gradient collectives.

Modes (combine via flags):
  * plain run      — train ``--steps`` steps, print per-step losses;
  * ``--ckpt-dir`` — collective checkpoint at the end (process 0 writes,
                     manifest digest cross-validated on restore);
  * ``--resume``   — restore from the manifest first (the node-loss path:
                     the test re-launches fewer processes than wrote the
                     checkpoint and training must continue seamlessly);
  * ``--force-devices N`` — single-process baseline with N simulated
                     devices, for N-global-device loss parity against the
                     N-process run.

Prints one ``RESULT {json}`` line on process 0 (and on every process when
single-process).
"""
import argparse
import json
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--port", default=None)
ap.add_argument("--num-processes", type=int, default=1)
ap.add_argument("--process-id", type=int, default=0)
ap.add_argument("--force-devices", type=int, default=0)
ap.add_argument("--steps", type=int, default=6)
ap.add_argument("--start-batch", type=int, default=0)
ap.add_argument("--bucket-elems", type=int, default=None)
ap.add_argument("--ckpt-dir", default=None)
ap.add_argument("--resume", action="store_true")
args = ap.parse_args()

os.environ.setdefault("JAX_PLATFORMS", "cpu")
if args.force_devices:
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count="
                                 f"{args.force_devices}").strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.mesh import initialize_distributed  # noqa: E402

if args.num_processes > 1:
    initialize_distributed(f"127.0.0.1:{args.port}", args.num_processes,
                           args.process_id)

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.gpt2 import GPT2_TINY  # noqa: E402
from repro.data import DataConfig, make_source  # noqa: E402
from repro.launch.train import (_put_tree, build_mesh,  # noqa: E402
                                compile_train_step)
from repro.train import TrainerConfig, checkpoint as ckpt  # noqa: E402

CFG = dataclasses.replace(GPT2_TINY, dtype="float32")
HESS_INTERVAL = 3

tc = TrainerConfig(optimizer="sophia_g", peak_lr=1e-3, total_steps=100,
                   warmup_steps=2, hess_interval=HESS_INTERVAL,
                   hess_subbatch=4, compress_grads=True,
                   comm_bucket_elems=args.bucket_elems, seed=0)
src = make_source(DataConfig(seq_len=32, global_batch=8,
                             vocab_size=CFG.vocab_size, seed=0))
sample = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}

mesh = build_mesh()
train_step, init_fn, ssh, bsh = compile_train_step(CFG, tc, mesh, sample)

if args.resume:
    like = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    state, start = ckpt.restore_resharded(args.ckpt_dir, like, shardings=ssh)
else:
    state = _put_tree(init_fn(jax.random.PRNGKey(0)), ssh)
    start = args.start_batch

losses = []
for t in range(start, start + args.steps):
    batch = _put_tree(
        {k: jnp.asarray(v) for k, v in src.batch_at(t).items()}, bsh)
    state, metrics = train_step(
        state, batch, jnp.asarray(t % HESS_INTERVAL == 0))
    losses.append(float(metrics["loss"]))

if args.ckpt_dir and not args.resume:
    ckpt.save(args.ckpt_dir, start + args.steps, state)

out = {"losses": losses, "start": int(start),
       "process_count": jax.process_count(),
       "global_devices": len(jax.devices()),
       "manifest_digest": (ckpt.manifest_digest(args.ckpt_dir)
                           if args.ckpt_dir else None)}
if jax.process_index() == 0:
    print("RESULT " + json.dumps(out), flush=True)
