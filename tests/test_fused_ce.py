"""Logits-free fused LM loss: fused-vs-reference parity for loss /
d_hidden / d_W across {fp32, bf16} x {tied, untied} embeddings, exact-zero
gradients on padded vocab columns, in-sweep GNB sampling parity and chunk
invariance, online-chunked-Gumbel-argmax == jax.random.categorical, and
the model/trainer wiring (all three impls of models.loss.lm_loss)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import HAS_HYPOTHESIS, given, settings, st  # noqa: F401

from repro.core.estimators import chunked_sampled_stats
from repro.kernels.fused_ce import (fused_lm_loss, fused_lm_loss_sampled,
                                    fused_lm_sample, hash_gumbel,
                                    lm_loss_hbm_bytes_fused,
                                    lm_loss_hbm_bytes_unfused, seed_from_key)
from repro.kernels.ref import (lm_loss_grads_ref, lm_loss_ref,
                               lm_loss_sampled_ref)

TOL = 3e-6


VOCAB = 200   # padded to 256 -> two 128-wide chunks: every kernel test
#               exercises the cross-chunk online carries (lse rescale,
#               running argmax, scratch init/flush gating), not just n_v=1


def _setup(dtype, tied, *, B=4, T=12, D=32, V=VOCAB, Vp=256, seed=0,
           w_dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    hidden = jax.random.normal(ks[0], (B, T, D), dtype)
    w_shape = (Vp, D) if tied else (D, Vp)
    w = (jax.random.normal(ks[1], w_shape, jnp.float32) * 0.2) \
        .astype(w_dtype)
    labels = jax.random.randint(ks[2], (B, T), 0, V)
    mask = (jax.random.uniform(ks[3], (B, T)) > 0.3).astype(jnp.float32)
    return hidden, w, labels, mask


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("tied", [True, False])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_fused_matches_ref_loss_and_grads(dtype, tied, softcap):
    hidden, w, labels, mask = _setup(dtype, tied)
    tw = not tied

    def f(h, w_):
        return fused_lm_loss(h, w_, labels, mask, vocab_size=VOCAB,
                             transpose_w=tw, softcap=softcap,
                             block_n=16, block_v=64)[0]

    loss, (dh, dw) = jax.value_and_grad(f, argnums=(0, 1))(hidden, w)
    loss_r, dh_r, dw_r = lm_loss_grads_ref(
        hidden, w, labels, mask, vocab_size=VOCAB, transpose_w=tw,
        softcap=softcap)
    np.testing.assert_allclose(float(loss), float(loss_r), atol=TOL)
    assert dh.dtype == hidden.dtype and dw.dtype == w.dtype
    np.testing.assert_allclose(np.asarray(dh, np.float32),
                               np.asarray(dh_r, np.float32), atol=TOL)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_r), atol=TOL)


def test_bf16_weights_accumulate_dw_in_fp32():
    """With bf16 weights d_W must accumulate across row tiles in fp32 and
    round ONCE at the flush — per-tile rounding in the output dtype drifts
    per-mille at real tile counts.  Many row tiles (block_n=8 over N=96)
    against the closed-form oracle, which also rounds once."""
    hidden, w, labels, mask = _setup(jnp.bfloat16, True, T=24,
                                     w_dtype=jnp.bfloat16)

    def f(h, w_):
        return fused_lm_loss(h, w_, labels, mask, vocab_size=VOCAB,
                             block_n=8, block_v=128)[0]

    _, dw = jax.value_and_grad(f, argnums=1)(hidden, w)
    _, _, dw_r = lm_loss_grads_ref(hidden, w, labels, mask,
                                   vocab_size=VOCAB)
    assert dw.dtype == jnp.bfloat16
    # both sides round the same fp32 value to bf16: agreement to ~1 ulp
    np.testing.assert_allclose(np.asarray(dw, np.float32),
                               np.asarray(dw_r, np.float32), atol=2e-5)


def test_closed_form_oracle_matches_autodiff_fp32():
    """lm_loss_grads_ref (the kernel-parity oracle) == jax.grad of the
    differentiable materialized-logits oracle in fp32."""
    hidden, w, labels, mask = _setup(jnp.float32, True)

    def f(h, w_):
        return lm_loss_ref(h, w_, labels, mask, vocab_size=VOCAB)

    loss, (dh, dw) = jax.value_and_grad(f, argnums=(0, 1))(hidden, w)
    loss_r, dh_r, dw_r = lm_loss_grads_ref(hidden, w, labels, mask,
                                           vocab_size=VOCAB)
    np.testing.assert_allclose(float(loss), float(loss_r), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(dh), np.asarray(dh_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_r), atol=1e-5)


@pytest.mark.parametrize("tied", [True, False])
def test_padded_vocab_columns_get_exactly_zero_grad(tied):
    """Padded columns (vocab_size <= col < padded_vocab) must receive
    bitwise-zero d_W in the fused kernel AND the reference oracle."""
    hidden, w, labels, mask = _setup(jnp.float32, tied)
    tw = not tied

    def fused(h, w_):
        return fused_lm_loss(h, w_, labels, mask, vocab_size=VOCAB,
                             transpose_w=tw, block_n=16, block_v=64)[0]

    def ref(h, w_):
        return lm_loss_ref(h, w_, labels, mask, vocab_size=VOCAB,
                           transpose_w=tw)

    for f in (fused, ref):
        dw = jax.grad(f, argnums=1)(hidden, w)
        pad = dw[:, VOCAB:] if tw else dw[VOCAB:, :]
        np.testing.assert_array_equal(np.asarray(pad), 0.0)
        live = dw[:, :VOCAB] if tw else dw[:VOCAB, :]
        assert float(jnp.max(jnp.abs(live))) > 0.0


def test_unfused_model_path_masks_padding():
    """The materialized-logits path (unembed + cross_entropy) must not
    leak padding into the CE denominator or its gradient either."""
    from repro.models import get_model, lm_loss
    from repro.models.common import ModelConfig

    cfg = ModelConfig(name="padded", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=VOCAB,
                      tie_embeddings=True, dtype="float32")
    assert cfg.padded_vocab == 256
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "labels": jnp.ones((2, 8), jnp.int32)}

    losses = {}
    for impl in ("unfused", "chunked", "fused"):
        loss, _ = model.loss_fn(cfg, params, batch, loss_impl=impl)
        losses[impl] = float(loss)
        dtok = jax.grad(
            lambda p: model.loss_fn(cfg, p, batch, loss_impl=impl)[0]
        )(params)["embed"]["tok"]
        # tied embeddings: rows >= vocab_size exist only as logits columns,
        # so their gradient must be exactly zero
        np.testing.assert_array_equal(np.asarray(dtok[VOCAB:]), 0.0, impl)
    assert abs(losses["unfused"] - losses["chunked"]) < 1e-5
    assert abs(losses["unfused"] - losses["fused"]) < 1e-5
    # denominator excludes padding: at init (logits ~ uniform) the CE must
    # sit near log(vocab_size), not log(padded_vocab)
    assert abs(losses["unfused"] - np.log(VOCAB)) < 0.5


def test_sampled_fused_matches_ref_and_is_chunk_invariant():
    hidden, w, _, mask = _setup(jnp.float32, True)
    rng = jax.random.PRNGKey(9)

    def f(h, w_):
        return fused_lm_loss_sampled(h, w_, rng, mask, vocab_size=VOCAB,
                                     block_n=16, block_v=64)[0]

    loss, (dh, dw) = jax.value_and_grad(f, argnums=(0, 1))(hidden, w)
    loss_r, yhat_r, dh_r, dw_r = lm_loss_sampled_ref(
        hidden, w, rng, mask, vocab_size=VOCAB)
    np.testing.assert_allclose(float(loss), float(loss_r), atol=TOL)
    np.testing.assert_allclose(np.asarray(dh), np.asarray(dh_r), atol=TOL)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_r), atol=TOL)
    np.testing.assert_array_equal(np.asarray(dw_r[VOCAB:]), 0.0)

    # the draw is a pure function of (seed, row, col): any (block_n,
    # block_v) tiling yields bit-identical labels
    yh = fused_lm_sample(hidden, w, rng, vocab_size=VOCAB, block_n=16,
                         block_v=128)
    np.testing.assert_array_equal(np.asarray(yh), np.asarray(yhat_r))
    for bn, bv in [(48, 256), (8, 128)]:
        yh2 = fused_lm_sample(hidden, w, rng, vocab_size=VOCAB, block_n=bn,
                              block_v=bv)
        np.testing.assert_array_equal(np.asarray(yh), np.asarray(yh2))
    # never samples a padded column
    assert int(jnp.max(yh)) < VOCAB


def test_hash_gumbel_is_gumbel_distributed():
    """Counter-based noise matches Gumbel(0,1) moments (mean ~ gamma,
    var ~ pi^2/6)."""
    rows = jnp.arange(512, dtype=jnp.int32)[:, None]
    cols = jnp.arange(256, dtype=jnp.int32)[None, :]
    g = np.asarray(hash_gumbel(seed_from_key(jax.random.PRNGKey(3)),
                               rows, cols))
    assert abs(g.mean() - 0.5772) < 0.02
    assert abs(g.var() - np.pi ** 2 / 6) < 0.05


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 33), st.integers(1, 6),
       st.integers(1, 8))
def test_chunked_gumbel_argmax_identical_to_categorical(seed, v, n, chunk):
    """Online chunked Gumbel-argmax over noise from a fixed key is
    DISTRIBUTION-IDENTICAL to jax.random.categorical — bit-for-bit, since
    categorical(key, logits) == argmax(logits + gumbel(key), -1) and the
    online reduction is exact for any chunking."""
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(jax.random.fold_in(key, 1), (n, v),
                               jnp.float32) * 3.0
    noise = jax.random.gumbel(key, logits.shape, jnp.float32)
    _, _, yhat = chunked_sampled_stats(logits, noise=noise, chunk=chunk)
    expect = jax.random.categorical(key, logits, axis=-1)
    np.testing.assert_array_equal(np.asarray(yhat), np.asarray(expect))


def test_chunked_sampled_stats_lse_and_grad():
    """The single-sweep stats reproduce log-sum-exp exactly and
    grad(lse - ll) == softmax - onehot(yhat)."""
    key = jax.random.PRNGKey(4)
    logits = jax.random.normal(key, (6, 37), jnp.float32) * 2.0
    lse, ll, yhat = chunked_sampled_stats(logits, jax.random.PRNGKey(5),
                                          chunk=7)
    np.testing.assert_allclose(np.asarray(lse),
                               np.asarray(jax.nn.logsumexp(logits, -1)),
                               rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(ll),
        np.asarray(jnp.take_along_axis(logits, yhat[:, None], 1)[:, 0]),
        rtol=1e-6)

    def nll(lg):
        lse_, ll_, _ = chunked_sampled_stats(lg, jax.random.PRNGKey(5),
                                             chunk=7)
        return (lse_ - ll_).sum()

    d = jax.grad(nll)(logits)
    p = jax.nn.softmax(logits, -1)
    onehot = jax.nn.one_hot(yhat, 37)
    np.testing.assert_allclose(np.asarray(d), np.asarray(p - onehot),
                               atol=1e-6)


@pytest.mark.parametrize("norm", ["rms", "ln"])
@pytest.mark.parametrize("tied", [True, False])
def test_norm_producer_fusion_matches_jnp_norm(norm, tied):
    """The in-kernel final-norm producer == jnp norm then kernel, for loss
    AND grads (hidden, W, norm scale/bias) — the (N, D) round-trip the
    fusion eliminates must not change a single ulp beyond fp tolerance."""
    from repro.models.layers import layer_norm, rms_norm

    hidden, w, labels, mask = _setup(jnp.float32, tied)
    tw = not tied
    D = hidden.shape[-1]
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    scale = jax.random.normal(ks[0], (D,), jnp.float32) * 0.1
    bias = jax.random.normal(ks[1], (D,), jnp.float32) * 0.1

    def fused_norm(h, w_, sc, bi):
        return fused_lm_loss(h, w_, labels, mask, vocab_size=VOCAB,
                             transpose_w=tw, block_n=16, block_v=64,
                             norm_kind=norm, norm_scale=sc,
                             norm_bias=bi if norm == "ln" else None)[0]

    def jnp_then_kernel(h, w_, sc, bi):
        hn = (layer_norm(h, sc, bi, 1e-6) if norm == "ln"
              else rms_norm(h, sc, 1e-6))
        return fused_lm_loss(hn, w_, labels, mask, vocab_size=VOCAB,
                             transpose_w=tw, block_n=16, block_v=64)[0]

    args = (hidden, w, scale, bias)
    la, ga = jax.value_and_grad(fused_norm, argnums=(0, 1, 2, 3))(*args)
    lb, gb = jax.value_and_grad(jnp_then_kernel, argnums=(0, 1, 2, 3))(*args)
    np.testing.assert_allclose(float(la), float(lb), atol=TOL)
    for x, y, name in zip(ga, gb, ("dh", "dw", "dscale", "dbias")):
        if norm == "rms" and name == "dbias":
            continue  # rms has no bias; the fused arg gets zero cotangent
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5,
                                   err_msg=name)


@pytest.mark.parametrize("tied", [True, False])
def test_backward_schedules_agree(tied):
    """The combined revisit-free backward ("fused" schedule, legal at
    single-axis grids) == the two-sweep "split" backward at the same
    tiling."""
    hidden, w, labels, mask = _setup(jnp.float32, tied)
    tw = not tied

    def f(sched):
        def loss(h, w_):
            return fused_lm_loss(h, w_, labels, mask, vocab_size=VOCAB,
                                 transpose_w=tw, block_n=16, block_v=256,
                                 schedule=sched)[0]
        return jax.value_and_grad(loss, argnums=(0, 1))(hidden, w)

    (lf, (dhf, dwf)), (ls, (dhs, dws)) = f("fused"), f("split")
    np.testing.assert_allclose(float(lf), float(ls), atol=TOL)
    np.testing.assert_allclose(np.asarray(dhf), np.asarray(dhs), atol=TOL)
    np.testing.assert_allclose(np.asarray(dwf), np.asarray(dws), atol=TOL)


@pytest.mark.parametrize("family", ["dense", "rwkv"])
def test_model_loss_impls_agree(family):
    """fused == chunked == unfused (to fp tolerance) through a real model
    trunk, including the masked mean."""
    from repro.models import get_model
    from repro.models.common import ModelConfig

    d_model = 64 if family == "rwkv" else 32  # rwkv decay heads are 64-wide
    cfg = ModelConfig(name="t", family=family, n_layers=2, d_model=d_model,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=96,
                      tie_embeddings=False, dtype="float32", rope=True)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    S = 64  # rwkv time-mix needs a 64-multiple sequence
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    batch = {"tokens": jax.random.randint(ks[0], (2, S), 0, 96),
             "labels": jax.random.randint(ks[1], (2, S), 0, 96),
             "mask": (jax.random.uniform(ks[2], (2, S)) > 0.25)
             .astype(jnp.float32)}

    vals, grads = {}, {}
    for impl in ("unfused", "chunked", "fused"):
        loss, g = jax.value_and_grad(
            lambda p: model.loss_fn(cfg, p, batch, loss_impl=impl)[0]
        )(params)
        vals[impl] = float(loss)
        grads[impl] = g
    assert abs(vals["chunked"] - vals["unfused"]) < 1e-5
    assert abs(vals["fused"] - vals["unfused"]) < 1e-5
    for impl in ("chunked", "fused"):
        for a, b in zip(jax.tree.leaves(grads[impl]),
                        jax.tree.leaves(grads["unfused"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-5)


def test_trainer_fused_loss_end_to_end():
    """Sophia-G + fused loss + in-kernel GNB refresh: losses finite and
    the non-refresh hot path matches the chunked run step-for-step until
    the first refresh diverges the h state (different sampling streams)."""
    from repro.configs.gpt2 import GPT2_TINY
    from repro.data import DataConfig, make_source
    from repro.train import TrainerConfig, train_loop

    src = make_source(DataConfig(seq_len=32, global_batch=4, vocab_size=512,
                                 seed=0))
    hists = {}
    for fused in (False, True):
        tc = TrainerConfig(optimizer="sophia_g", peak_lr=3e-4,
                           total_steps=10, hess_interval=4, hess_subbatch=2,
                           seed=0, fused_loss=fused)
        _, hist = train_loop(GPT2_TINY, tc, src, num_steps=6)
        hists[fused] = hist
        assert all(np.isfinite(h["loss"]) for h in hist)
    # identical grads until the first refresh's h takes effect (step 1)
    assert abs(hists[True][0]["loss"] - hists[False][0]["loss"]) < 1e-5
    assert abs(hists[True][1]["loss"] - hists[False][1]["loss"]) < 1e-4


def test_hbm_bytes_model_v_independence():
    """The analytic fused-loss traffic has no N*V term: growing V only
    adds W-stream bytes, while the unfused model blows up linearly in
    N*V."""
    N, D = 4096, 1024
    f1 = lm_loss_hbm_bytes_fused(N, D, 32_000)
    f2 = lm_loss_hbm_bytes_fused(N, D, 256_000)
    u1 = lm_loss_hbm_bytes_unfused(N, D, 32_000)
    u2 = lm_loss_hbm_bytes_unfused(N, D, 256_000)
    w_delta = 4 * (256_000 - 32_000) * D * 4  # 3 reads + 1 write of dW
    assert f2 - f1 == w_delta
    assert u2 - u1 > 5 * N * (256_000 - 32_000) * 4 * 0.99
    assert f1 < u1 and f2 < u2
