"""Data pipeline: determinism, host sharding, memmap format."""
import numpy as np
import pytest

from repro.data import DataConfig, MemmapTokens, SyntheticLM, host_slice


def test_batch_is_pure_function_of_step():
    cfg = DataConfig(seq_len=32, global_batch=4, vocab_size=100, seed=7)
    src = SyntheticLM(cfg)
    a = src.batch_at(13)
    b = src.batch_at(13)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch_at(14)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(seq_len=16, global_batch=2, vocab_size=50, seed=0)
    b = SyntheticLM(cfg).batch_at(0)
    assert b["tokens"].shape == (2, 16)
    assert b["labels"].shape == (2, 16)


def test_learnable_structure():
    """Bigram table makes next-token partially predictable (loss can drop)."""
    cfg = DataConfig(seq_len=64, global_batch=8, vocab_size=32, seed=1)
    src = SyntheticLM(cfg)
    b = src.batch_at(0)
    hits = (src.next_tok[b["tokens"]] == b["labels"]).mean()
    assert hits > 0.4  # ~70% deterministic transitions


def test_host_slice_partitions():
    cfg = DataConfig(seq_len=8, global_batch=8, vocab_size=10, seed=0)
    b = SyntheticLM(cfg).batch_at(0)
    parts = [host_slice(b, i, 4) for i in range(4)]
    stacked = np.sort(np.concatenate([p["tokens"] for p in parts]), axis=None)
    np.testing.assert_array_equal(stacked, np.sort(b["tokens"], axis=None))
    assert all(p["tokens"].shape[0] == 2 for p in parts)


def test_memmap_source(tmp_path):
    data = np.arange(10000, dtype=np.uint16) % 512
    path = tmp_path / "train.bin"
    data.tofile(path)
    cfg = DataConfig(seq_len=64, global_batch=4, vocab_size=512, seed=0,
                     source="memmap", path=str(path))
    src = MemmapTokens(cfg)
    b = src.batch_at(3)
    assert b["tokens"].shape == (4, 64)
    # labels are next-token shifted views of the same stream
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
