import os
import sys

# Tests run on the single real CPU device (the dry-run sets its own
# XLA_FLAGS in a separate process; never set host_platform_device_count
# here — smoke tests and benches must see 1 device).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
