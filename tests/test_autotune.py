"""The fused-CE block autotuner (kernels/autotune.py) + the fused JVP rule.

Fast-tier policy: every test here runs the tuner in roofline-only mode
(``measure=False`` — deterministic, no wall-clock timing, no disk writes);
the single measured-persistence test is marked ``slow``.  Covers the
ISSUE-6 satellite contracts:

  * cache determinism — same key -> same config, and the tuned fused loss
    is bit-identical across independent tuner runs;
  * parity vs the kernels/ref.py closed-form oracles at tuned (bn, bv)
    configs that cross chunk boundaries, both backward schedules;
  * fused-JVP vs chunked-HVP equivalence <= 3e-6, and the trainer's
    Hutchinson refresh actually traces through the fused JVP rule (no
    silent chunked fallback — KERNEL_CALLS counter);
  * interpret-mode clamps (``_pick_bv`` / candidate caps) so CPU CI never
    unrolls a pathological interpreter grid;
  * residency cap — no candidate can reconstruct the [N, Vp] logits
    buffer the memory audit forbids.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune
from repro.kernels.autotune import (TunedCE, cache_key, candidate_blocks,
                                    clear_memory_cache, get_tuned,
                                    predict_seconds, residency_cap)
from repro.kernels.fused_ce import (_pick_bv, fused_lm_loss,
                                    fused_lm_loss_jvp, kernel_calls,
                                    reset_kernel_calls)
from repro.kernels.ref import lm_loss_grads_ref, lm_loss_ref

N, D, VOCAB, VP = 64, 32, 200, 256
TOL = 3e-6


@pytest.fixture(autouse=True)
def _fresh_tuner(monkeypatch, tmp_path):
    """Every test gets an empty in-memory cache and a throwaway disk path
    (never the user's ~/.cache)."""
    monkeypatch.setenv("REPRO_FUSED_CE_CACHE",
                       str(tmp_path / "autotune.json"))
    monkeypatch.setenv("REPRO_FLASH_ATTN_CACHE",
                       str(tmp_path / "attn_autotune.json"))
    clear_memory_cache()
    yield
    clear_memory_cache()


def _data(n=N, d=D, vp=VP, transpose_w=False, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    h = jax.random.normal(ks[0], (n, d), jnp.float32) * 0.5
    wshape = (d, vp) if transpose_w else (vp, d)
    w = jax.random.normal(ks[1], wshape, jnp.float32) * 0.5
    labels = jax.random.randint(ks[2], (n,), 0, VOCAB)
    return h, w, labels


# ---------------------------------------------------------------------------
# cache determinism + hermeticity


def test_same_key_same_config():
    kw = dict(dtype="float32", transpose_w=False, softcap=None, norm=None,
              interpret=True)
    a = get_tuned(N, D, VP, **kw)
    clear_memory_cache()
    b = get_tuned(N, D, VP, **kw)
    assert dataclasses.asdict(a) == dataclasses.asdict(b)
    assert a.source == "roofline"
    # and the in-memory hit is the exact same decision
    assert get_tuned(N, D, VP, **kw) == b


def test_tuned_loss_bit_identical_across_tuner_runs():
    """Two independent tuner resolutions (cache cleared between) must
    produce bit-identical losses — the tuner is part of the numerics
    contract, not just a performance hint."""
    h, w, labels = _data()

    def run():
        f = jax.jit(lambda h, w: fused_lm_loss(
            h, w, labels, vocab_size=VOCAB)[0])
        return np.asarray(f(h, w))

    a = run()
    clear_memory_cache()
    jax.clear_caches()
    b = run()
    assert a.tobytes() == b.tobytes()


def test_roofline_only_mode_touches_no_disk(tmp_path):
    path = os.environ["REPRO_FUSED_CE_CACHE"]
    for vp in (VP, 2 * VP):
        get_tuned(N, D, vp, dtype="float32", transpose_w=False,
                  softcap=None, norm=None, interpret=True)
    assert not os.path.exists(path)


@pytest.mark.slow
def test_measured_entry_persists_and_reloads():
    t = autotune.tune_shape(N, D, VP, interpret=True)
    assert t.source == "measured" and t.measured_ms is not None
    assert os.path.exists(os.environ["REPRO_FUSED_CE_CACHE"])
    clear_memory_cache()       # force the disk round-trip
    t2 = get_tuned(N, D, VP, dtype="float32", transpose_w=False,
                   softcap=None, norm=None, interpret=True)
    assert t2 == t


def test_cache_key_separates_backends_and_layouts():
    keys = {cache_key(N, D, VP, dtype="float32", transpose_w=tw,
                      softcap=sc, norm=nm, backend=be)
            for tw in (False, True) for sc in (None, 30.0)
            for nm in (None, "rms", "ln") for be in ("tpu", "interpret")}
    assert len(keys) == 2 * 2 * 3 * 2


# ---------------------------------------------------------------------------
# candidate legality


def test_candidates_respect_residency_cap():
    for interpret in (False, True):
        cands = candidate_blocks(1024, 256, 32768, bytes_h=2,
                                 interpret=interpret)
        assert cands
        cap = residency_cap(1024, 32768)
        for bn, bv, schedule in cands:
            assert 1024 % bn == 0 and 32768 % bv == 0
            assert bn * bv <= cap
            if schedule == "fused":
                assert 1024 // bn == 1 or 32768 // bv == 1


def test_predict_prefers_fewer_cells_in_interpret():
    """The interpret cost model must rank a single-row-block tiling above
    a many-cell one of equal arithmetic — per-cell dispatch dominates."""
    few = predict_seconds(256, 64, 4096, 256, 4096, "fused", bytes_h=4,
                          bytes_w=4, interpret=True)
    many = predict_seconds(256, 64, 4096, 8, 128, "split", bytes_h=4,
                           bytes_w=4, interpret=True)
    assert few < many


def test_pick_bv_interpret_clamp():
    # an explicit tiny chunk at a big vocab would unroll 256 interpreter
    # cells per row block; the clamp caps the vocab grid at 64
    assert _pick_bv(32768, 128, interpret=True) >= 32768 // 64
    # ... but passes through where the grid is already small
    assert _pick_bv(1024, 128, interpret=True) == 128
    # and never clamps for a real backend
    assert _pick_bv(32768, 128, interpret=False) == 128


def test_autotuned_defaults_keep_interpret_grid_small():
    t = get_tuned(256, 64, 32768, dtype="float32", transpose_w=False,
                  softcap=None, norm=None, interpret=True)
    assert (256 // t.bn) * (32768 // t.bv) <= 64


# ---------------------------------------------------------------------------
# parity at tuned configs (vs the closed-form oracles in kernels/ref.py)


def _three_tuned_configs():
    """Three tuner-legal (bn, bv, schedule) configs crossing chunk
    boundaries: a multi-cell split grid, a fused row-grid (n_v == 1), and
    a fused vocab-grid (n_r == 1)."""
    cands = candidate_blocks(N, D, VP, bytes_h=4, interpret=True)
    split = next(c for c in cands
                 if c[2] == "split" and N // c[0] > 1 and VP // c[1] > 1)
    fused_rows = next(c for c in cands if c[2] == "fused" and VP // c[1] == 1
                      and N // c[0] > 1)
    fused_cols = next(c for c in cands if c[2] == "fused" and N // c[0] == 1
                      and VP // c[1] > 1)
    return [split, fused_rows, fused_cols]


@pytest.mark.parametrize("transpose_w", [False, True])
def test_parity_at_tuned_configs(transpose_w):
    h, w, labels = _data(transpose_w=transpose_w)
    ref_l, ref_dh, ref_dw = lm_loss_grads_ref(
        h, w, labels, vocab_size=VOCAB, transpose_w=transpose_w)
    for bn, bv, schedule in _three_tuned_configs():
        f = jax.jit(lambda h, w: fused_lm_loss(
            h, w, labels, vocab_size=VOCAB, transpose_w=transpose_w,
            block_n=bn, block_v=bv, schedule=schedule)[0])
        loss, (dh, dw) = jax.value_and_grad(f, argnums=(0, 1))(h, w)
        tag = f"bn={bn} bv={bv} {schedule}"
        np.testing.assert_allclose(np.asarray(loss), np.asarray(ref_l),
                                   rtol=TOL, atol=TOL, err_msg=tag)
        np.testing.assert_allclose(np.asarray(dh), np.asarray(ref_dh),
                                   rtol=TOL, atol=TOL, err_msg=tag)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(ref_dw),
                                   rtol=TOL, atol=TOL, err_msg=tag)


# ---------------------------------------------------------------------------
# the fused JVP rule (Hutchinson's HVP path)


@pytest.mark.parametrize("transpose_w", [False, True])
def test_fused_jvp_matches_chunked_hvp(transpose_w):
    """H·u through the fused custom_jvp twin == H·u through the
    materialized-logits oracle, <= 3e-6 (forward-over-reverse both)."""
    h, w, labels = _data(transpose_w=transpose_w)
    u = jax.random.normal(jax.random.PRNGKey(9), h.shape, jnp.float32)

    def loss_fused(h):
        return fused_lm_loss_jvp(h, w, labels, vocab_size=VOCAB,
                                 transpose_w=transpose_w)[0]

    def loss_ref(h):
        return lm_loss_ref(h, w, labels, vocab_size=VOCAB,
                           transpose_w=transpose_w)

    g_f, hvp_f = jax.jvp(jax.grad(loss_fused), (h,), (u,))
    g_r, hvp_r = jax.jvp(jax.grad(loss_ref), (h,), (u,))
    np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_r),
                               rtol=TOL, atol=TOL)
    np.testing.assert_allclose(np.asarray(hvp_f), np.asarray(hvp_r),
                               rtol=1e-5, atol=TOL)


def test_hutchinson_traces_through_fused_jvp_rule():
    """The trainer's Hutchinson refresh with ``fused_loss=True`` (the
    default) must enter the fused JVP rule — and never the plain fused
    forward, which would mean the custom_vjp path (no HVP) or a silent
    chunked fallback."""
    from repro.configs.gpt2 import GPT2_TINY
    from repro.data import DataConfig, make_source
    from repro.train import TrainerConfig, make_train_fns

    tc = TrainerConfig(optimizer="sophia_h", estimator="hutchinson",
                       total_steps=4, warmup_steps=1, hess_interval=1,
                       hess_subbatch=2, seed=0)
    assert tc.fused_loss, "fused_loss must default to True (ISSUE 6)"
    init_fn, train_step = make_train_fns(GPT2_TINY, tc)
    state = init_fn(jax.random.PRNGKey(0))
    src = make_source(DataConfig(seq_len=16, global_batch=2,
                                 vocab_size=GPT2_TINY.vocab_size, seed=0))
    batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
    reset_kernel_calls()
    state, _ = jax.jit(train_step)(state, batch, jnp.asarray(True))
    calls = kernel_calls()
    assert calls.get("jvp_rule", 0) >= 1, calls
    jax.block_until_ready(state)


# ---------------------------------------------------------------------------
# flash-attention tuner (same contracts, separate cache)

ATTN = dict(B=2, H=4, Hkv=2, Sq=256, Sk=256, hd=32)
ATTN_KW = dict(dtype="float32", causal=True, softcap=None, interpret=True)


def test_attn_same_key_same_config():
    a = autotune.get_tuned_attn(**ATTN, **ATTN_KW)
    clear_memory_cache()
    b = autotune.get_tuned_attn(**ATTN, **ATTN_KW)
    assert dataclasses.asdict(a) == dataclasses.asdict(b)
    assert a.source == "roofline"
    assert ATTN["Sq"] % a.bq == 0 and ATTN["Sk"] % a.bk == 0
    assert a.schedule in ("skip", "dense")
    # in-memory hit is the exact same decision object
    assert autotune.get_tuned_attn(**ATTN, **ATTN_KW) == b


def test_attn_roofline_only_touches_no_disk():
    path = os.environ["REPRO_FLASH_ATTN_CACHE"]
    autotune.get_tuned_attn(**ATTN, **ATTN_KW)
    assert not os.path.exists(path)


def test_attn_cache_is_separate_from_ce_cache():
    """TunedAttn and TunedCE have disjoint fields — a shared JSON would
    crash either loader, so the caches must be separate files."""
    assert autotune.attn_cache_path() != autotune.cache_path()


def test_attn_key_separates_configs():
    keys = {autotune.attn_cache_key(2, 4, hkv, 256, 256, 32, dtype=dt,
                                    causal=ca, softcap=sc, backend=be)
            for hkv in (2, 4) for dt in ("float32", "bfloat16")
            for ca in (True, False) for sc in (None, 20.0)
            for be in ("interpret", "tpu")}
    assert len(keys) == 32


def test_attn_interpret_candidates_fit_cell_cap():
    from repro.kernels.flash_attention import INTERPRET_CELL_CAP
    t = autotune.get_tuned_attn(**ATTN, **ATTN_KW)
    cells = (ATTN["Sq"] // t.bq) * (ATTN["Sk"] // t.bk)
    assert ATTN["B"] * ATTN["H"] * cells <= INTERPRET_CELL_CAP


def test_attn_predict_skip_beats_dense_when_causal():
    """The roofline cost charges only in-band tiles under "skip": on a
    multi-block causal grid it must price below "dense" (which streams the
    full rectangle), on the real backend where cells aren't emulated."""
    kw = dict(bytes_el=2, causal=True, interpret=False)
    skip = autotune.attn_predict_seconds(8, 12, 4, 2048, 2048, 128,
                                         256, 256, "skip", **kw)
    dense = autotune.attn_predict_seconds(8, 12, 4, 2048, 2048, 128,
                                          256, 256, "dense", **kw)
    assert skip < dense


@pytest.mark.slow
def test_attn_measured_entry_persists_and_reloads():
    t = autotune.tune_attn_shape(1, 2, 1, 128, 128, 32, interpret=True,
                                 refresh=True)
    assert t.source == "measured" and t.measured_ms is not None
    assert os.path.exists(os.environ["REPRO_FLASH_ATTN_CACHE"])
    clear_memory_cache()       # force the disk round-trip
    t2 = autotune.get_tuned_attn(1, 2, 1, 128, 128, 32, **ATTN_KW)
    assert t2 == t


def test_attn_tuned_flash_bit_identical_across_tuner_runs():
    """Tuner resolution is part of the numerics contract for attention
    too: two independent resolutions give bit-identical outputs."""
    from repro.kernels.flash_attention import flash_attention
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32)) * 0.5
    k = jax.random.normal(ks[1], (1, 1, 128, 32)) * 0.5
    v = jax.random.normal(ks[2], (1, 1, 128, 32)) * 0.5

    def run():
        return np.asarray(jax.jit(flash_attention)(q, k, v))

    a = run()
    clear_memory_cache()
    jax.clear_caches()
    b = run()
    assert a.tobytes() == b.tobytes()
