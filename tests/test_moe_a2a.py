"""shard_map all-to-all MoE dispatch == gspmd scatter dispatch (oracle).

With ample capacity neither path drops tokens, so outputs must match to
bf16 tolerance.  Runs in a subprocess with 4 host devices.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # excluded from the fast tier-1 default

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_a2a_matches_gspmd():
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, {json.dumps(SRC)})
        import jax, jax.numpy as jnp, numpy as np
        import dataclasses
        from repro.configs import get_config
        from repro.distributed.sharding import set_activation_mesh
        from repro.launch.mesh import make_mesh
        from repro.models import moe as M

        cfg = get_config("deepseek-moe-16b", smoke=True)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0, dtype="float32")
        p = M.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))

        ref, aux_ref = M.moe_ffn_gspmd(p, x, cfg)

        mesh = make_mesh((2, 2), ("data", "model"))
        set_activation_mesh(mesh)
        M.set_moe_impl("a2a")
        out, aux = jax.jit(lambda p, x: M.moe_ffn(p, x, cfg))(p, x)

        err = float(jnp.max(jnp.abs(out - ref)))
        print("max err:", err, "aux:", float(aux), float(aux_ref))
        assert err < 1e-4, err
        # gradient flows through the a2a path
        g = jax.grad(lambda p_: M.moe_ffn(p_, x, cfg)[0].sum())(p)
        gn = sum(float(jnp.abs(t).sum()) for t in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0
        print("A2A_OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=600)
    assert "A2A_OK" in r.stdout, (r.stdout[-800:], r.stderr[-3000:])
