"""Hypothesis property tests for the chunked WKV kernel (RWKV-6).

The chunked path (MXU matmuls + per-channel mid-shift log-decay) must match
the exact sequential recurrence for any decay profile within the clamp,
any state, any chunk-multiple length.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # whole module is property-based
from hypothesis import given, settings, strategies as st

from repro.models.rwkv import CHUNK, LOG_DECAY_CLAMP, wkv_chunked, wkv_scan


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**30),
    n_chunks=st.integers(1, 4),
    decay_scale=st.floats(min_value=0.01, max_value=1.0),
    state_scale=st.floats(min_value=0.0, max_value=2.0),
)
def test_wkv_chunked_equals_scan(seed, n_chunks, decay_scale, state_scale):
    B, H, K = 1, 2, 64
    S = CHUNK * n_chunks
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (B, S, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, K)) * 0.5
    # decays anywhere in the clamp range, incl. near the -4 floor
    logw = -jnp.abs(jax.random.normal(ks[3], (B, S, H, K))) \
        * decay_scale * LOG_DECAY_CLAMP
    logw = jnp.clip(logw, -LOG_DECAY_CLAMP, -1e-6)
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    st0 = jax.random.normal(ks[5], (B, H, K, K)) * state_scale

    o1, s1 = wkv_scan(r, k, v, logw, u, st0)
    o2, s2 = wkv_chunked(r, k, v, logw, u, st0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)
    # no overflow anywhere in the chunked math
    assert bool(jnp.all(jnp.isfinite(o2))) and bool(jnp.all(jnp.isfinite(s2)))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_wkv_state_carry_composes(seed):
    """Running two halves sequentially == running the whole sequence."""
    B, H, K = 1, 1, 64
    S = CHUNK * 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, K)) * 0.5 for i in range(3))
    logw = jnp.clip(-jnp.abs(jax.random.normal(ks[3], (B, S, H, K))),
                    -LOG_DECAY_CLAMP, -1e-6)
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    st0 = jnp.zeros((B, H, K, K))

    o_full, s_full = wkv_chunked(r, k, v, logw, u, st0)
    h = S // 2
    o1, s_mid = wkv_chunked(r[:, :h], k[:, :h], v[:, :h], logw[:, :h], u, st0)
    o2, s_end = wkv_chunked(r[:, h:], k[:, h:], v[:, h:], logw[:, h:], u,
                            s_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(o_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_full),
                               rtol=2e-4, atol=2e-4)
