"""Trainer: optimizer parity, grad accumulation, fused-kernel path,
hessian refresh cadence, telemetry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gpt2 import GPT2_TINY
from repro.data import DataConfig, make_source
from repro.train import TrainerConfig, make_train_fns, train_loop


def _tiny_tc(**kw):
    base = dict(optimizer="sophia_g", peak_lr=5e-4, total_steps=50,
                warmup_steps=5, hess_interval=5, hess_subbatch=4, seed=0)
    base.update(kw)
    return TrainerConfig(**base)


def _src(B=8, S=32, seed=0):
    return make_source(DataConfig(seq_len=S, global_batch=B,
                                  vocab_size=GPT2_TINY.vocab_size, seed=seed))


def test_hessian_refresh_every_k():
    tc = _tiny_tc()
    src = _src()
    state, hist = train_loop(GPT2_TINY, tc, src, num_steps=11)
    # steps 0,5,10 refresh => hess_count == 3
    assert int(state.opt_state.hess_count) == 3
    assert int(state.step) == 11


@pytest.mark.slow
def test_all_optimizers_run():
    src = _src()
    for opt in ("sophia_g", "sophia_h", "adamw", "lion", "signgd",
                "adahessian"):
        tc = _tiny_tc(optimizer=opt,
                      estimator="hutchinson" if opt in ("sophia_h",
                                                        "adahessian")
                      else "gnb")
        state, hist = train_loop(GPT2_TINY, tc, src, num_steps=6)
        assert np.isfinite(hist[-1]["loss"]), opt


def test_grad_accum_equivalence():
    """accum=2 with the same global batch gives (near-)identical params."""
    src = _src(B=8)
    tc1 = _tiny_tc(grad_accum=1, optimizer="adamw")
    tc2 = _tiny_tc(grad_accum=2, optimizer="adamw")
    s1, _ = train_loop(GPT2_TINY, tc1, src, num_steps=3)
    s2, _ = train_loop(GPT2_TINY, tc2, src, num_steps=3)
    a = jax.flatten_util.ravel_pytree(s1.params)[0]
    b = jax.flatten_util.ravel_pytree(s2.params)[0]
    # bf16 forward: microbatch grads differ from full-batch grads by
    # rounding, amplified by Adam's normalizer — allow small absolute slack
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-2, atol=5e-3)


def test_fused_kernel_path_matches_unfused():
    """Pallas fused Sophia apply == pure-JAX optimizer over several steps."""
    src = _src()
    s1, _ = train_loop(GPT2_TINY, _tiny_tc(fused_kernel=False), src,
                       num_steps=7)
    s2, _ = train_loop(GPT2_TINY, _tiny_tc(fused_kernel=True), src,
                       num_steps=7)
    a = jax.flatten_util.ravel_pytree(s1.params)[0]
    b = jax.flatten_util.ravel_pytree(s2.params)[0]
    # kernel computes p*(1-lr*wd) vs unfused p - lr*wd*p: algebraically
    # identical, rounds differently; divergence compounds over 7 bf16 steps
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-2, atol=5e-3)
    am = jax.flatten_util.ravel_pytree(s1.opt_state.m)[0]
    bm = jax.flatten_util.ravel_pytree(s2.opt_state.m)[0]
    np.testing.assert_allclose(np.asarray(am), np.asarray(bm),
                               rtol=1e-2, atol=1e-3)


def test_grad_clip_telemetry():
    src = _src()
    state, hist = train_loop(GPT2_TINY, _tiny_tc(grad_clip=1e-6), src,
                             num_steps=4)
    assert int(state.clip_state.triggers) == 4  # tiny threshold: always


def test_sophia_clip_fraction_reported():
    src = _src()
    state, hist = train_loop(GPT2_TINY, _tiny_tc(), src, num_steps=6)
    assert "sophia_clip_fraction" in hist[-1]
    assert 0.0 <= hist[-1]["sophia_clip_fraction"] <= 1.0


def test_compressed_grads_still_train():
    src = _src()
    tc = _tiny_tc(compress_grads=True)
    state, hist = train_loop(GPT2_TINY, tc, src, num_steps=20)
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.1


def test_error_feedback_state_persists():
    """The quantization residual must accumulate across steps (it used to be
    re-initialized every step, discarding error feedback)."""
    src = _src()
    tc = _tiny_tc(compress_grads=True, optimizer="adamw")
    state, _ = train_loop(GPT2_TINY, tc, src, num_steps=2)
    err = jax.flatten_util.ravel_pytree(state.comp_state.error)[0]
    assert float(jnp.sum(jnp.abs(err))) > 0.0
    # and it is part of the train state pytree (checkpointable)
    state2, _ = train_loop(GPT2_TINY, tc, src, num_steps=1, state=state,
                           start_step=2)
    err2 = jax.flatten_util.ravel_pytree(state2.comp_state.error)[0]
    assert not np.allclose(np.asarray(err), np.asarray(err2))


@pytest.mark.slow
def test_estimator_choices():
    src = _src()
    for est in ("gnb", "hutchinson", "empirical_fisher"):
        tc = _tiny_tc(estimator=est)
        state, _ = train_loop(GPT2_TINY, tc, src, num_steps=6)
        h = jax.flatten_util.ravel_pytree(state.opt_state.h)[0]
        assert float(jnp.sum(jnp.abs(h))) > 0.0, est
