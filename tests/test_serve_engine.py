"""Continuous-batching engine: slot isolation, one-program compilation,
EOS/length masking, scheduling telemetry, encdec requests.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.serve import Request, ServeEngine, generate_lockstep

pytestmark = pytest.mark.serve

MIXED = [(5, 7), (13, 3), (8, 9), (21, 5), (3, 8)]


def _setup(arch="yi-6b", **over):
    cfg = dataclasses.replace(get_config(arch, smoke=True), **over)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, model, params


def _mixed_requests(cfg, spec=MIXED, seed=10):
    reqs, prompts = [], []
    for i, (sp, mn) in enumerate(spec):
        toks = jax.random.randint(jax.random.PRNGKey(seed + i), (sp,), 0,
                                  cfg.vocab_size)
        prompts.append(toks)
        reqs.append(Request(uid=i, tokens=np.asarray(toks), max_new=mn))
    return reqs, prompts


def test_slot_isolation_matches_per_request_decode():
    """5 mixed-length requests over 2 slots (continuous batching, slot
    reuse, chunked prefill interleaved with decodes) produce exactly the
    tokens each request gets decoded alone — slots are independent rows."""
    cfg, model, params = _setup(dtype="float32")
    eng = ServeEngine(cfg, params, n_slots=2, cache_len=64, page_len=8,
                      steps_per_tick=4, seed=0)
    reqs, prompts = _mixed_requests(cfg)
    for r in reqs:
        eng.submit(r)
    res = {r.uid: r.tokens for r in eng.run()}
    assert sorted(res) == list(range(len(MIXED)))
    for i, (sp, mn) in enumerate(MIXED):
        ref = np.asarray(generate_lockstep(cfg, params, prompts[i][None],
                                           max_new=mn))[0]
        np.testing.assert_array_equal(np.array(res[i]), ref,
                                      err_msg=f"request {i}")


def test_engine_compiles_one_program_per_phase():
    """Mixed request lengths and shifting batch composition never grow the
    jit caches: one prefill program + one decode program (cf. the
    compile-count asserts in test_unified_step.py).

    The engine shares jitted programs per config, so pin a uniquely-named
    config to start from an empty cache."""
    cfg, model, params = _setup(name="compile-count-probe")
    eng = ServeEngine(cfg, params, n_slots=2, cache_len=64, page_len=8,
                      steps_per_tick=4, seed=0)
    reqs, _ = _mixed_requests(cfg)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert eng._prefill_jit._cache_size() == 1
    assert eng._burst_jit._cache_size() == 1


def test_eos_truncates_inside_scan():
    """A request whose EOS appears mid-burst stops emitting there; the
    freed budget is not spent."""
    cfg, model, params = _setup(dtype="float32")
    prompt = jax.random.randint(jax.random.PRNGKey(1), (6,), 0,
                                cfg.vocab_size)
    ref = np.asarray(generate_lockstep(cfg, params, prompt[None],
                                       max_new=12))[0]
    # pick the greedy token emitted at step 3 as the "EOS"
    eos = int(ref[3])
    eng = ServeEngine(cfg, params, n_slots=1, cache_len=32, page_len=8,
                      steps_per_tick=8, seed=0)
    eng.submit(Request(uid=0, tokens=np.asarray(prompt), max_new=12,
                       eos_id=eos))
    res = eng.run()[0]
    first_hit = int(np.argmax(ref == eos))
    np.testing.assert_array_equal(np.array(res.tokens),
                                  ref[:first_hit + 1])
    assert res.tokens[-1] == eos


def test_length_budgets_respected_and_slots_reused():
    cfg, model, params = _setup()
    eng = ServeEngine(cfg, params, n_slots=2, cache_len=64, page_len=8,
                      steps_per_tick=4, seed=0)
    reqs, _ = _mixed_requests(cfg)
    for r in reqs:
        eng.submit(r)
    res = {r.uid: r for r in eng.run()}
    for i, (sp, mn) in enumerate(MIXED):
        assert len(res[i].tokens) == mn
    # 5 requests over 2 slots forces reuse; telemetry must show it
    stats = eng.stats()
    assert stats["tokens_emitted"] >= sum(mn for _, mn in MIXED)
    assert 0.0 < stats["slot_utilization"] <= 1.0
    assert stats["token_lat_p50_s"] > 0.0
    assert stats["token_lat_p95_s"] >= stats["token_lat_p50_s"]
    for r in res.values():
        assert r.done_t >= r.first_token_t >= r.admitted_t >= r.submitted_t


def test_request_exceeding_cache_rejected():
    cfg, model, params = _setup()
    eng = ServeEngine(cfg, params, n_slots=1, cache_len=16, page_len=8)
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, tokens=np.zeros((12,), np.int32),
                           max_new=8))


def test_encdec_requests_through_engine():
    """Frames-driven encdec requests: deterministic, isolated per slot."""
    cfg, model, params = _setup("seamless-m4t-medium", dtype="float32")

    def run():
        eng = ServeEngine(cfg, params, n_slots=2, cache_len=16, page_len=4,
                          steps_per_tick=4, seed=0, src_len=6)
        for i in range(3):
            frames = jax.random.normal(jax.random.PRNGKey(20 + i),
                                       (6, cfg.d_model))
            eng.submit(Request(uid=i, tokens=np.zeros((1,), np.int32),
                               max_new=5, frames=frames))
        return {r.uid: r.tokens for r in eng.run()}

    a, b = run(), run()
    assert a == b
    assert all(len(t) == 5 for t in a.values())
    # distinct frame streams should decode differently (not a frozen path)
    assert len({tuple(t) for t in a.values()}) > 1


def test_mixed_temperature_batch():
    """Greedy and sampling requests share a batch; the greedy slot's output
    equals its solo greedy decode."""
    cfg, model, params = _setup(dtype="float32")
    prompt = jax.random.randint(jax.random.PRNGKey(2), (6,), 0,
                                cfg.vocab_size)
    ref = np.asarray(generate_lockstep(cfg, params, prompt[None],
                                       max_new=6))[0]
    eng = ServeEngine(cfg, params, n_slots=2, cache_len=32, page_len=8,
                      steps_per_tick=4, seed=0)
    eng.submit(Request(uid="greedy", tokens=np.asarray(prompt), max_new=6))
    hot = jax.random.randint(jax.random.PRNGKey(3), (9,), 0, cfg.vocab_size)
    eng.submit(Request(uid="hot", tokens=np.asarray(hot), max_new=6,
                       temperature=2.0))
    res = {r.uid: r.tokens for r in eng.run()}
    np.testing.assert_array_equal(np.array(res["greedy"]), ref)
