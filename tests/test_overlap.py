"""Bucketed-collective invariance + planning + telemetry (fast tier).

The bucketed overlapped reduction (distributed/overlap.py) is only
shippable because of one property: ANY bucketing of a flat shard
dequantizes bit-identically to the monolithic path, for the same seed —
scales and stochastic-rounding noise are keyed on the global element
index, and bucket boundaries stay 256-block-aligned.  The property tests
here sweep bucket sizes that straddle block boundaries (hypothesis when
installed); the 8-device mesh version of the same assertion lives in the
slow tier (tests/test_distributed_engine.py bucketed parity case and the
HLO audit).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.engine import bucket_slices, build_layout
from repro.distributed.compression import FlatCompressionState, GradCompressor
from repro.distributed.overlap import (allreduce_shards_bucketed,
                                       decode_timeline, delta_seconds,
                                       plan_buckets, stamp, timeline_enable)
from repro.launch.roofline import choose_bucket_elems, ring_collective_seconds


# ---------------------------------------------------------------------------
# bucket planning


def test_bucket_slices_tile_exactly():
    for n, b in [(2048, 512), (2048, 500), (2048, 2048), (2048, 4096),
                 (2048, 0), (256, 256), (0, 128)]:
        sl = bucket_slices(n, b, align=256)
        if n == 0:
            assert sl == ()
            continue
        # disjoint, ordered, exact cover
        assert sl[0][0] == 0 and sl[-1][1] == n
        for (a0, a1), (b0, b1) in zip(sl, sl[1:]):
            assert a1 == b0
        # every boundary block-aligned
        assert all(s % 256 == 0 for s, _ in sl)


def test_bucket_slices_monolithic_cases():
    # 0 => monolithic; >= n => monolithic; unaligned n => monolithic
    assert bucket_slices(2048, 0) == ((0, 2048),)
    assert bucket_slices(2048, 2048) == ((0, 2048),)
    assert bucket_slices(1000, 256, align=256) == ((0, 1000),)


def test_plan_buckets_semantics():
    # explicit N rounds up to block*ndev alignment
    (plan,) = plan_buckets([256 * 24], 4, bucket_elems=1000)
    assert all((b - a) % (256 * 4) == 0 for a, b in plan[:-1])
    # auto on <= 1 device is monolithic (nothing to overlap)
    assert plan_buckets([256 * 24], 1) == (((0, 256 * 24),),)
    # 0 forces monolithic regardless of devices
    assert plan_buckets([256 * 24], 8, bucket_elems=0) == (((0, 256 * 24),),)


def test_choose_bucket_elems_alignment_and_bounds():
    for total in (128 * 1024, 16 * 1024 * 1024):
        for ndev in (2, 4, 8):
            b = choose_bucket_elems(total, ndev)
            assert 0 < b <= total
            assert b == total or b % (256 * ndev) == 0
    # tiny shard: one bucket
    assert choose_bucket_elems(256, 8) == 256
    # launch-dominated regime keeps buckets above the latency floor
    assert ring_collective_seconds(0, 4) > 0  # pure launch cost
    assert ring_collective_seconds(0, 1) == 0.0


def test_exposed_comm_model_bucketing_wins():
    from repro.launch.roofline import exposed_comm_seconds

    n, ndev, budget = 917504, 8, 0.2
    mono = exposed_comm_seconds([n], ndev, budget)
    plan = plan_buckets([n], ndev, bucket_elems=128 * 1024)[0]
    buck = exposed_comm_seconds([b - a for a, b in plan], ndev, budget)
    # monolithic exposes its ENTIRE wire time (1 bucket, ready only when
    # backward completes); the bucketed schedule hides all but the tail
    assert mono > 0
    assert buck < mono
    # with no compute to hide behind, bucketing cannot win (launch
    # overhead makes it strictly worse) — the model must not fantasize
    assert exposed_comm_seconds([b - a for a, b in plan], ndev, 0.0) \
        >= exposed_comm_seconds([n], ndev, 0.0)
    # single device: no interconnect, nothing exposed
    assert exposed_comm_seconds([n], 1, budget) == 0.0


# ---------------------------------------------------------------------------
# bit-parity: bucketed vs monolithic (mesh-less fast path; the 8-device
# mesh version is in the slow tier)


def _setup(n_shards=2, n=256 * 24):
    c = GradCompressor()
    g = tuple(jax.random.normal(jax.random.PRNGKey(i + 1),
                                (n // (i + 1) // 256 * 256,))
              for i in range(n_shards))
    st_ = FlatCompressionState(error=tuple(
        jax.random.normal(jax.random.PRNGKey(40 + i), e.shape) * 1e-3
        for i, e in enumerate(g)))
    return c, g, st_


def _assert_bit_equal(a, b, what):
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y)), what


@pytest.mark.parametrize("bucket_elems", [256, 512, 1000, 4096, 10**9])
def test_bucketed_matches_monolithic_bitwise(bucket_elems):
    c, g, st_ = _setup()
    rng = jax.random.PRNGKey(7)
    mono_g, mono_s = c.allreduce_shards(g, st_, rng, bucket_elems=0)
    bg, bs = c.allreduce_shards(g, st_, rng, bucket_elems=bucket_elems)
    _assert_bit_equal(mono_g, bg, f"deq mismatch at bucket={bucket_elems}")
    _assert_bit_equal(mono_s.error, bs.error,
                      f"error-feedback mismatch at bucket={bucket_elems}")


def test_bucketed_matches_monolithic_none_rng():
    """rng=None (deterministic round-to-nearest) survives bucketing too."""
    c, g, st_ = _setup()
    mono_g, _ = c.allreduce_shards(g, st_, None, bucket_elems=0)
    bg, _ = c.allreduce_shards(g, st_, None, bucket_elems=512)
    _assert_bit_equal(mono_g, bg, "rng=None bucketed mismatch")


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=3000))
def test_bucketed_bit_parity_hypothesis(bucket_elems):
    """Property: EVERY bucket size — aligned, unaligned, straddling
    256-block boundaries, larger than the shard — dequantizes bit-
    identically to monolithic (scales + noise keyed on global index)."""
    c = GradCompressor()
    g = (jax.random.normal(jax.random.PRNGKey(1), (256 * 9,)),)
    st_ = FlatCompressionState(error=(jnp.full((256 * 9,), 1e-3),))
    rng = jax.random.PRNGKey(3)
    mono_g, mono_s = c.allreduce_shards(g, st_, rng, bucket_elems=0)
    bg, bs = c.allreduce_shards(g, st_, rng, bucket_elems=bucket_elems)
    _assert_bit_equal(mono_g, bg, f"deq mismatch at bucket={bucket_elems}")
    _assert_bit_equal(mono_s.error, bs.error,
                      f"EF mismatch at bucket={bucket_elems}")


def test_bucketed_jit_parity_and_shapes():
    """Under one jit program, bucketed == monolithic bitwise (same
    compilation regime), and outputs keep the shard shapes."""
    c, g, st_ = _setup()
    rng = jax.random.PRNGKey(11)
    f = jax.jit(lambda be: c.allreduce_shards(g, st_, rng, bucket_elems=be),
                static_argnums=0)
    mg, ms = f(0)
    bg, bs = f(768)
    _assert_bit_equal(mg, bg, "jit deq mismatch")
    _assert_bit_equal(ms.error, bs.error, "jit EF mismatch")
    assert all(a.shape == b.shape for a, b in zip(g, bg))


def test_layout_bucket_slices_method():
    lay = build_layout({"w": jnp.zeros((300_000,))}, block=256)
    plans = lay.bucket_slices(1024)
    assert len(plans) == len(lay.shard_sizes)
    for n, plan in zip(lay.shard_sizes, plans):
        assert plan[0][0] == 0 and plan[-1][1] == int(n)


# ---------------------------------------------------------------------------
# telemetry


def test_stamp_orders_by_dataflow_and_measures():
    timeline_enable(True)
    try:
        def fn(x):
            t0, x = stamp(x, 0)
            y = x * 2.0
            t1, y = stamp(y, 1)
            return y, delta_seconds(t0, t1)

        y, dt = jax.jit(fn)(jnp.arange(8.0))
        jax.block_until_ready(y)
        np.testing.assert_array_equal(np.asarray(y), np.arange(8.0) * 2)
        assert float(dt) >= 0.0
        recs = decode_timeline()
        assert [r["bucket"] for r in recs] == [0, 0]  # tags 0 then 1
        assert recs[0]["phase"] == "pre" and recs[1]["phase"] == "post"
    finally:
        timeline_enable(False)


def test_allreduce_telemetry_returns_window_and_keeps_values():
    c, g, st_ = _setup(n_shards=1)
    rng = jax.random.PRNGKey(5)
    f = jax.jit(lambda tele: c.allreduce_shards(
        g, st_, rng, bucket_elems=512, telemetry=tele), static_argnums=0)
    plain_g, plain_s = f(False)
    tg, ts, tele = f(True)
    jax.block_until_ready(tg)
    _assert_bit_equal(plain_g, tg, "telemetry changed dequantized values")
    _assert_bit_equal(plain_s.error, ts.error, "telemetry changed EF")
    assert float(tele["comm_seconds"]) >= 0.0
    assert tele["comm_t0"].shape == (2,)


def test_trainer_telemetry_metrics_and_parity():
    """comm_telemetry + bucketing produce the new metrics WITHOUT changing
    the training trajectory."""
    import dataclasses as dc

    from repro.configs.gpt2 import GPT2_TINY
    from repro.data import DataConfig, make_source
    from repro.train.trainer import TrainerConfig, make_train_fns

    cfg = dc.replace(GPT2_TINY, dtype="float32")
    src = make_source(DataConfig(seq_len=32, global_batch=4,
                                 vocab_size=cfg.vocab_size, seed=0))

    def run(**kw):
        tc = TrainerConfig(optimizer="sophia_g", peak_lr=1e-3,
                           total_steps=50, warmup_steps=2, hess_interval=2,
                           hess_subbatch=2, compress_grads=True, seed=0,
                           **kw)
        init_fn, step = make_train_fns(cfg, tc)
        state = init_fn(jax.random.PRNGKey(0))
        sj = jax.jit(step)
        out = []
        for t in range(3):
            batch = {k: jnp.asarray(v) for k, v in src.batch_at(t).items()}
            state, m = sj(state, batch, jnp.asarray(t % 2 == 0))
            out.append(m)
        jax.block_until_ready(state)
        return out

    base = run()
    tele = run(comm_bucket_elems=256 * 17, comm_telemetry=True)
    assert [float(m["loss"]) for m in base] == \
        [float(m["loss"]) for m in tele]
    last = tele[-1]
    for key in ("comm_seconds", "step_seconds", "exposed_comm_fraction"):
        assert key in last and float(last[key]) >= 0.0
    assert float(last["exposed_comm_fraction"]) <= 1.5  # sane, not garbage
    assert "comm_seconds" not in base[-1]


# ---------------------------------------------------------------------------
# elastic: node-loss classification (unit; the subprocess walk is in
# tests/test_multiprocess.py)


def test_is_distributed_failure_classification():
    from repro.train.elastic import NodeLoss, is_distributed_failure

    class XlaRuntimeError(Exception):
        pass

    assert is_distributed_failure(
        XlaRuntimeError("DEADLINE_EXCEEDED: barrier timed out"))
    assert is_distributed_failure(
        RuntimeError("gloo: connection reset by peer"))
    assert not is_distributed_failure(ValueError("connection refused"))
    assert not is_distributed_failure(XlaRuntimeError("shape mismatch"))
    assert issubclass(NodeLoss, RuntimeError)


def test_run_resumable_reraises_node_loss():
    from repro.train.elastic import NodeLoss, run_resumable

    calls = {"n": 0}

    def run(state, start):
        calls["n"] += 1
        raise NodeLoss("peer died")

    with pytest.raises(NodeLoss):
        run_resumable(lambda: 0, run, lambda: None, max_restarts=3)
    assert calls["n"] == 1  # no in-process retry against a dead peer


def test_latency_hiding_flags_platform_keyed():
    from repro.launch.mesh import latency_hiding_flags

    assert latency_hiding_flags("cpu") == ()
    assert all(f.startswith("--xla_tpu") or f.startswith("--xla_")
               for f in latency_hiding_flags("tpu"))
    assert latency_hiding_flags("tpu")
    assert latency_hiding_flags("gpu")
