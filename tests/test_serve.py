"""Serving: batched generation across families, greedy determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.serve import generate


def test_dense_generate_greedy_deterministic():
    cfg = get_config("yi-6b", smoke=True)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0,
                                cfg.vocab_size)
    a = generate(cfg, params, prompt, max_new=6)
    b = generate(cfg, params, prompt, max_new=6)
    assert a.shape == (3, 6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_generate_matches_stepwise_forward():
    """Greedy generation equals argmax over incremental full forwards."""
    cfg = get_config("yi-6b", smoke=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    got = np.asarray(generate(cfg, params, prompt, max_new=4))
    seq = np.asarray(prompt)
    for t in range(4):
        logits, _ = model.forward(cfg, params, jnp.asarray(seq))
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))[:, None]
        assert (nxt[:, 0] == got[:, t]).all(), t
        seq = np.concatenate([seq, nxt], axis=1)


def test_rwkv_generate():
    cfg = get_config("rwkv6-7b", smoke=True)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0,
                                cfg.vocab_size)
    out = generate(cfg, params, prompt, max_new=4)
    assert out.shape == (2, 4)
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.padded_vocab))


def test_griffin_generate():
    cfg = get_config("recurrentgemma-2b", smoke=True)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0,
                                cfg.vocab_size)
    out = generate(cfg, params, prompt, max_new=4)
    assert out.shape == (2, 4)


def test_temperature_sampling_varies():
    cfg = get_config("yi-6b", smoke=True)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                cfg.vocab_size)
    a = generate(cfg, params, prompt, max_new=8, temperature=2.0, seed=0)
    b = generate(cfg, params, prompt, max_new=8, temperature=2.0, seed=1)
    assert not np.array_equal(np.asarray(a), np.asarray(b))
