"""Serving: engine-backed generation across families, greedy determinism,
legacy-parity pinning, and sampling behavior.

Everything here carries the explicit ``serve`` marker so the serve surface
is a selectable tier (`pytest -m serve`) and provably collected in CI.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.serve import generate, generate_lockstep

pytestmark = pytest.mark.serve


def _setup(arch, *, dtype=None, **over):
    cfg = get_config(arch, smoke=True)
    if dtype is not None:
        over["dtype"] = dtype
    if over:
        cfg = dataclasses.replace(cfg, **over)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, model, params


def test_dense_generate_greedy_deterministic():
    cfg, model, params = _setup("yi-6b")
    prompt = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0,
                                cfg.vocab_size)
    a = generate(cfg, params, prompt, max_new=6)
    b = generate(cfg, params, prompt, max_new=6)
    assert a.shape == (3, 6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_generate_matches_stepwise_forward():
    """Greedy generation equals argmax over incremental full forwards."""
    cfg, model, params = _setup("yi-6b", dtype="float32")
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    got = np.asarray(generate(cfg, params, prompt, max_new=4))
    seq = np.asarray(prompt)
    for t in range(4):
        logits, _ = model.forward(cfg, params, jnp.asarray(seq))
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))[:, None]
        assert (nxt[:, 0] == got[:, t]).all(), t
        seq = np.concatenate([seq, nxt], axis=1)


# ---------------------------------------------------------------------------
# pinned: engine greedy decode is token-identical to the legacy lockstep
# path for all four decoder families


@pytest.mark.parametrize("arch", ["yi-6b", "llama4-maverick-400b-a17b",
                                  "rwkv6-7b", "recurrentgemma-2b"])
def test_engine_matches_legacy_greedy(arch):
    # capacity_factor bumped so MoE never drops tokens: capacity contention
    # depends on batch grouping, which legitimately differs between joint
    # legacy prefill and per-slot chunked prefill
    cfg, model, params = _setup(arch, dtype="float32", capacity_factor=8.0)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 7), 0,
                                cfg.vocab_size)
    legacy = np.asarray(generate_lockstep(cfg, params, prompt, max_new=5))
    engine = np.asarray(generate(cfg, params, prompt, max_new=5))
    np.testing.assert_array_equal(engine, legacy)


def test_rwkv_generate():
    cfg, model, params = _setup("rwkv6-7b")
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0,
                                cfg.vocab_size)
    out = generate(cfg, params, prompt, max_new=4)
    assert out.shape == (2, 4)
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.padded_vocab))


def test_griffin_generate():
    cfg, model, params = _setup("recurrentgemma-2b")
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0,
                                cfg.vocab_size)
    out = generate(cfg, params, prompt, max_new=4)
    assert out.shape == (2, 4)


# ---------------------------------------------------------------------------
# sampling (temperature > 0)


def test_temperature_sampling_varies():
    cfg, model, params = _setup("yi-6b")
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                cfg.vocab_size)
    a = generate(cfg, params, prompt, max_new=8, temperature=2.0, seed=0)
    b = generate(cfg, params, prompt, max_new=8, temperature=2.0, seed=1)
    assert not np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ["yi-6b", "rwkv6-7b"])
def test_temperature_sampling_seeded_deterministic(arch):
    """Same seed -> identical samples; across two families."""
    cfg, model, params = _setup(arch)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                cfg.vocab_size)
    a = generate(cfg, params, prompt, max_new=6, temperature=1.0, seed=7)
    b = generate(cfg, params, prompt, max_new=6, temperature=1.0, seed=7)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ["yi-6b", "rwkv6-7b"])
def test_temperature_to_zero_recovers_greedy(arch):
    """T -> 0 sampling collapses onto the greedy trajectory (distribution
    sanity: the categorical at 1e-5 temperature is a point mass)."""
    cfg, model, params = _setup(arch)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                cfg.vocab_size)
    greedy = generate(cfg, params, prompt, max_new=6, temperature=0.0)
    cold = generate(cfg, params, prompt, max_new=6, temperature=1e-5, seed=3)
    np.testing.assert_array_equal(np.asarray(cold), np.asarray(greedy))
