"""Fig 8c reproduction: element-wise clipping is the load-bearing part.

* Clip only (no pre-conditioner)   == sign momentum (Lion-1-beta)
* GNB pre-conditioner without clip == diverges at k >= 5 (paper: k=5)
* Sophia-G (clip + GNB)            == best
We detect divergence as loss explosion / NaN.
"""
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gpt2 import GPT2_TINY
from repro.core import apply_updates
from repro.core.sophia import scale_by_sophia
from repro.train import TrainerConfig, make_train_fns, train_loop

from .common import bench_source, csv_line, run_opt, val_loss


def _sophia_noclip(steps, k, lr=8e-4):
    """Sophia-G with the per-coordinate clip removed (rho -> 1e9).

    Coordinates with tiny |h| now take updates ~ m/max(gamma*h, eps) —
    unbounded; the paper (Fig 8c) reports divergence at k >= 5."""
    src = bench_source()
    init_fn, step = make_train_fns(
        GPT2_TINY, TrainerConfig(optimizer="sophia_g", peak_lr=lr,
                                 total_steps=steps, warmup_steps=2,
                                 hess_interval=k, hess_subbatch=4,
                                 grad_clip=1.0, clip_threshold=1e9))
    state = init_fn(jax.random.PRNGKey(0))
    step = jax.jit(step)
    losses = []
    for t in range(steps):
        batch = {k2: jnp.asarray(v) for k2, v in src.batch_at(t).items()}
        state, m = step(state, batch, jnp.asarray(t % k == 0))
        losses.append(float(m["loss"]))
        if not np.isfinite(losses[-1]) or losses[-1] > 50:
            return losses, True
    return losses, False


def main(quick=False):
    steps = 80 if quick else 160
    out = {}

    st, _, wall = run_opt("signgd", steps, peak_lr=3e-4, weight_decay=0.2)
    out["clip_only(sign momentum)"] = val_loss(st)
    csv_line("ablate_clipping.clip_only", wall * 1e6 / steps,
             f"val={out['clip_only(sign momentum)']:.4f}")

    st, _, wall = run_opt("sophia_g", steps, peak_lr=8e-4, weight_decay=0.2)
    out["sophia_g(clip+gnb)"] = val_loss(st)
    csv_line("ablate_clipping.sophia_g", wall * 1e6 / steps,
             f"val={out['sophia_g(clip+gnb)']:.4f}")

    losses, diverged = _sophia_noclip(steps, k=10)
    out["gnb_no_clip_diverged"] = diverged or losses[-1] > \
        out["sophia_g(clip+gnb)"] + 0.5
    csv_line("ablate_clipping.gnb_no_clip", 0.0,
             f"diverged_or_worse={out['gnb_no_clip_diverged']};"
             f"last={losses[-1]:.3f}")
    return out


if __name__ == "__main__":
    print(main())
