"""Open-loop sustained-load serving benchmark: prefix reuse + int8 KV.

Unlike benchmarks/serve_throughput.py (closed loop: submit everything,
drain), this driver replays a *seeded Poisson arrival schedule* against
the wall clock — the offered load doesn't slow down when the engine
falls behind, which is what exposes tail latency.  The request mix
shares a common prompt preamble (``SHARED_PAGES`` pages), the shape the
prefix cache exists for.

Three sections land under the ``"sustained"`` key of BENCH_serve.json
(merged into the closed-loop report, not replacing it):

  * ``cold`` / ``warm`` — the same arrival schedules at several offered
    loads (fractions of the calibrated closed-loop capacity) without and
    with the prefix cache; p50/p95/p99 TTFT + TPOT, queue wait, SLA
    goodput, slot/pool utilization.  Greedy outputs must be
    token-identical cold vs warm (checked per uid, every load).
  * ``int8`` — capacity at an equal HBM budget: the byte model
    (launch/roofline.kv_cache_slot_bytes) sizes an int8 engine against
    the bf16 engine's KV footprint (checked against jax.Array.nbytes of
    the live state), and both run the same open-loop stream.  Decode
    parity vs the bf16 oracle and the per-token quantization bound ride
    along.
  * ``ok`` — the gate: >= 2x mean-TTFT win at some offered load with
    identity intact, >= 1.7x slots at equal budget, parity <= 1e-2,
    roundtrip error <= scale/2, and the one-prefill/one-decode-program
    invariant.

``--baseline PATH`` diffs a fresh run against the committed JSON and
fails on a >15% p99-TTFT or throughput regression in any matching
cold/warm cell (same offered-load ratio AND request count — an open-loop
run is only comparable to an identically shaped one), mirroring
benchmarks/loss_memory.py; the nightly CI job runs the full sweep so its
cells match the committed report.  ``--smoke`` shrinks loads/request
counts for a quick local pass (its cells then intentionally don't gate).

    PYTHONPATH=src python benchmarks/serve_sustained.py --smoke
"""
import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.ref import decode_attention_ref
from repro.launch.roofline import kv_cache_slot_bytes, kv_slots_at_budget
from repro.models import get_model
from repro.quant import quantize_kv
from repro.serve import Request, ServeEngine

PAGE_LEN = 32
SHARED_PAGES = 8              # common preamble: 8 pages = 256 tokens
TAIL_MAX = 32                 # per-request unique suffix (<= 1 page)
MAX_NEW = 16
N_SLOTS = 4
CACHE_LEN = SHARED_PAGES * PAGE_LEN + TAIL_MAX + MAX_NEW  # engine rounds up
STEPS_PER_TICK = 4
SLA_MULT = 5.0                # SLA = this x the unloaded latency


def bench_config():
    """yi-6b smoke scaled so (a) prefill compute dominates page-copy
    dispatch and (b) E = n_kv_heads * head_dim = 64, where the int8 byte
    model 2E/(E+4) gives 1.88x slots — comfortably past the 1.7x gate
    (the stock smoke config's E=16 would only reach 1.6x)."""
    cfg = get_config("yi-6b", smoke=True)
    return dataclasses.replace(cfg, name="serve-sustained-bench",
                               n_layers=4, d_model=256, head_dim=32,
                               d_ff=512)


def make_requests(cfg, n, seed):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size,
                          SHARED_PAGES * PAGE_LEN).astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(8, TAIL_MAX + 1)))
        reqs.append(Request(uid=i, tokens=np.concatenate(
            [shared, tail]).astype(np.int32), max_new=MAX_NEW))
    return reqs


def new_engine(cfg, params, *, n_slots=N_SLOTS, prefix_cache=False,
               kv_dtype=None):
    return ServeEngine(cfg, params, n_slots=n_slots, cache_len=CACHE_LEN,
                       page_len=PAGE_LEN, steps_per_tick=STEPS_PER_TICK,
                       prefix_cache=prefix_cache,
                       prefix_pool_pages=4 * SHARED_PAGES,
                       kv_dtype=kv_dtype)


def run_open_loop(eng, reqs, arrivals, max_wall_s=600.0):
    """Replay the arrival schedule against the wall clock; returns
    (results, duration_s).  Offered load is independent of service rate:
    late requests queue, they don't throttle the generator."""
    t0 = time.perf_counter()
    i = 0
    while i < len(reqs) or not eng.idle():
        now = time.perf_counter() - t0
        if now > max_wall_s:
            raise RuntimeError("open-loop run exceeded max_wall_s")
        while i < len(reqs) and arrivals[i] <= now:
            eng.submit(reqs[i])
            i += 1
        if eng.idle():
            time.sleep(min(1e-3, max(0.0, arrivals[i] - now)))
            continue
        eng.tick()
    return eng.results, time.perf_counter() - t0


def summarize(eng, results, duration, *, sla_ttft, sla_tpot, load_rps,
              offered_ratio):
    s = eng.stats()
    toks = sum(len(r.tokens) for r in results)
    good = sum(len(r.tokens) for r in results
               if r.ttft_s <= sla_ttft
               and (r.done_t - r.first_token_t) / max(1, len(r.tokens) - 1)
               <= sla_tpot)
    row = {"offered_rps": load_rps, "offered_ratio": offered_ratio,
           "requests": len(results), "duration_s": duration,
           "throughput_tok_s": toks / duration,
           "goodput_tok_s": good / duration,
           "mean_ttft_s": s["mean_ttft_s"],
           "slot_utilization": s["slot_utilization"]}
    for k in ("ttft_p50_s", "ttft_p95_s", "ttft_p99_s", "tpot_p50_s",
              "tpot_p99_s", "queue_wait_p50_s", "queue_wait_p99_s"):
        row[k] = s[k]
    for k in ("prefix_hit_rate", "prefix_pages_reused", "prefix_evictions",
              "prefix_pool_used", "prefix_pool_pages"):
        if k in s:
            row[k] = s[k]
    return row


def int8_numerics(cfg, seed=0):
    """Kernel-level parity + quantization bound for the int8 decode path.

    Cache length spans several pages (ring positions cross >= 2 page
    boundaries); checked in fp32 and bf16 compute dtypes against the
    unquantized bf16-oracle reference."""
    rng = np.random.default_rng(seed)
    N, H, Hkv, hd, C = 3, 4, 2, 32, 4 * PAGE_LEN
    pos = np.array([C // 2 + 3, C - 1, 2 * PAGE_LEN + 5], np.int32)
    out = {}
    for dt in (jnp.float32, jnp.bfloat16):
        q = jnp.asarray(rng.standard_normal((N, H, hd)), dt)
        k = jnp.asarray(rng.standard_normal((N, C, Hkv, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((N, C, Hkv, hd)), jnp.float32)
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        # roundtrip bound: |deq - x| <= scale / 2 per token (deterministic
        # round-to-nearest in repro.quant._quantize)
        deq = kq.astype(np.float32) * np.asarray(ks)[..., None, None]
        rt_err = np.abs(deq - np.asarray(k))
        rt_bound = np.asarray(ks)[..., None, None] / 2 + 1e-6
        oracle = decode_attention_ref(q, k.astype(dt), v.astype(dt),
                                      jnp.asarray(pos))
        got = decode_attention_pallas(q, kq, vq, jnp.asarray(pos),
                                      page_len=PAGE_LEN, k_scale=ks,
                                      v_scale=vs)
        err = float(np.max(np.abs(np.asarray(got, np.float32)
                                  - np.asarray(oracle, np.float32))))
        name = np.dtype(dt).name if dt != jnp.bfloat16 else "bfloat16"
        out[name] = {"parity_max_err": err,
                     "roundtrip_ok": bool((rt_err <= rt_bound).all())}
    out["parity_ok"] = all(v["parity_max_err"] <= 1e-2
                           for v in out.values() if isinstance(v, dict))
    return out


def state_nbytes(state) -> int:
    return int(sum(l.nbytes for l in jax.tree.leaves(state)))


def diff_vs_baseline(report, baseline_path, *, tol=1.15, ttft_slack_s=0.1):
    """Nightly gate: >15% p99-TTFT or throughput regression in any
    cold/warm cell matching on (mode, offered_ratio, request count).

    Small absolute TTFTs also get ``ttft_slack_s`` of absolute headroom —
    a 150ms -> 180ms wiggle on a shared CPU runner is scheduler noise,
    not a regression.  The int8_budget section is deliberately NOT
    throughput-gated: its overloaded 7-slot engine is capacity-checked
    analytically (slots ratio + byte model + parity in ``ok``), and its
    open-loop tok/s swings far more than 15% run to run."""
    with open(baseline_path) as f:
        base = json.load(f).get("sustained")
    if not base:
        return []  # committed report predates the sustained section
    fails = []
    for mode in ("cold", "warm"):
        bcells = {(round(r["offered_ratio"], 3), r["requests"]): r
                  for r in base.get(mode, [])}
        for r in report[mode]:
            b = bcells.get((round(r["offered_ratio"], 3), r["requests"]))
            if b is None:
                continue  # different sweep shape: not comparable
            cell = f"{mode} @ {r['offered_ratio']:.2f}x"
            if (r["ttft_p99_s"] > b["ttft_p99_s"] * tol
                    and r["ttft_p99_s"] > b["ttft_p99_s"] + ttft_slack_s):
                fails.append(f"{cell}: p99 ttft {r['ttft_p99_s']:.3f}s > "
                             f"{tol}x baseline {b['ttft_p99_s']:.3f}s")
            if r["throughput_tok_s"] < b["throughput_tok_s"] / tol:
                fails.append(
                    f"{cell}: throughput {r['throughput_tok_s']:.1f} tok/s "
                    f"< baseline {b['throughput_tok_s']:.1f} / {tol}")
    return fails


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer loads/requests (CI nightly)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "BENCH_serve.json"))
    ap.add_argument("--baseline", default=None,
                    help="diff against a committed BENCH_serve.json; fail "
                         "on >15%% p99-TTFT or throughput regression")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = bench_config()
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(args.seed))
    n_req = 16 if args.smoke else 32
    # smoke keeps the endpoints so its cells match the committed full
    # sweep in the --baseline diff (cells key on offered_ratio)
    ratios = (0.5, 1.5) if args.smoke else (0.5, 1.0, 1.5)

    # --- warmup + calibration: compile everything, measure closed-loop
    # capacity so offered loads are machine-relative ratios ---------------
    for pc, kvd in ((False, None), (True, None), (False, "int8"),
                    (True, "int8")):
        w = new_engine(cfg, params, prefix_cache=pc, kv_dtype=kvd)
        for r in make_requests(cfg, 2, args.seed + 999):
            w.submit(r)
        w.run()
    calib = new_engine(cfg, params)
    calib_reqs = make_requests(cfg, 2 * N_SLOTS, args.seed + 555)
    for r in calib_reqs:
        calib.submit(r)
    t0 = time.perf_counter()
    calib.run()
    calib_s = time.perf_counter() - t0
    cap_rps = len(calib_reqs) / calib_s
    cs = calib.stats()
    sla_ttft = SLA_MULT * cs["mean_ttft_s"]
    sla_tpot = SLA_MULT * max(cs["tpot_p50_s"], 1e-4)
    print(f"calibration: {cap_rps:.2f} req/s closed-loop; "
          f"SLA ttft<={sla_ttft * 1e3:.0f}ms tpot<={sla_tpot * 1e3:.1f}ms")

    # --- cold vs warm across offered loads ------------------------------
    sustained = {"config": {"arch": cfg.name, "n_slots": N_SLOTS,
                            "page_len": PAGE_LEN,
                            "shared_prefix_tokens": SHARED_PAGES * PAGE_LEN,
                            "max_new": MAX_NEW, "cap_rps": cap_rps,
                            "sla_ttft_s": sla_ttft, "sla_tpot_s": sla_tpot},
                 "cold": [], "warm": []}
    identical = True
    jit_cache_one = True
    for ratio in ratios:
        rps = ratio * cap_rps
        reqs = make_requests(cfg, n_req, args.seed + int(ratio * 100))
        rng = np.random.default_rng(args.seed + int(ratio * 1000))
        arrivals = rng.exponential(1.0 / rps, n_req).cumsum()
        outs = {}
        for mode, pc in (("cold", False), ("warm", True)):
            eng = new_engine(cfg, params, prefix_cache=pc)
            res, dur = run_open_loop(eng, make_requests(
                cfg, n_req, args.seed + int(ratio * 100)), arrivals)
            row = summarize(eng, res, dur, sla_ttft=sla_ttft,
                            sla_tpot=sla_tpot, load_rps=rps,
                            offered_ratio=ratio)
            sustained[mode].append(row)
            outs[mode] = {r.uid: r.tokens for r in res}
            jit_cache_one &= (eng._prefill_jit._cache_size() == 1
                              and eng._burst_jit._cache_size() == 1)
            print(f"{mode:4s} @ {ratio:.1f}x ({rps:.2f} rps): mean ttft "
                  f"{row['mean_ttft_s'] * 1e3:.0f}ms p99 "
                  f"{row['ttft_p99_s'] * 1e3:.0f}ms goodput "
                  f"{row['goodput_tok_s']:.0f} tok/s", flush=True)
        identical &= outs["cold"] == outs["warm"]
        del reqs
    speedups = [c["mean_ttft_s"] / max(w["mean_ttft_s"], 1e-9)
                for c, w in zip(sustained["cold"], sustained["warm"])]
    sustained["ttft_speedup_by_load"] = speedups

    # --- int8 at an equal HBM budget ------------------------------------
    rounded_c = -(-CACHE_LEN // PAGE_LEN) * PAGE_LEN  # engine page-rounds
    slot_b_bf16 = kv_cache_slot_bytes(cfg, rounded_c, kv_dtype="bf16")
    budget = N_SLOTS * slot_b_bf16
    n_int8 = kv_slots_at_budget(cfg, rounded_c, budget, kv_dtype="int8")
    ratio_rps = (1.0 if args.smoke else 1.5) * cap_rps
    budget_rows = {}
    for side, kvd, ns in (("bf16", None, N_SLOTS), ("int8", "int8", n_int8)):
        eng = new_engine(cfg, params, n_slots=ns, kv_dtype=kvd)
        measured = state_nbytes(eng.state)
        predicted = ns * kv_cache_slot_bytes(cfg, eng.cache_len,
                                             kv_dtype=kvd or "bf16")
        reqs = make_requests(cfg, n_req, args.seed + 777)
        rng = np.random.default_rng(args.seed + 778)
        arrivals = rng.exponential(1.0 / ratio_rps, n_req).cumsum()
        res, dur = run_open_loop(eng, reqs, arrivals)
        row = summarize(eng, res, dur, sla_ttft=sla_ttft, sla_tpot=sla_tpot,
                        load_rps=ratio_rps, offered_ratio=ratio_rps / cap_rps)
        row.update(n_slots=ns, kv_state_bytes_measured=measured,
                   kv_state_bytes_model=predicted)
        budget_rows[side] = row
        print(f"{side}: {ns} slots in budget {budget / 1e6:.2f}MB "
              f"(state {measured / 1e6:.2f}MB measured vs "
              f"{predicted / 1e6:.2f}MB model), goodput "
              f"{row['goodput_tok_s']:.0f} tok/s", flush=True)
    numerics = int8_numerics(cfg, args.seed)
    sustained["int8_budget"] = {
        "hbm_budget_bytes": budget, "slots_bf16": N_SLOTS,
        "slots_int8": n_int8, "slots_ratio": n_int8 / N_SLOTS,
        "bf16": budget_rows["bf16"], "int8": budget_rows["int8"],
        "numerics": numerics}

    sustained["ok"] = {
        "warm_tokens_identical_to_cold": bool(identical),
        "ttft_speedup_ge_2x": bool(max(speedups) >= 2.0),
        "int8_slots_ratio_ge_1_7x": bool(n_int8 / N_SLOTS >= 1.7),
        "int8_state_bytes_match_model": all(
            budget_rows[s]["kv_state_bytes_measured"]
            == budget_rows[s]["kv_state_bytes_model"]
            for s in ("bf16", "int8")),
        "int8_decode_parity_le_1e2": bool(numerics["parity_ok"]),
        "int8_roundtrip_in_bound": all(
            v["roundtrip_ok"] for v in numerics.values()
            if isinstance(v, dict)),
        "one_program_per_side": bool(jit_cache_one),
    }

    # merge into the closed-loop report rather than clobbering it
    report = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            report = json.load(f)
    report["sustained"] = sustained
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print("ttft speedup by load:",
          [f"{s:.2f}x" for s in speedups])
    print("ok:", sustained["ok"], "->", args.out)
    if args.baseline:
        fails = diff_vs_baseline(sustained, args.baseline)
        for msg in fails:
            print("REGRESSION:", msg)
        if fails:
            raise SystemExit(1)
    if not all(sustained["ok"].values()):
        raise SystemExit(1)
    return report


if __name__ == "__main__":
    main()
