"""Roofline table from the dry-run sweeps (EXPERIMENTS.md Section Roofline).

Reads results/dryrun_single.json (+ _multi), prints the per-cell three-term
roofline, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness, and a
one-line what-would-help note.

For train cells it also prints the per-stage kernel overlays (the Pallas
calls are opaque to XLA's cost model, so the dry-run t_memory charges the
UNFUSED path for both): ``loss_stage_seconds`` (fused CE) and
``attention_stage_seconds`` (flash attention) fused-vs-unfused, i.e. how
much of the cell's memory term each kernel deletes.
"""
import json
import os

from .common import csv_line

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

_ADVICE = {
    "memory": "fuse attention/update (cut HBM round-trips), raise arithmetic"
              " intensity per byte",
    "collective": "shard activations over model (sequence parallel), "
                  "compress DP gradients, overlap collectives with scan",
    "compute": "near roofline: raise MFU via remat policy / larger "
               "microbatch",
}


def load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def report(rows, tag):
    out = []
    for r in rows:
        if r.get("skipped"):
            csv_line(f"roofline.{tag}.{r['arch']}.{r['shape']}", 0.0,
                     "SKIP:" + r["skipped"][:60])
            continue
        if r.get("error"):
            csv_line(f"roofline.{tag}.{r['arch']}.{r['shape']}", 0.0,
                     "ERROR:" + r["error"][:60])
            continue
        t = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        frac = r["t_compute_s"] / t * r.get("useful_flops_ratio", 0) if t else 0
        csv_line(
            f"roofline.{tag}.{r['arch']}.{r['shape']}", t * 1e6,
            f"tc={r['t_compute_s']:.3f};tm={r['t_memory_s']:.3f};"
            f"tcoll={r['t_collective_s']:.3f};dom={r['dominant']};"
            f"useful={r.get('useful_flops_ratio', 0):.2f};"
            f"roofline_frac={frac:.3f};mem={r['mem_peak_gb']:.1f}GB")
        out.append(dict(r, roofline_frac=frac))
    return out


def stage_overlays(rows, tag):
    """Fused-vs-unfused kernel-stage overlay per train cell (analytic —
    Pallas kernels never appear in the dry-run HLO)."""
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    from repro.launch.roofline import (attention_stage_seconds,
                                       loss_stage_seconds)
    out = []
    for r in rows:
        if r.get("skipped") or r.get("error"):
            continue
        sh = SHAPES.get(r.get("shape") or "", {})
        if sh.get("kind") != "train":
            continue
        cfg = get_config(r["arch"])
        B, S = sh["batch"], sh["seq"]
        loss_f = loss_stage_seconds(B * S, cfg.d_model, cfg.padded_vocab,
                                    fused=True)
        loss_u = loss_stage_seconds(B * S, cfg.d_model, cfg.padded_vocab,
                                    fused=False)
        attn_f = cfg.n_layers * attention_stage_seconds(
            B, cfg.n_heads, cfg.n_kv_heads, S, cfg.hd, fused=True)
        attn_u = cfg.n_layers * attention_stage_seconds(
            B, cfg.n_heads, cfg.n_kv_heads, S, cfg.hd, fused=False)
        csv_line(
            f"roofline.{tag}.{r['arch']}.{r['shape']}.stages",
            (loss_u - loss_f + attn_u - attn_f) * 1e6,
            f"loss_fused={loss_f:.4f};loss_unfused={loss_u:.4f};"
            f"attn_fused={attn_f:.4f};attn_unfused={attn_u:.4f};"
            f"t_memory={r['t_memory_s']:.4f}")
        out.append({"arch": r["arch"], "shape": r["shape"],
                    "loss_fused_s": loss_f, "loss_unfused_s": loss_u,
                    "attn_fused_s": attn_f, "attn_unfused_s": attn_u})
    return out


def main(quick=False):
    single = report(load("dryrun_single.json"), "1pod")
    multi = report(load("dryrun_multi.json"), "2pod")
    stages = stage_overlays(single, "1pod")
    return {"single_cells": len(single), "multi_cells": len(multi),
            "stage_overlays": len(stages)}


if __name__ == "__main__":
    print(main())
