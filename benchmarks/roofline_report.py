"""Roofline table from the dry-run sweeps (EXPERIMENTS.md Section Roofline).

Reads results/dryrun_single.json (+ _multi), prints the per-cell three-term
roofline, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness, and a
one-line what-would-help note.
"""
import json
import os

from .common import csv_line

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

_ADVICE = {
    "memory": "fuse attention/update (cut HBM round-trips), raise arithmetic"
              " intensity per byte",
    "collective": "shard activations over model (sequence parallel), "
                  "compress DP gradients, overlap collectives with scan",
    "compute": "near roofline: raise MFU via remat policy / larger "
               "microbatch",
}


def load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def report(rows, tag):
    out = []
    for r in rows:
        if r.get("skipped"):
            csv_line(f"roofline.{tag}.{r['arch']}.{r['shape']}", 0.0,
                     "SKIP:" + r["skipped"][:60])
            continue
        if r.get("error"):
            csv_line(f"roofline.{tag}.{r['arch']}.{r['shape']}", 0.0,
                     "ERROR:" + r["error"][:60])
            continue
        t = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        frac = r["t_compute_s"] / t * r.get("useful_flops_ratio", 0) if t else 0
        csv_line(
            f"roofline.{tag}.{r['arch']}.{r['shape']}", t * 1e6,
            f"tc={r['t_compute_s']:.3f};tm={r['t_memory_s']:.3f};"
            f"tcoll={r['t_collective_s']:.3f};dom={r['dominant']};"
            f"useful={r.get('useful_flops_ratio', 0):.2f};"
            f"roofline_frac={frac:.3f};mem={r['mem_peak_gb']:.1f}GB")
        out.append(dict(r, roofline_frac=frac))
    return out


def main(quick=False):
    single = report(load("dryrun_single.json"), "1pod")
    multi = report(load("dryrun_multi.json"), "2pod")
    return {"single_cells": len(single), "multi_cells": len(multi)}


if __name__ == "__main__":
    print(main())
