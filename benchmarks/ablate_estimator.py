"""Fig 8b reproduction: diagonal pre-conditioner choice under the same
clipping — Empirical Fisher vs GNB (Sophia-G) vs Hutchinson (Sophia-H)
vs AdaHessian(EMA of squared estimates)."""
import time

from .common import csv_line, run_opt, val_loss


def main(quick=False):
    steps = 100 if quick else 150
    lrs = (8e-4,) if quick else (8e-4, 2e-3)
    runs = {
        "sophia_g(gnb)": dict(optimizer="sophia_g", estimator="gnb",
                              weight_decay=0.2),
        "sophia_h(hutchinson)": dict(optimizer="sophia_h",
                                     estimator="hutchinson",
                                     weight_decay=0.2),
        "ef+clip": dict(optimizer="sophia_g", estimator="empirical_fisher",
                        weight_decay=0.2),
        "adahessian": dict(optimizer="adahessian", estimator="hutchinson",
                           hess_interval=1),
    }
    out = {}
    for name, kw in runs.items():
        t0 = time.time()
        # per-arm LR grid (the paper tunes each method's LR separately)
        best = None
        opt = kw.pop("optimizer")
        for lr in lrs:
            st, _, wall = run_opt(opt, steps, peak_lr=lr, **kw)
            l = val_loss(st)
            if best is None or l < best[0]:
                best = (l, lr)
        out[name] = best[0]
        csv_line(f"ablate_estimator.{name}", wall * 1e6 / steps,
                 f"val={best[0]:.4f};lr={best[1]}")
    return out


if __name__ == "__main__":
    print(main())
