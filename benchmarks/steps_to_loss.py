"""Fig 1/4/5 reproduction: Sophia reaches the baseline's loss in ~half the
steps, judged by the paper's own methodology (Section 3.2, eq. 14):

    Eval(AdamW, T, best H) >= Eval(Sophia, T/2, some H)

AdamW's cosine schedule is tuned *for T*; Sophia's for T/2 (both pinned to
their own budget, as the paper insists).  CPU-scale: 30M-class tiny GPT-2 on
the synthetic corpus.
"""
import time

import numpy as np

from .common import bench_source, csv_line, run_opt, val_loss


def main(T=240, quick=False):
    if quick:
        T = 120
    t0 = time.time()
    # AdamW with budget T (paper-tuned betas 0.9/0.95, wd 0.1; lr grid)
    best_adam = None
    for lr in (3e-4, 1e-3):
        st, _, _ = run_opt("adamw", T, peak_lr=lr, weight_decay=0.1)
        l = val_loss(st)
        if best_adam is None or l < best_adam[0]:
            best_adam = (l, lr)
    adam_loss, adam_lr = best_adam

    # Sophia-G with budget T/2 (lr = 0.8x AdamW's per Section 3.1)
    st, hist, _ = run_opt("sophia_g", T // 2, peak_lr=0.8 * adam_lr,
                          weight_decay=0.2, hess_interval=10)
    sophia_half_loss = val_loss(st)

    # and with the full budget for the loss-at-same-steps view (Fig 5)
    st_full, _, _ = run_opt("sophia_g", T, peak_lr=0.8 * adam_lr,
                            weight_decay=0.2, hess_interval=10)
    sophia_full_loss = val_loss(st_full)

    us = (time.time() - t0) * 1e6 / (T * 3)
    speedup2x = sophia_half_loss <= adam_loss
    csv_line("steps_to_loss.adamw_T", us,
             f"val={adam_loss:.4f};lr={adam_lr}")
    csv_line("steps_to_loss.sophia_T/2", us,
             f"val={sophia_half_loss:.4f};2x_criterion_met={speedup2x}")
    csv_line("steps_to_loss.sophia_T", us, f"val={sophia_full_loss:.4f}")
    return {"adam_T": adam_loss, "sophia_half": sophia_half_loss,
            "sophia_T": sophia_full_loss, "criterion_eq14": bool(speedup2x)}


if __name__ == "__main__":
    print(main())
