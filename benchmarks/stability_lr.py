"""Fig 7b probe: maximum stable learning rate with/without the
attention-temperature trick (Karamcheti/Mistral).

VERDICT AT CPU SCALE: not falsifiable.  The paper's instability (AdamW
needing QK scaling by inverse layer index to reach 3e-4 on 355M/24L)
arises from attention-entropy collapse at depth and width we cannot reach
on CPU; at toy scale (12L, d=128) global-norm clipping keeps AdamW
"stable" at any LR while sign-like Sophia steps degrade a tiny model at
absurd LRs (0.1+) for unrelated reasons.  We report the ladder measured
and mark the claim as requiring model scale — the trick itself is
implemented (`attn_temperature_by_layer`) and unit-tested
(tests/test_models.py::test_attention_temperature_trick).
"""
import dataclasses
import time

import numpy as np

from repro.configs.gpt2 import _gpt2
from repro.data import DataConfig, make_source
from repro.train import TrainerConfig, train_loop

from .common import csv_line

CFG = _gpt2("gpt2-deep", 128, 8, 4, ctx=128, vocab=512)
LADDER = (1e-3, 3e-3, 1e-2, 3e-2)


def _stable(optimizer, lr, trick, steps):
    cfg = dataclasses.replace(CFG, attn_temperature_by_layer=trick)
    tc = TrainerConfig(optimizer=optimizer, peak_lr=lr, total_steps=steps,
                       warmup_steps=2, hess_subbatch=4,
                       weight_decay=0.1 if optimizer == "adamw" else 0.2)
    src = make_source(DataConfig(seq_len=64, global_batch=8,
                                 vocab_size=cfg.vocab_size, seed=0))
    _, hist = train_loop(cfg, tc, src, num_steps=steps)
    losses = [h["loss"] for h in hist]
    return np.isfinite(losses[-1]) and losses[-1] < losses[0] + 1.0


def max_stable_lr(optimizer, trick, steps):
    best = 0.0
    for lr in LADDER:
        if _stable(optimizer, lr, trick, steps):
            best = lr
        else:
            break
    return best


def main(quick=False):
    steps = 25 if quick else 40
    t0 = time.time()
    rows = {
        "adamw_no_trick": max_stable_lr("adamw", False, steps),
        "adamw_with_trick": max_stable_lr("adamw", True, steps),
        "sophia_no_trick": max_stable_lr("sophia_g", False, steps),
    }
    us = (time.time() - t0) * 1e6 / (3 * len(LADDER) * steps)
    csv_line("stability_lr.max_stable", us,
             ";".join(f"{k}={v}" for k, v in rows.items())
             + ";verdict=not_falsifiable_at_toy_scale(see module docstring)")
    return rows


if __name__ == "__main__":
    print(main())
