"""Serve-engine throughput: continuous batching vs legacy lockstep.

Drives the same mixed-length request set through (a) the slot-based
continuous-batching engine (compiled burst decode) and (b) the legacy
``generate_lockstep`` path (Python token loop, fixed batches padded to the
longest request).  Compile/warmup is measured separately for both sides;
steady-state tok/s, per-token latency and slot utilization land in
``BENCH_serve.json``.

    PYTHONPATH=src python benchmarks/serve_throughput.py
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.serve import Request, ServeEngine, generate_lockstep

ARCH = "yi-6b"
N_SLOTS = 4
PAGE_LEN = 8
STEPS_PER_TICK = 4
# mixed-length request set: (prompt_len, max_new)
REQUESTS = [(6, 24), (14, 6), (8, 18), (20, 8), (4, 24), (12, 12),
            (16, 4), (6, 16)]
CACHE_LEN = 48


def make_prompts(cfg, seed=0):
    return [np.asarray(jax.random.randint(jax.random.PRNGKey(seed + i),
                                          (sp,), 0, cfg.vocab_size))
            for i, (sp, _) in enumerate(REQUESTS)]


def run_engine(cfg, params, prompts):
    def one_pass():
        eng = ServeEngine(cfg, params, n_slots=N_SLOTS, cache_len=CACHE_LEN,
                          page_len=PAGE_LEN, steps_per_tick=STEPS_PER_TICK)
        for i, (p, (_, mn)) in enumerate(zip(prompts, REQUESTS)):
            eng.submit(Request(uid=i, tokens=p, max_new=mn))
        t0 = time.perf_counter()
        res = eng.run()
        return eng, res, time.perf_counter() - t0

    t0 = time.perf_counter()
    one_pass()                                   # warmup / compile
    compile_s = time.perf_counter() - t0
    eng, res, dt = one_pass()                    # steady state
    stats = eng.stats()
    toks = sum(len(r.tokens) for r in res)
    return {"compile_s": compile_s, "steady_s": dt, "tokens": toks,
            "tok_s": toks / dt,
            "slot_utilization": stats["slot_utilization"],
            "token_lat_p50_s": stats["token_lat_p50_s"],
            "token_lat_p95_s": stats["token_lat_p95_s"]}, res


def run_lockstep(cfg, params, prompts):
    """Legacy baseline: fixed batches of N_SLOTS, every batch padded to its
    longest prompt and decoded for its longest max_new (lockstep)."""
    def one_pass():
        t0 = time.perf_counter()
        toks = 0
        for b0 in range(0, len(REQUESTS), N_SLOTS):
            group = list(range(b0, min(b0 + N_SLOTS, len(REQUESTS))))
            sp = max(REQUESTS[i][0] for i in group)
            mn = max(REQUESTS[i][1] for i in group)
            batch = np.stack([np.pad(prompts[i], (sp - len(prompts[i]), 0))
                              for i in group])
            out = generate_lockstep(cfg, params, jax.numpy.asarray(batch),
                                    max_new=mn, max_len=CACHE_LEN)
            out.block_until_ready()
            # only the per-request requested tokens count as useful output
            toks += sum(REQUESTS[i][1] for i in group)
        return toks, time.perf_counter() - t0

    t0 = time.perf_counter()
    one_pass()                                   # warmup / compile
    compile_s = time.perf_counter() - t0
    toks, dt = one_pass()                        # steady state
    return {"compile_s": compile_s, "steady_s": dt, "tokens": toks,
            "tok_s": toks / dt}


def main():
    cfg = get_config(ARCH, smoke=True)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = make_prompts(cfg)

    engine, _ = run_engine(cfg, params, prompts)
    lockstep = run_lockstep(cfg, params, prompts)
    speedup = engine["tok_s"] / lockstep["tok_s"]

    report = {"arch": cfg.name, "n_slots": N_SLOTS, "page_len": PAGE_LEN,
              "steps_per_tick": STEPS_PER_TICK,
              "requests": REQUESTS, "engine": engine, "lockstep": lockstep,
              "speedup": speedup}
    out = os.path.join(os.path.dirname(__file__), "BENCH_serve.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))
    print(f"\nengine {engine['tok_s']:.1f} tok/s vs lockstep "
          f"{lockstep['tok_s']:.1f} tok/s -> {speedup:.2f}x")
    return report


if __name__ == "__main__":
    main()
