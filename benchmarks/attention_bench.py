"""Training attention: fused flash vs materialized-scores step time + memory.

Measures one attention layer (x -> qkv -> attention -> out proj, loss +
grad) across an (S x window x softcap) grid for the two training routes
(``models.layers.train_attention``):

  * ``impl="flash"``    the Pallas fused kernel (custom_vjp bwd), the
                        ``TrainerConfig.fused_attn`` default
  * ``impl="full"``     the XLA materialized-scores reference path

and records per cell:

  * ``ms``               wall time per loss+grad call (best of reps)
  * ``temp_bytes``       XLA's compiled peak temp allocation
  * ``max_buffer_numel`` largest buffer in the optimized HLO
  * ``has_score_buffer`` whether any buffer of >= S*S elements survives —
                         the (.., S, S) fp32 score residency the fused
                         path exists to eliminate
  * ``model_hbm_bytes``  the analytic traffic model
                         (kernels.flash_attention.attention_hbm_bytes_*)
  * ``bq/bk/schedule``   the autotuned block config for flash cells

plus an end-to-end train smoke (GPT2_TINY, sophia_g + Hutchinson) with
``fused_attn`` on/off, asserting via ``KERNEL_CALLS`` that all four flash
kernels (fwd, dQ, dKV, jvp rule) actually traced — no silent fallback.
Emits ``benchmarks/BENCH_attn.json``.

The ``ok`` block fails the run (exit 1) if any flash cell keeps an (S, S)
score buffer, loses to the unfused path on wall time, or fails to shrink
the max live buffer; ``--baseline PATH`` additionally diffs a fresh run
against the committed JSON and fails on a >15% step-time regression or
ANY max-live-buffer growth (the nightly CI job).

Note: on CPU the Pallas kernel runs in interpret mode (grid unrolled into
the jit, auto-clamped to <= 64 cells), so absolute wall times are NOT
hardware-representative — the grid starts at S=1024 because that is where
streaming beats materialization even under the interpreter; on a real
backend the crossover sits far lower.  The fused-vs-unfused comparison is
apples-to-apples (same backend, same compiled-program measurement) and
the residency audit is exact.
"""
import argparse
import json
import os
import re
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.kernels.autotune import get_tuned_attn
from repro.kernels.flash_attention import (attention_hbm_bytes_train_flash,
                                           attention_hbm_bytes_unfused)
from repro.kernels.fused_ce import KERNEL_CALLS, _interpret_default
from repro.models.common import ModelConfig
from repro.models.layers import train_attention

_SHAPE = re.compile(r"(?:f32|f16|bf16|s32|u32|pred|s8|u8)\[([0-9,]+)\]")

# one attention layer's dims; hd << S so legitimate (B, H, S, hd)
# activations never collide with the S*S score-residency threshold
B, H, HKV, HD = 2, 4, 2, 64
D = H * HD


def _max_buffer_numel(hlo_text: str) -> int:
    best = 0
    for dims in _SHAPE.findall(hlo_text):
        n = 1
        for d in dims.split(","):
            n *= int(d)
        best = max(best, n)
    return best


def _mk_cfg(softcap):
    return ModelConfig(name="attn-bench", family="dense", n_layers=1,
                       d_model=D, n_heads=H, n_kv_heads=HKV, d_ff=4 * D,
                       vocab_size=512, dtype="float32",
                       attn_logit_softcap=softcap)


def prepare_attn_stage(S, window, softcap, impl):
    """Compile + audit one grid cell; defer timing to the caller.

    Returns ``(row, run)``; the driver interleaves ``run`` calls across
    impls within a cell so machine-speed drift hits both equally."""
    cfg = _mk_cfg(softcap)
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, D), jnp.float32)
    p = {"wq": 0.05 * jax.random.normal(ks[1], (D, H * HD), jnp.float32),
         "wk": 0.05 * jax.random.normal(ks[2], (D, HKV * HD), jnp.float32),
         "wv": 0.05 * jax.random.normal(ks[3], (D, HKV * HD), jnp.float32),
         "wo": 0.05 * jax.random.normal(ks[4], (H * HD, D), jnp.float32)}
    positions = jnp.arange(S)[None, :].repeat(B, 0)

    tuned = None
    if impl == "flash":
        # the roofline pick flash_attention resolves at trace time — so a
        # regression is attributable to tuning vs kernel changes
        tuned = get_tuned_attn(B, H, HKV, S, S, HD, dtype="float32",
                               causal=True, softcap=softcap,
                               interpret=_interpret_default())

    def f(p_, x_):
        o = train_attention(p_, x_, cfg, positions, window=window,
                            impl=impl)
        return jnp.sum(o * o)

    g = jax.jit(jax.value_and_grad(f, argnums=(0, 1)))
    compiled = g.lower(p, x).compile()
    temp = int(compiled.memory_analysis().temp_size_in_bytes)
    max_numel = _max_buffer_numel(compiled.as_text())
    jax.block_until_ready(g(p, x))
    model_bytes = (attention_hbm_bytes_train_flash(B, H, HKV, S, HD,
                                                   bytes_per_el=4)
                   if impl == "flash" else
                   attention_hbm_bytes_unfused(B, H, S, HD, passes=5))
    row = {"S": S, "window": window, "softcap": softcap, "impl": impl,
           "temp_bytes": temp, "max_buffer_numel": max_numel,
           "has_score_buffer": bool(max_numel >= S * S),
           "model_hbm_bytes": int(model_bytes)}
    if tuned is not None:
        row.update(bq=tuned.bq, bk=tuned.bk, schedule=tuned.schedule,
                   tuned_source=tuned.source)

    def run():
        t0 = time.perf_counter()
        jax.block_until_ready(g(p, x))
        return time.perf_counter() - t0

    return row, run


def bench_train_smoke(steps=6):
    """Full train-step wall time, ``fused_attn`` on vs off.

    GPT2_TINY at its full 256-token context, sophia_g with the Hutchinson
    estimator so the refresh crosses the kernel's custom_jvp twin; the
    fused run clears and then checks ``KERNEL_CALLS`` to prove all four
    flash kernels traced (no chunked/full fallback)."""
    from repro.configs.gpt2 import GPT2_TINY
    from repro.data import DataConfig, make_source
    from repro.train import TrainerConfig, train_loop

    out = {}
    for fused in (False, True):
        if fused:
            KERNEL_CALLS.clear()
        src = make_source(DataConfig(seq_len=256, global_batch=4,
                                     vocab_size=512, seed=0))
        tc = TrainerConfig(optimizer="sophia_g", peak_lr=3e-4,
                           total_steps=steps, hess_interval=3,
                           hess_subbatch=4, estimator="hutchinson",
                           seed=0, fused_attn=fused)
        # steps 0 (hot-path compile) and 1 (first compiled refresh) are
        # dropped so the gate measures steady state, not compile time
        stamps = [time.perf_counter()]
        _, hist = train_loop(
            GPT2_TINY, tc, src, num_steps=steps,
            callback=lambda *_: stamps.append(time.perf_counter()))
        deltas = [b - a for a, b in zip(stamps[2:-1], stamps[3:])]
        tag = "fused" if fused else "unfused"
        out[f"{tag}_ms"] = 1e3 * sum(deltas) / len(deltas)
        out[f"{tag}_loss_final"] = hist[-1]["loss"]
    out["flash_kernel_calls"] = {k: KERNEL_CALLS[k] for k in
                                 ("attn_fwd", "attn_bwd_dq",
                                  "attn_bwd_dkv", "attn_jvp_rule")}
    return out


def diff_vs_baseline(report, baseline_path, *, ms_tol=1.15):
    """Nightly regression diff: fresh ``report`` vs the committed JSON.

    Fails (returns a non-empty list of reasons) on a >15% step-time
    regression in any matching cell or the train smoke, or on ANY growth
    of a cell's max live buffer."""
    with open(baseline_path) as f:
        base = json.load(f)
    key = lambda r: (r["S"], r["window"], r["softcap"], r["impl"])  # noqa: E731
    bcells = {key(r): r for r in base["attn_stage"]}
    fails = []
    for r in report["attn_stage"]:
        b = bcells.get(key(r))
        if b is None:
            continue  # new cell: no baseline to regress against
        cell = (f"S={r['S']} win={r['window']} cap={r['softcap']} "
                f"{r['impl']}")
        if r["ms"] > b["ms"] * ms_tol:
            fails.append(f"{cell}: ms {r['ms']:.2f} > {ms_tol}x baseline "
                         f"{b['ms']:.2f}")
        if r["max_buffer_numel"] > b["max_buffer_numel"]:
            fails.append(f"{cell}: max live buffer grew "
                         f"{b['max_buffer_numel']:,} -> "
                         f"{r['max_buffer_numel']:,} elements")
    bt, nt = base.get("train_smoke", {}), report["train_smoke"]
    for k in ("unfused_ms", "fused_ms"):
        if k in bt and nt[k] > bt[k] * ms_tol:
            fails.append(f"train smoke {k}: {nt[k]:.1f} > {ms_tol}x "
                         f"baseline {bt[k]:.1f}")
    return fails


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="diagonal of the grid + fewer reps (nightly CI)")
    ap.add_argument("--out", default="benchmarks/BENCH_attn.json")
    ap.add_argument("--baseline", default=None,
                    help="diff against a committed BENCH_attn.json and "
                         "fail on >15%% step time or any max-live-buffer "
                         "regression (nightly CI)")
    args = ap.parse_args()

    seqs = (1024, 2048)
    if args.smoke:
        combos, reps = ((None, None), (128, 8.0)), 3
    else:
        combos = ((None, None), (None, 8.0), (128, None), (128, 8.0))
        reps = 5

    rows = []
    for S in seqs:
        for window, softcap in combos:
            cells = [(impl, *prepare_attn_stage(S, window, softcap, impl))
                     for impl in ("full", "flash")]
            best = {impl: float("inf") for impl, _, _ in cells}
            for _ in range(reps):
                for impl, _, run in cells:
                    best[impl] = min(best[impl], run())
            for impl, r, _ in cells:
                r["ms"] = best[impl] * 1e3
                rows.append(r)
                blk = (f" bq={r['bq']}/bk={r['bk']}/{r['schedule']}"
                       if impl == "flash" else "")
                print(f"S={S:5d} win={str(window):4s} cap={str(softcap):4s} "
                      f"{impl:5s} max={r['max_buffer_numel']:>11,}el "
                      f"score_buf={str(r['has_score_buffer']):5s} "
                      f"{r['ms']:8.2f}ms{blk}", flush=True)

    train = bench_train_smoke()
    print(f"train smoke: unfused {train['unfused_ms']:.1f}ms/step, "
          f"fused (default) {train['fused_ms']:.1f}ms/step, "
          f"kernels {train['flash_kernel_calls']}")

    by = lambda impl: [r for r in rows if r["impl"] == impl]  # noqa: E731
    full_ms = {(r["S"], r["window"], r["softcap"]): r["ms"]
               for r in by("full")}
    full_buf = {(r["S"], r["window"], r["softcap"]): r["max_buffer_numel"]
                for r in by("full")}
    ok = {
        # the acceptance criterion: no (.., S, S) score residency on the
        # fused path at any grid point
        "flash_score_free": not any(r["has_score_buffer"]
                                    for r in by("flash")),
        # sanity: the unfused path really does materialize it
        "full_materializes": all(r["has_score_buffer"]
                                 for r in by("full")),
        # ... and the fused path's biggest live buffer is strictly smaller
        "flash_shrinks_live_buffer": all(
            r["max_buffer_numel"]
            < full_buf[(r["S"], r["window"], r["softcap"])]
            for r in by("flash")),
        # the tentpole's exit criterion: fused <= unfused wall time in
        # every grid cell
        "flash_beats_full": all(
            r["ms"] <= full_ms[(r["S"], r["window"], r["softcap"])]
            for r in by("flash")),
        # the trainer default actually ran all four flash kernels
        "train_smoke_flash_engaged": all(
            v > 0 for v in train["flash_kernel_calls"].values()),
        # same objective being optimized (route parity, loose: six steps
        # of independent fp32 rounding)
        "train_smoke_loss_close": abs(train["fused_loss_final"]
                                      - train["unfused_loss_final"]) < 0.05,
    }
    report = {"smoke": args.smoke, "attn_stage": rows,
              "train_smoke": train, "ok": ok}
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print("ok:", ok, "->", args.out)
    if args.baseline:
        fails = diff_vs_baseline(report, args.baseline)
        for msg in fails:
            print("REGRESSION:", msg)
        if fails:
            raise SystemExit(1)
    if not all(ok.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
