"""Exposed-comm benchmark for the bucketed overlapped gradient reduction.

Measures what distributed/overlap.py is FOR: how much of the compressed
data-parallel collective's time survives on the step critical path.  Two
complementary views land in BENCH_comm.json:

**Measured (wall clock, this host).**  Per cell: median step time without
compression, monolithic, and bucketed, plus the differential
``measured step - no-comm step`` per variant, the trainer's in-graph
telemetry, and a per-bucket TIMELINE from one instrumented step.  Heads
up when reading these on a CI host: simulated devices share the host's
cores (often ONE — recorded as ``host_cores``), every collective is a
serializing shared-memory rendezvous with zero wire time, so bucketing
can only ever ADD wall time there.  The raw numbers are kept honest, not
massaged — they are the step-time regression signal.

**Modeled (ICI bandwidth, the headline).**  ``exposed_comm_seconds``
(launch/roofline.py) schedules each variant's buckets on a comm channel
against the *measured* per-step compute budget: bucket j's fp32
reduce-scatter + int8 all-gather start when its slice of backward is
produced (XLA's slice-of-concatenate rewrite makes bucket chains depend
on only a suffix of backward), and exposed comm is what the channel
still owes after compute ends.  Monolithic = 1 bucket = its whole wire
time exposed; the bucketed schedule exposes only the tail.  This is the
quantity "exposed comm" the overlap machinery exists to shrink, and the
only faithful way to report it from a host with no interconnect — the
same measured-compute + modeled-wire split as the repo's roofline tier.

Cells (each a subprocess so ``XLA_FLAGS`` device forcing is per-cell):

  * devices — 2 / 4 / 8 simulated devices, three variants per cell; the
    8-device cell also records the per-bucket timeline;
  * processes — the same bucketed step as 1 process x 2 devices vs
    2 real ``jax.distributed`` processes (gloo) x 1 device each, with
    step-loss parity between the two.

``--baseline`` (the nightly CI gate) re-measures the 8-device cell and
fails (exit 1) against the committed BENCH_comm.json when either

  * the modeled bucketed exposed-comm fraction regresses by more than
    15 points of step time, or
  * the measured bucketed step time regresses by more than 15%.

Run as a script (``python benchmarks/comm_overlap.py``); results land in
``benchmarks/BENCH_comm.json``.  Everything is pinned to CPU
(``JAX_PLATFORMS=cpu``) so the artifact is hermetic; on a real multi-chip
accelerator the device-forcing would simply be dropped.
"""
import argparse
import json
import os
import statistics
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
OUT_PATH = os.path.join(HERE, "BENCH_comm.json")

#: 7 buckets over the GPT2_TINY 917504-element shard — big enough that a
#: bucket's collective is not launch-dominated, small enough for a legible
#: per-bucket timeline.  (The auto roofline chooser targets real ICI
#: bandwidth and picks monolithic for a model this small.)
BUCKET_ELEMS = 128 * 1024

STEPS = 12          # per variant; first 2 are compile+warmup, median of rest
MP_STEPS = 6
HESS_INTERVAL = 3

EXPOSED_REGRESSION_POINTS = 0.15   # absolute step-time fraction
STEP_REGRESSION_REL = 0.15


# ---------------------------------------------------------------------------
# workers (run in subprocesses with per-cell env)

def _train_setup(bucket_elems, compress, telemetry=False, mesh=None):
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs.gpt2 import GPT2_TINY
    from repro.data import DataConfig, make_source
    from repro.launch.train import _put_tree, build_mesh, compile_train_step
    from repro.train import TrainerConfig

    cfg = dataclasses.replace(GPT2_TINY, dtype="float32")
    tc = TrainerConfig(optimizer="sophia_g", peak_lr=1e-3, total_steps=1000,
                       warmup_steps=2, hess_interval=HESS_INTERVAL,
                       hess_subbatch=4, compress_grads=compress,
                       comm_bucket_elems=bucket_elems,
                       comm_telemetry=telemetry, seed=0)
    src = make_source(DataConfig(seq_len=32, global_batch=8,
                                 vocab_size=cfg.vocab_size, seed=0))
    sample = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
    mesh = build_mesh() if mesh is None else mesh
    train_step, init_fn, ssh, bsh = compile_train_step(cfg, tc, mesh, sample)
    state = _put_tree(init_fn(jax.random.PRNGKey(0)), ssh)

    def run(steps):
        import time as _time
        nonlocal state
        dts, losses, tele = [], [], []
        for t in range(steps):
            batch = _put_tree({k: jnp.asarray(v)
                               for k, v in src.batch_at(t).items()}, bsh)
            t0 = _time.perf_counter()
            state, metrics = train_step(
                state, batch, jnp.asarray(t % HESS_INTERVAL == 0))
            jax.block_until_ready((state, metrics))
            dts.append(_time.perf_counter() - t0)
            losses.append(float(metrics["loss"]))
            if "comm_seconds" in metrics:
                tele.append({k: float(metrics[k]) for k in
                             ("comm_seconds", "step_seconds",
                              "exposed_comm_fraction")})
        return dts, losses, tele

    return run


def _median_step(run, steps):
    dts, losses, tele = run(steps)
    return statistics.median(dts[2:]), losses, tele


def _shard_sizes():
    import dataclasses

    import jax

    from repro.configs.gpt2 import GPT2_TINY
    from repro.train import TrainerConfig, make_engine, make_train_fns

    cfg = dataclasses.replace(GPT2_TINY, dtype="float32")
    tc = TrainerConfig(optimizer="sophia_g", peak_lr=1e-3, total_steps=1000,
                       compress_grads=True)
    init_fn, _ = make_train_fns(cfg, tc)
    params = jax.eval_shape(init_fn, jax.random.PRNGKey(0)).params
    return [int(n) for n in make_engine(tc).layout(params).shard_sizes]


def cell_devices(args):
    """One device-count cell: no-comp vs monolithic vs bucketed."""
    out = {"ndev": args.ndev, "bucket_elems": args.bucket_elems}
    t_nocomp, _, _ = _median_step(
        _train_setup(None, compress=False), args.steps)
    t_mono, _, _ = _median_step(_train_setup(0, compress=True), args.steps)
    t_buck, losses, _ = _median_step(
        _train_setup(args.bucket_elems, compress=True), args.steps)
    # measured compression overhead: quantize compute + the host's
    # SERIALIZED collectives (no wire, no concurrency on shared cores) —
    # the regression-gate numbers, not the exposed-comm estimate
    out.update(t_nocomp_s=t_nocomp, t_mono_s=t_mono, t_buck_s=t_buck,
               overhead_mono_s=max(0.0, t_mono - t_nocomp),
               overhead_buck_s=max(0.0, t_buck - t_nocomp),
               losses=losses[:6])

    # modeled exposed comm at ICI bandwidth against the measured compute
    # budget (see module docstring): every bucket of every shard on one
    # comm channel, ready when its backward slice completes
    from repro.distributed.overlap import plan_buckets
    from repro.launch.roofline import exposed_comm_seconds
    sizes = _shard_sizes()
    plans = plan_buckets(sizes, args.ndev, bucket_elems=args.bucket_elems)
    buckets = [stop - start for plan in plans for start, stop in plan]
    em = exposed_comm_seconds(sizes, args.ndev, t_nocomp)
    eb = exposed_comm_seconds(buckets, args.ndev, t_nocomp)
    out["model"] = {"compute_budget_s": t_nocomp, "n_buckets": len(buckets),
                    "exposed_mono_s": em, "exposed_buck_s": eb,
                    "exposed_mono_frac": em / (t_nocomp + em),
                    "exposed_buck_frac": eb / (t_nocomp + eb)}

    # in-graph telemetry + per-bucket timeline on a short bucketed run
    from repro.distributed import overlap
    run = _train_setup(args.bucket_elems, compress=True, telemetry=True)
    _, _, tele = run(3)
    overlap.timeline_enable(True)
    _, _, tele2 = run(1)
    timeline = overlap.decode_timeline()
    overlap.timeline_enable(False)
    tele += tele2
    out["telemetry"] = {
        k: statistics.median(r[k] for r in tele)
        for k in ("comm_seconds", "step_seconds", "exposed_comm_fraction")}
    if args.timeline:
        out["timeline"] = timeline
    return out


def cell_mp(args):
    """One rank of the 2-process cell (or the 1-process reference)."""
    from repro.launch.mesh import initialize_distributed
    if args.nproc > 1:
        initialize_distributed(f"127.0.0.1:{args.port}", args.nproc,
                               args.rank)
    import jax
    run = _train_setup(args.bucket_elems, compress=True)
    t_step, losses, _ = _median_step(run, args.steps)
    return {"t_step_s": t_step, "losses": losses,
            "process_count": jax.process_count(),
            "global_devices": len(jax.devices())}


# ---------------------------------------------------------------------------
# orchestrator

def _env(force_devices=0):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # never inherit another cell's forcing
    env["JAX_PLATFORMS"] = "cpu"
    if force_devices:
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                            f"{force_devices}")
    return env


def _parse_result(stdout, stderr):
    for line in reversed(stdout.splitlines()):
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no RESULT line\n--- stdout\n{stdout[-2000:]}"
                       f"\n--- stderr\n{stderr[-2000:]}")


def _spawn_cell(extra, force_devices=0, timeout=1200):
    cmd = [sys.executable, os.path.abspath(__file__)] + extra
    p = subprocess.run(cmd, env=_env(force_devices), capture_output=True,
                      text=True, timeout=timeout)
    if p.returncode != 0:
        raise RuntimeError(f"cell {extra} failed rc={p.returncode}\n"
                           f"{p.stderr[-3000:]}")
    return _parse_result(p.stdout, p.stderr)


def _run_devices_cell(ndev, bucket_elems, steps, timeline=False):
    extra = ["--cell", "devices", "--ndev", str(ndev),
             "--bucket-elems", str(bucket_elems), "--steps", str(steps)]
    if timeline:
        extra.append("--timeline")
    return _spawn_cell(extra, force_devices=ndev)


def _run_mp_cell(bucket_elems, steps):
    """2 real processes x 1 device, plus the 1-process x 2-device
    reference, with loss parity between them."""
    import socket
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    base = ["--cell", "mp", "--bucket-elems", str(bucket_elems),
            "--steps", str(steps), "--port", str(port), "--nproc", "2"]
    procs = [subprocess.Popen(
        [sys.executable, os.path.abspath(__file__)] + base
        + ["--rank", str(r)], env=_env(), stdout=subprocess.PIPE,
        stderr=subprocess.PIPE, text=True) for r in range(2)]
    outs = [p.communicate(timeout=1200) for p in procs]
    for p, (so, se) in zip(procs, outs):
        if p.returncode != 0:
            raise RuntimeError(f"mp rank failed rc={p.returncode}\n"
                               f"{se[-3000:]}")
    two = _parse_result(*outs[0])
    one = _spawn_cell(["--cell", "mp", "--bucket-elems", str(bucket_elems),
                       "--steps", str(steps), "--nproc", "1", "--rank", "0"],
                      force_devices=2)
    parity = max(abs(a - b) for a, b in zip(one["losses"], two["losses"]))
    return {"one_proc_two_dev": one, "two_proc_one_dev": two,
            "loss_parity_max_abs": parity}


def measure(full=True):
    result = {"arch": "gpt2-tiny-fp32", "seq_len": 32, "global_batch": 8,
              "bucket_elems": BUCKET_ELEMS, "steps_per_variant": STEPS,
              "host_cores": os.cpu_count(), "cells": {}}
    for ndev in ((2, 4, 8) if full else (8,)):
        cell = _run_devices_cell(ndev, BUCKET_ELEMS, STEPS,
                                 timeline=(ndev == 8))
        result["cells"][str(ndev)] = cell
        m = cell["model"]
        print(f"comm_overlap.devices{ndev},{cell['t_buck_s'] * 1e6:.1f},"
              f"exposed_mono={m['exposed_mono_s'] * 1e6:.1f}us;"
              f"exposed_buck={m['exposed_buck_s'] * 1e6:.1f}us;"
              f"overhead_buck={cell['overhead_buck_s'] * 1e3:.1f}ms")
    if full:
        result["processes"] = _run_mp_cell(BUCKET_ELEMS, MP_STEPS)
        pr = result["processes"]
        print(f"comm_overlap.processes,"
              f"{pr['two_proc_one_dev']['t_step_s'] * 1e6:.1f},"
              f"one_proc={pr['one_proc_two_dev']['t_step_s'] * 1e3:.1f}ms;"
              f"parity={pr['loss_parity_max_abs']:.2e}")
    m8 = result["cells"]["8"]["model"]
    result["win_at_8dev"] = bool(
        m8["exposed_buck_s"] < m8["exposed_mono_s"])
    return result


def check_baseline(current):
    """Nightly gate: compare a fresh 8-device cell to the committed JSON."""
    with open(OUT_PATH) as f:
        base = json.load(f)
    b8, c8 = base["cells"]["8"], current["cells"]["8"]
    failures = []
    if (c8["model"]["exposed_buck_frac"]
            > b8["model"]["exposed_buck_frac"] + EXPOSED_REGRESSION_POINTS):
        failures.append(
            f"exposed-comm fraction {c8['model']['exposed_buck_frac']:.3f} "
            f"vs baseline {b8['model']['exposed_buck_frac']:.3f} "
            f"(+{EXPOSED_REGRESSION_POINTS} budget)")
    if not current["win_at_8dev"]:
        failures.append("bucketed no longer beats monolithic exposed comm "
                        "at 8 devices")
    if c8["t_buck_s"] > b8["t_buck_s"] * (1 + STEP_REGRESSION_REL):
        failures.append(
            f"bucketed step {c8['t_buck_s'] * 1e3:.1f}ms vs baseline "
            f"{b8['t_buck_s'] * 1e3:.1f}ms (+{STEP_REGRESSION_REL:.0%})")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=["devices", "mp"], default=None)
    ap.add_argument("--ndev", type=int, default=8)
    ap.add_argument("--bucket-elems", type=int, default=BUCKET_ELEMS)
    ap.add_argument("--steps", type=int, default=STEPS)
    ap.add_argument("--timeline", action="store_true")
    ap.add_argument("--port", default=None)
    ap.add_argument("--nproc", type=int, default=1)
    ap.add_argument("--rank", type=int, default=0)
    ap.add_argument("--baseline", action="store_true",
                    help="8-device cell only; exit 1 on regression vs the "
                         "committed BENCH_comm.json")
    args = ap.parse_args()

    if args.cell:  # subprocess worker
        sys.path.insert(0, os.path.join(HERE, "..", "src"))
        out = {"devices": cell_devices, "mp": cell_mp}[args.cell](args)
        import jax
        if jax.process_index() == 0:
            print("RESULT " + json.dumps(out), flush=True)
        return

    if args.baseline:
        current = measure(full=False)
        failures = check_baseline(current)
        if failures:
            print("comm_overlap BASELINE FAIL:\n  " + "\n  ".join(failures),
                  file=sys.stderr)
            sys.exit(1)
        print("comm_overlap baseline OK")
        return

    result = measure(full=True)
    with open(OUT_PATH, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {OUT_PATH}  win_at_8dev={result['win_at_8dev']}")


if __name__ == "__main__":
    main()
