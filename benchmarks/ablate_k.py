"""Fig 8a reproduction: Hessian update frequency k in {1, 10, 100}.

k=1 gives the best loss per *step* but ~50%(paper) extra compute; k=10 is
the compute-optimal point; k=100 degrades but still beats AdamW.
We report loss AND amortized compute (hessian steps cost ~2x a normal step
at our sub-batch ratio).
"""
import time

from .common import bench_source, csv_line, run_opt, val_loss


def main(quick=False):
    steps = 100 if quick else 200
    out = {}
    for k in (1, 10, 100):
        t0 = time.time()
        st, hist, wall = run_opt("sophia_g", steps, peak_lr=8e-4,
                                 weight_decay=0.2, hess_interval=k)
        l = val_loss(st)
        # amortized compute in "step units": hess step ~ +1 fwd+bwd on the
        # sub-batch fraction
        sub_frac = 4 / 8
        compute_units = steps * (1 + sub_frac / k)
        out[k] = {"val": l, "compute_units": compute_units,
                  "wall_s": wall}
        csv_line(f"ablate_k.k={k}", wall * 1e6 / steps,
                 f"val={l:.4f};compute={compute_units:.0f}")
    return out


if __name__ == "__main__":
    print(main())
