"""Fig 2 reproduction: heterogeneous-curvature 2D toy (footnote 1).

GD crawls in the flat dim, SignGD/Adam bounce in the sharp dim, Newton
runs to a saddle from the nonconvex region, Sophia (clipped Newton with
positive-curvature guard) converges fast in both dims.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import csv_line


def loss(theta):
    t1, t2 = theta[0], theta[1]
    return 8 * (t1 - 1) ** 2 * (1.3 * t1 ** 2 + 2 * t1 + 1) \
        + 0.5 * (t2 - 4) ** 2


def trajectories(steps=50):
    grad = jax.grad(loss)

    def hess_diag(t):
        return jnp.diag(jax.hessian(loss)(t))

    # start inside the global basin's negative-curvature region (see
    # tests/test_convergence.py) — Newton runs to the t1=0 local max there.
    # 0.23 (not 0.20) so SignGD's 0.1-steps never land exactly on t1=1.
    theta0 = jnp.array([0.23, 0.0])
    out = {}

    t = theta0
    for _ in range(steps):
        t = t - 0.01 * grad(t)
    out["gd"] = float(loss(t))

    t = theta0
    for _ in range(steps):
        t = t - 0.1 * jnp.sign(grad(t))
    out["signgd"] = float(loss(t))

    t = theta0
    for _ in range(steps):  # vanilla Newton: no positivity guard
        h = hess_diag(t)
        t = t - grad(t) / h
    out["newton"] = float(loss(t))
    out["newton_grad_norm"] = float(jnp.linalg.norm(grad(t)))

    t = theta0
    for _ in range(steps):  # Sophia eq. (4)
        h = hess_diag(t)
        u = jnp.clip(grad(t) / jnp.maximum(h, 1e-12), -1.0, 1.0)
        t = t - 0.5 * u
    out["sophia"] = float(loss(t))
    out["sophia_theta"] = [float(x) for x in t]
    return out


def main(quick=False):
    t0 = time.time()
    res = trajectories()
    us = (time.time() - t0) * 1e6
    csv_line("toy_fig2.final_losses", us,
             f"gd={res['gd']:.2e};signgd={res['signgd']:.2e};"
             f"newton={res['newton']:.2e};sophia={res['sophia']:.2e}")
    assert res["sophia"] < min(res["gd"], res["signgd"]), res
    return res


if __name__ == "__main__":
    print(main())
