"""Fig 3 reproduction: the diagonal Hessian of an LM is dispersed
(heterogeneous curvature), and the stochastic estimators track the exact
diagonal.  Uses a tiny 2-layer LM so the exact diagonal is computable.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exact_diag_hessian, gnb_estimator, hutchinson_estimator
from repro.models import ModelConfig, get_model

from .common import bench_source, csv_line


def main(quick=False):
    cfg = ModelConfig(name="nano", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=64,
                      rope=False, learned_pos=True, norm_type="ln",
                      activation="gelu", max_position_embeddings=32,
                      dtype="float32")
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    src = bench_source(seq=16, batch=4, vocab=cfg.vocab_size)
    batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}

    def loss_fn(p):
        return model.loss_fn(cfg, p, batch)[0]

    def logits_fn(p):
        return model.logits_fn(cfg, p, batch)

    t0 = time.time()
    exact = exact_diag_hessian(loss_fn, params)
    t_exact = time.time() - t0
    flat_exact = np.asarray(jax.flatten_util.ravel_pytree(exact)[0])
    pos = flat_exact[flat_exact > 1e-12]

    # dispersion (Fig 3's point): orders of magnitude between percentiles
    p10, p50, p90 = np.percentile(pos, [10, 50, 90])
    dispersion = p90 / max(p10, 1e-20)

    # estimator fidelity (correlation with exact diag)
    keys = jax.random.split(jax.random.PRNGKey(1), 64)
    hutch = np.asarray(jax.vmap(
        lambda k: jax.flatten_util.ravel_pytree(
            hutchinson_estimator(loss_fn, params, k))[0])(keys).mean(0))
    gnb = np.asarray(jax.vmap(
        lambda k: jax.flatten_util.ravel_pytree(
            gnb_estimator(logits_fn, params, k))[0])(keys).mean(0))
    corr_h = np.corrcoef(hutch, flat_exact)[0, 1]
    corr_g = np.corrcoef(gnb, flat_exact)[0, 1]

    csv_line("hessian_spectrum.dispersion_p90_p10",
             t_exact * 1e6, f"{dispersion:.1f}x")
    csv_line("hessian_spectrum.estimator_corr", 0.0,
             f"hutchinson={corr_h:.3f};gnb={corr_g:.3f}")
    return {"dispersion": float(dispersion), "corr_hutchinson": float(corr_h),
            "corr_gnb": float(corr_g)}


if __name__ == "__main__":
    print(main())
