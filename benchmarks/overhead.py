"""Table 1 reproduction: per-step wall time, T(Hessian), and compute.

Paper: Sophia's Hessian refresh (every k=10 steps on a reduced sub-batch)
adds <5% average wall-clock overhead vs AdamW and the same memory (two
states).  We measure all optimizers' jitted steps on the same model, plus
the amortized Hessian-step cost — every optimizer now runs through the
flat-buffer engine, so the comparison is apples-to-apples by construction.

We also audit the step's lowered HLO: the engine keeps optimizer state as
block-padded flat shards, so the hot step must contain NO per-leaf pad ops
(the seed's per-step per-leaf flatten/pad/unpad round-trip is gone; the
single tail pad per shard is a constant operand of the ravel concatenate).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gpt2 import GPT2_TINY
from repro.train import TrainerConfig, make_engine, make_train_fns

from .common import bench_source, csv_line


def _time(f, *args, n=20):
    out = f(*args)  # compile
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n


def _count_pads(fn, *args) -> int:
    """1-D pad ops in the step's lowered StableHLO.

    The seed's per-leaf fused path padded every flat leaf (4 inputs + 2
    outputs per leaf, every step) — those show up as pads of rank-1
    tensors.  The engine contract is zero of them: optimizer state is
    block-padded once at init and the model's own activation pads are
    rank>=2."""
    import re
    txt = jax.jit(fn).lower(*args).as_text()
    return len(re.findall(r"stablehlo\.pad[^\n]*tensor<\d+xf32>", txt))


def main(quick=False):
    cfg = GPT2_TINY
    src = bench_source()
    batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
    results = {}
    for opt, est in (("adamw", "gnb"), ("sophia_g", "gnb"),
                     ("sophia_h", "hutchinson"), ("adahessian", "hutchinson"),
                     ("lion", "gnb")):
        tc = TrainerConfig(optimizer=opt, peak_lr=1e-3, total_steps=1000,
                           estimator=est, hess_subbatch=4, hess_interval=10)
        init_fn, step, hess_step = make_train_fns(cfg, tc)
        state = init_fn(jax.random.PRNGKey(0))
        t_step = _time(jax.jit(step), state, batch)
        row = {"t_step_ms": t_step * 1e3}
        if opt.startswith("sophia") or opt == "adahessian":
            t_hess = _time(jax.jit(hess_step), state, batch)
            row["t_hess_step_ms"] = t_hess * 1e3
            k = tc.hess_interval if opt.startswith("sophia") else 1
            row["amortized_ms"] = (t_step * (k - 1) + t_hess) / k * 1e3
            row["overhead_vs_step_pct"] = 100 * (row["amortized_ms"]
                                                 / (t_step * 1e3) - 1)
        if opt == "sophia_g":
            row["hlo_pad_ops"] = _count_pads(step, state, batch)
        results[opt] = row
        csv_line(f"overhead.{opt}", t_step * 1e6,
                 ";".join(f"{k2}={v:.2f}" for k2, v in row.items()))

    # memory: Sophia state count == AdamW state count (m,h vs m,v), both
    # living as block-padded flat shards
    tc = TrainerConfig(optimizer="sophia_g", peak_lr=1e-3, total_steps=10)
    init_fn, *_ = make_train_fns(cfg, tc)
    s = init_fn(jax.random.PRNGKey(0))
    sophia_state = sum(x.size for x in jax.tree.leaves(s.opt_state.m)) + \
        sum(x.size for x in jax.tree.leaves(s.opt_state.h))
    nparams = sum(x.size for x in jax.tree.leaves(s.params))
    layout = make_engine(tc).describe(s.params)
    csv_line("overhead.sophia_state_elems", 0.0,
             f"{sophia_state};params={nparams};ratio={sophia_state/nparams:.2f}")
    csv_line("overhead.engine_layout", 0.0,
             f"shards={len(layout['shards'])};block={layout['block']};"
             f"pad_elems={sum(sh['size'] - sh['used'] for sh in layout['shards'])}")
    return results


if __name__ == "__main__":
    print(main())
