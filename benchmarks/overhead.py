"""Table 1 reproduction: per-step wall time, T(Hessian), and compute.

Paper: Sophia's Hessian refresh (every k=10 steps on a reduced sub-batch)
adds <5% average wall-clock overhead vs AdamW and the same memory (two
states).  We measure every optimizer's UNIFIED jitted step — one compiled
program whose refresh branch is gated by a traced flag — with the flag
clear (hot path) and set (refresh path), and report the amortized overhead
((k-1) * t_hot + t_refresh) / k against the paper's <5% target.  The jit
cache size is asserted to stay at one program per optimizer: the refresh
cadence must never trigger a second compilation.

We also audit the step's lowered HLO: the engine keeps optimizer state as
block-padded flat shards and the estimators emit flat shards directly, so
the unified program — refresh branch included — must contain NO rank-1 pad
ops (the seed's per-step per-leaf flatten/pad/unpad round-trip is gone; the
single tail pad per shard is a constant operand of the ravel concatenate).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gpt2 import GPT2_TINY
from repro.core import hessian_aware_optimizer
from repro.train import TrainerConfig, make_engine, make_train_fns

from .common import bench_source, csv_line

AMORTIZED_TARGET_PCT = 5.0  # paper Section 4.3


def _time(f, *args, n=20):
    out = f(*args)  # compile
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n


def _count_pads(fn, *args) -> int:
    """1-D pad ops in the step's lowered StableHLO.

    The seed's per-leaf fused path padded every flat leaf (4 inputs + 2
    outputs per leaf, every step) — those show up as pads of rank-1
    tensors.  The engine contract is zero of them, refresh branch included:
    optimizer state is block-padded once at init, estimates ravel once
    through the layout, and the model's own activation pads are rank>=2."""
    import re
    txt = jax.jit(fn).lower(*args).as_text()
    return len(re.findall(r"stablehlo\.pad[^\n]*tensor<\d+xf32>", txt))


def main(quick=False):
    cfg = GPT2_TINY
    src = bench_source()
    batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
    off, on = jnp.asarray(False), jnp.asarray(True)
    results = {}
    for opt, est in (("adamw", "gnb"), ("sophia_g", "gnb"),
                     ("sophia_h", "hutchinson"), ("adahessian", "hutchinson"),
                     ("lion", "gnb")):
        tc = TrainerConfig(optimizer=opt, peak_lr=1e-3, total_steps=1000,
                           estimator=est, hess_subbatch=4, hess_interval=10)
        init_fn, step = make_train_fns(cfg, tc)
        state = init_fn(jax.random.PRNGKey(0))
        jstep = jax.jit(step)
        t_step = _time(jstep, state, batch, off)
        row = {"t_step_ms": t_step * 1e3}
        if hessian_aware_optimizer(opt):
            t_hess = _time(jstep, state, batch, on)
            row["t_hess_step_ms"] = t_hess * 1e3
            k = tc.hess_interval if opt.startswith("sophia") else 1
            row["amortized_ms"] = (t_step * (k - 1) + t_hess) / k * 1e3
            row["overhead_vs_step_pct"] = 100 * (row["amortized_ms"]
                                                 / (t_step * 1e3) - 1)
            row["meets_5pct_target"] = float(
                row["overhead_vs_step_pct"] < AMORTIZED_TARGET_PCT)
        # one program per optimizer: hot + refresh both hit the same cache
        # entry (the flag is traced) — compile count > 1 is a regression
        row["programs_compiled"] = jstep._cache_size()
        assert row["programs_compiled"] == 1, row
        if opt == "sophia_g":
            row["hlo_pad_ops"] = _count_pads(step, state, batch, on)
        results[opt] = row
        csv_line(f"overhead.{opt}", t_step * 1e6,
                 ";".join(f"{k2}={v:.2f}" for k2, v in row.items()))

    # comm/compute split: the trainer's in-graph telemetry (dataflow-ordered
    # host stamps around every compression bucket, distributed/overlap.py).
    # On one device the window covers the local quantize pipeline; under a
    # mesh the same metrics cover the collective window — the differential
    # exposed-comm benchmark lives in benchmarks/comm_overlap.py.
    tc = TrainerConfig(optimizer="sophia_g", peak_lr=1e-3, total_steps=1000,
                       hess_subbatch=4, hess_interval=10,
                       compress_grads=True, comm_telemetry=True)
    init_fn, step = make_train_fns(cfg, tc)
    state = init_fn(jax.random.PRNGKey(0))
    jstep = jax.jit(step)
    tele = []
    for _ in range(3):
        state, metrics = jstep(state, batch, off)
        jax.block_until_ready(metrics)
        tele.append({k: float(metrics[k]) for k in
                     ("comm_seconds", "step_seconds",
                      "exposed_comm_fraction")})
    med = {k: float(np.median([r[k] for r in tele])) for k in tele[0]}
    csv_line("overhead.comm_telemetry", med["comm_seconds"] * 1e6,
             f"step_ms={med['step_seconds'] * 1e3:.2f};"
             f"exposed_frac={med['exposed_comm_fraction']:.3f}")
    results["comm_telemetry"] = med

    # memory: Sophia state count == AdamW state count (m,h vs m,v), both
    # living as block-padded flat shards
    tc = TrainerConfig(optimizer="sophia_g", peak_lr=1e-3, total_steps=10)
    init_fn, _ = make_train_fns(cfg, tc)
    s = init_fn(jax.random.PRNGKey(0))
    sophia_state = sum(x.size for x in jax.tree.leaves(s.opt_state.m)) + \
        sum(x.size for x in jax.tree.leaves(s.opt_state.h))
    nparams = sum(x.size for x in jax.tree.leaves(s.params))
    layout = make_engine(tc).describe(s.params)
    csv_line("overhead.sophia_state_elems", 0.0,
             f"{sophia_state};params={nparams};ratio={sophia_state/nparams:.2f}")
    csv_line("overhead.engine_layout", 0.0,
             f"shards={len(layout['shards'])};block={layout['block']};"
             f"pad_elems={sum(sh['size'] - sh['used'] for sh in layout['shards'])}")
    return results


if __name__ == "__main__":
    print(main())
