"""Table 1 reproduction: per-step wall time, T(Hessian), and compute.

Paper: Sophia's Hessian refresh (every k=10 steps on a reduced sub-batch)
adds <5% average wall-clock overhead vs AdamW and the same memory (two
states).  We measure all three optimizers' jitted steps on the same model,
plus the amortized Hessian-step cost, and the fused-kernel update.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gpt2 import GPT2_TINY
from repro.train import TrainerConfig, make_train_fns

from .common import bench_source, csv_line


def _time(f, *args, n=20):
    out = f(*args)  # compile
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n


def main(quick=False):
    cfg = GPT2_TINY
    src = bench_source()
    batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
    results = {}
    for opt, est in (("adamw", "gnb"), ("sophia_g", "gnb"),
                     ("sophia_h", "hutchinson"), ("adahessian", "hutchinson"),
                     ("lion", "gnb")):
        tc = TrainerConfig(optimizer=opt, peak_lr=1e-3, total_steps=1000,
                           estimator=est, hess_subbatch=4, hess_interval=10)
        init_fn, step, hess_step = make_train_fns(cfg, tc)
        state = init_fn(jax.random.PRNGKey(0))
        t_step = _time(jax.jit(step), state, batch)
        row = {"t_step_ms": t_step * 1e3}
        if opt.startswith("sophia") or opt == "adahessian":
            t_hess = _time(jax.jit(hess_step), state, batch)
            row["t_hess_step_ms"] = t_hess * 1e3
            k = tc.hess_interval if opt.startswith("sophia") else 1
            row["amortized_ms"] = (t_step * (k - 1) + t_hess) / k * 1e3
            row["overhead_vs_step_pct"] = 100 * (row["amortized_ms"]
                                                 / (t_step * 1e3) - 1)
        results[opt] = row
        csv_line(f"overhead.{opt}", t_step * 1e6,
                 ";".join(f"{k2}={v:.2f}" for k2, v in row.items()))

    # memory: Sophia state count == AdamW state count (m,h vs m,v)
    tc = TrainerConfig(optimizer="sophia_g", peak_lr=1e-3, total_steps=10)
    init_fn, *_ = make_train_fns(cfg, tc)
    s = init_fn(jax.random.PRNGKey(0))
    sophia_state = sum(x.size for x in jax.tree.leaves(s.opt_state.m)) + \
        sum(x.size for x in jax.tree.leaves(s.opt_state.h))
    nparams = sum(x.size for x in jax.tree.leaves(s.params))
    csv_line("overhead.sophia_state_elems", 0.0,
             f"{sophia_state};params={nparams};ratio={sophia_state/nparams:.2f}")
    return results


if __name__ == "__main__":
    print(main())
