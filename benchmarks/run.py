"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Paper artifact map:
    Fig 2          -> toy_landscape
    Fig 3          -> hessian_spectrum
    Fig 1/4/5      -> steps_to_loss   (eq. 14 methodology)
    Table 1        -> overhead
    Fig 7a / Fig 9 -> stability
    Fig 8a         -> ablate_k
    Fig 8b         -> ablate_estimator
    Fig 8c         -> ablate_clipping
    Dry-run/roofline tables (EXPERIMENTS.md) -> roofline_report
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter runs (CI mode)")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()

    from . import (ablate_clipping, ablate_estimator, ablate_k,
                   hessian_spectrum, overhead, roofline_report,
                   stability, stability_lr, steps_to_loss, toy_landscape)

    suites = {
        "toy_landscape": toy_landscape.main,
        "hessian_spectrum": hessian_spectrum.main,
        "overhead": overhead.main,
        "stability": stability.main,
        "stability_lr": stability_lr.main,
        "ablate_k": ablate_k.main,
        "ablate_estimator": ablate_estimator.main,
        "ablate_clipping": ablate_clipping.main,
        "steps_to_loss": steps_to_loss.main,
        "roofline_report": roofline_report.main,
    }
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites.items():
        if args.only and name not in args.only:
            continue
        try:
            fn(quick=args.quick)
        except Exception as e:  # keep the harness running
            traceback.print_exc()
            failures.append(name)
            print(f"{name},0.0,ERROR:{repr(e)[:120]}")
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
