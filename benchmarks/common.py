"""Shared benchmark setup: CPU-sized models + the paper's protocol."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.gpt2 import GPT2_TINY
from repro.data import DataConfig, make_source
from repro.train import TrainerConfig, train_loop


def bench_source(seq=64, batch=8, vocab=None, seed=0):
    return make_source(DataConfig(seq_len=seq, global_batch=batch,
                                  vocab_size=vocab or GPT2_TINY.vocab_size,
                                  seed=seed))


def run_opt(optimizer, steps, *, peak_lr, seed=0, cfg=GPT2_TINY, src=None,
            **tc_kw):
    """Train `steps` with the schedule pinned to `steps` (paper eq. 14)."""
    tc_kw.setdefault("hess_subbatch", 4)
    tc_kw.setdefault("warmup_steps", max(2, steps // 20))
    tc = TrainerConfig(optimizer=optimizer, peak_lr=peak_lr,
                       total_steps=steps, seed=seed, **tc_kw)
    src = src or bench_source(seed=seed)
    t0 = time.time()
    state, hist = train_loop(cfg, tc, src, num_steps=steps)
    wall = time.time() - t0
    return state, hist, wall


def val_loss(state, cfg=GPT2_TINY, seed=1234, batches=4):
    """Held-out loss on a disjoint synthetic stream."""
    from repro.models import get_model
    import jax.numpy as jnp
    model = get_model(cfg)
    src = bench_source(seed=seed)
    losses = []
    for b in range(batches):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(10_000 + b).items()}
        losses.append(float(model.loss_fn(cfg, state.params, batch)[0]))
    return float(np.mean(losses))


def csv_line(name, us_per_call, derived):
    print(f"{name},{us_per_call:.1f},{derived}")
