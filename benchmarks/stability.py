"""Fig 7a + Fig 9 reproduction: training-stability telemetry.

* Fig 7a: fraction of steps where global-norm gradient clipping (threshold
  1.0) triggers — Sophia rarely, AdamW/Lion frequently.
* Fig 9a: proportion of Sophia coordinates whose update is clipped
  (the gamma-tuning signal; paper: ~50-90% when effective).
* Fig 9b: ||h_t|| growth over training.
"""
import time

import jax
import numpy as np

from .common import bench_source, csv_line, run_opt


def main(quick=False):
    steps = 100 if quick else 200
    t0 = time.time()
    out = {}
    for opt, lr, wd in (("sophia_g", 8e-4, 0.2), ("adamw", 1e-3, 0.1),
                        ("lion", 3e-4, 0.2)):
        state, hist, _ = run_opt(opt, steps, peak_lr=lr, weight_decay=wd,
                                 grad_clip=1.0)  # paper threshold
        # paper Fig 7a concerns steady-state stability: rate that clipping
        # triggers AFTER the init transient (second half of the run)
        half = steps // 2
        trig = (hist[-1]["clip_triggers"] - hist[half]["clip_triggers"]) \
            / (steps - half - 1)
        out[opt] = {"clip_trigger_rate_late": trig}
        if opt == "sophia_g":
            cf = [h["sophia_clip_fraction"] for h in hist if
                  "sophia_clip_fraction" in h]
            hnorm = float(jax.numpy.sqrt(sum(
                (x.astype(jax.numpy.float32) ** 2).sum()
                for x in jax.tree.leaves(state.opt_state.h))))
            out[opt]["sophia_clip_fraction_final"] = float(np.mean(cf[-10:]))
            out[opt]["h_norm_final"] = hnorm
        csv_line(f"stability.{opt}", (time.time() - t0) * 1e6 / steps,
                 ";".join(f"{k}={v:.4f}" for k, v in out[opt].items()))
    return out


if __name__ == "__main__":
    print(main())
