"""Loss-stage memory + step time: logits-free vs materialized logits.

Measures the isolated LM-loss stage (hidden -> loss, d_hidden, d_W) for
the three ``models.loss.lm_loss`` implementations across a vocab sweep:

  * ``temp_bytes``       XLA's compiled peak temp allocation
                         (``compiled.memory_analysis()``)
  * ``has_btv_buffer``   whether any buffer of >= B*T*V elements appears in
                         the optimized HLO — the [B*T, V] logits residency
                         the fused path exists to eliminate
  * ``ms``               wall time per loss+grad call
  * ``model_hbm_bytes``  the analytic traffic model
                         (kernels.fused_ce.lm_loss_hbm_bytes_*)

plus an end-to-end train-step smoke comparison (chunked — the compiled
logits-free default — vs the legacy unfused path).  Emits
``benchmarks/BENCH_loss.json``; the nightly CI job runs ``--smoke`` and
fails if the fused/chunked paths regress to [B*T, V] residency or the
logits-free step time regresses past 1.25x unfused.

Note: on CPU the Pallas kernel runs in interpret mode (its grid unrolled
into the jit), so its wall time is NOT representative — the compiled
logits-free proxy for step time is the chunked path; the fused row is
still the one that proves V-independent residency for the kernel program.
"""
import argparse
import json
import os
import re
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.kernels.fused_ce import (lm_loss_hbm_bytes_fused,
                                    lm_loss_hbm_bytes_unfused)
from repro.models import lm_loss, set_lm_loss_impl
from repro.models.common import ModelConfig

_SHAPE = re.compile(r"(?:f32|f16|bf16|s32|u32|pred|s8|u8)\[([0-9,]+)\]")


def _max_buffer_numel(hlo_text: str, exclude=()) -> int:
    """Largest buffer (elements) in the optimized HLO; ``exclude`` drops
    exact element counts (the V*D weight/d_W buffers, which are gradient
    outputs and necessarily scale with V — the residency claim is about
    activations)."""
    best = 0
    for dims in _SHAPE.findall(hlo_text):
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n in exclude:
            continue
        best = max(best, n)
    return best


def _mk_cfg(D, V):
    return ModelConfig(name=f"loss-bench-v{V}", family="dense", n_layers=1,
                      d_model=D, n_heads=4, n_kv_heads=4, d_ff=4 * D,
                      vocab_size=V, tie_embeddings=True, dtype="float32")


def bench_loss_stage(B, T, D, V, impl, reps=3):
    cfg = _mk_cfg(D, V)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    hidden = jax.random.normal(ks[0], (B, T, D), jnp.float32)
    params = {"embed": {"tok": jax.random.normal(
        ks[1], (cfg.padded_vocab, D), jnp.float32) * 0.2}}
    labels = jax.random.randint(ks[2], (B, T), 0, V)

    def f(h, p, lab):
        return lm_loss(cfg, p, h, lab, impl=impl)[0]

    g = jax.jit(jax.value_and_grad(f, argnums=(0, 1)))
    lowered = g.lower(hidden, params, labels)
    compiled = lowered.compile()
    temp = int(compiled.memory_analysis().temp_size_in_bytes)
    text = compiled.as_text()
    max_numel = _max_buffer_numel(text)
    max_act_numel = _max_buffer_numel(text,
                                      exclude={cfg.padded_vocab * D})
    out = g(hidden, params, labels)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(g(hidden, params, labels))
        best = min(best, time.perf_counter() - t0)
    model_bytes = (lm_loss_hbm_bytes_fused(B * T, D, cfg.padded_vocab,
                                           bytes_h=4)
                   if impl != "unfused" else
                   lm_loss_hbm_bytes_unfused(B * T, D, cfg.padded_vocab,
                                             bytes_h=4))
    return {"B": B, "T": T, "D": D, "V": V, "impl": impl,
            "temp_bytes": temp, "max_buffer_numel": max_numel,
            "max_act_buffer_numel": max_act_numel,
            "has_btv_buffer": bool(max_numel >= B * T * V),
            "ms": best * 1e3, "model_hbm_bytes": int(model_bytes)}


def bench_train_smoke(steps=8):
    """Full train-step wall time on the smoke config per loss impl."""
    from repro.configs.gpt2 import GPT2_TINY
    from repro.data import DataConfig, make_source
    from repro.train import TrainerConfig, train_loop

    out = {}
    for impl in ("unfused", "chunked"):
        set_lm_loss_impl(impl)
        try:
            src = make_source(DataConfig(seq_len=64, global_batch=8,
                                         vocab_size=512, seed=0))
            tc = TrainerConfig(optimizer="sophia_g", peak_lr=3e-4,
                               total_steps=steps, hess_interval=4,
                               hess_subbatch=4, seed=0)
            # per-step timestamps via the loop callback; steps 0 (hot-path
            # compile) and 1 (first refresh executes the cond's estimator
            # branch) are dropped so the gate measures steady-state step
            # time, not compile time
            stamps = [time.perf_counter()]
            _, hist = train_loop(
                GPT2_TINY, tc, src, num_steps=steps,
                callback=lambda *_: stamps.append(time.perf_counter()))
            deltas = [b - a for a, b in zip(stamps[2:-1], stamps[3:])]
            out[f"{impl}_ms"] = 1e3 * sum(deltas) / len(deltas)
            out[f"{impl}_loss_final"] = hist[-1]["loss"]
        finally:
            set_lm_loss_impl("chunked")
    out["ratio_chunked_vs_unfused"] = out["chunked_ms"] / out["unfused_ms"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep for CI (seconds, not minutes)")
    ap.add_argument("--out", default="benchmarks/BENCH_loss.json")
    args = ap.parse_args()

    # vocab sizes sit past the chunk-size plateau (fused block_v=1024,
    # chunked chunk=2048): above it the logits-free paths' biggest buffer
    # is one [rows, chunk] tile, flat in V, while unfused grows as B*T*V
    # (D chosen so V*D never collides with a rows*chunk tile size — the
    # weight-buffer exclusion in the activation audit stays unambiguous)
    if args.smoke:
        B, T, D = 4, 64, 96
        vocabs = [4096, 8192]
    else:
        B, T, D = 8, 128, 160
        vocabs = [8192, 16384, 32768]

    rows = []
    for V in vocabs:
        for impl in ("unfused", "chunked", "fused"):
            r = bench_loss_stage(B, T, D, V, impl)
            rows.append(r)
            print(f"V={V:6d} {impl:8s} temp={r['temp_bytes']:>12,}B "
                  f"max_buf={r['max_buffer_numel']:>12,}el "
                  f"max_act={r['max_act_buffer_numel']:>12,}el "
                  f"btv={str(r['has_btv_buffer']):5s} {r['ms']:8.2f}ms")

    train = bench_train_smoke()
    print(f"train smoke: unfused {train['unfused_ms']:.1f}ms/step, "
          f"chunked (logits-free) {train['chunked_ms']:.1f}ms/step "
          f"(ratio {train['ratio_chunked_vs_unfused']:.2f})")

    by = lambda impl: [r for r in rows if r["impl"] == impl]  # noqa: E731
    ok = {
        # the acceptance criterion: no [B*T, V] residency at any vocab size
        "fused_logits_free": not any(r["has_btv_buffer"] for r in by("fused")),
        "chunked_logits_free": not any(r["has_btv_buffer"]
                                       for r in by("chunked")),
        # ... and the biggest *activation* buffer (everything except the
        # V*D weight / d_W, which is a gradient output) is flat in V
        "fused_v_independent": len({r["max_act_buffer_numel"]
                                    for r in by("fused")}) == 1,
        # sanity: the unfused oracle really does materialize it
        "unfused_materializes": all(r["has_btv_buffer"]
                                    for r in by("unfused")),
        # no step-time regression for the compiled logits-free path
        "no_step_time_regression":
            train["ratio_chunked_vs_unfused"] <= 1.25,
    }
    report = {"smoke": args.smoke, "loss_stage": rows, "train_smoke": train,
              "ok": ok}
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print("ok:", ok, "->", args.out)
    if not all(ok.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
