"""Loss-stage memory + step time: logits-free vs materialized logits.

Measures the isolated LM-loss stage (hidden -> loss, d_hidden, d_W) for
the ``models.loss.lm_loss`` implementations across a (vocab x tied/untied)
grid:

  * ``temp_bytes``       XLA's compiled peak temp allocation
                         (``compiled.memory_analysis()``)
  * ``has_btv_buffer``   whether any buffer of >= B*T*V elements appears in
                         the optimized HLO — the [B*T, V] logits residency
                         the fused path exists to eliminate
  * ``ms``               wall time per loss+grad call
  * ``model_hbm_bytes``  the analytic traffic model
                         (kernels.fused_ce.lm_loss_hbm_bytes_*)
  * ``bn/bv/schedule``   the autotuned block config for fused cells
                         (kernels.autotune) — so a regression is
                         attributable to tuning vs kernel changes

plus an end-to-end train-step smoke comparison (unfused / chunked / the
fused default).  Emits ``benchmarks/BENCH_loss.json``.

This file is the regression gate: the ``ok`` block fails the run (exit 1)
if any fused cell regresses to [B*T, V] residency, exceeds the logits
footprint, or loses to the chunked path on wall time; ``--baseline PATH``
additionally diffs a fresh run against the committed JSON and fails on a
>15% step-time regression or ANY max-live-buffer growth (the nightly CI
job).

Note: on CPU the Pallas kernel runs in interpret mode (its grid unrolled
into the jit), so absolute wall times are NOT hardware-representative;
the fused-vs-chunked comparison is still apples-to-apples (same backend,
same compiled-program measurement), and the residency audit is exact.
"""
import argparse
import json
import os
import re
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.kernels.autotune import tune_shape
from repro.kernels.fused_ce import (lm_loss_hbm_bytes_fused,
                                    lm_loss_hbm_bytes_unfused)
from repro.models import lm_loss, set_lm_loss_impl
from repro.models.common import ModelConfig

_SHAPE = re.compile(r"(?:f32|f16|bf16|s32|u32|pred|s8|u8)\[([0-9,]+)\]")


def _max_buffer_numel(hlo_text: str, exclude=()) -> int:
    """Largest buffer (elements) in the optimized HLO; ``exclude`` drops
    exact element counts (the V*D weight/d_W buffers, which are gradient
    outputs and necessarily scale with V — the residency claim is about
    activations)."""
    best = 0
    for dims in _SHAPE.findall(hlo_text):
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n in exclude:
            continue
        best = max(best, n)
    return best


def _mk_cfg(D, V, tied=True):
    return ModelConfig(name=f"loss-bench-v{V}", family="dense", n_layers=1,
                      d_model=D, n_heads=4, n_kv_heads=4, d_ff=4 * D,
                      vocab_size=V, tie_embeddings=tied, dtype="float32")


def prepare_loss_stage(B, T, D, V, impl, tied=True):
    """Compile + audit one grid cell; defer timing to the caller.

    Returns ``(row, run)`` where ``row`` has every field except ``ms``
    and ``run()`` executes one timed step and returns seconds.  The
    grid driver interleaves ``run`` calls across impls within a cell so
    slow machine-speed drift (thermal, co-tenant load) hits every impl
    equally — the fused-vs-chunked gate compares within-cell times."""
    cfg = _mk_cfg(D, V, tied=tied)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    hidden = jax.random.normal(ks[0], (B, T, D), jnp.float32)
    params = {"embed": {
        "tok": jax.random.normal(ks[1], (cfg.padded_vocab, D),
                                 jnp.float32) * 0.2}}
    if not tied:
        params["embed"]["unembed"] = jax.random.normal(
            ks[1], (D, cfg.padded_vocab), jnp.float32) * 0.2
    labels = jax.random.randint(ks[2], (B, T), 0, V)

    tuned = None
    if impl == "fused":
        # measured tuning up front: the jitted loss below then hits the
        # cache, so the recorded (bn, bv, schedule) is what actually ran
        tuned = tune_shape(B * T, D, cfg.padded_vocab, dtype="float32",
                           transpose_w=not tied, softcap=None, norm=None)

    def f(h, p, lab):
        return lm_loss(cfg, p, h, lab, impl=impl)[0]

    g = jax.jit(jax.value_and_grad(f, argnums=(0, 1)))
    lowered = g.lower(hidden, params, labels)
    compiled = lowered.compile()
    temp = int(compiled.memory_analysis().temp_size_in_bytes)
    text = compiled.as_text()
    max_numel = _max_buffer_numel(text)
    max_act_numel = _max_buffer_numel(text,
                                      exclude={cfg.padded_vocab * D})
    out = g(hidden, params, labels)
    jax.block_until_ready(out)
    model_bytes = (lm_loss_hbm_bytes_fused(B * T, D, cfg.padded_vocab,
                                           bytes_h=4)
                   if impl != "unfused" else
                   lm_loss_hbm_bytes_unfused(B * T, D, cfg.padded_vocab,
                                             bytes_h=4))
    row = {"B": B, "T": T, "D": D, "V": V, "impl": impl, "tied": tied,
           "temp_bytes": temp, "max_buffer_numel": max_numel,
           "max_act_buffer_numel": max_act_numel,
           "has_btv_buffer": bool(max_numel >= B * T * V),
           "model_hbm_bytes": int(model_bytes)}
    if tuned is not None:
        row.update(bn=tuned.bn, bv=tuned.bv, schedule=tuned.schedule,
                   tuned_source=tuned.source)

    def run():
        t0 = time.perf_counter()
        jax.block_until_ready(g(hidden, params, labels))
        return time.perf_counter() - t0

    return row, run


def bench_loss_stage(B, T, D, V, impl, tied=True, reps=7):
    row, run = prepare_loss_stage(B, T, D, V, impl, tied=tied)
    row["ms"] = min(run() for _ in range(reps)) * 1e3
    return row


def bench_train_smoke(steps=8):
    """Full train-step wall time on the smoke config per loss impl.

    ``fused`` runs the production default (``TrainerConfig.fused_loss``,
    in-sweep GNB refresh); the other two pin ``fused_loss=False`` and
    select the module-level impl the hot path should compile."""
    from repro.configs.gpt2 import GPT2_TINY
    from repro.data import DataConfig, make_source
    from repro.train import TrainerConfig, train_loop

    out = {}
    for impl in ("unfused", "chunked", "fused"):
        set_lm_loss_impl(impl if impl != "fused" else "chunked")
        try:
            src = make_source(DataConfig(seq_len=64, global_batch=8,
                                         vocab_size=512, seed=0))
            tc = TrainerConfig(optimizer="sophia_g", peak_lr=3e-4,
                               total_steps=steps, hess_interval=4,
                               hess_subbatch=4, seed=0,
                               fused_loss=(impl == "fused"))
            # per-step timestamps via the loop callback; steps 0 (hot-path
            # compile) and 1 (first refresh executes the cond's estimator
            # branch) are dropped so the gate measures steady-state step
            # time, not compile time
            stamps = [time.perf_counter()]
            _, hist = train_loop(
                GPT2_TINY, tc, src, num_steps=steps,
                callback=lambda *_: stamps.append(time.perf_counter()))
            deltas = [b - a for a, b in zip(stamps[2:-1], stamps[3:])]
            out[f"{impl}_ms"] = 1e3 * sum(deltas) / len(deltas)
            out[f"{impl}_loss_final"] = hist[-1]["loss"]
        finally:
            set_lm_loss_impl("chunked")
    out["ratio_chunked_vs_unfused"] = out["chunked_ms"] / out["unfused_ms"]
    out["ratio_fused_vs_chunked"] = out["fused_ms"] / out["chunked_ms"]
    return out


def diff_vs_baseline(report, baseline_path, *, ms_tol=1.15):
    """Nightly regression diff: fresh ``report`` vs the committed JSON.

    Fails (returns a non-empty list of reasons) on a >15% step-time
    regression in any matching loss-stage cell or the train smoke, or on
    ANY growth of a cell's max live activation buffer."""
    with open(baseline_path) as f:
        base = json.load(f)
    bcells = {(r["V"], r.get("tied", True), r["impl"]): r
              for r in base["loss_stage"]}
    fails = []
    for r in report["loss_stage"]:
        b = bcells.get((r["V"], r.get("tied", True), r["impl"]))
        if b is None:
            continue  # new cell: no baseline to regress against
        cell = f"V={r['V']} tied={r.get('tied', True)} {r['impl']}"
        if r["ms"] > b["ms"] * ms_tol:
            fails.append(f"{cell}: ms {r['ms']:.2f} > {ms_tol}x baseline "
                         f"{b['ms']:.2f}")
        if r["max_act_buffer_numel"] > b["max_act_buffer_numel"]:
            fails.append(f"{cell}: max live activation buffer grew "
                         f"{b['max_act_buffer_numel']:,} -> "
                         f"{r['max_act_buffer_numel']:,} elements")
    bt, nt = base.get("train_smoke", {}), report["train_smoke"]
    for k in ("unfused_ms", "chunked_ms", "fused_ms"):
        if k in bt and nt[k] > bt[k] * ms_tol:
            fails.append(f"train smoke {k}: {nt[k]:.1f} > {ms_tol}x "
                         f"baseline {bt[k]:.1f}")
    return fails


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep for CI (seconds, not minutes)")
    ap.add_argument("--out", default="benchmarks/BENCH_loss.json")
    ap.add_argument("--baseline", default=None,
                    help="diff against a committed BENCH_loss.json and "
                         "fail on >15%% step time or any max-live-buffer "
                         "regression (nightly CI)")
    args = ap.parse_args()

    # vocab sizes sit past the chunk-size plateau (chunked chunk=2048):
    # above it the chunked path's biggest buffer is one [rows, chunk]
    # tile, flat in V, while unfused grows as B*T*V; the fused path's
    # tile is the autotuner's pick, bounded by the residency cap
    # (D chosen so V*D never collides with a rows*chunk tile size — the
    # weight-buffer exclusion in the activation audit stays unambiguous)
    if args.smoke:
        B, T, D = 4, 64, 96
        vocabs = [4096, 8192, 32768]
    else:
        B, T, D = 8, 128, 160
        vocabs = [4096, 8192, 32768]

    rows = []
    reps = 7
    for V in vocabs:
        for tied in (True, False):
            # compile all three impls first, then round-robin the timed
            # reps across them: machine-speed drift between reps lands
            # on every impl, so the within-cell fused-vs-chunked gate
            # compares like with like (best-of-reps per impl)
            cells = [(impl,
                      *prepare_loss_stage(B, T, D, V, impl, tied=tied))
                     for impl in ("unfused", "chunked", "fused")]
            best = {impl: float("inf") for impl, _, _ in cells}
            for _ in range(reps):
                for impl, _, run in cells:
                    best[impl] = min(best[impl], run())
            for impl, r, _ in cells:
                r["ms"] = best[impl] * 1e3
                rows.append(r)
                blk = (f" bn={r['bn']}/bv={r['bv']}/{r['schedule']}"
                       if impl == "fused" else "")
                print(f"V={V:6d} {'tied  ' if tied else 'untied'} "
                      f"{impl:8s} temp={r['temp_bytes']:>12,}B "
                      f"max_act={r['max_act_buffer_numel']:>11,}el "
                      f"btv={str(r['has_btv_buffer']):5s} "
                      f"{r['ms']:8.2f}ms{blk}", flush=True)

    train = bench_train_smoke()
    print(f"train smoke: unfused {train['unfused_ms']:.1f}ms/step, "
          f"chunked {train['chunked_ms']:.1f}ms/step, "
          f"fused (default) {train['fused_ms']:.1f}ms/step")

    by = lambda impl: [r for r in rows if r["impl"] == impl]  # noqa: E731
    chunked_ms = {(r["V"], r["tied"]): r["ms"] for r in by("chunked")}
    ok = {
        # the acceptance criterion: no [B*T, V] residency at any vocab size
        "fused_logits_free": not any(r["has_btv_buffer"] for r in by("fused")),
        "chunked_logits_free": not any(r["has_btv_buffer"]
                                       for r in by("chunked")),
        # ... and the biggest *activation* buffer stays strictly below the
        # logits footprint in every fused cell.  (The tuned tile differs
        # per cell, so the old flat-in-V set test is replaced by the
        # per-cell bound the autotuner's residency cap guarantees.)
        "fused_tile_bounded": all(
            r["max_act_buffer_numel"] < r["B"] * r["T"] * r["V"]
            for r in by("fused")),
        # sanity: the unfused oracle really does materialize it
        "unfused_materializes": all(r["has_btv_buffer"]
                                    for r in by("unfused")),
        # the tentpole's exit criterion: tuned fused wins wall-clock in
        # every grid cell
        "fused_beats_chunked": all(
            r["ms"] <= chunked_ms[(r["V"], r["tied"])]
            for r in by("fused")),
        # no step-time regression for the compiled logits-free path
        "no_step_time_regression":
            train["ratio_chunked_vs_unfused"] <= 1.25,
    }
    report = {"smoke": args.smoke, "loss_stage": rows, "train_smoke": train,
              "ok": ok}
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print("ok:", ok, "->", args.out)
    if args.baseline:
        fails = diff_vs_baseline(report, args.baseline)
        for msg in fails:
            print("REGRESSION:", msg)
        if fails:
            raise SystemExit(1)
    if not all(ok.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
