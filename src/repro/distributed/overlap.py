"""Bucketed, backward-overlapped compressed data-parallel reduction.

PR 2's in-collective int8 compression ran each engine shard through ONE
``shard_map`` — a monolithic reduce-scatter → quantize → all-gather after
the full backward pass, leaving every microsecond of comm time exposed.
This module re-expresses the same reduction as a pipeline of independent
per-bucket collectives:

    shard  =  [bucket 0 | bucket 1 | ... | bucket B-1]     (static slices,
                                                            256-block- and
                                                            segment-aligned)
    for each bucket:  reduce-scatter(fp32) -> int8 quantize (+EF view)
                      -> all-gather(int8 + scales) -> dequantize

Each bucket is its own ``shard_map`` call, so the compiled HLO contains B
*independent* collective chains instead of one monolithic chain.  That is
exactly the shape XLA's latency-hiding scheduler (flags threaded through
``launch/mesh.py``) needs to start early buckets' collectives while later
buckets' inputs are still being produced by backward compute — and on the
CPU thunk runtime, independent chains execute concurrently with compute
without any flags at all.

Bucket geometry — device-major under a mesh, contiguous without one:

    mesh-less     bucket j  =  global elements [start_j, stop_j)
    under a mesh  bucket j  =  each device's LOCAL elements
                               [start_j/ndev, stop_j/ndev) of its segment,
                               i.e. global {d*seg + start_j/ndev ... } for
                               every device d

The mesh form matters: the gradient and error-feedback buffers arrive
sharded ``P(fsdp)``, so a *contiguous* global slice [start, stop) crosses
device boundaries and SPMD partitioning has to insert collective-permutes
to reshard every bucket (measured: +56% total collective bytes at 8
devices).  Slicing each device's own segment instead is comm-free — a
reshape to ``[ndev, seg]``, a column slice, and the inverse reassembly
(concatenate along columns) all stay device-local.

Numerical contract — the load-bearing property of this file:

    *any* bucketing dequantizes BIT-IDENTICALLY to the monolithic path.

Both the per-256-block fp32 scales and the stochastic-rounding noise are
functions of the **global element index** within the flat shard (PR 2's
device-count-invariance discipline: ``repro.quant._quantize(..., offset=)``
hashes ``offset + arange``).  Bucket boundaries are multiples of
``block * ndev``, so bucket-local runs land on the same scale blocks and
the same noise as the whole-shard call whichever geometry is in play —
the device-major form passes ``stride = seg`` to ``_allreduce_one`` so
device ``d``'s run still hashes ``d*seg + start/ndev + arange``.
``tests/test_overlap.py`` pins this across bucket sizes straddling block
boundaries.

Bucketing also fixes the monolithic path's peak comm buffer: the int8
all-gather buffer is O(bucket) instead of O(shard), and the fp32 gradient
only ever crosses the wire reduce-scattered, so peak per-collective bytes
are O(n/devices + bucket).  The 8-device HLO audit asserts this on the
compiled program.

Telemetry (``telemetry=True``) threads host timestamps around each
bucket's collective using *unordered* ``io_callback`` — ordering is
enforced purely by dataflow (the stamp consumes a probe of its
predecessor, the successor consumes the stamp), which is the only ordering
that is safe under multi-device jit.  ``time.perf_counter`` deltas exceed
f32 precision, so stamps are (2,) f32 ``[whole_seconds, fraction]`` pairs;
``delta_seconds`` recombines them.  A process-local ``TIMELINE`` records
(tag, t) pairs for the per-bucket timeline in ``BENCH_comm.json``.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

from ..core.engine import bucket_slices
from ..quant import _GOLDEN, _as_seed

__all__ = ["allreduce_shards_bucketed", "plan_buckets", "stamp",
           "delta_seconds", "timeline_enable", "timeline_snapshot",
           "decode_timeline"]


# ---------------------------------------------------------------------------
# bucket planning

def plan_buckets(shard_sizes: Sequence[int], ndev: int, *, block: int = 256,
                 bucket_elems: Optional[int] = None
                 ) -> Tuple[Tuple[Tuple[int, int], ...], ...]:
    """Static bucket plan: per shard, a tuple of ``(start, stop)`` slices.

    ``bucket_elems`` semantics (shared with ``allreduce_shards``):

      * ``None``  — auto: roofline-chosen size (``choose_bucket_elems``)
        when the reduction actually spans devices; monolithic when
        ``ndev <= 1`` (no collective to overlap, bucketing is pure
        overhead);
      * ``0``     — force the monolithic single-bucket path (PR 2 shape);
      * ``N > 0`` — explicit size, rounded up to ``block * ndev`` so every
        per-device segment of every bucket stays aligned with the
        quantization scale blocks.

    The plan is pure static metadata — slicing happens at trace time, so
    the compiled program sees fixed bucket shapes.
    """
    align = block * max(1, ndev)
    plans = []
    for n in shard_sizes:
        n = int(n)
        if bucket_elems is None:
            if ndev <= 1:
                b = 0
            else:
                from ..launch.roofline import choose_bucket_elems
                b = choose_bucket_elems(n, ndev, block=block)
        else:
            b = int(bucket_elems)
        plans.append(bucket_slices(n, b, align=align))
    return tuple(plans)


# ---------------------------------------------------------------------------
# host-timestamp telemetry (dataflow-ordered, multi-device safe)

#: process-local (tag, perf_counter_seconds) pairs, appended by stamps when
#: timeline recording is enabled.  Tags decode via :func:`decode_timeline`.
TIMELINE: List[Tuple[int, float]] = []
_TIMELINE_ON = False

_TAG_SHARD = 10000  # tag = shard * 10000 + bucket * 2 + phase(0=pre, 1=post)


def timeline_enable(on: bool = True) -> None:
    """Toggle TIMELINE recording (clears any prior records)."""
    global _TIMELINE_ON
    _TIMELINE_ON = bool(on)
    TIMELINE.clear()


def timeline_snapshot() -> List[Tuple[int, float]]:
    return list(TIMELINE)


def decode_timeline(records=None) -> List[Dict[str, Any]]:
    """TIMELINE records as dicts, times relative to the first record."""
    records = timeline_snapshot() if records is None else list(records)
    if not records:
        return []
    t0 = min(t for _, t in records)
    out = []
    for tag, t in records:
        shard, rest = divmod(int(tag), _TAG_SHARD)
        bucket, phase = divmod(rest, 2)
        out.append({"shard": shard, "bucket": bucket,
                    "phase": "post" if phase else "pre",
                    "t_rel_s": t - t0})
    out.sort(key=lambda r: r["t_rel_s"])
    return out


def _host_stamp(tag, _probe):
    t = time.perf_counter()
    if _TIMELINE_ON:
        TIMELINE.append((int(tag), float(t)))
    whole = float(int(t))
    return np.asarray([whole, t - whole], np.float32)


def stamp(dep: jnp.ndarray, tag: int = 0):
    """Host timestamp ordered by dataflow: fires after ``dep`` exists.

    Returns ``(t, dep')`` where ``t`` is a (2,) f32 ``[whole, frac]``
    seconds pair and ``dep'`` equals ``dep`` but additionally depends on
    ``t`` — thread ``dep'`` (not ``dep``) into downstream compute so the
    stamp is pinned *between* producer and consumer.  The callback is
    deliberately unordered: ``ordered=True`` is unsupported/unsafe on
    multi-device programs, and dataflow gives the only ordering we need.
    """
    probe = (jnp.reshape(dep, (-1,))[0].astype(jnp.float32)
             if dep.size else jnp.float32(0))
    t = io_callback(_host_stamp, jax.ShapeDtypeStruct((2,), jnp.float32),
                    jnp.int32(tag), probe, ordered=False)
    dep = dep + (t[0] * 0).astype(dep.dtype)
    return t, dep


def delta_seconds(t0, t1):
    """Seconds between two :func:`stamp` pairs, f32-precision-safe."""
    return (t1[0] - t0[0]) + (t1[1] - t0[1])


# ---------------------------------------------------------------------------
# the bucketed pipeline

def allreduce_shards_bucketed(compressor, g_shards, state, rng, *,
                              mesh=None, axis=None,
                              bucket_elems: Optional[int] = None,
                              telemetry: bool = False):
    """Per-bucket compressed reduction over flat gradient shards.

    Entry point behind ``GradCompressor.allreduce_shards`` (see its
    docstring for the user-facing contract).  With ``telemetry=True``
    returns a third element ``{"comm_seconds", "comm_t0"}``:
    ``comm_seconds`` is the wall span of the comm *window* — earliest
    bucket pre-stamp to latest bucket post-stamp in actual execution order
    (buckets run out of program order under the latency-hiding scheduler,
    so min/max over stamps, not first/last) — and ``comm_t0`` is the
    absolute (2,) f32 reference stamp the window is measured from, for
    correlating with step-level stamps.  Per-bucket stamps additionally
    land in TIMELINE when recording is on.
    """
    if mesh is None:
        from .sharding import activation_mesh
        mesh = activation_mesh()
    if axis is None and mesh is not None:
        from .sharding import fsdp_axis
        axis = fsdp_axis(mesh)
    axes = (axis,) if isinstance(axis, str) else tuple(axis or ())
    ndev = (int(np.prod([mesh.shape[a] for a in axes]))
            if (mesh is not None and axes) else 1)

    plans = plan_buckets([g.shape[0] for g in g_shards], ndev,
                         block=compressor.block, bucket_elems=bucket_elems)
    seed = _as_seed(rng)

    out_g, out_e = [], []
    pre_stamps, post_stamps = [], []
    for i, (g, e, plan) in enumerate(zip(g_shards, state.error, plans)):
        # rng None selects deterministic round-to-nearest (see _quantize)
        # — preserve it instead of xor-ing into a crash
        sseed = None if seed is None else \
            seed ^ jnp.uint32((_GOLDEN * (i + 1)) & 0xFFFFFFFF)
        n = int(g.shape[0])
        # device-major geometry (see module docstring): plan boundaries are
        # multiples of block*ndev whenever the plan has >1 bucket, so the
        # [ndev, seg] view and its column slices are always exact
        interleave = len(plan) > 1 and ndev > 1
        if interleave:
            seg = n // ndev
            g2 = g.reshape(ndev, seg)
            e2 = e.reshape(ndev, seg)
        deq_parts, err_parts = [], []
        for j, (start, stop) in enumerate(plan):
            if interleave:
                s0, s1 = start // ndev, stop // ndev
                g_b = g2[:, s0:s1].reshape(-1)
                e_b = e2[:, s0:s1].reshape(-1)
                off, stride = s0, seg
            else:
                g_b = g if len(plan) == 1 else g[start:stop]
                e_b = e if len(plan) == 1 else e[start:stop]
                off, stride = start, None
            if telemetry:
                t0, g_b = stamp(g_b, _TAG_SHARD * i + 2 * j)
                pre_stamps.append(t0)
            with jax.named_scope(f"comm_shard{i}_bucket{j}"):
                deq, err = compressor._allreduce_one(g_b, e_b, sseed, mesh,
                                                     axis, offset=off,
                                                     stride=stride)
            if telemetry:
                t1, deq = stamp(deq, _TAG_SHARD * i + 2 * j + 1)
                post_stamps.append(t1)
            if interleave:
                deq = deq.reshape(ndev, -1)
                err = err.reshape(ndev, -1)
            deq_parts.append(deq)
            err_parts.append(err)
        if interleave:
            out_g.append(jnp.concatenate(deq_parts, axis=1).reshape(-1))
            out_e.append(jnp.concatenate(err_parts, axis=1).reshape(-1))
        else:
            out_g.append(deq_parts[0] if len(deq_parts) == 1
                         else jnp.concatenate(deq_parts))
            out_e.append(err_parts[0] if len(err_parts) == 1
                         else jnp.concatenate(err_parts))

    from .compression import FlatCompressionState
    new_state = FlatCompressionState(error=tuple(out_e))
    if telemetry:
        if pre_stamps:
            ref = pre_stamps[0]
            lo = jnp.stack([delta_seconds(ref, t) for t in pre_stamps]).min()
            hi = jnp.stack([delta_seconds(ref, t) for t in post_stamps]).max()
            tele = {"comm_seconds": hi - lo, "comm_t0": ref}
        else:
            tele = {"comm_seconds": jnp.float32(0),
                    "comm_t0": jnp.zeros((2,), jnp.float32)}
        return tuple(out_g), new_state, tele
    return tuple(out_g), new_state
