from .sharding import (batch_specs, cache_specs, constrain, fsdp_axis,
                       param_shardings, partition_params,
                       set_activation_mesh, to_shardings)
from .compression import (CompressionState, FlatCompressionState,
                          GradCompressor, compressed_bytes)
