"""In-collective gradient compression for the data-parallel reduction.

Beyond-paper lever (DESIGN.md §5): int8 block-quantized gradients with
per-block fp32 scales and *error feedback* (the quantization residual is
carried into the next step), cutting the DP gather bytes ~4x vs fp32 /
~2x vs bf16.  Unbiasedness is preserved in expectation by stochastic
rounding; error feedback bounds the bias accumulation (Karimireddy et al.).

The production path operates on the optimizer engine's **flat gradient
shards** (core/engine.py), not on a params-shaped pytree, and runs *inside*
the data-parallel collective via ``shard_map`` over the fsdp axis:

    full fp32 grad shard                 (XLA reduce-scatters to feed the
        |  in_spec P(fsdp)               shard_map — fp32 only ever exists
        v                                segment-sharded on the wire)
    local segment + error-feedback segment
        |  _quantize: int8 + per-256-block fp32 scales
        v
    all_gather(int8), all_gather(scales)   <-- the bytes that cross the wire
        |  dequantize
        v
    full reduced fp32 shard (replicated), new error segment (sharded)

Two properties make the result *identical* on any device count (the
1-vs-8-device parity tier in tests/test_distributed_engine.py):

  * segments are always multiples of the quantization block (engine shards
    are padded to 128K elements, so any power-of-two fsdp axis keeps the
    256-element scale blocks aligned with the single-device blocking);
  * stochastic rounding noise is a counter-based hash of
    (seed, global element index) — never of device id or segment shape.

Error feedback is a flat fp32 buffer per engine shard
(:class:`FlatCompressionState`, stored in ``TrainState.comp_state`` and
sharded over the fsdp axis like the engine's m/h shards).

The legacy params-pytree ``roundtrip`` API is kept for tests and for
mesh-agnostic experimentation.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# The quantization core lives in repro.quant (shared with the serving KV
# cache); the underscore names are re-exported here because this module is
# their historical home (tests and experiments import them from here).
from ..quant import _GOLDEN, _as_seed, _quantize, _uniform_noise

__all__ = ["CompressionState", "FlatCompressionState", "GradCompressor",
           "compressed_bytes", "_as_seed", "_quantize", "_uniform_noise"]

PyTree = Any


class CompressionState(NamedTuple):
    error: PyTree  # error-feedback residuals, same structure as grads


class FlatCompressionState(NamedTuple):
    """Error feedback over the engine's flat gradient shards.

    Layout: ONE flat fp32 buffer per engine shard, the shard's full padded
    length, sharded over the fsdp axis exactly like the engine's m/h shards
    (``flat_shard_spec``).  The buffer is indexed by *global element index*
    within the shard — the same coordinate system the quantization noise
    hash and the per-256-block scales use.

    The bucketed overlapped path (``distributed/overlap.py``) does NOT add
    bucket structure to this state: each bucket's error feedback is a
    disjoint, 256-block-aligned **view** of the same flat buffer —
    ``error[i][start:stop]`` mesh-less, or the device-major column slice
    ``error[i].reshape(ndev, seg)[:, start//ndev:stop//ndev]`` under a
    mesh (comm-free on ``P(fsdp)``-sharded buffers) — read and written in
    place by that bucket's collective.  Because views tile the buffer
    exactly, the bucketed and monolithic paths share one state layout —
    checkpoints, donation, re-sharding and ``TrainState.comp_state`` are
    identical whichever path produced them, and switching bucket sizes
    mid-run (or between save and restore) is always legal."""

    error: Tuple[jnp.ndarray, ...]


# ---------------------------------------------------------------------------
# the compressor


class GradCompressor:
    def __init__(self, block: int = 256):
        self.block = block

    # -- flat-shard path (the production pipeline) --------------------------

    def init_shards(self, layout) -> FlatCompressionState:
        """Zero error feedback matching an engine ShardLayout."""
        return FlatCompressionState(error=tuple(
            jnp.zeros((s,), jnp.float32) for s in layout.shard_sizes))

    def wire_bytes(self, layout) -> Tuple[int, ...]:
        """Per-shard bytes on the wire for the compressed gather phase:
        n int8 payload + 4 bytes per 256-block fp32 scale."""
        return tuple(int(n) + 4 * (-(-int(n) // self.block))
                     for n in layout.shard_sizes)

    def allreduce_shards(self, g_shards, state: FlatCompressionState, rng, *,
                         mesh=None, axis=None,
                         bucket_elems: Optional[int] = None,
                         telemetry: bool = False
                         ) -> tuple[Tuple[jnp.ndarray, ...],
                                    FlatCompressionState]:
        """Compressed data-parallel reduction over flat gradient shards.

        With a mesh carrying the fsdp axis, each shard runs through one
        ``shard_map`` **per bucket** (a 256-block-aligned slice of the
        shard): XLA ring reduce-scatters the bucket's fp32 gradient to feed
        the shard_map, the device's reduced segment (+ its error-feedback
        view) is quantized to int8 + per-block scales, the int8/scale
        representation is gathered across the axis — the bytes on the wire
        — and dequantized on the far side.  Bucketing bounds the peak comm
        buffer at O(bucket) instead of O(shard) and gives the latency-
        hiding scheduler independent per-bucket collective chains to
        overlap with compute (distributed/overlap.py); ``bucket_elems``
        None picks the roofline bucket size, 0 forces the monolithic
        single-bucket path, and any value is bit-identical to any other
        because quantization is keyed on the global element index only.

        Without a mesh (or when the axis doesn't divide a bucket into
        block-aligned segments) the identical math runs locally, so
        enabling a mesh never changes the training trajectory.
        """
        from .overlap import allreduce_shards_bucketed
        return allreduce_shards_bucketed(self, g_shards, state, rng,
                                         mesh=mesh, axis=axis,
                                         bucket_elems=bucket_elems,
                                         telemetry=telemetry)

    def allreduce_shards_stateless(self, g_shards, rng, *, mesh=None,
                                   axis=None) -> Tuple[jnp.ndarray, ...]:
        """Compressed reduction over flat shards WITHOUT error feedback.

        The Hessian-refresh path uses this for the estimator sub-batch
        gradient: at 1/k refresh sparsity a residual carried between
        refreshes would contribute O((1-beta2)/k) of EMA mass — noise-level
        next to the stochastic-rounding unbiasedness already in
        ``_quantize`` — and persisting one more params-sized buffer in
        TrainState isn't worth that.  Same wire representation and
        device-count invariance as :meth:`allreduce_shards`."""
        zero = FlatCompressionState(error=tuple(
            jnp.zeros(g.shape, jnp.float32) for g in g_shards))
        deq, _ = self.allreduce_shards(g_shards, zero, rng, mesh=mesh,
                                       axis=axis)
        return deq

    def _allreduce_one(self, g, e, seed, mesh, axis, *, offset: int = 0,
                       stride: Optional[int] = None):
        """One bucket (or whole shard) through the in-collective pipeline.

        ``offset`` and ``stride`` locate this bucket's elements in the
        GLOBAL flat-shard coordinate system that keys the stochastic-
        rounding hash and the per-256-block scales (never the math): the
        device at combined mesh index ``idx`` quantizes global elements
        ``offset + idx * stride + [0, n/ndev)``.  ``stride`` defaults to
        this call's own per-device segment (contiguous bucket — PR 2's
        monolithic layout); the device-major bucketed path
        (distributed/overlap.py) passes ``stride = whole-shard segment``
        so its interleaved buckets still hash the true global index.  Any
        256-aligned bucketing therefore dequantizes bit-identically to the
        monolithic whole-shard call."""
        n = g.shape[0]
        axes = (axis,) if isinstance(axis, str) else tuple(axis or ())
        ndev = (int(np.prod([mesh.shape[a] for a in axes]))
                if (mesh is not None and axes) else 1)
        if ndev <= 1 or n % (ndev * self.block) != 0:
            # mesh-less (tests, single host) or segments would straddle a
            # scale block: same math, whole bucket, global offset
            x = g.astype(jnp.float32) + e
            _, _, deq = _quantize(x, self.block, seed, offset=offset)
            return deq, x - deq

        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        block, seg = self.block, n // ndev
        stride = seg if stride is None else stride

        def body(g_seg, e_seg, sd):
            # combined (major-to-minor) index along the composite fsdp axis
            idx = jnp.int32(0)
            for a in axes:
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
            x = g_seg.astype(jnp.float32) + e_seg
            q, scale, deq = _quantize(x, block,
                                      None if seed is None else sd,
                                      offset=offset + idx * stride)
            # int8 payload + fp32 scales are what cross the wire
            q_all = jax.lax.all_gather(q.reshape(-1), axes[0] if
                                       len(axes) == 1 else axes, tiled=True)
            s_all = jax.lax.all_gather(scale, axes[0] if
                                       len(axes) == 1 else axes, tiled=True)
            full = (q_all.reshape(-1, block).astype(jnp.float32)
                    * s_all).reshape(-1)
            return full, x - deq

        spec = P(axes if len(axes) > 1 else axes[0])
        sd = jnp.uint32(0) if seed is None else seed  # placeholder operand
        return shard_map(body, mesh=mesh, in_specs=(spec, spec, P()),
                         out_specs=(P(), spec), check_rep=False)(g, e, sd)

    # -- legacy params-pytree path (mesh-agnostic simulation) ----------------

    def init(self, grads: PyTree) -> CompressionState:
        return CompressionState(
            error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                               grads))

    def roundtrip(self, grads: PyTree, state: CompressionState,
                  rng) -> tuple[PyTree, CompressionState]:
        """Simulate the compressed all-reduce on a params-shaped pytree:
        returns the gradients as the receiving end would see them, plus
        updated error feedback.  The flat-shard ``allreduce_shards`` is the
        production path; this form stays for A/B experiments on unraveled
        trees."""
        leaves, treedef = jax.tree.flatten(grads)
        keys = jax.random.split(rng, len(leaves))
        keys = jax.tree.unflatten(treedef, list(keys))

        def one(g, e, k):
            g32 = g.astype(jnp.float32) + e
            _, _, deq = _quantize(g32, self.block, k)
            return deq, g32 - deq

        out = jax.tree.map(one, grads, state.error, keys)
        deq = jax.tree.map(lambda o: o[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        return deq, CompressionState(error=err)


def compressed_bytes(grads: PyTree, block: int = 256) -> int:
    """Bytes on the wire for the compressed representation (int8 + scales).

    Works on any pytree of arrays — a params-shaped grad tree or a tuple of
    the engine's flat shards."""
    total = 0
    for g in jax.tree.leaves(grads):
        n = g.size
        nblocks = -(-n // block)
        total += n + nblocks * 4
    return total
