"""Gradient compression for data-parallel reduction at 1000+ node scale.

Beyond-paper lever (DESIGN.md §5): int8 block-quantized gradients with
per-block fp32 scales and *error feedback* (the quantization residual is
carried into the next step), cutting DP all-reduce bytes ~4x vs fp32 /
~2x vs bf16.  Unbiasedness is preserved in expectation by stochastic
rounding; error feedback bounds the bias accumulation (Karimireddy et al.).

Usage (wraps any GradientTransformation's input):

    comp = GradCompressor(block=256)
    cstate = comp.init(grads_shape)
    grads_q, cstate = comp.roundtrip(grads, cstate, rng)   # quantize+dequant
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class CompressionState(NamedTuple):
    error: PyTree  # error-feedback residuals, same structure as grads


def _quantize(x, block: int, rng):
    """int8 block quantization with stochastic rounding.

    Returns (q int8, scales fp32, dequantized fp32)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    scaled = blocks / scale
    noise = jax.random.uniform(rng, scaled.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:x.size].reshape(x.shape)
    return q, scale, deq


class GradCompressor:
    def __init__(self, block: int = 256):
        self.block = block

    def init(self, grads: PyTree) -> CompressionState:
        return CompressionState(
            error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                               grads))

    def roundtrip(self, grads: PyTree, state: CompressionState,
                  rng) -> tuple[PyTree, CompressionState]:
        """Simulate the compressed all-reduce: returns the gradients as the
        receiving end would see them, plus updated error feedback.

        In the jitted train step the quantize happens *before* the psum and
        the dequantize after; XLA then moves int8 bytes over ICI.  Here the
        roundtrip form keeps the math identical while staying mesh-agnostic.
        """
        leaves, treedef = jax.tree.flatten(grads)
        keys = jax.random.split(rng, len(leaves))
        keys = jax.tree.unflatten(treedef, list(keys))

        def one(g, e, k):
            g32 = g.astype(jnp.float32) + e
            _, _, deq = _quantize(g32, self.block, k)
            return deq, g32 - deq

        out = jax.tree.map(one, grads, state.error, keys)
        deq = jax.tree.map(lambda o: o[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        return deq, CompressionState(error=err)


def compressed_bytes(grads: PyTree, block: int = 256) -> int:
    """Bytes on the wire for the compressed representation (int8 + scales)."""
    total = 0
    for g in jax.tree.leaves(grads):
        n = g.size
        nblocks = -(-n // block)
        total += n + nblocks * 4
    return total
