"""Sharding rules: one table maps parameter-tree paths to PartitionSpecs.

Mesh axes (see launch/mesh.py):
    single-pod:  ("data", "model")            = (16, 16)
    multi-pod:   ("pod", "data", "model")     = (2, 16, 16)

``pod`` composes with ``data`` into the gradient/FSDP axis — specs use the
tuple ``("pod", "data")`` when the mesh has a pod axis, so the same rule
table serves both meshes (and any pod count).

Design:
  * tensor-parallel ("model") axis shards heads / MLP hidden / experts /
    vocab — the contraction patterns XLA turns into all-reduce or
    reduce-scatter per layer.
  * FSDP (ZeRO-3) optionally shards the *other* large axis of every weight
    over the data axis; optimizer states (Sophia m, h) inherit param specs,
    so Sophia trains with the same memory footprint as AdamW (paper Table 1)
    at any scale.
  * every rule is validated for divisibility; non-divisible dims fall back
    to replication (correct, just less sharded).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any


def fsdp_axis(mesh: Mesh):
    """The (composite) data axis: ("pod","data") on multi-pod meshes."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data")
    return "data"


# ---------------------------------------------------------------------------
# activation-sharding context
#
# GSPMD propagation alone can drop the batch sharding mid-model (it may
# trade per-layer FSDP weight gathers for replicated activations, a
# catastrophic choice at 4k x 256).  Models therefore pin their residual
# streams / logits / expert buffers through ``constrain`` — a no-op unless
# the launcher installs a mesh via ``set_activation_mesh``.

_ACT_CTX = {"mesh": None, "seq_shard": False}


def set_activation_mesh(mesh: Optional[Mesh]) -> None:
    _ACT_CTX["mesh"] = mesh


def activation_mesh() -> Optional[Mesh]:
    return _ACT_CTX["mesh"]


def set_sequence_sharding(on: bool) -> None:
    """Megatron-style sequence parallelism: the residual stream between
    blocks is sharded over ("model") along the SEQUENCE dim.  Saved remat
    carries shrink by the model-axis size and the post-block all-reduce
    becomes reduce-scatter(+all-gather at the next attention) at half the
    volume.  Hillclimb lever; see EXPERIMENTS.md §Perf."""
    _ACT_CTX["seq_shard"] = on


def residual_axes():
    """Logical axes for the (B, S, D) residual stream."""
    if _ACT_CTX["seq_shard"]:
        return ("batch", "model", None)
    return ("batch", None, None)


def constrain(x, *axes):
    """with_sharding_constraint by logical axis name.

    axes: one entry per dim of x — "batch" (data axis), "model", or None.
    Dims that don't divide evenly fall back to unsharded.
    """
    mesh = _ACT_CTX["mesh"]
    if mesh is None:
        return x

    def resolve(ax, size):
        if ax is None:
            return None
        phys = batch_axis(mesh) if ax == "batch" else ax
        n = (int(np.prod([mesh.shape[a] for a in phys]))
             if isinstance(phys, tuple) else mesh.shape[phys])
        return phys if size % n == 0 else None

    spec = P(*[resolve(a, s) for a, s in zip(axes, x.shape)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_axis(mesh: Mesh):
    return fsdp_axis(mesh)


# ---------------------------------------------------------------------------
# rule table: (path regex, builder(dims, model_ax, fsdp_ax) -> P)
# paths look like: "['layers']['attn']['wq']" from jax.tree_util.keystr


def _rules(model="model"):
    M = model
    return [
        # embeddings: vocab over TP, d_model over FSDP
        (r"\['embed'\]\['tok'\]$",      lambda f: P(M, f)),
        (r"\['embed'\]\['unembed'\]$",  lambda f: P(f, M)),
        (r"\['embed'\]\['pos'\]$",      lambda f: P(None, f)),
        # attention
        (r"\['wq'\]$",                  lambda f: P(f, M)),
        (r"\['wk'\]$",                  lambda f: P(f, M)),
        (r"\['wv'\]$",                  lambda f: P(f, M)),
        (r"\['wo'\]$",                  lambda f: P(M, f)),
        (r"\['b[qkv]'\]$",              lambda f: P(M)),
        # dense MLP / shared experts / rwkv channel-mix
        (r"\['w_gate'\]$",              lambda f: P(f, M)),
        (r"\['w_up'\]$",                lambda f: P(f, M)),
        (r"\['w_down'\]$",              lambda f: P(M, f)),
        (r"\['b_up'\]$",                lambda f: P(M)),
        (r"\['b_down'\]$",              lambda f: P()),
        # MoE experts: E over TP (expert parallelism)
        (r"\['moe'\]\['router'\]$",     lambda f: P(f, None)),
        (r"\['moe'\]\['w_gate'\]$",     lambda f: P(M, f, None)),
        (r"\['moe'\]\['w_up'\]$",       lambda f: P(M, f, None)),
        (r"\['moe'\]\['w_down'\]$",     lambda f: P(M, None, f)),
        # rwkv time-mix
        (r"\['tm'\]\['w[rkvg]'\]$",     lambda f: P(f, M)),
        (r"\['tm'\]\['wo'\]$",          lambda f: P(M, f)),
        (r"\['tm'\]\['wa'\]$",          lambda f: P(f, None)),
        (r"\['tm'\]\['wb'\]$",          lambda f: P(None, M)),
        (r"\['tm'\]\['w0'\]$",          lambda f: P(M)),
        (r"\['tm'\]\['u'\]$",           lambda f: P(M, None)),
        (r"\['tm'\]\['mu'\]$",          lambda f: P(None, None)),
        (r"\['cm'\]\['wk'\]$",          lambda f: P(f, M)),
        (r"\['cm'\]\['wv'\]$",          lambda f: P(M, f)),
        (r"\['cm'\]\['wr'\]$",          lambda f: P(f, M)),
        # griffin RG-LRU
        (r"\['w_in'\]$",                lambda f: P(f, M)),
        (r"\['conv_k'\]$",              lambda f: P(None, M)),
        (r"\['conv_b'\]$",              lambda f: P(M)),
        (r"\['lam'\]$",                 lambda f: P(M)),
        (r"\['w_[ax]'\]$",              lambda f: P(None, M)),
        (r"\['b_[ax]'\]$",              lambda f: P(M)),
        (r"\['w_out'\]$",               lambda f: P(M, f)),
        # frontends
        (r"\['patch_proj'\]$",          lambda f: P(f, None)),
        (r"\['frame_proj'\]$",          lambda f: P(f, None)),
    ]


def _spec_for(path: str, shape, n_prefix: int, mesh: Mesh,
              fsdp: bool) -> P:
    """Match path against the rule table; prepend None for stacked axes;
    drop shardings that don't divide."""
    f_ax = fsdp_axis(mesh) if fsdp else None
    for pat, builder in _rules():
        if re.search(pat, path):
            spec = builder(f_ax)
            break
    else:
        spec = P()  # replicate (norm scales, biases, scalars)

    dims = list(spec) + [None] * (len(shape) - n_prefix - len(spec))
    dims = [None] * n_prefix + dims
    dims = dims[:len(shape)]

    # divisibility check: replicate any axis that doesn't divide
    def size_of(ax):
        if ax is None:
            return 1
        if isinstance(ax, tuple):
            return int(np.prod([mesh.shape[a] for a in ax]))
        return mesh.shape[ax]

    fixed = [ax if (ax is None or s % size_of(ax) == 0) else None
             for ax, s in zip(dims, shape)]
    while fixed and fixed[-1] is None:
        fixed.pop()
    return P(*fixed)


_STACK_KEYS = ("layers", "groups", "tail", "encoder", "decoder")


def partition_params(params_shape: PyTree, mesh: Mesh, *,
                     fsdp: bool = True) -> PyTree:
    """Map a (ShapeDtypeStruct or array) param tree to PartitionSpecs."""

    def spec(path_entries, leaf):
        path = jax.tree_util.keystr(path_entries)
        n_prefix = 1 if any(f"['{k}']" in path for k in _STACK_KEYS) else 0
        return _spec_for(path, leaf.shape, n_prefix, mesh, fsdp)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def param_shardings(params_shape: PyTree, mesh: Mesh, *,
                    fsdp: bool = True) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        partition_params(params_shape, mesh, fsdp=fsdp))


# ---------------------------------------------------------------------------
# batch / activation / cache specs


def batch_specs(batch_shape: PyTree, mesh: Mesh) -> PyTree:
    """Shard the leading (batch) dim of every input over the data axis."""
    b_ax = batch_axis(mesh)

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        dims = [b_ax] + [None] * (leaf.ndim - 1)
        size = (np.prod([mesh.shape[a] for a in b_ax])
                if isinstance(b_ax, tuple) else mesh.shape[b_ax])
        if leaf.shape[0] % size != 0:
            return P(*([None] * leaf.ndim))
        return P(*dims)

    return jax.tree.map(spec, batch_shape)


def cache_specs(cache_shape: PyTree, mesh: Mesh) -> PyTree:
    """KV caches: (L, B, S, Hkv, hd) — batch over data, heads over model.

    MQA (Hkv=1) and rwkv/griffin states fall back per-dim on divisibility.
    """
    b_ax = batch_axis(mesh)

    def size_of(ax):
        return (np.prod([mesh.shape[a] for a in ax])
                if isinstance(ax, tuple) else mesh.shape[ax])

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        dims = [None] * leaf.ndim
        # find the batch dim: first dim after an optional layer-stack dim
        bdim = 1 if leaf.ndim >= 3 else 0
        if leaf.shape[bdim] % size_of(b_ax) == 0:
            dims[bdim] = b_ax
        # shard the first divisible dim after batch over "model":
        # attention caches (L,B,S,Hkv,hd) get SEQUENCE-sharded KV (the
        # production long-context layout; softmax over the sharded S axis
        # costs two tiny all-reduces), rwkv states get head-sharded,
        # griffin recurrences get width-sharded.
        for d in range(bdim + 1, leaf.ndim):
            if leaf.shape[d] % mesh.shape["model"] == 0:
                dims[d] = "model"
                break
        return P(*dims)

    return jax.tree.map(spec, cache_shape)


def to_shardings(specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs, is_leaf=lambda x: isinstance(x, P))
