from .pipeline import (DataConfig, MemmapTokens, SyntheticLM, host_slice,
                       iterate, make_source)
