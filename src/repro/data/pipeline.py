"""Data pipeline: deterministic, stateless, resumable.

Every batch is a pure function of (seed, step) — exact fault-tolerant resume
needs only the step counter from the checkpoint (DESIGN.md §5), and any host
can (re)compute any shard, which is what elastic re-scaling requires.

Two sources behind one interface:
  * SyntheticLM   — Zipf-distributed tokens with a Markov structure, so the
    loss actually *decreases* under training (used by tests/benchmarks; the
    paper's OpenWebText/Pile are not available offline).
  * MemmapTokens  — binary uint16/uint32 token files (the nanoGPT format the
    paper uses: train.bin / val.bin), memory-mapped, random offsets per step.

Per-host sharding: ``host_slice`` gives each process only its slice of the
global batch (process_index-strided), matching jax.make_array_from_callback.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    source: str = "synthetic"          # synthetic | memmap
    path: Optional[str] = None         # for memmap
    zipf_a: float = 1.2                # synthetic skew


class SyntheticLM:
    """Markov-Zipf synthetic LM stream.

    Token t+1 = (a * t + noise) mod V with Zipf-distributed resets: gives
    learnable bigram structure (optimizers separate cleanly on it) while
    staying O(1) memory.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed random bigram table (small vocab) for learnable structure
        rng = np.random.default_rng(cfg.seed)
        self.next_tok = rng.integers(0, cfg.vocab_size,
                                     size=(cfg.vocab_size,), dtype=np.int64)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        # 70% deterministic bigram transitions, 30% Zipf noise
        start = rng.integers(0, cfg.vocab_size, size=(B,))
        noise = (rng.zipf(cfg.zipf_a, size=(B, S + 1)) - 1) % cfg.vocab_size
        use_noise = rng.random((B, S + 1)) < 0.3
        toks = np.empty((B, S + 1), dtype=np.int64)
        toks[:, 0] = start
        for t in range(1, S + 1):
            det = self.next_tok[toks[:, t - 1]]
            toks[:, t] = np.where(use_noise[:, t], noise[:, t], det)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class MemmapTokens:
    """nanoGPT-style binary token file (the paper's data format)."""

    def __init__(self, cfg: DataConfig, dtype=np.uint16):
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=dtype, mode="r")

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        ix = rng.integers(0, len(self.data) - S - 1, size=(B,))
        toks = np.stack([self.data[i:i + S + 1].astype(np.int32) for i in ix])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_source(cfg: DataConfig):
    if cfg.source == "memmap":
        return MemmapTokens(cfg)
    return SyntheticLM(cfg)


def host_slice(batch: dict, process_index: int, process_count: int) -> dict:
    """This host's strided slice of the global batch."""
    return {k: v[process_index::process_count] for k, v in batch.items()}


def iterate(source, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield source.batch_at(step)
        step += 1
