"""Shared int8 block-quantization core.

One implementation serves two consumers:

  * the data-parallel gradient compressor
    (``distributed/compression.py``) — 256-element blocks, stochastic
    rounding keyed by a counter-based hash so the wire representation is
    device-count invariant, error feedback carried by the caller;
  * the serving KV cache (``models/layers.py`` + the serve engine) — one
    block per written token (``n_kv_heads * head_dim`` elements),
    deterministic round-to-nearest so quantized pages are pure functions
    of their content and shared-prefix page reuse stays bit-exact.

Properties the tests pin (tests/test_compression.py hypothesis suite,
tests/test_decode_attention.py quant-bound checks):

  * round-to-nearest (``rng=None``): |deq - x| <= scale / 2 per element,
    and the fp32 residual ``x - deq`` is exact (Sterbenz);
  * stochastic rounding: |deq - x| <= scale, unbiased in expectation,
    noise a pure function of (seed, global element index).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_GOLDEN = 0x9E3779B9  # 2^32 / golden ratio; per-shard seed decorrelation


def _as_seed(rng):
    """Normalize an rng (PRNGKey, typed key, or int scalar) to uint32."""
    if rng is None:
        return None
    if not isinstance(rng, jax.Array):
        rng = jnp.asarray(rng)
    if rng.ndim == 0 and jnp.issubdtype(rng.dtype, jnp.integer):
        return rng.astype(jnp.uint32)
    return jax.random.randint(rng, (), 0,
                              jnp.iinfo(jnp.int32).max).astype(jnp.uint32)


def _uniform_noise(seed, idx):
    """Counter-based uniform noise in [-0.5, 0.5).

    A pure function of (seed, global element index) — murmur3-style integer
    finalizer — so the same element rounds the same way regardless of how
    the shard is segmented across devices.  jax.random.uniform keyed per
    device would break 1-vs-N-device trajectory parity.
    """
    x = idx.astype(jnp.uint32) * jnp.uint32(2654435761) + seed
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x.astype(jnp.float32) * jnp.float32(2.0 ** -32) - jnp.float32(0.5)


def _quantize(x, block: int, rng=None, *, offset=0):
    """int8 block quantization with per-block fp32 scales.

    ``rng`` None selects round-to-nearest (|deq - x| <= scale/2, and the
    fp32 residual ``x - deq`` is *exact* by Sterbenz); otherwise stochastic
    rounding driven by ``_uniform_noise`` (|deq - x| <= scale, unbiased in
    expectation).  ``offset`` is the global element index of ``x[0]`` within
    its flat shard — it keys the noise, not the math, so segmenting a shard
    changes nothing as long as segments stay block-aligned.

    Returns (q int8 [nblocks, block], scales fp32 [nblocks, 1], deq fp32
    shaped like x)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:  # engine shards are block multiples: keep their HLO pad-free
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    scaled = blocks / scale
    seed = _as_seed(rng)
    if seed is not None:
        idx = (jnp.asarray(offset, jnp.uint32)
               + jnp.arange(flat.size, dtype=jnp.uint32)).reshape(-1, block)
        scaled = scaled + _uniform_noise(seed, idx)
    q = jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:x.size].reshape(x.shape)
    return q, scale, deq


# ---------------------------------------------------------------------------
# KV-cache view: one quantization block per written token


def quantize_kv(x):
    """Per-token int8 KV quantization: x (..., Hkv, hd) -> (q int8 shaped
    like x, scales fp32 (...,)).

    Each token's (Hkv, hd) slab is one :func:`_quantize` block with
    deterministic round-to-nearest, so a quantized page is a pure function
    of its content (prefix-cache page copies stay bit-exact) and the
    element-wise error is bounded by half that token's scale — strictly
    tighter than a one-scale-per-page bound."""
    hkv, hd = x.shape[-2], x.shape[-1]
    q, scale, _ = _quantize(x.astype(jnp.float32), hkv * hd, None)
    return q.reshape(x.shape), scale.reshape(x.shape[:-2])


def dequantize_kv(q, scale, dtype):
    """Inverse of :func:`quantize_kv`: int8 (..., Hkv, hd) + fp32 scales
    (...,) -> ``dtype``.  Dequantizes in fp32 (int8 * fp32 is exact) and
    rounds once into the compute dtype."""
    return (q.astype(jnp.float32) * scale[..., None, None]).astype(dtype)


def kv_bytes_per_token(n_kv_heads: int, head_dim: int,
                       kv_dtype: str = "bf16") -> int:
    """HBM bytes of ONE cache entry (K + V) for one token in one layer:
    bf16 spends 2 bytes/element; int8 spends 1 byte/element plus one fp32
    scale per token per K/V plane.  The serving-capacity model in
    launch/roofline.py multiplies this by L * cache_len per slot."""
    el = n_kv_heads * head_dim
    if kv_dtype == "int8":
        return 2 * (el + 4)
    return 2 * 2 * el
