"""Diagonal-Hessian estimators for Sophia (paper Section 2.3).

Two estimators, each with the run-time cost of O(1) extra gradient
computations:

* :func:`hutchinson_estimator` — Algorithm 1.  Draw ``u ~ N(0, I)`` and return
  ``u * (H u)`` via a Hessian-vector product.  Unbiased for diag(H).
  We implement the HVP as forward-over-reverse (``jvp`` of ``grad``), which is
  the memory-cheap direction and compiles to one extra fwd+bwd pass on TPU.

* :func:`gnb_estimator` — Algorithm 2 (Gauss-Newton-Bartlett).  Sample labels
  ``yhat_b ~ softmax(f(theta, x_b))`` from the *model's own* logits, take the
  mini-batch gradient ``ghat`` of the CE loss against the sampled labels, and
  return ``B * ghat * ghat``.  Unbiased for diag of the Gauss-Newton matrix
  (PSD), biased for diag(H).  Uses Bartlett's 1st+2nd identities (eq. 9-13).

Both take a ``loss_fn``/``logits_fn`` over a (possibly reduced) estimator
sub-batch — the paper uses 32 of 480 examples for Sophia-H and 240 of 480 for
Sophia-G (Section 3.1) to keep amortized overhead ~5%.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.flatten_util
import jax.numpy as jnp

from .types import PyTree


def hutchinson_estimator(
    loss_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
    rng: jax.Array,
) -> PyTree:
    """u * (H u) with u ~ N(0, I): unbiased estimate of diag(H).

    ``loss_fn`` must be a scalar-valued function of params closed over the
    estimator mini-batch.
    """
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(rng, len(leaves))
    u = jax.tree.unflatten(
        treedef,
        [jax.random.normal(k, p.shape, jnp.float32).astype(p.dtype)
         for k, p in zip(keys, leaves)])
    # forward-over-reverse HVP: d/dt grad(theta + t u) |_{t=0} = H u
    _, hvp = jax.jvp(jax.grad(loss_fn), (params,), (u,))
    return jax.tree.map(lambda u_, hv: (u_ * hv).astype(jnp.float32), u, hvp)


def sample_labels(logits: jnp.ndarray, rng: jax.Array) -> jnp.ndarray:
    """yhat ~ Categorical(softmax(logits)) via Gumbel-max (fused on TPU)."""
    return jax.random.categorical(rng, logits, axis=-1)


def gnb_estimator_sq(
    logits_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
    rng: jax.Array,
    *,
    mask: jnp.ndarray | None = None,
) -> Tuple[PyTree, jnp.ndarray]:
    """GNB pieces: ``(ghat (*) ghat, B)`` with the batch scale unfolded.

    The optimizer engine folds ``B`` into the Hessian-EMA kernel
    (h' = b2 h + (1-b2) B ghat^2), so ``B * ghat^2`` never materializes as a
    separate buffer.  ``B`` is traced when ``mask`` is given (it counts the
    step's valid positions)."""

    def sampled_loss(p) -> jnp.ndarray:
        logits = logits_fn(p)
        yhat = sample_labels(jax.lax.stop_gradient(logits), rng)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, yhat[..., None], axis=-1)[..., 0]
        if mask is not None:
            nll = nll * mask
            return nll.sum() / jnp.maximum(mask.sum(), 1)
        return nll.mean()

    if mask is not None:
        batch_size = jnp.maximum(mask.sum(), 1).astype(jnp.float32)
    else:
        shape = jax.eval_shape(logits_fn, params).shape
        batch_size = 1
        for s in shape[:-1]:
            batch_size *= s
        batch_size = jnp.asarray(batch_size, jnp.float32)
    ghat = jax.grad(sampled_loss)(params)
    sq = jax.tree.map(
        lambda g: g.astype(jnp.float32) * g.astype(jnp.float32), ghat)
    return sq, batch_size


def gnb_estimator(
    logits_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
    rng: jax.Array,
    *,
    mask: jnp.ndarray | None = None,
) -> PyTree:
    """Gauss-Newton-Bartlett estimator (Algorithm 2).

    ``logits_fn(params) -> logits`` of shape ``(..., V)`` over the estimator
    sub-batch; every leading position is one CE "example" (for LMs: every
    token position, matching the per-token CE pre-training loss).

    ``mask`` (same shape as ``logits[..., 0]``) marks valid positions
    (e.g. non-padding); B counts valid positions only.

    Returns ``B * ghat (*) ghat`` (element-wise square) where ``ghat`` is the
    gradient of the mean CE against *sampled* labels.
    """
    sq, batch_size = gnb_estimator_sq(logits_fn, params, rng, mask=mask)
    return jax.tree.map(lambda s: batch_size * s, sq)


def empirical_fisher_estimator(
    loss_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
    batch_size: int,
) -> PyTree:
    """E-F baseline (Fig 8b): B * g*g (element-wise) with TRUE labels.

    This is the ablation the paper shows is *worse* than GNB — the only
    difference from GNB is the lack of label sampling.
    """
    g = jax.grad(loss_fn)(params)
    return jax.tree.map(
        lambda g_: batch_size * g_.astype(jnp.float32) * g_.astype(jnp.float32), g)


def exact_diag_hessian(
    loss_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
) -> PyTree:
    """Exact diag(H) via d basis-vector HVPs — tests/benchmarks only (tiny d)."""
    flat, unravel = jax.flatten_util.ravel_pytree(params)
    d = flat.shape[0]

    def flat_loss(x):
        return loss_fn(unravel(x))

    def one(i):
        e = jnp.zeros(d).at[i].set(1.0)
        _, hv = jax.jvp(jax.grad(flat_loss), (flat,), (e,))
        return hv[i]

    diag = jax.lax.map(one, jnp.arange(d))
    return unravel(diag)


def subsample_batch(batch: PyTree, n: int) -> PyTree:
    """First-n sub-batch for the estimator (paper Section 3.1).

    Keeping the slice contiguous preserves the data-parallel sharding of the
    batch (no resharding collective on TPU) as long as ``n`` is a multiple of
    the DP degree.
    """
    return jax.tree.map(lambda x: x[:n], batch)
