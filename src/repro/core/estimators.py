"""Diagonal-Hessian estimators for Sophia (paper Section 2.3).

Two estimators, each with the run-time cost of O(1) extra gradient
computations:

* :func:`hutchinson_estimator` — Algorithm 1.  Draw ``u ~ N(0, I)`` and return
  ``u * (H u)`` via a Hessian-vector product.  Unbiased for diag(H).
  We implement the HVP as forward-over-reverse (``jvp`` of ``grad``), which is
  the memory-cheap direction and compiles to one extra fwd+bwd pass on TPU.
  With ``fused_loss`` the trainer routes the HVP through the fused CE
  kernel's ``custom_jvp`` twin (``models.loss.lm_loss`` impl "fused_jvp"),
  so there is no silent fallback to the chunked path at the loss boundary.

* :func:`gnb_estimator` — Algorithm 2 (Gauss-Newton-Bartlett).  Sample labels
  ``yhat_b ~ softmax(f(theta, x_b))`` from the *model's own* logits, take the
  mini-batch gradient ``ghat`` of the CE loss against the sampled labels, and
  return ``B * ghat * ghat``.  Unbiased for diag of the Gauss-Newton matrix
  (PSD), biased for diag(H).  Uses Bartlett's 1st+2nd identities (eq. 9-13).
  The sampling and the log-probability come from ONE online vocab-chunk
  sweep (:func:`chunked_sampled_stats`): chunked Gumbel-argmax draws the
  label while the same pass accumulates the log-sum-exp, so there is no
  second softmax and no whole-tensor fp32 ``log_softmax`` copy.  The fully
  logits-free route (label drawn inside the fused CE kernel's vocab sweep)
  is :func:`gnb_ghat_flat_from_loss` over ``models.loss.lm_loss_sampled``.

Both take a ``loss_fn``/``logits_fn`` over a (possibly reduced) estimator
sub-batch — the paper uses 32 of 480 examples for Sophia-H and 240 of 480 for
Sophia-G (Section 3.1) to keep amortized overhead ~5%.

Each estimator also has a ``*_flat`` twin that emits the estimate directly as
the optimizer engine's flat fp32 shards (one ravel through the static
:class:`~repro.core.engine.ShardLayout`, the tail pad a constant operand of
the concatenate): the unified train step's refresh branch consumes these, so
no params-shaped curvature tree — and no per-leaf pad/unpad — ever
materializes between the estimator gradient and the fused Hessian-EMA.
Hutchinson's flat form draws its probe per flat shard (one key split per
shard instead of per leaf).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.flatten_util
import jax.numpy as jnp

from .types import PyTree


def hutchinson_estimator(
    loss_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
    rng: jax.Array,
) -> PyTree:
    """u * (H u) with u ~ N(0, I): unbiased estimate of diag(H).

    ``loss_fn`` must be a scalar-valued function of params closed over the
    estimator mini-batch.
    """
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(rng, len(leaves))
    u = jax.tree.unflatten(
        treedef,
        [jax.random.normal(k, p.shape, jnp.float32).astype(p.dtype)
         for k, p in zip(keys, leaves)])
    # forward-over-reverse HVP: d/dt grad(theta + t u) |_{t=0} = H u
    _, hvp = jax.jvp(jax.grad(loss_fn), (params,), (u,))
    return jax.tree.map(lambda u_, hv: (u_ * hv).astype(jnp.float32), u, hvp)


def hutchinson_estimator_flat(
    loss_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
    rng: jax.Array,
    layout,
) -> Tuple[jnp.ndarray, ...]:
    """:func:`hutchinson_estimator` emitting flat fp32 shards.

    The probe ``u`` is drawn per flat shard (``layout.n_shards`` key splits,
    typically one) and unraveled through the layout's static slices for the
    HVP tangent — padded tail elements carry probe noise but the raveled
    ``u * (H u)`` zeroes them again (the ravel's pad operand is zero), so
    the pad region stays a fixed point of the Hessian-EMA."""
    keys = jax.random.split(rng, layout.n_shards)
    from .engine import ravel_shards, unravel_shards
    u_sh = tuple(jax.random.normal(k, (s,), jnp.float32)
                 for k, s in zip(keys, layout.shard_sizes))
    u = unravel_shards(layout, u_sh)  # casts to leaf dtypes (tangent rule)
    _, hvp = jax.jvp(jax.grad(loss_fn), (params,), (u,))
    prod = jax.tree.map(
        lambda u_, hv: u_.astype(jnp.float32) * hv.astype(jnp.float32),
        u, hvp)
    return ravel_shards(layout, prod, dtype=jnp.float32)


def sample_labels(logits: jnp.ndarray, rng: jax.Array) -> jnp.ndarray:
    """yhat ~ Categorical(softmax(logits)) via Gumbel-max (fused on TPU)."""
    return jax.random.categorical(rng, logits, axis=-1)


_NEG_INF = -1e30
_DEFAULT_VCHUNK = 4096


def chunked_sampled_stats(
    logits: jnp.ndarray,
    rng: jax.Array | None = None,
    *,
    chunk: int = _DEFAULT_VCHUNK,
    noise: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One online vocab-chunk sweep: ``(lse, logit_at_yhat, yhat)``.

    Draws ``yhat ~ softmax(logits)`` by online chunked Gumbel-argmax and
    accumulates the log-sum-exp in the same pass, so the GNB reference
    needs neither a second softmax nor a whole-tensor fp32 ``log_softmax``
    copy.  Differentiating ``lse - logit_at_yhat`` w.r.t. ``logits`` gives
    ``softmax - onehot(yhat)`` — the selects carry the chosen logit's
    gradient, the draw itself is non-differentiable (stop-grad sampling by
    construction).  The scan body is checkpointed: backward recomputes each
    chunk instead of saving [*, V]-sized residuals.

    Per-chunk noise comes from ``fold_in(rng, chunk_idx)``; passing a full
    ``noise`` tensor instead (tests) makes the online argmax bit-identical
    to ``jnp.argmax(logits + noise, -1)`` — i.e. with Gumbel noise from a
    fixed key, identical to ``jax.random.categorical`` on that key.
    """
    assert (rng is None) != (noise is None), "exactly one of rng/noise"
    from ..kernels.fused_ce import (online_argmax_step, online_lse_step,
                                    vocab_chunk)
    V = logits.shape[-1]
    lead = logits.shape[:-1]
    flat = logits.astype(jnp.float32).reshape(-1, V)
    nflat = None if noise is None else noise.reshape(-1, V)
    bv = vocab_chunk(V, chunk)
    n_c = V // bv

    def body(carry, c):
        m, l, zm, zi, zl = carry
        s = jax.lax.dynamic_slice_in_dim(flat, c * bv, bv, axis=1)
        if nflat is not None:
            g = jax.lax.dynamic_slice_in_dim(nflat, c * bv, bv, axis=1)
        else:
            g = jax.random.gumbel(jax.random.fold_in(rng, c), s.shape,
                                  jnp.float32)
        # value-based validity: masked columns arrive as the -1e30
        # sentinel (models.layers.unembed) rather than a separate mask
        m, l = online_lse_step(m, l, s, valid=s > _NEG_INF / 2)
        zm, zi, zl = online_argmax_step((zm, zi, zl), s, s + g, c * bv)
        return (m, l, zm, zi, zl), None

    N = flat.shape[0]
    init = (jnp.full((N,), _NEG_INF, jnp.float32),
            jnp.zeros((N,), jnp.float32),
            jnp.full((N,), _NEG_INF, jnp.float32),
            jnp.zeros((N,), jnp.int32),
            jnp.zeros((N,), jnp.float32))
    (m, l, _, zi, zl), _ = jax.lax.scan(
        jax.checkpoint(body), init, jnp.arange(n_c))
    lse = m + jnp.log(jnp.maximum(l, 1e-37))
    return lse.reshape(lead), zl.reshape(lead), zi.reshape(lead)


def _gnb_ghat(
    logits_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
    rng: jax.Array,
    mask: jnp.ndarray | None,
    *,
    chunk: int = _DEFAULT_VCHUNK,
) -> Tuple[PyTree, jnp.ndarray]:
    """Shared GNB core: ``(ghat, B)`` — the mini-batch gradient of the mean
    CE against the model's *sampled* labels, and the batch factor B (traced
    when ``mask`` is given: it counts the step's valid positions).

    One :func:`chunked_sampled_stats` sweep serves both the label draw and
    the log-probability — the old path materialized the logits twice (a
    Gumbel-max pass plus a whole-tensor fp32 ``log_softmax`` copy)."""

    def sampled_loss(p) -> jnp.ndarray:
        logits = logits_fn(p)
        lse, ll, _ = chunked_sampled_stats(logits, rng, chunk=chunk)
        nll = lse - ll
        if mask is not None:
            nll = nll * mask
            return nll.sum() / jnp.maximum(mask.sum(), 1)
        return nll.mean()

    if mask is not None:
        batch_size = jnp.maximum(mask.sum(), 1).astype(jnp.float32)
    else:
        shape = jax.eval_shape(logits_fn, params).shape
        batch_size = 1
        for s in shape[:-1]:
            batch_size *= s
        batch_size = jnp.asarray(batch_size, jnp.float32)
    return jax.grad(sampled_loss)(params), batch_size


def gnb_estimator_sq(
    logits_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
    rng: jax.Array,
    *,
    mask: jnp.ndarray | None = None,
) -> Tuple[PyTree, jnp.ndarray]:
    """GNB pieces: ``(ghat (*) ghat, B)`` with the batch scale unfolded.

    The optimizer engine folds ``B`` into the Hessian-EMA kernel
    (h' = b2 h + (1-b2) B ghat^2), so ``B * ghat^2`` never materializes as a
    separate buffer.  ``B`` is traced when ``mask`` is given (it counts the
    step's valid positions)."""
    ghat, batch_size = _gnb_ghat(logits_fn, params, rng, mask)
    sq = jax.tree.map(
        lambda g: g.astype(jnp.float32) * g.astype(jnp.float32), ghat)
    return sq, batch_size


def gnb_ghat_flat(
    logits_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
    rng: jax.Array,
    layout,
    *,
    mask: jnp.ndarray | None = None,
) -> Tuple[Tuple[jnp.ndarray, ...], jnp.ndarray]:
    """GNB pieces *before* squaring, as flat fp32 shards: ``(ghat, B)``.

    This is the quantity a data-parallel estimator reduction puts on the
    wire — the refresh-path int8 compression must quantize ``ghat``, not
    ``ghat^2`` (squaring first squares the per-block dynamic range, zeroing
    every coordinate below ~max/16 of its scale block instead of ~max/254).
    """
    from .engine import ravel_shards
    ghat, batch_size = _gnb_ghat(logits_fn, params, rng, mask)
    return ravel_shards(layout, ghat, dtype=jnp.float32), batch_size


def gnb_ghat_flat_from_loss(
    sampled_loss_fn: Callable[[PyTree], Tuple[jnp.ndarray, jnp.ndarray]],
    params: PyTree,
    layout,
) -> Tuple[Tuple[jnp.ndarray, ...], jnp.ndarray]:
    """GNB ``(ghat shards, B)`` from a model-level sampled-CE loss.

    ``sampled_loss_fn(params) -> (mean_nll, n_valid)`` draws its own labels
    (e.g. the fused kernel's in-sweep Gumbel-argmax,
    ``models.loss.lm_loss_sampled``) — the logits-free route: unlike
    :func:`gnb_ghat_flat` no ``logits_fn`` materializes ``[B*T, V]``
    anywhere between the trunk and the flat-shard ravel."""
    from .engine import ravel_shards
    ghat, n_valid = jax.grad(sampled_loss_fn, has_aux=True)(params)
    return ravel_shards(layout, ghat, dtype=jnp.float32), \
        n_valid.astype(jnp.float32)


def gnb_estimator_sq_flat(
    logits_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
    rng: jax.Array,
    layout,
    *,
    mask: jnp.ndarray | None = None,
) -> Tuple[Tuple[jnp.ndarray, ...], jnp.ndarray]:
    """:func:`gnb_estimator_sq` emitting flat fp32 shards: ``ghat`` ravels
    once through the engine layout and squares in flat space (one fused
    element-wise op per shard), so the estimate never exists as a
    params-shaped pytree.  Returns ``(shards, B)`` with B unfolded for the
    fused Hessian-EMA."""
    g_sh, batch_size = gnb_ghat_flat(logits_fn, params, rng, layout,
                                     mask=mask)
    return tuple(g * g for g in g_sh), batch_size


def gnb_estimator(
    logits_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
    rng: jax.Array,
    *,
    mask: jnp.ndarray | None = None,
) -> PyTree:
    """Gauss-Newton-Bartlett estimator (Algorithm 2).

    ``logits_fn(params) -> logits`` of shape ``(..., V)`` over the estimator
    sub-batch; every leading position is one CE "example" (for LMs: every
    token position, matching the per-token CE pre-training loss).

    ``mask`` (same shape as ``logits[..., 0]``) marks valid positions
    (e.g. non-padding); B counts valid positions only.

    Returns ``B * ghat (*) ghat`` (element-wise square) where ``ghat`` is the
    gradient of the mean CE against *sampled* labels.
    """
    sq, batch_size = gnb_estimator_sq(logits_fn, params, rng, mask=mask)
    return jax.tree.map(lambda s: batch_size * s, sq)


def empirical_fisher_estimator(
    loss_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
    batch_size: int,
) -> PyTree:
    """E-F baseline (Fig 8b): B * g*g (element-wise) with TRUE labels.

    This is the ablation the paper shows is *worse* than GNB — the only
    difference from GNB is the lack of label sampling.
    """
    g = jax.grad(loss_fn)(params)
    return jax.tree.map(
        lambda g_: batch_size * g_.astype(jnp.float32) * g_.astype(jnp.float32), g)


def empirical_fisher_ghat_flat(
    loss_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
    layout,
) -> Tuple[jnp.ndarray, ...]:
    """The E-F gradient (TRUE labels) as flat fp32 shards, pre-squaring —
    the wire form for the refresh-path compression (see
    :func:`gnb_ghat_flat` for why the square must come after)."""
    from .engine import ravel_shards
    return ravel_shards(layout, jax.grad(loss_fn)(params),
                        dtype=jnp.float32)


def empirical_fisher_estimator_flat(
    loss_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
    layout,
) -> Tuple[jnp.ndarray, ...]:
    """:func:`empirical_fisher_estimator` emitting flat fp32 shards of
    ``g (*) g`` with the batch factor B *unfolded* — the caller passes B as
    the fused Hessian-EMA's traced ``scale`` (exactly like the GNB path)
    instead of pre-multiplying a params-shaped tree."""
    g_sh = empirical_fisher_ghat_flat(loss_fn, params, layout)
    return tuple(g_ * g_ for g_ in g_sh)


def exact_diag_hessian(
    loss_fn: Callable[[PyTree], jnp.ndarray],
    params: PyTree,
) -> PyTree:
    """Exact diag(H) via d basis-vector HVPs — tests/benchmarks only (tiny d)."""
    flat, unravel = jax.flatten_util.ravel_pytree(params)
    d = flat.shape[0]

    def flat_loss(x):
        return loss_fn(unravel(x))

    def one(i):
        e = jnp.zeros(d).at[i].set(1.0)
        _, hv = jax.jvp(jax.grad(flat_loss), (flat,), (e,))
        return hv[i]

    diag = jax.lax.map(one, jnp.arange(d))
    return unravel(diag)


def subsample_batch(batch: PyTree, n: int) -> PyTree:
    """First-n sub-batch for the estimator (paper Section 3.1).

    Keeping the slice contiguous preserves the data-parallel sharding of the
    batch (no resharding collective on TPU) as long as ``n`` is a multiple of
    the DP degree.
    """
    return jax.tree.map(lambda x: x[:n], batch)
