"""Sophia: Second-order Clipped Stochastic Optimization (Algorithm 3).

Faithful to the paper:

    m_t = beta1 * m_{t-1} + (1 - beta1) * g_t
    if t % k == 1:  h_t = beta2 * h_{t-k} + (1 - beta2) * hhat_t   (out-of-band)
    theta <- theta - lr * weight_decay * theta                      (decoupled WD)
    theta <- theta - lr * clip(m_t / max(gamma * h_t, eps), 1)

The Hessian EMA refresh is exposed as ``update_hessian`` so the trainer can
invoke it every ``k`` steps with a fresh estimate from
:mod:`repro.core.estimators` — exactly the split in Algorithm 3 lines 7-11.

Telemetry: the state carries ``clip_fraction`` (fraction of coordinates whose
update hit the clip), the quantity the paper uses to tune ``gamma``
(Section 3.1: target "proportion NOT clipped" in 10%-50%, i.e. clip fraction
50%-90%, Figure 9a).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from .types import (GradientTransformation, HessianAwareTransformation, PyTree,
                    Schedule, apply_updates, tree_zeros_like)


class SophiaState(NamedTuple):
    count: jnp.ndarray          # step counter t
    m: PyTree                   # EMA of gradients
    h: PyTree                   # EMA of diagonal-Hessian estimates
    hess_count: jnp.ndarray     # number of hessian refreshes so far
    clip_fraction: jnp.ndarray  # telemetry: fraction of clipped coords last step


def scale_by_sophia(
    beta1: float = 0.96,
    beta2: float = 0.99,
    gamma: float = 0.05,
    eps: float = 1e-12,
    clip_threshold: float = 1.0,
    state_dtype=jnp.float32,
) -> HessianAwareTransformation:
    """The preconditioning core of Sophia (no LR / WD — see :func:`sophia`)."""

    def init(params):
        return SophiaState(
            count=jnp.zeros([], jnp.int32),
            m=tree_zeros_like(params, state_dtype),
            h=tree_zeros_like(params, state_dtype),
            hess_count=jnp.zeros([], jnp.int32),
            clip_fraction=jnp.zeros([], jnp.float32),
        )

    def update(grads, state, params=None):
        del params
        m = jax.tree.map(
            lambda m_, g: beta1 * m_ + (1.0 - beta1) * g.astype(m_.dtype),
            state.m, grads)

        def precondition(m_, h_):
            raw = m_ / jnp.maximum(gamma * h_, eps)
            u = jnp.clip(raw, -clip_threshold, clip_threshold)
            n_clipped = jnp.sum(jnp.abs(raw) >= clip_threshold,
                                dtype=jnp.float32)  # fp32: >2^31 params
            return -u, n_clipped

        out = jax.tree.map(precondition, m, state.h)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        clipped = sum(
            jax.tree.leaves(
                jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple)))
        ).astype(jnp.float32)
        total = float(sum(x.size for x in jax.tree.leaves(m)))
        new_state = SophiaState(
            count=state.count + 1, m=m, h=state.h,
            hess_count=state.hess_count,
            clip_fraction=(clipped / total).astype(jnp.float32),
        )
        return updates, new_state

    def update_hessian(hess_estimate, state):
        """EMA per eq. (5): h <- beta2 * h + (1-beta2) * hhat."""
        h = jax.tree.map(
            lambda h_, e: beta2 * h_ + (1.0 - beta2) * e.astype(h_.dtype),
            state.h, hess_estimate)
        return state._replace(h=h, hess_count=state.hess_count + 1)

    return HessianAwareTransformation(init=init, update=update,
                                      update_hessian=update_hessian)


class ScaleByLrState(NamedTuple):
    count: jnp.ndarray


def scale_by_learning_rate(lr: Union[float, Schedule]) -> GradientTransformation:
    def init(params):
        del params
        return ScaleByLrState(count=jnp.zeros([], jnp.int32))

    def update(updates, state, params=None):
        del params
        step_lr = lr(state.count) if callable(lr) else lr
        updates = jax.tree.map(lambda u: step_lr * u, updates)
        return updates, ScaleByLrState(count=state.count + 1)

    return GradientTransformation(init=init, update=update)


class WeightDecayState(NamedTuple):
    count: jnp.ndarray


def add_decayed_weights(weight_decay: float,
                        lr: Union[float, Schedule, None] = None
                        ) -> GradientTransformation:
    """Decoupled weight decay (AdamW-style): update -= lr * wd * theta.

    When ``lr`` is given the decay is pre-multiplied by the schedule so it can
    sit *before* no further lr scaling (Sophia line 12 decays with eta_t).
    """

    def init(params):
        del params
        return WeightDecayState(count=jnp.zeros([], jnp.int32))

    def update(updates, state, params=None):
        assert params is not None, "weight decay needs params"
        step_lr = (lr(state.count) if callable(lr) else lr) if lr is not None else 1.0
        updates = jax.tree.map(
            lambda u, p: u - step_lr * weight_decay * p.astype(u.dtype),
            updates, params)
        return updates, WeightDecayState(count=state.count + 1)

    return GradientTransformation(init=init, update=update)


def sophia(
    learning_rate: Union[float, Schedule],
    *,
    beta1: float = 0.96,
    beta2: float = 0.99,
    gamma: float = 0.05,
    eps: float = 1e-12,
    weight_decay: float = 0.2,
    clip_threshold: float = 1.0,
    state_dtype=jnp.float32,
) -> HessianAwareTransformation:
    """Full Sophia optimizer (Algorithm 3), estimator supplied externally.

    Usage::

        opt = sophia(lr_schedule, gamma=0.05)             # Sophia-G defaults
        state = opt.init(params)
        # every step:
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
        # every k steps (Algorithm 3 line 7):
        hhat = gnb_estimator(...) or hutchinson_estimator(...)
        state = opt.update_hessian(hhat, state)
    """
    core = scale_by_sophia(beta1=beta1, beta2=beta2, gamma=gamma, eps=eps,
                           clip_threshold=clip_threshold,
                           state_dtype=state_dtype)

    def init(params):
        return core.init(params)

    def update(grads, state, params=None):
        updates, state = core.update(grads, state, params)
        step = state.count - 1  # lr uses the pre-increment step index
        step_lr = learning_rate(step) if callable(learning_rate) else learning_rate
        # decoupled weight decay, then scale the clipped update by lr
        updates = jax.tree.map(
            lambda u, p: step_lr * (u - weight_decay * p.astype(u.dtype)),
            updates, params)
        return updates, state

    def update_hessian(hess, state):
        return core.update_hessian(hess, state)

    return HessianAwareTransformation(init=init, update=update,
                                      update_hessian=update_hessian)


def sophia_h(learning_rate, *, gamma: float = 0.01, weight_decay: float = 0.2,
             **kw) -> HessianAwareTransformation:
    """Sophia with the paper's Sophia-H default gamma=0.01."""
    return sophia(learning_rate, gamma=gamma, weight_decay=weight_decay, **kw)


def sophia_g(learning_rate, *, gamma: float = 0.05, weight_decay: float = 0.2,
             **kw) -> HessianAwareTransformation:
    """Sophia with the paper's Sophia-G default gamma=0.05."""
    return sophia(learning_rate, gamma=gamma, weight_decay=weight_decay, **kw)
