"""Learning-rate schedules (paper protocol: cosine to 0.05x peak, 2k warmup)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def linear_warmup_cosine(peak_lr: float, total_steps: int,
                         warmup_steps: int = 2000,
                         final_lr_ratio: float = 0.05):
    """Cosine decay to final_lr_ratio * peak with linear warmup.

    Matches the paper: "cosine LR schedule with the final LR equal to 0.05
    times the peak LR ... fixed 2k steps of LR warm-up".  The schedule is
    pinned to ``total_steps`` — the paper's evaluation methodology (eq. 14)
    requires tuning the schedule to the pre-specified budget T.
    """
    final_lr = peak_lr * final_lr_ratio

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        frac = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        frac = jnp.clip(frac, 0.0, 1.0)
        cos = final_lr + 0.5 * (peak_lr - final_lr) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, cos).astype(jnp.float32)

    return schedule


def linear_warmup_linear_decay(peak_lr: float, total_steps: int,
                               warmup_steps: int = 2000,
                               final_lr_ratio: float = 0.0):
    final_lr = peak_lr * final_lr_ratio

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        frac = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        frac = jnp.clip(frac, 0.0, 1.0)
        dec = peak_lr + frac * (final_lr - peak_lr)
        return jnp.where(step < warmup_steps, warm, dec).astype(jnp.float32)

    return schedule


def inverse_sqrt(peak_lr: float, warmup_steps: int = 2000):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        decay = peak_lr * jnp.sqrt(warmup_steps / jnp.maximum(step, warmup_steps))
        return jnp.where(step < warmup_steps, warm, decay).astype(jnp.float32)

    return schedule
