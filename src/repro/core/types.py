"""Minimal optax-style optimizer protocol in pure JAX.

optax is not available offline, so we implement the same
``GradientTransformation`` contract: ``init(params) -> state`` and
``update(grads, state, params) -> (updates, state)``.  Updates are *added*
to params by ``apply_updates`` (i.e. they already carry the minus sign).

The Sophia-specific extension is ``HessianAware``: transformations that
consume a diagonal-Hessian estimate expose ``update_hessian(hess, state)``
which refreshes the EMA'd curvature state out-of-band (every k steps).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> lr


@dataclasses.dataclass(frozen=True)
class GradientTransformation:
    """A pair of pure functions (init, update)."""

    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], tuple]


@dataclasses.dataclass(frozen=True)
class HessianAwareTransformation(GradientTransformation):
    """GradientTransformation that also consumes diagonal-Hessian estimates.

    ``update_hessian(hess_estimate, state) -> state`` folds a fresh stochastic
    estimate of diag(H) into the optimizer state (EMA per Sophia eq. (5)).
    """

    update_hessian: Callable[[PyTree, PyTree], PyTree] = None


class EmptyState(NamedTuple):
    pass


def tree_zeros_like(params: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def tree_map2(f, a, b):
    return jax.tree.map(f, a, b)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    """params + updates, preserving param dtypes (updates may be fp32)."""
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    """Compose transforms left-to-right (like optax.chain).

    Hessian-awareness propagates: ``update_hessian`` is forwarded to every
    member that defines it; state is a tuple of member states.
    """

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    def update_hessian(hess, state):
        new_state = []
        for t, s in zip(transforms, state):
            if isinstance(t, HessianAwareTransformation) and t.update_hessian is not None:
                s = t.update_hessian(hess, s)
            new_state.append(s)
        return tuple(new_state)

    if any(isinstance(t, HessianAwareTransformation) for t in transforms):
        return HessianAwareTransformation(init=init, update=update,
                                          update_hessian=update_hessian)
    return GradientTransformation(init=init, update=update)
