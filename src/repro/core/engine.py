"""Flat-buffer optimizer engine: one update path for every optimizer.

The paper's pitch is negligible per-step overhead (Section 4.3); the way to
keep that true in a production system is to make the optimizer update pure
streaming work.  This engine ravels the parameter pytree's *optimizer state*
once at init into a small set of dtype-homogeneous flat shards — one shard
per parameter dtype, tail-padded to a multiple of the kernel block — and
keeps it flat forever.  A static :class:`ShardLayout` (leaf offsets + shapes)
maps between the model-facing pytree view and the flat view, so each train
step does exactly:

    params, grads --ravel-->  one flat buffer per dtype shard
    one pallas_call grid sweep per shard (or the pure-jnp reference)
    flat params   --slice-->  parameter pytree

There is no per-leaf pad/unpad anywhere in the step: the single tail pad per
shard is fused into the ravel concatenate, and padded elements are fixed
points of every update rule here (p = m = h = g = 0 stays 0), so the pad is
paid once at init, never per step.  This mirrors how AdaHessian (Yao et al.,
2021) and distributed Shampoo (Anil et al., 2021) organize second-order
state, and makes the Sophia-vs-AdamW overhead comparison apples-to-apples:
both run through literally the same machinery.

Backends:
    * ``reference`` — pure jnp over the flat shards (kernels/ref.py math);
    * ``pallas``    — fused kernels (kernels/sophia_update.py), one grid
      sweep per shard, clip-fraction telemetry computed in-kernel.

Swapping one for the other is a one-line change and must agree to fp32
tolerance (tests/test_engine.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ref as kref
from ..kernels import sophia_update as kblk

PyTree = Any

BLOCK = kblk.BLOCK

#: trainer-level optimizer names -> engine family
FAMILIES = {
    "sophia_g": "sophia",
    "sophia_h": "sophia",
    "adamw": "adamw",
    "lion": "lion",
    "signgd": "signgd",
    "adahessian": "adahessian",
    "sgd": "sgd",
}

_CURVATURE_FAMILIES = ("sophia", "adamw", "adahessian")
_HESSIAN_AWARE = ("sophia", "adahessian")


def hessian_aware_optimizer(optimizer: str) -> bool:
    """True for trainer-level optimizer names whose curvature refreshes
    out-of-band (the Algorithm-3 cadence).  The single source of truth the
    trainer / drivers / benchmarks consult for the refresh flag — never a
    hardcoded optimizer-name tuple — without constructing an engine."""
    return FAMILIES.get(optimizer) in _HESSIAN_AWARE


# ---------------------------------------------------------------------------
# Static layout


@dataclasses.dataclass(frozen=True)
class ShardLayout:
    """Static map between a parameter pytree and its flat dtype shards."""

    treedef: Any
    leaf_shapes: Tuple[Tuple[int, ...], ...]
    leaf_dtypes: Tuple[Any, ...]
    leaf_shard: Tuple[int, ...]    # which shard each leaf lives in
    leaf_offset: Tuple[int, ...]   # element offset of the leaf in its shard
    shard_dtypes: Tuple[Any, ...]
    shard_sizes: Tuple[int, ...]   # padded: multiples of ``block``
    shard_used: Tuple[int, ...]    # true element counts (pad excluded)
    block: int

    @property
    def n_shards(self) -> int:
        return len(self.shard_sizes)

    @property
    def n_params(self) -> int:
        return sum(self.shard_used)

    def bucket_slices(self, bucket_elems: int, *,
                      align: int = 256) -> Tuple[Tuple[Tuple[int, int], ...],
                                                 ...]:
        """Per-shard bucket views (see :func:`bucket_slices`): the static
        slice table the bucketed compressed all-reduce
        (distributed/overlap.py) iterates, one tuple of (start, stop)
        pairs per flat shard."""
        return tuple(bucket_slices(int(n), bucket_elems, align=align)
                     for n in self.shard_sizes)

    def manifest(self) -> dict:
        """JSON-serializable summary (stored in checkpoint manifests)."""
        return {
            "block": self.block,
            "n_leaves": len(self.leaf_shapes),
            "n_params": self.n_params,
            "shards": [
                {"dtype": str(jnp.dtype(d)), "size": int(s), "used": int(u)}
                for d, s, u in zip(self.shard_dtypes, self.shard_sizes,
                                   self.shard_used)
            ],
        }


def bucket_slices(n: int, bucket_elems: int, *,
                  align: int = 256) -> Tuple[Tuple[int, int], ...]:
    """Static (start, stop) views partitioning a flat shard into buckets.

    ``bucket_elems`` is rounded up to a multiple of ``align`` (the
    quantization scale block, possibly multiplied by the collective axis
    size so per-device segments stay block-aligned); the last bucket takes
    the remainder.  ``bucket_elems <= 0`` means one bucket — the monolithic
    view.  Every boundary is a multiple of ``align``, which is what keeps
    bucketed quantization bit-identical to whole-shard quantization: the
    per-256-block scales and the (seed, global element index) rounding hash
    never see the bucket structure (distributed/overlap.py)."""
    if n <= 0:
        return ()
    if bucket_elems <= 0 or bucket_elems >= n:
        return ((0, n),)
    b = -(-bucket_elems // align) * align
    if b <= 0 or n % align != 0:
        return ((0, n),)
    edges = list(range(0, n, b)) + [n]
    return tuple((edges[i], edges[i + 1]) for i in range(len(edges) - 1))


def build_layout(params: PyTree, *, block: int = BLOCK) -> ShardLayout:
    """Group leaves into dtype-homogeneous shards, assign static offsets."""
    leaves, treedef = jax.tree.flatten(params)
    leaf_shapes = tuple(tuple(l.shape) for l in leaves)
    leaf_dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    shard_dtypes: list = []
    used: list = []
    leaf_shard, leaf_offset = [], []
    for shape, dt in zip(leaf_shapes, leaf_dtypes):
        if dt not in shard_dtypes:
            shard_dtypes.append(dt)
            used.append(0)
        si = shard_dtypes.index(dt)
        leaf_shard.append(si)
        leaf_offset.append(used[si])
        used[si] += math.prod(shape)
    sizes = tuple(-(-u // block) * block for u in used)
    return ShardLayout(treedef=treedef, leaf_shapes=leaf_shapes,
                       leaf_dtypes=leaf_dtypes, leaf_shard=tuple(leaf_shard),
                       leaf_offset=tuple(leaf_offset),
                       shard_dtypes=tuple(shard_dtypes), shard_sizes=sizes,
                       shard_used=tuple(used), block=block)


def ravel_shards(layout: ShardLayout, tree: PyTree, *,
                 dtype=None) -> Tuple[jnp.ndarray, ...]:
    """Pytree -> flat shards.  One concatenate per shard; the tail pad is a
    constant-zeros operand of that concatenate, not a per-leaf pad op.

    ``dtype`` overrides the shard dtype (grads/estimates ravel to fp32)."""
    leaves = jax.tree.leaves(tree)
    parts: list = [[] for _ in layout.shard_sizes]
    for leaf, si in zip(leaves, layout.leaf_shard):
        tdt = dtype if dtype is not None else layout.shard_dtypes[si]
        parts[si].append(leaf.reshape(-1).astype(tdt))
    out = []
    for si, chunks in enumerate(parts):
        tdt = dtype if dtype is not None else layout.shard_dtypes[si]
        pad = layout.shard_sizes[si] - layout.shard_used[si]
        if pad:
            chunks = chunks + [jnp.zeros((pad,), tdt)]
        out.append(chunks[0] if len(chunks) == 1 else jnp.concatenate(chunks))
    return tuple(out)


def unravel_shards(layout: ShardLayout,
                   shards: Tuple[jnp.ndarray, ...]) -> PyTree:
    """Flat shards -> pytree (static slices, no pad/unpad)."""
    leaves = []
    for shape, dt, si, off in zip(layout.leaf_shapes, layout.leaf_dtypes,
                                  layout.leaf_shard, layout.leaf_offset):
        n = math.prod(shape)
        leaves.append(shards[si][off:off + n].reshape(shape).astype(dt))
    return jax.tree.unflatten(layout.treedef, leaves)


# ---------------------------------------------------------------------------
# Engine state


class EngineState(NamedTuple):
    """Optimizer state over flat shards (lives flat across the whole run).

    ``m`` is the first-moment slot; ``h`` is the curvature / second-moment
    slot (Sophia's diagonal-Hessian EMA, AdamW's v, AdaHessian's EMA of
    squared estimates) — ``()`` for families that don't need one."""

    count: jnp.ndarray            # step counter t
    m: Tuple[jnp.ndarray, ...]
    h: Tuple[jnp.ndarray, ...]
    hess_count: jnp.ndarray       # number of Hessian refreshes so far
    clip_fraction: jnp.ndarray    # telemetry (paper Fig 9a); 0 if untracked


# ---------------------------------------------------------------------------
# The engine


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


class OptimizerEngine:
    """One update path for reference and fused optimizers over flat shards.

    Usage::

        eng = OptimizerEngine("sophia_g", hypers=dict(beta1=.96, beta2=.99,
                              gamma=.05, eps=1e-12, weight_decay=.2,
                              clip_threshold=1.0), backend="pallas")
        opt_state = eng.init(params)
        params, opt_state = eng.step(opt_state, params, grads, lr)
        # unified pipeline: refresh fused into the step, flag traced
        params, opt_state = eng.step_with_refresh(
            opt_state, params, g_sh, lr, est_shards, scale, do_refresh)
        # out-of-band form (tests/tooling):
        opt_state = eng.update_hessian(opt_state, est, scale=B, params=params)
    """

    def __init__(self, optimizer: str, *, hypers: dict,
                 backend: str = "reference", block: int = BLOCK,
                 state_dtype=jnp.float32,
                 interpret: Optional[bool] = None):
        if optimizer not in FAMILIES:
            raise ValueError(f"unknown optimizer {optimizer!r}")
        if backend not in ("reference", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.optimizer = optimizer
        self.family = FAMILIES[optimizer]
        self.hypers = dict(hypers)
        self.backend = backend
        self.block = block
        self.state_dtype = jnp.dtype(state_dtype)
        self.interpret = interpret
        self._layouts: dict = {}

    # -- properties ---------------------------------------------------------

    @property
    def needs_curvature(self) -> bool:
        return self.family in _CURVATURE_FAMILIES

    @property
    def hessian_aware(self) -> bool:
        return self.family in _HESSIAN_AWARE

    @property
    def tracks_clip_fraction(self) -> bool:
        return self.family == "sophia"

    def _interp(self) -> bool:
        return _interpret_default() if self.interpret is None else self.interpret

    # -- layout -------------------------------------------------------------

    def layout(self, params: PyTree) -> ShardLayout:
        leaves = jax.tree.leaves(params)
        key = (jax.tree.structure(params),
               tuple(tuple(l.shape) for l in leaves),
               tuple(str(jnp.dtype(l.dtype)) for l in leaves))
        lay = self._layouts.get(key)
        if lay is None:
            lay = build_layout(params, block=self.block)
            self._layouts[key] = lay
        return lay

    def describe(self, params: PyTree) -> dict:
        return self.layout(params).manifest()

    # -- init ---------------------------------------------------------------

    def init(self, params: PyTree) -> EngineState:
        lay = self.layout(params)
        zeros = tuple(jnp.zeros((s,), self.state_dtype)
                      for s in lay.shard_sizes)
        return EngineState(
            count=jnp.zeros((), jnp.int32),
            m=zeros,
            h=zeros if self.needs_curvature else (),
            hess_count=jnp.zeros((), jnp.int32),
            clip_fraction=jnp.zeros((), jnp.float32),
        )

    # -- the step -----------------------------------------------------------

    def ravel_grads(self, params: PyTree,
                    grads: PyTree) -> Tuple[jnp.ndarray, ...]:
        """Grads pytree -> fp32 flat shards in this engine's layout — the
        representation the compressed all-reduce (distributed/compression)
        and :meth:`step_shards` consume."""
        return ravel_shards(self.layout(params), grads, dtype=jnp.float32)

    def step(self, state: EngineState, params: PyTree, grads: PyTree,
             lr) -> tuple:
        """One optimizer step.  ``lr`` is a traced scalar (the trainer
        evaluates the schedule once, outside the engine).

        Returns ``(new_params, new_state)``."""
        return self.step_shards(state, params, self.ravel_grads(params, grads),
                                lr)

    def step_shards(self, state: EngineState, params: PyTree,
                    g_sh: Tuple[jnp.ndarray, ...], lr) -> tuple:
        """:meth:`step` with the gradients already raveled to flat fp32
        shards (the trainer ravels once, optionally runs the in-collective
        compression on the flat view, then lands here)."""
        return self._apply_shards(state, params, g_sh, lr,
                                  None, None, None)

    def _apply_shards(self, state: EngineState, params: PyTree, g_sh, lr,
                      e_sh, flag, scale) -> tuple:
        """Shared shard loop for the plain step (``e_sh is None``) and the
        fused update+refresh (``e_sh``/``flag``/``scale`` set)."""
        lay = self.layout(params)
        lr = jnp.asarray(lr, jnp.float32)
        c1 = (state.count + 1).astype(jnp.float32)  # bias-correction step
        p_sh = ravel_shards(lay, params)
        new_p, new_m, new_h = [], [], []
        nclip = jnp.zeros((), jnp.float32)
        for i in range(lay.n_shards):
            h_i = state.h[i] if self.needs_curvature else None
            e_i = e_sh[i] if e_sh is not None else None
            p_i, m_i, h_i, nclip_i = self._step_shard(
                p_sh[i], state.m[i], h_i, g_sh[i], e_i, lr, c1, flag, scale)
            new_p.append(p_i)
            new_m.append(m_i)
            if h_i is not None:
                new_h.append(h_i)
            if nclip_i is not None:
                nclip = nclip + nclip_i.astype(jnp.float32)
        clip_fraction = (nclip / lay.n_params if self.tracks_clip_fraction
                         else state.clip_fraction)
        kw = {} if flag is None else \
            dict(hess_count=state.hess_count + flag.astype(jnp.int32))
        new_state = state._replace(
            count=state.count + 1, m=tuple(new_m),
            h=tuple(new_h) if new_h else state.h,
            clip_fraction=jnp.asarray(clip_fraction, jnp.float32), **kw)
        return unravel_shards(lay, tuple(new_p)), new_state

    def _step_shard(self, p, m, h, g, e, lr, c1, flag, scale):
        """Dispatch one flat shard to the backend — the plain update when
        ``e`` is None, the fused update+refresh otherwise.  Returns
        (p', m', h' or None, n_clipped or None)."""
        hp = self.hypers
        fused = self.backend == "pallas"
        kw = dict(block=self.block, interpret=self._interp()) if fused else {}
        fam = self.family
        if fam == "sophia":
            args = dict(beta1=hp["beta1"], gamma=hp["gamma"], eps=hp["eps"],
                        weight_decay=hp["weight_decay"],
                        clip_threshold=hp["clip_threshold"])
            if e is not None:
                args["beta2"] = hp["beta2"]
                if fused:
                    p2, m2, h2, nclip = kblk.sophia_refresh_fused_block(
                        p, m, h, g, e, lr, flag, scale, **args, **kw)
                    return p2, m2, h2, jnp.sum(nclip)
                p2, m2, h2, nclip = kref.sophia_step_refresh_ref(
                    p, m, h, g, e, lr=lr, flag=flag, scale=scale, **args)
                return p2, m2, h2, nclip
            if fused:
                p2, m2, nclip = kblk.sophia_fused_block(p, m, h, g, lr,
                                                        **args, **kw)
                return p2, m2, h, jnp.sum(nclip)
            p2, m2, nclip = kref.sophia_fused_ref(p, m, h, g, lr=lr, **args)
            return p2, m2, h, nclip
        if fam == "adahessian":
            args = dict(beta1=hp["beta1"], beta2=hp["beta2"], eps=hp["eps"],
                        weight_decay=hp["weight_decay"])
            if e is not None:
                if fused:
                    p2, m2, h2 = kblk.adahessian_refresh_fused_block(
                        p, m, h, g, e, lr, flag, scale, c1, **args, **kw)
                else:
                    p2, m2, h2 = kref.adahessian_step_refresh_ref(
                        p, m, h, g, e, lr=lr, flag=flag, scale=scale,
                        step=c1, **args)
                return p2, m2, h2, None
            if fused:
                p2, m2 = kblk.adahessian_fused_block(p, m, h, g, lr, c1,
                                                     **args, **kw)
            else:
                p2, m2 = kref.adahessian_fused_ref(p, m, h, g, lr=lr,
                                                   step=c1, **args)
            return p2, m2, h, None
        if fam == "adamw":
            args = dict(beta1=hp["beta1"], beta2=hp["beta2"], eps=hp["eps"],
                        weight_decay=hp["weight_decay"])
            if fused:
                p2, m2, v2 = kblk.adamw_fused_block(p, m, h, g, lr, c1,
                                                    **args, **kw)
            else:
                p2, m2, v2 = kref.adamw_fused_ref(p, m, h, g, lr=lr, step=c1,
                                                  **args)
            return p2, m2, v2, None
        if fam == "lion":
            args = dict(beta1=hp["beta1"], beta2=hp["beta2"],
                        weight_decay=hp["weight_decay"])
            if fused:
                p2, m2 = kblk.lion_fused_block(p, m, g, lr, **args, **kw)
            else:
                p2, m2 = kref.lion_fused_ref(p, m, g, lr=lr, **args)
            return p2, m2, None, None
        if fam == "signgd":
            args = dict(beta1=hp["beta1"], weight_decay=hp["weight_decay"])
            if fused:
                p2, m2 = kblk.signgd_fused_block(p, m, g, lr, **args, **kw)
            else:
                p2, m2 = kref.signgd_fused_ref(p, m, g, lr=lr, **args)
            return p2, m2, None, None
        if fam == "sgd":
            args = dict(momentum=hp.get("momentum", 0.0))
            if fused:
                p2, m2 = kblk.sgd_fused_block(p, m, g, lr, **args, **kw)
            else:
                p2, m2 = kref.sgd_fused_ref(p, m, g, lr=lr, **args)
            return p2, m2, None, None
        raise ValueError(self.family)

    # -- fused step + Hessian-EMA refresh (the unified curvature pipeline) --

    def _est_shards(self, lay: ShardLayout, est) -> Tuple[jnp.ndarray, ...]:
        """Estimate as flat fp32 shards: a tuple matching the layout passes
        through untouched (the flat estimators' output — no params-shaped
        curvature tree ever materializes); a pytree ravels once."""
        if (isinstance(est, tuple) and len(est) == lay.n_shards
                and all(getattr(e, "ndim", None) == 1
                        and e.shape[0] == s
                        for e, s in zip(est, lay.shard_sizes))):
            return tuple(e.astype(jnp.float32) for e in est)
        return ravel_shards(lay, est, dtype=jnp.float32)

    def step_with_refresh(self, state: EngineState, params: PyTree,
                          g_sh: Tuple[jnp.ndarray, ...], lr, est, scale,
                          do_refresh) -> tuple:
        """One optimizer step with the Hessian-EMA refresh fused in.

        ``do_refresh`` is a *traced* 0/1 flag: when set, the curvature shard
        absorbs ``scale * est`` (Algorithm 3 line 9) in the same grid sweep
        that applies the update — h is read and written exactly once either
        way, so the unified train step compiles to a single program whose
        refresh branch adds no extra h traffic.  ``est`` is a tuple of flat
        fp32 shards (or a pytree, raveled once); ``scale`` is the GNB batch
        factor B, still a traced scalar.

        Semantically identical to ``update_hessian(...)`` followed by
        ``step_shards(...)`` when the flag is set, and to ``step_shards``
        alone when clear (tests/test_unified_step.py pins both).

        Returns ``(new_params, new_state)``."""
        if not self.hessian_aware:
            raise ValueError(
                f"step_with_refresh requires a hessian-aware family, "
                f"got {self.family!r} (use step/step_shards)")
        flag = jnp.asarray(do_refresh).astype(jnp.float32)
        scale = jnp.asarray(scale, jnp.float32)
        e_sh = self._est_shards(self.layout(params), est)
        return self._apply_shards(state, params, g_sh, lr, e_sh, flag, scale)

    # -- Hessian-EMA refresh (Algorithm 3 line 9, out-of-band form) ---------

    def update_hessian(self, state: EngineState, est, *,
                       scale=1.0, params: PyTree) -> EngineState:
        """Fold a fresh diagonal-Hessian estimate into the curvature shards.

        ``est`` is either a params-shaped pytree (raveled once) or already a
        tuple of flat fp32 shards in this engine's layout — the flat
        estimators (core/estimators.py) hand shards in directly, so no
        params-shaped curvature tree materializes.  ``scale`` is the GNB
        batch factor B (a traced scalar — it depends on the step's
        valid-token mask), folded into the EMA in-kernel so the scaled
        estimate never materializes.  AdaHessian squares the scaled
        estimate (its state is an EMA of squared estimates).

        The unified train step fuses this into :meth:`step_with_refresh`;
        this standalone form remains for tests and offline tooling."""
        if not self.hessian_aware:
            return state
        lay = self.layout(params)
        e_sh = self._est_shards(lay, est)
        beta2 = self.hypers["beta2"]
        square = self.family == "adahessian"
        new_h = []
        for h, e in zip(state.h, e_sh):
            if self.backend == "pallas":
                new_h.append(kblk.hessian_ema_block(
                    h, e, beta2=beta2, scale=scale, square=square,
                    block=self.block, interpret=self._interp()))
            else:
                new_h.append(kref.hessian_ema_ref(h, e, beta2=beta2,
                                                  scale=scale, square=square))
        return state._replace(h=tuple(new_h),
                              hess_count=state.hess_count + 1)

    # -- debugging / telemetry views ---------------------------------------

    def state_as_trees(self, state: EngineState, params: PyTree) -> dict:
        """Unravel m/h back into params-shaped pytrees (inspection only)."""
        lay = self.layout(params)
        out = {"m": unravel_shards(lay, state.m)}
        if state.h:
            out["h"] = unravel_shards(lay, state.h)
        return out


def flat_shard_spec(a, mesh=None):
    """PartitionSpec for one 1-D flat shard: sharded over the ``data`` mesh
    axis when divisible (FSDP-style), else replicated.  Shared by the
    engine's m/h shards and the compressor's error-feedback shards."""
    from jax.sharding import PartitionSpec as P
    if (mesh is not None and "data" in mesh.shape
            and a.shape[0] % mesh.shape["data"] == 0):
        return P("data")
    return P()


def engine_partition_specs(opt_state: EngineState, mesh=None) -> EngineState:
    """PartitionSpecs for an EngineState: flat shards are sharded over the
    ``data`` mesh axis when divisible (FSDP-style), else replicated."""
    from jax.sharding import PartitionSpec as P
    scalar = P()
    return EngineState(count=scalar,
                       m=tuple(flat_shard_spec(a, mesh) for a in opt_state.m),
                       h=tuple(flat_shard_spec(a, mesh) for a in opt_state.h),
                       hess_count=scalar, clip_fraction=scalar)
