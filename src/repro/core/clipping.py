"""Global-norm gradient clipping with trigger telemetry (paper Fig 7a).

The paper uses "standard gradient clipping (by norm) threshold 1.0" for all
optimizers and reports the *trigger frequency* as a stability metric: AdamW
and Lion trigger >10% of steps while Sophia rarely does.  We return the
trigger indicator so the trainer can log/accumulate it.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .types import GradientTransformation, PyTree, global_norm


class ClipState(NamedTuple):
    count: jnp.ndarray
    triggers: jnp.ndarray  # cumulative number of clipped steps
    last_norm: jnp.ndarray


def clip_by_global_norm(max_norm: float = 1.0) -> GradientTransformation:
    def init(params):
        del params
        return ClipState(jnp.zeros([], jnp.int32), jnp.zeros([], jnp.int32),
                         jnp.zeros([], jnp.float32))

    def update(grads, state, params=None):
        del params
        norm = global_norm(grads)
        trigger = norm > max_norm
        scale = jnp.where(trigger, max_norm / (norm + 1e-16), 1.0)
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)
        return grads, ClipState(state.count + 1,
                                state.triggers + trigger.astype(jnp.int32),
                                norm)

    return GradientTransformation(init=init, update=update)


def clip_trigger_rate(state: ClipState) -> jnp.ndarray:
    return state.triggers / jnp.maximum(state.count, 1)
