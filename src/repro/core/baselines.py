"""Baseline optimizers the paper compares against (Section 3.1).

AdamW (Loshchilov & Hutter), Lion (Chen et al. 2023), SignGD-with-momentum
(the paper's simplified-Adam / "Clip" ablation), AdaHessian (Yao et al. 2021,
EMA of *squared* Hessian estimates in the denominator), and plain SGD.

All are pure-JAX GradientTransformations sharing the protocol in
:mod:`repro.core.types`, so the trainer is optimizer-agnostic.
"""
from __future__ import annotations

from typing import NamedTuple, Union

import jax
import jax.numpy as jnp

from .types import (GradientTransformation, HessianAwareTransformation,
                    PyTree, Schedule, tree_zeros_like)


def _lr_at(lr, step):
    return lr(step) if callable(lr) else lr


class AdamWState(NamedTuple):
    count: jnp.ndarray
    m: PyTree
    v: PyTree


def adamw(learning_rate: Union[float, Schedule], *, beta1: float = 0.9,
          beta2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> GradientTransformation:
    """AdamW with the paper's LM defaults (b1=0.9, b2=0.95, wd=0.1)."""

    def init(params):
        return AdamWState(jnp.zeros([], jnp.int32),
                          tree_zeros_like(params, jnp.float32),
                          tree_zeros_like(params, jnp.float32))

    def update(grads, state, params=None):
        count = state.count + 1
        m = jax.tree.map(lambda m_, g: beta1 * m_ + (1 - beta1) * g.astype(jnp.float32),
                         state.m, grads)
        v = jax.tree.map(lambda v_, g: beta2 * v_ + (1 - beta2)
                         * jnp.square(g.astype(jnp.float32)), state.v, grads)
        c = count.astype(jnp.float32)
        bc1 = 1 - beta1 ** c
        bc2 = 1 - beta2 ** c
        lr = _lr_at(learning_rate, state.count)
        updates = jax.tree.map(
            lambda m_, v_, p: -lr * ((m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
                                     + weight_decay * p.astype(jnp.float32)),
            m, v, params)
        return updates, AdamWState(count, m, v)

    return GradientTransformation(init=init, update=update)


class LionState(NamedTuple):
    count: jnp.ndarray
    m: PyTree


def lion(learning_rate: Union[float, Schedule], *, beta1: float = 0.95,
         beta2: float = 0.98, weight_decay: float = 0.2) -> GradientTransformation:
    """Lion (paper's LM tuning: b1=0.95, b2=0.98, wd=0.2)."""

    def init(params):
        return LionState(jnp.zeros([], jnp.int32),
                         tree_zeros_like(params, jnp.float32))

    def update(grads, state, params=None):
        lr = _lr_at(learning_rate, state.count)
        updates = jax.tree.map(
            lambda m_, g, p: -lr * (jnp.sign(beta1 * m_ + (1 - beta1)
                                             * g.astype(jnp.float32))
                                    + weight_decay * p.astype(jnp.float32)),
            state.m, grads, params)
        m = jax.tree.map(lambda m_, g: beta2 * m_ + (1 - beta2) * g.astype(jnp.float32),
                         state.m, grads)
        return updates, LionState(state.count + 1, m)

    return GradientTransformation(init=init, update=update)


class SignGDState(NamedTuple):
    count: jnp.ndarray
    m: PyTree


def signgd(learning_rate: Union[float, Schedule], *, beta1: float = 0.96,
           weight_decay: float = 0.0) -> GradientTransformation:
    """Stochastic momentum SignSGD — the 'Clip' ablation in Fig 8c and the
    fallback Sophia reduces to when curvature is untrusted."""

    def init(params):
        return SignGDState(jnp.zeros([], jnp.int32),
                           tree_zeros_like(params, jnp.float32))

    def update(grads, state, params=None):
        m = jax.tree.map(lambda m_, g: beta1 * m_ + (1 - beta1) * g.astype(jnp.float32),
                         state.m, grads)
        lr = _lr_at(learning_rate, state.count)
        updates = jax.tree.map(
            lambda m_, p: -lr * (jnp.sign(m_) + weight_decay * p.astype(jnp.float32)),
            m, params)
        return updates, SignGDState(state.count + 1, m)

    return GradientTransformation(init=init, update=update)


class AdaHessianState(NamedTuple):
    count: jnp.ndarray
    m: PyTree
    v: PyTree  # EMA of squared Hessian-diagonal estimates


def adahessian(learning_rate: Union[float, Schedule], *, beta1: float = 0.92,
               beta2: float = 0.99, eps: float = 1e-8,
               weight_decay: float = 0.0) -> HessianAwareTransformation:
    """AdaHessian: Adam-like but the denominator is sqrt(EMA(hhat^2)).

    Hessian-aware: the trainer feeds it the same Hutchinson estimates as
    Sophia-H (paper tunes b1=0.92, b2=0.99; needs estimates every step to be
    stable — Fig 8c shows divergence at k=2 without clipping).
    """

    def init(params):
        return AdaHessianState(jnp.zeros([], jnp.int32),
                               tree_zeros_like(params, jnp.float32),
                               tree_zeros_like(params, jnp.float32))

    def update(grads, state, params=None):
        count = state.count + 1
        m = jax.tree.map(lambda m_, g: beta1 * m_ + (1 - beta1) * g.astype(jnp.float32),
                         state.m, grads)
        c = count.astype(jnp.float32)
        bc1 = 1 - beta1 ** c
        bc2 = 1 - beta2 ** c
        lr = _lr_at(learning_rate, state.count)
        updates = jax.tree.map(
            lambda m_, v_, p: -lr * ((m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
                                     + weight_decay * p.astype(jnp.float32)),
            m, state.v, params)
        return updates, AdaHessianState(count, m, state.v)

    def update_hessian(hess, state):
        v = jax.tree.map(
            lambda v_, h: beta2 * v_ + (1 - beta2) * jnp.square(h.astype(jnp.float32)),
            state.v, hess)
        return state._replace(v=v)

    return HessianAwareTransformation(init=init, update=update,
                                      update_hessian=update_hessian)


class SGDState(NamedTuple):
    count: jnp.ndarray
    m: PyTree


def sgd(learning_rate: Union[float, Schedule], *, momentum: float = 0.0
        ) -> GradientTransformation:
    def init(params):
        return SGDState(jnp.zeros([], jnp.int32),
                        tree_zeros_like(params, jnp.float32))

    def update(grads, state, params=None):
        del params
        m = jax.tree.map(lambda m_, g: momentum * m_ + g.astype(jnp.float32),
                         state.m, grads)
        lr = _lr_at(learning_rate, state.count)
        updates = jax.tree.map(lambda m_: -lr * m_, m)
        return updates, SGDState(state.count + 1, m)

    return GradientTransformation(init=init, update=update)
