"""repro.core — Sophia (the paper's contribution) + optimizer substrate.

Public API:
    sophia, sophia_h, sophia_g          — Algorithm 3 (pytree reference impl)
    OptimizerEngine, EngineState        — flat-buffer engine (the trainer's
                                          single update path; fused Pallas or
                                          pure-jnp backend over flat shards)
    hutchinson_estimator, gnb_estimator — Section 2.3 estimators
    adamw, lion, signgd, adahessian     — paper baselines
    clip_by_global_norm                 — stability telemetry (Fig 7a)
    linear_warmup_cosine                — paper LR protocol
"""
from .types import (GradientTransformation, HessianAwareTransformation,
                    apply_updates, chain, global_norm, tree_zeros_like)
from .sophia import (SophiaState, scale_by_sophia, sophia, sophia_g, sophia_h)
from .estimators import (chunked_sampled_stats, empirical_fisher_estimator,
                         empirical_fisher_estimator_flat,
                         empirical_fisher_ghat_flat, exact_diag_hessian,
                         gnb_estimator, gnb_estimator_sq,
                         gnb_estimator_sq_flat, gnb_ghat_flat,
                         gnb_ghat_flat_from_loss, hutchinson_estimator,
                         hutchinson_estimator_flat, sample_labels,
                         subsample_batch)
from .baselines import adahessian, adamw, lion, sgd, signgd
from .engine import (EngineState, OptimizerEngine, ShardLayout, build_layout,
                     engine_partition_specs, flat_shard_spec,
                     hessian_aware_optimizer, ravel_shards, unravel_shards)
from .clipping import ClipState, clip_by_global_norm, clip_trigger_rate
from .schedule import (constant, inverse_sqrt, linear_warmup_cosine,
                       linear_warmup_linear_decay)

OPTIMIZERS = {
    "sophia_h": sophia_h,
    "sophia_g": sophia_g,
    "adamw": adamw,
    "lion": lion,
    "signgd": signgd,
    "adahessian": adahessian,
    "sgd": sgd,
}
