"""Decode-attention Pallas TPU kernel: one query token per slot against its
ring/paged KV cache.

The serve engine's hot path is token-at-a-time attention: for every slot a
single query attends to that slot's valid cache entries.  XLA lowers the
jnp path as score-materialize / mask / softmax / AV — each an HBM round
trip over the (N, C) score plane.  This kernel keeps the online softmax in
VMEM scratch and streams the cache one page at a time, so HBM traffic is
one read of the slot's K/V pages plus one write of the output.

Layout (matches ``models/transformer.init_slots``):
    q          (N, H, hd)       one query token per slot
    k_cache/v  (N, C, Hkv, hd)  slot-major ring cache, C = n_pages * page_len
    positions  (N,) int32       per-slot write position (the query's position)

Ring semantics: cache index ``s`` holds absolute position
``pos - ((pos - s) mod C)``; entries are valid when that is >= 0 (and
inside the sliding window when one is set).  When C covers the whole
request the ring degenerates to a linear cache and the mask to the causal
prefix — this is the layout ``ring_mask`` in models/layers.py defines, and
the kernel reproduces it page by page.

Grid: (N, H, C / page_len) with the page axis innermost ("arbitrary"),
accumulating via the same m/l/acc VMEM scratch pattern as
kernels/flash_attention.py.  GQA maps h -> h // G in the KV BlockSpec.
Per-slot positions arrive through ``PrefetchScalarGridSpec`` so the mask
offsets are known before the body runs.

Validated under ``interpret=True`` against ``kernels/ref.decode_attention_ref``
(<= 3e-6 fp32) in tests/test_decode_attention.py; on a real TPU the same
pallas_call compiles natively.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_PAGE = 128
NEG_INF = -1e30


def _decode_kernel(pos_ref, win_ref, q_ref, k_ref, v_ref, o_ref, m_scr,
                   l_scr, acc_scr, *, scale, page_len, cache_len, n_pages,
                   softcap, ks_ref=None, vs_ref=None, kv_cast=None):
    n = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    pos = pos_ref[n]
    win = win_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)                  # (hd,)
    k = k_ref[0, :, 0]                                   # (page_len, hd)
    v = v_ref[0, :, 0]
    if ks_ref is not None:
        # int8 page dequant: fp32 payload * per-token scale, rounded once
        # into the compute dtype — bit-identical to the XLA read path
        # (models/layers._dequant_cache), so kernel on/off never changes
        # sampled tokens
        k = (k.astype(jnp.float32) * ks_ref[0][:, None]).astype(kv_cast)
        v = (v.astype(jnp.float32) * vs_ref[0][:, None]).astype(kv_cast)
    k = k.astype(jnp.float32)
    s = (q[None, :] @ k.T) * scale                       # (1, page_len)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    # ring validity: index s holds absolute position pos - ((pos - s) mod C)
    idx = j * page_len + jax.lax.broadcasted_iota(jnp.int32, (1, page_len), 1)
    abs_pos = pos - jnp.mod(pos - idx, cache_len)
    valid = (abs_pos >= 0) & (abs_pos > pos - win)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v.astype(jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == n_pages - 1)
    def _flush():
        o_ref[0, 0] = (acc_scr[...][0]
                       / jnp.maximum(l_scr[...][0], 1e-30)).astype(o_ref.dtype)


def _decode_kernel_q8(pos_ref, win_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                      o_ref, m_scr, l_scr, acc_scr, **kw):
    """Operand-order shim: the quantized call streams two extra per-page
    scale planes between the caches and the output."""
    _decode_kernel(pos_ref, win_ref, q_ref, k_ref, v_ref, o_ref, m_scr,
                   l_scr, acc_scr, ks_ref=ks_ref, vs_ref=vs_ref, **kw)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def decode_attention_pallas(q, k_cache, v_cache, positions, *, scale=None,
                            window=None, softcap=None,
                            page_len=DEFAULT_PAGE, interpret=None,
                            k_scale=None, v_scale=None):
    """q (N, H, hd); k/v (N, C, Hkv, hd); positions (N,) -> (N, H, hd).

    One grid step per (slot, head, page); HBM traffic = K + V pages once
    plus Q and O.  ``page_len`` must divide C.  ``window`` may be a traced
    scalar (it rides in as a scalar-prefetch operand, so per-layer sliding
    windows scan cleanly); None means global attention.  ``interpret``
    defaults to interpreter mode off-TPU, native compilation on TPU.

    int8 caches pass ``k_scale``/``v_scale`` (N, C) fp32 per-token scales:
    each page's scale slice streams into VMEM alongside its K/V page
    (same index map on the ring axis) and the page is dequantized in
    registers — HBM reads the 1-byte payloads, halving cache traffic and
    doubling the slots a fixed HBM budget sustains.
    """
    interpret = _interpret_default() if interpret is None else interpret
    N, H, hd = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    # largest page <= requested that divides C (C is engine-rounded to its
    # own page size, which need not divide the kernel's default of 128)
    page_len = min(page_len, C)
    while C % page_len:
        page_len -= 1
    n_pages = C // page_len
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    win = jnp.reshape(jnp.asarray(
        (1 << 30) if window is None else window, jnp.int32), (1,))
    quant = k_scale is not None

    kern = functools.partial(
        _decode_kernel_q8 if quant else _decode_kernel, scale=scale,
        page_len=page_len, cache_len=C, n_pages=n_pages, softcap=softcap)
    if quant:
        kern = functools.partial(kern, kv_cast=q.dtype)
    kv_spec = pl.BlockSpec((1, page_len, 1, hd),
                           lambda n, h, j, pos, w: (n, j, h // G, 0))
    in_specs = [
        pl.BlockSpec((1, 1, hd), lambda n, h, j, pos, w: (n, h, 0)),
        kv_spec,
        kv_spec,
    ]
    operands = [q, k_cache, v_cache]
    if quant:
        scale_spec = pl.BlockSpec((1, page_len),
                                  lambda n, h, j, pos, w: (n, j))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N, H, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, hd), lambda n, h, j, pos, w: (n, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),    # running max
            pltpu.VMEM((1, 1), jnp.float32),    # running sum
            pltpu.VMEM((1, hd), jnp.float32),   # accumulator
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(positions.astype(jnp.int32), win, *operands)


def decode_attention_hbm_bytes(N, H, Hkv, C, hd, bytes_per_el=2,
                               kv_dtype="bf16") -> int:
    """Analytic HBM floor of the fused decode step (roofline overlay).

    ``kv_dtype="int8"`` charges 1 byte/element for the cache payload plus
    one fp32 per-token scale per K/V plane; Q and O stay in the compute
    dtype either way."""
    q_o = 2 * N * H * hd * bytes_per_el
    if kv_dtype == "int8":
        kv = 2 * N * C * (Hkv * hd + 4)
    else:
        kv = 2 * N * C * Hkv * hd * bytes_per_el
    return q_o + kv
