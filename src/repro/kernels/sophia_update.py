"""Fused Sophia-step Pallas TPU kernels.

Why a kernel: the optimizer update is element-wise over every parameter —
pure HBM-bandwidth work.  Unfused, XLA materializes m', raw-update, clipped
update, decayed params as separate buffers: ~6 reads + ~4 writes per element.
The fused kernel reads (p, m, h, g) once and writes (p', m') once — the
bandwidth floor — and streams VMEM blocks of 128k elements (512 KiB fp32
per operand; 4 in + 2 out = 3 MiB live, well under the ~16 MiB v5e VMEM
budget).  Blocks are 1-D and lane-aligned (128k = 1024 x 128).

Validated under ``interpret=True`` on CPU against kernels/ref.py across a
shape x dtype sweep (tests/test_kernels.py); on a real TPU the same
pallas_call compiles natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128 * 1024  # elements per VMEM block (fp32: 512 KiB per operand)


def _sophia_kernel(lr_ref, p_ref, m_ref, h_ref, g_ref,
                   p_out, m_out, nclip_out, *,
                   beta1, gamma, eps, weight_decay, clip_threshold):
    lr = lr_ref[0]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g_ref[...]
    raw = m / jnp.maximum(gamma * h_ref[...], eps)
    u = jnp.clip(raw, -clip_threshold, clip_threshold)
    p_out[...] = p_ref[...] * (1.0 - lr * weight_decay) - lr * u
    m_out[...] = m
    nclip_out[0] = jnp.sum((jnp.abs(raw) >= clip_threshold)
                           .astype(jnp.int32))


def sophia_fused_block(p, m, h, g, lr, *, beta1, gamma, eps, weight_decay,
                       clip_threshold=1.0, block=BLOCK, interpret=True):
    """Run the fused step on a flat fp32 array (length % block == 0)."""
    n = p.shape[0]
    grid = n // block
    kern = functools.partial(
        _sophia_kernel, beta1=beta1, gamma=gamma, eps=eps,
        weight_decay=weight_decay, clip_threshold=clip_threshold)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    lr_spec = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[lr_spec, spec, spec, spec, spec],
        out_specs=[spec, spec, pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct((grid,), jnp.int32)],
        interpret=interpret,
    )(lr.reshape(1).astype(jnp.float32), p, m, h, g)


def _hess_ema_kernel(h_ref, e_ref, h_out, *, beta2, scale):
    h_out[...] = beta2 * h_ref[...] + (1.0 - beta2) * scale * e_ref[...]


def hessian_ema_block(h, est, *, beta2, scale=1.0, block=BLOCK,
                      interpret=True):
    """h' = beta2 h + (1-beta2) * scale * est on a flat fp32 array.

    ``scale`` folds the GNB batch factor B in (Algorithm 2 line 6) so the
    squared-gradient estimate never materializes separately.
    """
    n = h.shape[0]
    grid = n // block
    kern = functools.partial(_hess_ema_kernel, beta2=beta2, scale=scale)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(h, est)


def _adamw_kernel(sc_ref, p_ref, m_ref, v_ref, g_ref, p_out, m_out, v_out, *,
                  beta1, beta2, eps, weight_decay):
    lr, bc1, bc2 = sc_ref[0], sc_ref[1], sc_ref[2]
    g = g_ref[...]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    p_out[...] = p_ref[...] * (1.0 - lr * weight_decay) - lr * u
    m_out[...] = m
    v_out[...] = v


def adamw_fused_block(p, m, v, g, lr, step, *, beta1, beta2, eps,
                      weight_decay, block=BLOCK, interpret=True):
    """Fused AdamW on a flat fp32 array (baseline parity for Table 1)."""
    n = p.shape[0]
    grid = n // block
    bc1 = 1.0 - beta1 ** step.astype(jnp.float32)
    bc2 = 1.0 - beta2 ** step.astype(jnp.float32)
    scalars = jnp.stack([lr.astype(jnp.float32), bc1, bc2])
    kern = functools.partial(_adamw_kernel, beta1=beta1, beta2=beta2,
                             eps=eps, weight_decay=weight_decay)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    sc_spec = pl.BlockSpec((3,), lambda i: (0,))
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[sc_spec, spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=interpret,
    )(scalars, p, m, v, g)
