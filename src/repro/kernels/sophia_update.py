"""Fused optimizer-step Pallas TPU kernels (flat-shard granularity).

Why kernels: the optimizer update is element-wise over every parameter —
pure HBM-bandwidth work.  Unfused, XLA materializes m', raw-update, clipped
update, decayed params as separate buffers: ~6 reads + ~4 writes per element.
Each fused kernel reads its operands once and writes its outputs once — the
bandwidth floor — and streams VMEM blocks of 128k elements (512 KiB fp32
per operand; 4 in + 2 out = 3 MiB live, well under the ~16 MiB v5e VMEM
budget).  Blocks are 1-D and lane-aligned (128k = 1024 x 128).

The engine (core/engine.py) calls these on whole dtype-homogeneous flat
shards whose length is a multiple of ``block`` (tail-padded once at init),
so one ``pallas_call`` grid sweep covers the entire parameter set.  All
kernels compute in fp32 and preserve input dtypes on write, so bf16
optimizer state (``state_dtype="bfloat16"`` at 400B scale) streams half the
bytes without a separate cast pass.

Validated under ``interpret=True`` on CPU against kernels/ref.py across a
shape x dtype sweep (tests/test_kernels.py, tests/test_engine.py); on a real
TPU the same pallas_call compiles natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128 * 1024  # elements per VMEM block (fp32: 512 KiB per operand)

_f32 = jnp.float32


def _grid_spec(block):
    return pl.BlockSpec((block,), lambda i: (i,))


def _scalar_spec(n):
    return pl.BlockSpec((n,), lambda i: (0,))


# ---------------------------------------------------------------------------
# Sophia (Algorithm 3 lines 6, 12, 13) + Hessian EMA (line 9)


def _sophia_kernel(lr_ref, p_ref, m_ref, h_ref, g_ref,
                   p_out, m_out, nclip_out, *,
                   beta1, gamma, eps, weight_decay, clip_threshold):
    lr = lr_ref[0]
    m = beta1 * m_ref[...].astype(_f32) + (1.0 - beta1) * g_ref[...].astype(_f32)
    raw = m / jnp.maximum(gamma * h_ref[...].astype(_f32), eps)
    u = jnp.clip(raw, -clip_threshold, clip_threshold)
    p_out[...] = (p_ref[...].astype(_f32) * (1.0 - lr * weight_decay)
                  - lr * u).astype(p_out.dtype)
    m_out[...] = m.astype(m_out.dtype)
    nclip_out[0] = jnp.sum((jnp.abs(raw) >= clip_threshold)
                           .astype(jnp.int32))


def sophia_fused_block(p, m, h, g, lr, *, beta1, gamma, eps, weight_decay,
                       clip_threshold=1.0, block=BLOCK, interpret=True):
    """Run the fused step on flat arrays (length % block == 0).

    Dtypes are preserved: p' matches p, m' matches m (compute is fp32)."""
    n = p.shape[0]
    grid = n // block
    kern = functools.partial(
        _sophia_kernel, beta1=beta1, gamma=gamma, eps=eps,
        weight_decay=weight_decay, clip_threshold=clip_threshold)
    spec = _grid_spec(block)
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[_scalar_spec(1), spec, spec, spec, spec],
        out_specs=[spec, spec, pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), p.dtype),
                   jax.ShapeDtypeStruct((n,), m.dtype),
                   jax.ShapeDtypeStruct((grid,), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(lr, _f32).reshape(1), p, m, h, g)


def _hess_ema_kernel(sc_ref, h_ref, e_ref, h_out, *, beta2, square):
    e = sc_ref[0] * e_ref[...].astype(_f32)
    if square:
        e = e * e
    h_out[...] = (beta2 * h_ref[...].astype(_f32)
                  + (1.0 - beta2) * e).astype(h_out.dtype)


def hessian_ema_block(h, est, *, beta2, scale=1.0, square=False, block=BLOCK,
                      interpret=True):
    """h' = beta2 h + (1-beta2) * scale * est on a flat array.

    ``scale`` folds the GNB batch factor B in (Algorithm 2 line 6) so the
    squared-gradient estimate never materializes separately; it is a traced
    scalar (B depends on the step's valid-token mask).  ``square=True`` is
    the AdaHessian refresh: h' = b2 h + (1-b2) (scale * est)^2.
    """
    n = h.shape[0]
    grid = n // block
    kern = functools.partial(_hess_ema_kernel, beta2=beta2, square=square)
    spec = _grid_spec(block)
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[_scalar_spec(1), spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), h.dtype),
        interpret=interpret,
    )(jnp.asarray(scale, _f32).reshape(1), h, est)


def _sophia_refresh_kernel(sc_ref, p_ref, m_ref, h_ref, g_ref, e_ref,
                           p_out, m_out, h_out, nclip_out, *,
                           beta1, beta2, gamma, eps, weight_decay,
                           clip_threshold):
    lr, flag, scale = sc_ref[0], sc_ref[1], sc_ref[2]
    h32 = h_ref[...].astype(_f32)
    h_upd = beta2 * h32 + (1.0 - beta2) * (scale * e_ref[...].astype(_f32))
    # storage-dtype roundtrip before the update reads h: the two-pass path
    # (hessian_ema_block writes h, sophia_fused_block re-reads it) rounds
    # through h's dtype, and the fused sweep must be bit-compatible with it
    h_new = jnp.where(flag > 0.5, h_upd, h32).astype(h_out.dtype)
    h_out[...] = h_new
    m = beta1 * m_ref[...].astype(_f32) + (1.0 - beta1) * g_ref[...].astype(_f32)
    raw = m / jnp.maximum(gamma * h_new.astype(_f32), eps)
    u = jnp.clip(raw, -clip_threshold, clip_threshold)
    p_out[...] = (p_ref[...].astype(_f32) * (1.0 - lr * weight_decay)
                  - lr * u).astype(p_out.dtype)
    m_out[...] = m.astype(m_out.dtype)
    nclip_out[0] = jnp.sum((jnp.abs(raw) >= clip_threshold)
                           .astype(jnp.int32))


def sophia_refresh_fused_block(p, m, h, g, e, lr, flag, scale, *,
                               beta1, beta2, gamma, eps, weight_decay,
                               clip_threshold=1.0, block=BLOCK,
                               interpret=True):
    """One grid sweep fusing the Hessian-EMA refresh into the Sophia step.

    ``flag`` (traced 0/1) selects whether h absorbs ``scale * e`` before the
    update reads it — h streams through VMEM exactly once either way, which
    is what makes the unified train step's refresh branch free of a second
    h read/write pass.  ``scale`` is the GNB batch factor B (traced).

    Returns (p', m', h', nclip per block)."""
    n = p.shape[0]
    grid = n // block
    scalars = jnp.stack([jnp.asarray(lr, _f32), jnp.asarray(flag, _f32),
                         jnp.asarray(scale, _f32)])
    kern = functools.partial(
        _sophia_refresh_kernel, beta1=beta1, beta2=beta2, gamma=gamma,
        eps=eps, weight_decay=weight_decay, clip_threshold=clip_threshold)
    spec = _grid_spec(block)
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[_scalar_spec(3), spec, spec, spec, spec, spec],
        out_specs=[spec, spec, spec, pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), p.dtype),
                   jax.ShapeDtypeStruct((n,), m.dtype),
                   jax.ShapeDtypeStruct((n,), h.dtype),
                   jax.ShapeDtypeStruct((grid,), jnp.int32)],
        interpret=interpret,
    )(scalars, p, m, h, g, e)


def _adahessian_refresh_kernel(sc_ref, p_ref, m_ref, v_ref, g_ref, e_ref,
                               p_out, m_out, v_out, *,
                               beta1, beta2, eps, weight_decay):
    lr, flag, scale = sc_ref[0], sc_ref[1], sc_ref[2]
    bc1, bc2 = sc_ref[3], sc_ref[4]
    v32 = v_ref[...].astype(_f32)
    es = scale * e_ref[...].astype(_f32)
    v_upd = beta2 * v32 + (1.0 - beta2) * es * es
    v_new = jnp.where(flag > 0.5, v_upd, v32).astype(v_out.dtype)
    v_out[...] = v_new
    m = beta1 * m_ref[...].astype(_f32) + (1.0 - beta1) * g_ref[...].astype(_f32)
    u = (m / bc1) / (jnp.sqrt(v_new.astype(_f32) / bc2) + eps)
    p_out[...] = (p_ref[...].astype(_f32) * (1.0 - lr * weight_decay)
                  - lr * u).astype(p_out.dtype)
    m_out[...] = m.astype(m_out.dtype)


def adahessian_refresh_fused_block(p, m, v, g, e, lr, flag, scale, step, *,
                                   beta1, beta2, eps, weight_decay,
                                   block=BLOCK, interpret=True):
    """AdaHessian step with the squared-estimate EMA fused in (flag-gated),
    the refresh analogue of :func:`sophia_refresh_fused_block`."""
    n = p.shape[0]
    grid = n // block
    step = jnp.asarray(step, _f32)
    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    scalars = jnp.stack([jnp.asarray(lr, _f32), jnp.asarray(flag, _f32),
                         jnp.asarray(scale, _f32), bc1, bc2])
    kern = functools.partial(_adahessian_refresh_kernel, beta1=beta1,
                             beta2=beta2, eps=eps, weight_decay=weight_decay)
    spec = _grid_spec(block)
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[_scalar_spec(5), spec, spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), p.dtype),
                   jax.ShapeDtypeStruct((n,), m.dtype),
                   jax.ShapeDtypeStruct((n,), v.dtype)],
        interpret=interpret,
    )(scalars, p, m, v, g, e)


# ---------------------------------------------------------------------------
# Baselines (the paper's Table 1 comparison runs through identical machinery)


def _adamw_kernel(sc_ref, p_ref, m_ref, v_ref, g_ref, p_out, m_out, v_out, *,
                  beta1, beta2, eps, weight_decay):
    lr, bc1, bc2 = sc_ref[0], sc_ref[1], sc_ref[2]
    g = g_ref[...].astype(_f32)
    m = beta1 * m_ref[...].astype(_f32) + (1.0 - beta1) * g
    v = beta2 * v_ref[...].astype(_f32) + (1.0 - beta2) * g * g
    u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    p_out[...] = (p_ref[...].astype(_f32) * (1.0 - lr * weight_decay)
                  - lr * u).astype(p_out.dtype)
    m_out[...] = m.astype(m_out.dtype)
    v_out[...] = v.astype(v_out.dtype)


def adamw_fused_block(p, m, v, g, lr, step, *, beta1, beta2, eps,
                      weight_decay, block=BLOCK, interpret=True):
    """Fused AdamW on flat arrays (baseline parity for Table 1)."""
    n = p.shape[0]
    grid = n // block
    step = jnp.asarray(step, _f32)
    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    scalars = jnp.stack([jnp.asarray(lr, _f32), bc1, bc2])
    kern = functools.partial(_adamw_kernel, beta1=beta1, beta2=beta2,
                             eps=eps, weight_decay=weight_decay)
    spec = _grid_spec(block)
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[_scalar_spec(3), spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), p.dtype),
                   jax.ShapeDtypeStruct((n,), m.dtype),
                   jax.ShapeDtypeStruct((n,), v.dtype)],
        interpret=interpret,
    )(scalars, p, m, v, g)


def _adahessian_kernel(sc_ref, p_ref, m_ref, v_ref, g_ref, p_out, m_out, *,
                       beta1, beta2, eps, weight_decay):
    lr, bc1, bc2 = sc_ref[0], sc_ref[1], sc_ref[2]
    m = beta1 * m_ref[...].astype(_f32) + (1.0 - beta1) * g_ref[...].astype(_f32)
    u = (m / bc1) / (jnp.sqrt(v_ref[...].astype(_f32) / bc2) + eps)
    p_out[...] = (p_ref[...].astype(_f32) * (1.0 - lr * weight_decay)
                  - lr * u).astype(p_out.dtype)
    m_out[...] = m.astype(m_out.dtype)


def adahessian_fused_block(p, m, v, g, lr, step, *, beta1, beta2, eps,
                           weight_decay, block=BLOCK, interpret=True):
    """AdaHessian step: Adam-shaped, v read-only (refreshed out-of-band)."""
    n = p.shape[0]
    grid = n // block
    step = jnp.asarray(step, _f32)
    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    scalars = jnp.stack([jnp.asarray(lr, _f32), bc1, bc2])
    kern = functools.partial(_adahessian_kernel, beta1=beta1, beta2=beta2,
                             eps=eps, weight_decay=weight_decay)
    spec = _grid_spec(block)
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[_scalar_spec(3), spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), p.dtype),
                   jax.ShapeDtypeStruct((n,), m.dtype)],
        interpret=interpret,
    )(scalars, p, m, v, g)


def _lion_kernel(lr_ref, p_ref, m_ref, g_ref, p_out, m_out, *,
                 beta1, beta2, weight_decay):
    lr = lr_ref[0]
    g = g_ref[...].astype(_f32)
    m = m_ref[...].astype(_f32)
    u = jnp.sign(beta1 * m + (1.0 - beta1) * g)
    p_out[...] = (p_ref[...].astype(_f32) * (1.0 - lr * weight_decay)
                  - lr * u).astype(p_out.dtype)
    m_out[...] = (beta2 * m + (1.0 - beta2) * g).astype(m_out.dtype)


def lion_fused_block(p, m, g, lr, *, beta1, beta2, weight_decay, block=BLOCK,
                     interpret=True):
    n = p.shape[0]
    grid = n // block
    kern = functools.partial(_lion_kernel, beta1=beta1, beta2=beta2,
                             weight_decay=weight_decay)
    spec = _grid_spec(block)
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[_scalar_spec(1), spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), p.dtype),
                   jax.ShapeDtypeStruct((n,), m.dtype)],
        interpret=interpret,
    )(jnp.asarray(lr, _f32).reshape(1), p, m, g)


def _signgd_kernel(lr_ref, p_ref, m_ref, g_ref, p_out, m_out, *,
                   beta1, weight_decay):
    lr = lr_ref[0]
    m = beta1 * m_ref[...].astype(_f32) + (1.0 - beta1) * g_ref[...].astype(_f32)
    p_out[...] = (p_ref[...].astype(_f32) * (1.0 - lr * weight_decay)
                  - lr * jnp.sign(m)).astype(p_out.dtype)
    m_out[...] = m.astype(m_out.dtype)


def signgd_fused_block(p, m, g, lr, *, beta1, weight_decay, block=BLOCK,
                       interpret=True):
    n = p.shape[0]
    grid = n // block
    kern = functools.partial(_signgd_kernel, beta1=beta1,
                             weight_decay=weight_decay)
    spec = _grid_spec(block)
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[_scalar_spec(1), spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), p.dtype),
                   jax.ShapeDtypeStruct((n,), m.dtype)],
        interpret=interpret,
    )(jnp.asarray(lr, _f32).reshape(1), p, m, g)


def _sgd_kernel(lr_ref, p_ref, m_ref, g_ref, p_out, m_out, *, momentum):
    lr = lr_ref[0]
    m = momentum * m_ref[...].astype(_f32) + g_ref[...].astype(_f32)
    p_out[...] = (p_ref[...].astype(_f32) - lr * m).astype(p_out.dtype)
    m_out[...] = m.astype(m_out.dtype)


def sgd_fused_block(p, m, g, lr, *, momentum, block=BLOCK, interpret=True):
    n = p.shape[0]
    grid = n // block
    kern = functools.partial(_sgd_kernel, momentum=momentum)
    spec = _grid_spec(block)
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[_scalar_spec(1), spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), p.dtype),
                   jax.ShapeDtypeStruct((n,), m.dtype)],
        interpret=interpret,
    )(jnp.asarray(lr, _f32).reshape(1), p, m, g)
