"""Pure-jnp oracles for the Pallas kernels (allclose-tested per shape/dtype).

These spell out Algorithm 3 lines 6, 12, 13 (fused apply) and line 9
(hessian EMA) exactly — the kernels must match bit-for-tolerance — plus
the plain-softmax oracle for the flash-attention kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sophia_fused_ref(p, m, h, g, *, lr, beta1, gamma, eps, weight_decay,
                     clip_threshold=1.0):
    """One fused Sophia step on a single tensor.

    Returns (new_p, new_m, n_clipped):
        m'  = beta1 m + (1-beta1) g
        u   = clip(m' / max(gamma h, eps), +-rho)
        p'  = p - lr wd p - lr u
    """
    f32 = jnp.float32
    m_new = beta1 * m.astype(f32) + (1.0 - beta1) * g.astype(f32)
    raw = m_new / jnp.maximum(gamma * h.astype(f32), eps)
    u = jnp.clip(raw, -clip_threshold, clip_threshold)
    p_new = p.astype(f32) * (1.0 - lr * weight_decay) - lr * u
    n_clipped = jnp.sum(jnp.abs(raw) >= clip_threshold).astype(jnp.int32)
    return p_new.astype(p.dtype), m_new.astype(m.dtype), n_clipped


def hessian_ema_ref(h, hhat, *, beta2, scale=1.0, square=False):
    """h' = beta2 h + (1-beta2) scale hhat  (Algorithm 3 line 9).

    ``scale`` folds the GNB batch factor B in (Algorithm 2 line 6);
    ``square=True`` gives the AdaHessian variant h' = b2 h + (1-b2)(s hhat)^2.
    """
    f32 = jnp.float32
    e = jnp.asarray(scale, f32) * hhat.astype(f32)
    if square:
        e = jnp.square(e)
    out = beta2 * h.astype(f32) + (1.0 - beta2) * e
    return out.astype(h.dtype)


def sophia_step_refresh_ref(p, m, h, g, e, *, lr, flag, scale, beta1, beta2,
                            gamma, eps, weight_decay, clip_threshold=1.0):
    """Fused Sophia step + conditional Hessian-EMA refresh on one tensor.

    ``flag`` is a traced 0/1 scalar (the unified train step's refresh flag):
    when set, h first absorbs the estimate (Algorithm 3 line 9, ``scale``
    folding the GNB batch factor B in) and the update then reads the
    refreshed h — exactly ``hessian_ema_ref`` followed by
    ``sophia_fused_ref``, with h touched once.  When clear, h passes
    through unchanged and the estimate operand is dead.

    Returns (new_p, new_m, new_h, n_clipped)."""
    h1 = hessian_ema_ref(h, e, beta2=beta2, scale=scale, square=False)
    on = jnp.asarray(flag, jnp.float32) > 0.5
    h_sel = jnp.where(on, h1, h)
    p2, m2, nclip = sophia_fused_ref(
        p, m, h_sel, g, lr=lr, beta1=beta1, gamma=gamma, eps=eps,
        weight_decay=weight_decay, clip_threshold=clip_threshold)
    return p2, m2, h_sel, nclip


def adahessian_step_refresh_ref(p, m, v, g, e, *, lr, flag, scale, beta1,
                                beta2, eps, weight_decay, step):
    """AdaHessian step + conditional squared-estimate EMA refresh.

    The refresh is ``hessian_ema_ref(square=True)`` — v is an EMA of
    (scale * estimate)^2 — selected by the traced ``flag`` exactly like
    :func:`sophia_step_refresh_ref`.  Returns (new_p, new_m, new_v)."""
    v1 = hessian_ema_ref(v, e, beta2=beta2, scale=scale, square=True)
    on = jnp.asarray(flag, jnp.float32) > 0.5
    v_sel = jnp.where(on, v1, v)
    p2, m2 = adahessian_fused_ref(p, m, v_sel, g, lr=lr, beta1=beta1,
                                  beta2=beta2, eps=eps,
                                  weight_decay=weight_decay, step=step)
    return p2, m2, v_sel


def _attn_mask_ref(Sq, Sk, *, causal, window, q_offset):
    """(Sq, Sk) bool attend-mask; ``window`` may be None, int, or traced."""
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m


def _attn_probs_ref(q, k, *, causal, scale, window, softcap, q_offset):
    """Shared fwd recompute: (s_raw, lse, p) with p row-normalized fp32,
    mirroring the kernel's fp32 rounding points (mask = -1e30, denominator
    floored at 1e-30)."""
    import math

    B, H, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kx = jnp.repeat(k, G, axis=1)
    s_raw = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       kx.astype(jnp.float32)) * scale
    s = softcap * jnp.tanh(s_raw / softcap) if softcap is not None else s_raw
    mask = _attn_mask_ref(Sq, Sk, causal=causal, window=window,
                          q_offset=q_offset)
    s = jnp.where(mask[None, None], s, -1e30)
    m = s.max(-1, keepdims=True)
    e = jnp.where(mask[None, None], jnp.exp(s - m), 0.0)
    l = jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    lse = (m + jnp.log(l))[..., 0]
    p = jnp.where(mask[None, None], jnp.exp(s - lse[..., None]), 0.0)
    return s_raw, lse, p


def flash_attention_ref(q, k, v, *, causal=True, scale=None, window=None,
                        softcap=None, q_offset=0):
    """Plain softmax attention oracle for the flash forward.

    q: (B, H, Sq, hd); k, v: (B, Hkv, Sk, hd) GQA.  Returns
    (o in q.dtype, lse (B, H, Sq) fp32) — the kernel's two outputs."""
    G = q.shape[1] // k.shape[1]
    _, lse, p = _attn_probs_ref(q, k, causal=causal, scale=scale,
                                window=window, softcap=softcap,
                                q_offset=q_offset)
    vx = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vx)
    return o.astype(q.dtype), lse


def flash_attention_grads_ref(q, k, v, g, *, causal=True, scale=None,
                              window=None, softcap=None, q_offset=0):
    """Closed-form (dq, dk, dv) oracle mirroring the backward kernels'
    fp32 math: ``delta`` from the *rounded* forward output (the kernel's
    residual), ``p = exp(z - lse)``, softcap chain on the raw scores."""
    import math

    B, H, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    s_raw, lse, p = _attn_probs_ref(q, k, causal=causal, scale=scale,
                                    window=window, softcap=softcap,
                                    q_offset=q_offset)
    kx = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vx = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    o32 = jnp.einsum("bhqk,bhkd->bhqd", p, vx)
    o_r = o32.astype(q.dtype).astype(jnp.float32)
    do = g.astype(jnp.float32)
    delta = (do * o_r).sum(-1, keepdims=True)
    ds = p * (jnp.einsum("bhqd,bhkd->bhqk", do, vx) - delta)
    if softcap is not None:
        ds = ds * (1.0 - jnp.tanh(s_raw / softcap) ** 2)
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, kx) * scale
    q32 = q.astype(jnp.float32)
    dkx = jnp.einsum("bhqk,bhqd->bhkd", ds, q32) * scale
    dvx = jnp.einsum("bhqk,bhqd->bhkd", p, do)
    dk = dkx.reshape(B, Hkv, G, Sk, hd).sum(2)
    dv = dvx.reshape(B, Hkv, G, Sk, hd).sum(2)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def flash_attention_jvp_ref(q, k, v, dq, dk, dv, *, causal=True, scale=None,
                            window=None, softcap=None, q_offset=0):
    """Forward-mode oracle for the custom_jvp twin's tangent:
    ``do = (p * dz) @ v - rowsum(p * dz) * o + p @ dv`` with
    ``dz = dcap * scale * (dq k^T + q dk^T)``, all fp32."""
    import math

    B, H, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    s_raw, lse, p = _attn_probs_ref(q, k, causal=causal, scale=scale,
                                    window=window, softcap=softcap,
                                    q_offset=q_offset)
    kx = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vx = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    dkx = jnp.repeat(dk, G, axis=1).astype(jnp.float32)
    dvx = jnp.repeat(dv, G, axis=1).astype(jnp.float32)
    q32, dq32 = q.astype(jnp.float32), dq.astype(jnp.float32)
    o32 = jnp.einsum("bhqk,bhkd->bhqd", p, vx)
    dz = (jnp.einsum("bhqd,bhkd->bhqk", dq32, kx)
          + jnp.einsum("bhqd,bhkd->bhqk", q32, dkx)) * scale
    if softcap is not None:
        dz = dz * (1.0 - jnp.tanh(s_raw / softcap) ** 2)
    pdz = p * dz
    do = (jnp.einsum("bhqk,bhkd->bhqd", pdz, vx)
          - pdz.sum(-1, keepdims=True) * o32
          + jnp.einsum("bhqk,bhkd->bhqd", p, dvx))
    return do.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, positions, *, scale=None,
                         window=None, softcap=None, k_scale=None,
                         v_scale=None):
    """Plain masked-softmax oracle for the decode-attention kernel.

    q: (N, H, hd) one query token per slot; k/v: (N, C, Hkv, hd) slot-major
    ring cache; positions: (N,) per-slot query position.  Ring index ``s``
    holds absolute position ``pos - ((pos - s) mod C)``; keys are valid when
    that is >= 0 (and within ``window`` of the query when set).

    int8 caches pass ``k_scale``/``v_scale`` (N, C) fp32 per-token scales;
    the oracle dequantizes exactly the way the kernel's page loop does
    (fp32 payload * scale, one rounding into the compute dtype) so the
    quantized parity bound stays as tight as the bf16 one."""
    import math

    N, H, hd = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if k_scale is not None:
        from ..quant import dequantize_kv
        k_cache = dequantize_kv(k_cache, k_scale, q.dtype)
        v_cache = dequantize_kv(v_cache, v_scale, q.dtype)
    kx = jnp.repeat(k_cache, G, axis=2)                 # (N, C, H, hd)
    vx = jnp.repeat(v_cache, G, axis=2)
    s = jnp.einsum("nhd,nchd->nhc", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = positions.astype(jnp.int32)[:, None]          # (N, 1)
    idx = jnp.arange(C, dtype=jnp.int32)[None, :]       # (1, C)
    abs_pos = pos - jnp.mod(pos - idx, C)
    valid = abs_pos >= 0
    if window is not None:
        valid = valid & (abs_pos > pos - window)
    s = jnp.where(valid[:, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("nhc,nchd->nhd", w,
                      vx.astype(jnp.float32)).astype(q.dtype)


def _lm_logits_ref(hidden, w, *, vocab_size, transpose_w, softcap):
    """Full-logits tile math in the fused-CE convention (fused_ce.py):
    W cast to the hidden dtype, fp32 accumulation, softcap in fp32, padded
    columns masked to -1e30.  Returns (s, dcap, h2) with ``dcap`` the
    softcap derivative factor (ones when uncapped)."""
    D = hidden.shape[-1]
    h2 = hidden.reshape(-1, D)
    wc = w.astype(hidden.dtype)
    if transpose_w:
        raw = jnp.dot(h2, wc, preferred_element_type=jnp.float32)
    else:
        raw = jnp.dot(h2, wc.T, preferred_element_type=jnp.float32)
    if softcap:
        t = jnp.tanh(raw / softcap)
        raw = softcap * t
        dcap = 1.0 - t * t
    else:
        dcap = jnp.ones_like(raw)
    cols = jnp.arange(raw.shape[-1])[None, :]
    return jnp.where(cols < vocab_size, raw, -1e30), dcap, h2


def _rowscale_ref(shape_lead, mask):
    from .fused_ce import rowscale
    n = 1
    for s in shape_lead:
        n *= s
    return rowscale(n, mask)


def lm_loss_ref(hidden, w, labels, mask=None, *, vocab_size,
                transpose_w=False, softcap=None):
    """Materialized-logits oracle for the fused LM loss (differentiable)."""
    s, _, _ = _lm_logits_ref(hidden, w, vocab_size=vocab_size,
                             transpose_w=transpose_w, softcap=softcap)
    lse = jax.nn.logsumexp(s, axis=-1)
    lab = labels.reshape(-1)
    ll = jnp.take_along_axis(s, lab[:, None], axis=1)[:, 0]
    rs, _ = _rowscale_ref(hidden.shape[:-1], mask)
    return jnp.sum(rs * (lse - ll))


def _lm_grads_from_labels(h2, w, s, dcap, lab, rs, *, transpose_w, cot):
    """Closed-form (loss, d_hidden, d_W) mirroring the fused kernels'
    fp32 compute exactly (the <=3e-6 parity oracle — autodiff through the
    bf16 cast chain would round at different points)."""
    lse = jax.nn.logsumexp(s, axis=-1)
    ll = jnp.take_along_axis(s, lab[:, None], axis=1)[:, 0]
    loss = jnp.sum(rs * (lse - ll))
    p = jnp.exp(s - lse[:, None])
    onehot = (jnp.arange(s.shape[-1])[None, :] == lab[:, None]) \
        .astype(jnp.float32)
    d = (p - onehot) * (rs * cot)[:, None] * dcap
    w32 = w.astype(jnp.float32)
    h32 = h2.astype(jnp.float32)
    if transpose_w:
        dh = d @ w32.T
        dw = h32.T @ d
    else:
        dh = d @ w32
        dw = d.T @ h32
    return loss, dh.astype(h2.dtype), dw.astype(w.dtype)


def lm_loss_grads_ref(hidden, w, labels, mask=None, *, vocab_size,
                      transpose_w=False, softcap=None, cot=1.0):
    """(loss, d_hidden, d_W) closed form; ``cot`` is the loss cotangent."""
    s, dcap, h2 = _lm_logits_ref(hidden, w, vocab_size=vocab_size,
                                 transpose_w=transpose_w, softcap=softcap)
    rs, _ = _rowscale_ref(hidden.shape[:-1], mask)
    loss, dh, dw = _lm_grads_from_labels(h2, w, s, dcap, labels.reshape(-1),
                                         rs, transpose_w=transpose_w,
                                         cot=cot)
    return loss, dh.reshape(hidden.shape), dw


def lm_loss_sampled_ref(hidden, w, rng, mask=None, *, vocab_size,
                        transpose_w=False, softcap=None, cot=1.0):
    """(loss, yhat, d_hidden, d_W) for the GNB sampled-label path, drawing
    the SAME counter-based Gumbel noise as the kernel (full [N, V] grid —
    tests only)."""
    from .fused_ce import hash_gumbel, seed_from_key
    s, dcap, h2 = _lm_logits_ref(hidden, w, vocab_size=vocab_size,
                                 transpose_w=transpose_w, softcap=softcap)
    N, V = s.shape
    rows = jnp.arange(N, dtype=jnp.int32)[:, None]
    cols = jnp.arange(V, dtype=jnp.int32)[None, :]
    g = hash_gumbel(seed_from_key(rng), rows, cols)
    z = jnp.where(cols < vocab_size, s + g, -1e30)
    yhat = jnp.argmax(z, axis=-1).astype(jnp.int32)
    rs, _ = _rowscale_ref(hidden.shape[:-1], mask)
    loss, dh, dw = _lm_grads_from_labels(h2, w, s, dcap, yhat, rs,
                                         transpose_w=transpose_w, cot=cot)
    return loss, yhat.reshape(hidden.shape[:-1]), \
        dh.reshape(hidden.shape), dw


def adamw_fused_ref(p, m, v, g, *, lr, beta1, beta2, eps, weight_decay,
                    step):
    """Fused AdamW step (baseline gets the same kernel treatment so the
    wall-clock overhead comparison in Table 1 stays apples-to-apples)."""
    f32 = jnp.float32
    m_new = beta1 * m.astype(f32) + (1.0 - beta1) * g.astype(f32)
    v_new = beta2 * v.astype(f32) + (1.0 - beta2) * jnp.square(g.astype(f32))
    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    p_new = p.astype(f32) * (1.0 - lr * weight_decay) - lr * u
    return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)


def lion_fused_ref(p, m, g, *, lr, beta1, beta2, weight_decay):
    """Lion step: sign of the (b1) interpolation, momentum EMA'd with b2."""
    f32 = jnp.float32
    g32 = g.astype(f32)
    u = jnp.sign(beta1 * m.astype(f32) + (1.0 - beta1) * g32)
    p_new = p.astype(f32) * (1.0 - lr * weight_decay) - lr * u
    m_new = beta2 * m.astype(f32) + (1.0 - beta2) * g32
    return p_new.astype(p.dtype), m_new.astype(m.dtype)


def signgd_fused_ref(p, m, g, *, lr, beta1, weight_decay):
    """Momentum SignSGD (the paper's 'Clip' ablation)."""
    f32 = jnp.float32
    m_new = beta1 * m.astype(f32) + (1.0 - beta1) * g.astype(f32)
    p_new = p.astype(f32) * (1.0 - lr * weight_decay) - lr * jnp.sign(m_new)
    return p_new.astype(p.dtype), m_new.astype(m.dtype)


def sgd_fused_ref(p, m, g, *, lr, momentum):
    f32 = jnp.float32
    m_new = momentum * m.astype(f32) + g.astype(f32)
    p_new = p.astype(f32) - lr * m_new
    return p_new.astype(p.dtype), m_new.astype(m.dtype)


def adahessian_fused_ref(p, m, v, g, *, lr, beta1, beta2, eps, weight_decay,
                         step):
    """AdaHessian step: Adam-shaped update, v refreshed out-of-band from
    squared Hessian estimates (see hessian_ema_ref(square=True))."""
    f32 = jnp.float32
    m_new = beta1 * m.astype(f32) + (1.0 - beta1) * g.astype(f32)
    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    u = (m_new / bc1) / (jnp.sqrt(v.astype(f32) / bc2) + eps)
    p_new = p.astype(f32) * (1.0 - lr * weight_decay) - lr * u
    return p_new.astype(p.dtype), m_new.astype(m.dtype)
