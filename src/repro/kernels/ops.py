"""Per-pytree wrappers around the fused kernels (test/bench harness).

Each leaf is flattened, zero-padded to the block size, streamed through the
Pallas kernel, and reshaped back.  Padding is benign for every fused op
(p=m=h=g=0 stays 0; clip counts on padding are masked out).

NOTE: the production train step does NOT go through these wrappers — the
per-leaf pad/unpad round-trip here is exactly what the flat-buffer engine
(core/engine.py) eliminates by raveling the whole tree into block-padded
dtype shards once at init.  These remain as the direct per-tensor harness
for kernel unit tests (tests/test_kernels.py) and micro-benchmarks.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import ref
from .sophia_update import (BLOCK, adamw_fused_block, hessian_ema_block,
                            sophia_fused_block)

PyTree = Any


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _flat_pad(x, block):
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def sophia_fused_apply(params: PyTree, m: PyTree, h: PyTree, grads: PyTree,
                       *, lr, beta1: float, gamma: float, eps: float,
                       weight_decay: float, clip_threshold: float = 1.0,
                       block: int = BLOCK, interpret: bool | None = None):
    """Fused Algorithm-3 apply over a whole parameter tree.

    Returns (new_params, new_m, clip_fraction)."""
    interpret = _interpret_default() if interpret is None else interpret
    lr = jnp.asarray(lr, jnp.float32)
    total = 0
    clipped = []

    def one(p, m_, h_, g_):
        nonlocal total
        flat_p, pad = _flat_pad(p, block)
        flat_m, _ = _flat_pad(m_, block)
        flat_h, _ = _flat_pad(h_, block)
        flat_g, _ = _flat_pad(g_, block)
        np_, nm, nclip = sophia_fused_block(
            flat_p, flat_m, flat_h, flat_g, lr, beta1=beta1, gamma=gamma,
            eps=eps, weight_decay=weight_decay,
            clip_threshold=clip_threshold, block=block, interpret=interpret)
        n = p.size
        total += n
        # padding zeros: raw = 0/eps = 0 -> |raw| < rho -> never counted
        clipped.append(nclip.astype(jnp.float32).sum())
        return (np_[:n].reshape(p.shape).astype(p.dtype),
                nm[:n].reshape(p.shape).astype(m_.dtype))

    out = jax.tree.map(one, params, m, h, grads)
    new_p = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    clip_fraction = (sum(clipped) / total).astype(jnp.float32)
    return new_p, new_m, clip_fraction


def hessian_ema_apply(h: PyTree, est: PyTree, *, beta2: float,
                      scale: float = 1.0, block: int = BLOCK,
                      interpret: bool | None = None) -> PyTree:
    """Fused EMA refresh of the diagonal-Hessian state (line 9)."""
    interpret = _interpret_default() if interpret is None else interpret

    def one(h_, e_):
        flat_h, _ = _flat_pad(h_, block)
        flat_e, _ = _flat_pad(e_, block)
        out = hessian_ema_block(flat_h, flat_e, beta2=beta2, scale=scale,
                                block=block, interpret=interpret)
        return out[:h_.size].reshape(h_.shape).astype(h_.dtype)

    return jax.tree.map(one, h, est)


def adamw_fused_apply(params: PyTree, m: PyTree, v: PyTree, grads: PyTree,
                      *, lr, step, beta1: float, beta2: float, eps: float,
                      weight_decay: float, block: int = BLOCK,
                      interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    lr = jnp.asarray(lr, jnp.float32)
    step = jnp.asarray(step, jnp.float32)

    def one(p, m_, v_, g_):
        fp, _ = _flat_pad(p, block)
        fm, _ = _flat_pad(m_, block)
        fv, _ = _flat_pad(v_, block)
        fg, _ = _flat_pad(g_, block)
        np_, nm, nv = adamw_fused_block(fp, fm, fv, fg, lr, step,
                                        beta1=beta1, beta2=beta2, eps=eps,
                                        weight_decay=weight_decay,
                                        block=block, interpret=interpret)
        n = p.size
        return (np_[:n].reshape(p.shape).astype(p.dtype),
                nm[:n].reshape(p.shape).astype(m_.dtype),
                nv[:n].reshape(p.shape).astype(v_.dtype))

    out = jax.tree.map(one, params, m, v, grads)
    pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), pick(1), pick(2)
