"""Logits-free fused LM cross-entropy Pallas kernels (chunked vocab sweep).

The training hot path used to materialize the full ``[B*T, V]`` logits
tensor in HBM — and the GNB Hessian refresh (Algorithm 2) materialized it
twice (Gumbel-max sampling + an fp32 ``log_softmax`` copy).  At GPT-2-and-up
vocab sizes that buffer dominates the step's memory peak and its ~5 HBM
crossings dominate loss-stage bandwidth.  These kernels stream ``lm_head``
weight *tiles* through VMEM instead, fusing the final projection with an
online-softmax cross-entropy:

  forward   one (rows, vocab-chunks) grid sweep; per row tile the kernel
            keeps running (max, sum-exp, label-logit) in VMEM scratch and
            emits only ``lse`` and ``label_logit`` vectors — ``(N,)`` each.
            The final-*norm* producer can be fused in: the kernel reads
            PRE-norm hidden tiles and applies rms/layer norm in VMEM, so
            the normed (N, D) activation never round-trips HBM.
  backward  ``custom_vjp``: vocab sweeps recompute each logits tile and
            emit ``d_hidden`` and ``d_W`` directly from
            ``softmax - onehot``.  Two schedules:
              * ``split``  — two sweeps (d_hidden chunks-inner with a VMEM
                accumulator; d_W rows-inner with the chunk block resident);
              * ``fused``  — ONE combined sweep computing both, legal
                whenever one grid axis is 1 (the autotuner only emits such
                tilings for it): every output block is then either written
                once or accumulated over *consecutive* grid steps, so no
                block is ever revisited non-consecutively (which Pallas TPU
                pipelining does not guarantee to re-fetch).  Saves one full
                logits recompute (backward 6 -> 4 matmul-sweeps).
            The ``[N, V]`` logits (and the fp32 log-probs copy) never touch
            HBM either way.
  sampling  the same forward sweep optionally draws ``yhat ~
            softmax(logits)`` by online chunked Gumbel-argmax (counter-based
            hash noise, pure function of ``(seed, row, col)``) and records
            the chosen column's raw logit, so the Algorithm-2 GNB refresh
            goes logits-free too: ``nll = lse - logit[yhat]`` with the
            identical backward.
  hvp       ``fused_lm_loss_jvp`` is a ``custom_jvp`` twin of the labeled
            NLL: the primal runs the same Pallas forward, the tangent is a
            checkpointed chunked jnp sweep (linear in the input tangents,
            so JAX's transpose gives a chunked backward for free).  The
            Hutchinson estimator's forward-over-reverse HVP composes with
            it — it cannot cross the ``custom_vjp`` path, which previously
            forced a silent fall back to the chunked loss.

Block sizes: ``block_n``/``block_v`` default to ``None``, which resolves
through the shape-keyed autotuner (``kernels/autotune.py`` — roofline-model
search with optional measured refinement and a persistent cache; the old
hardcoded ``DEFAULT_BN``/``DEFAULT_BV`` survive only as cache-miss seeds).
Explicit block sizes bypass the tuner (kernel unit tests).

Compute convention (matches ``models.layers.unembed``): W is cast to the
hidden dtype, the projection accumulates in fp32
(``preferred_element_type``), softcap (gemma2) applies in fp32, and
``padded_vocab`` columns are masked to ``NEG_INF`` — they contribute
nothing to the CE denominator, are never sampled, and receive exactly zero
gradient.  Tied embeddings pass W as ``(Vp, D)`` (``transpose_w=False``);
untied as ``(D, Vp)`` (``transpose_w=True``) — the BlockSpecs stream the
right tile either way, no host-side transpose.  The fused norm replicates
``models.layers.rms_norm`` / ``layer_norm`` bit-for-bit (fp32 statistics,
cast back to the hidden dtype before the projection).

Validated under ``interpret=True`` against the kernels/ref.py closed-form
oracles (``lm_loss_grads_ref`` / ``lm_loss_sampled_ref``) to <=3e-6 in
tests/test_fused_ce.py; on a real TPU the same pallas_call compiles
natively.
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BN = 256    # rows (B*T positions) per tile (autotuner seed)
DEFAULT_BV = 1024   # vocab columns per chunk (multiple of 128; seed)
NEG_INF = -1e30

_f32 = jnp.float32
_u32 = jnp.uint32

# Trace-time kernel invocation counters (per pallas_call wrapper).  Tests
# use these to assert a path really went through the fused kernels — e.g.
# that the Hutchinson HVP's primal ran the Pallas forward instead of
# silently falling back to the chunked jnp loss.
KERNEL_CALLS = collections.Counter()


def kernel_calls() -> dict:
    return dict(KERNEL_CALLS)


def reset_kernel_calls() -> None:
    KERNEL_CALLS.clear()


# ---------------------------------------------------------------------------
# counter-based Gumbel noise (shared by the kernel and the ref.py oracle)


def _mix32(x):
    """lowbias32-style finalizer: uint32 -> well-mixed uint32."""
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(0x7FEB352D)
    x = x ^ (x >> np.uint32(15))
    x = x * np.uint32(0x846CA68B)
    x = x ^ (x >> np.uint32(16))
    return x


def hash_gumbel(seed, rows, cols):
    """Gumbel(0, 1) noise as a pure function of ``(seed, row, col)``.

    ``seed``: (2,) uint32 (derived from a PRNG key); ``rows``/``cols``:
    broadcastable int32 global indices.  Chunk-shape independent by
    construction, so any vocab chunking of the sweep draws the *same*
    perturbation per (row, column) — online chunked Gumbel-argmax over this
    noise equals the monolithic argmax, hence a categorical draw.
    """
    r = _mix32(rows.astype(_u32) ^ seed[0])
    x = _mix32(r ^ (cols.astype(_u32) * np.uint32(0x9E3779B9)) ^ seed[1])
    u = (x >> np.uint32(8)).astype(_f32) * np.float32(1.0 / (1 << 24))
    u = jnp.clip(u, 1e-7, 1.0 - 1e-7)
    return -jnp.log(-jnp.log(u))


def seed_from_key(rng) -> jnp.ndarray:
    """(2,) uint32 noise seed derived from a JAX PRNG key."""
    return jax.random.bits(rng, (2,), _u32)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# the online-reduction rules, shared verbatim by the Pallas kernels, the
# chunked jnp loss (models/loss.py) and the chunked GNB reference
# (core/estimators.chunked_sampled_stats) — ONE copy of the trickiest
# numerics (running-max rescale, masked-chunk guard, strict-> tie handling)


def online_lse_step(m, l, s, valid=None):
    """One vocab chunk of a running log-sum-exp.

    m, l: (rows,) running max / rescaled sum; s: (rows, chunk) fp32 logits;
    ``valid`` masks columns (without it an all-masked chunk would add
    exp(0)=1 per column while m sits at the -inf sentinel).  Returns
    (m_new, l_new); the final lse is ``m + log(l)``."""
    m_new = jnp.maximum(m, s.max(-1))
    e = jnp.exp(s - m_new[:, None])
    if valid is not None:
        e = jnp.where(valid, e, 0.0)
    return m_new, l * jnp.exp(m - m_new) + e.sum(-1)


def online_argmax_step(best, s, z, c0):
    """One vocab chunk of a running Gumbel-argmax.

    best = (zm, zi, zl): running perturbed max, its global column index,
    and the RAW logit at that column; s/z: (rows, chunk) raw / perturbed
    logits; c0: the chunk's first global column.  Strict ``>`` keeps the
    earliest index on ties and argmax picks the first within the chunk, so
    any chunking reproduces the monolithic first-argmax exactly."""
    zm, zi, zl = best
    zmax = z.max(-1)
    zarg = jnp.argmax(z, axis=-1)
    hit = jax.lax.broadcasted_iota(jnp.int32, z.shape, z.ndim - 1) \
        == zarg[..., None]
    chunk_logit = jnp.where(hit, s, 0.0).sum(-1)
    upd = zmax > zm
    return (jnp.where(upd, zmax, zm),
            jnp.where(upd, c0 + zarg, zi),
            jnp.where(upd, chunk_logit, zl))


def vocab_chunk(v: int, want: int, quantum: int = 1) -> int:
    """Largest multiple of ``quantum`` <= want dividing ``v`` (static)."""
    b = max(quantum, min(want, v))
    b -= b % quantum
    while b >= quantum:
        if v % b == 0:
            return b
        b -= quantum
    return quantum


def rowscale(n_rows: int, mask):
    """(per-row scale, n_valid): the masked-mean weights ``mask/Σmask``
    flattened to (n_rows,), or uniform 1/N when unmasked.  ``n_valid`` is
    the GNB batch factor B."""
    if mask is None:
        return jnp.full((n_rows,), 1.0 / n_rows, _f32), \
            jnp.asarray(float(n_rows), _f32)
    m = mask.reshape(-1).astype(_f32)
    n_valid = jnp.maximum(m.sum(), 1.0)
    return m / n_valid, n_valid


# ---------------------------------------------------------------------------
# shared tile math


def apply_norm(x, normp, norm, eps):
    """The fused final-norm producer, bit-for-bit the models.layers
    convention: fp32 statistics over the last axis, cast back to ``x``'s
    dtype.  ``normp`` is the packed (2, D) fp32 [scale; bias] pair; rms
    ignores the bias row and uses the (1 + scale) parameterization, ln uses
    ``scale * xhat + bias``.  Plain jnp so the SAME function runs inside
    the Pallas kernels (on VMEM tiles) and as the differentiable host-side
    twin whose ``jax.vjp`` produces the d_x / d_scale / d_bias cotangents
    in the custom_vjp backward."""
    if norm is None:
        return x
    x32 = x.astype(_f32)
    scale = normp[0]
    if norm == "ln":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        out = (x32 - mu) * jax.lax.rsqrt(var + eps) * scale + normp[1]
    else:
        assert norm == "rms", norm
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale)
    return out.astype(x.dtype)


def _tile_logits(h, w, transpose_w, softcap):
    """One logits tile in the unembed convention: W cast to the hidden
    dtype, fp32 accumulation, softcap in fp32.  Returns (z, dcap) with
    ``dcap`` the softcap derivative factor (None when uncapped)."""
    wc = w.astype(h.dtype)
    if transpose_w:                       # w tile (D, bv)
        raw = jnp.dot(h, wc, preferred_element_type=_f32)
    else:                                 # w tile (bv, D)
        raw = jnp.dot(h, wc.T, preferred_element_type=_f32)
    if softcap is not None:
        t = jnp.tanh(raw / softcap)
        return softcap * t, 1.0 - t * t
    return raw, None


def _tile_cols(j, bn, bv):
    return j * bv + jax.lax.broadcasted_iota(jnp.int32, (bn, bv), 1)


def _label_logit_tile(s, lab, j, bn, bv, interpret):
    """Per-row logit at the label column, 0 for rows whose label falls
    outside this chunk.  Interpret mode uses a (bn,)-sized gather — on the
    CPU interpreter the (bn, bv) iota/compare/where dance costs real time
    at large tiles; TPU keeps the vectorized compare (lane-crossing
    gathers don't lower well in Mosaic)."""
    if interpret:
        idx = lab - j * bv
        ok = (idx >= 0) & (idx < bv)
        got = jnp.take_along_axis(s, jnp.clip(idx, 0, bv - 1)[:, None],
                                  axis=1)[:, 0]
        return jnp.where(ok, got, 0.0)
    hit = _tile_cols(j, bn, bv) == lab[:, None]
    return jnp.where(hit, s, 0.0).sum(-1)


# ---------------------------------------------------------------------------
# forward kernels


def _masked_tile(z, j, bn, bv, vocab, vp):
    """(s, valid): logits with padded-vocab columns forced to NEG_INF.
    Static no-op when the vocab needs no padding (vocab == vp) — the mask
    materializes two (bn, bv) temporaries, real money in interpret mode."""
    if vocab == vp:
        return z, None
    cols = _tile_cols(j, bn, bv)
    valid = cols < vocab
    return jnp.where(valid, z, NEG_INF), valid


def _ce_fwd_kernel(np_ref, lab_ref, h_ref, w_ref, lse_out, ll_out,
                   m_scr, l_scr, ll_scr, *,
                   bn, bv, vocab, vp, n_v, transpose_w, softcap, norm, eps,
                   interpret):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        ll_scr[...] = jnp.zeros_like(ll_scr[...])

    hn = apply_norm(h_ref[...], np_ref[...], norm, eps)
    z, _ = _tile_logits(hn, w_ref[...], transpose_w, softcap)
    s, valid = _masked_tile(z, j, bn, bv, vocab, vp)

    m_new, l_new = online_lse_step(m_scr[...][:, 0], l_scr[...][:, 0], s,
                                   valid)
    m_scr[...] = m_new[:, None]
    l_scr[...] = l_new[:, None]

    ll_scr[...] += _label_logit_tile(s, lab_ref[...], j, bn, bv,
                                     interpret)[:, None]

    @pl.when(j == n_v - 1)
    def _flush():
        lse_out[...] = (m_scr[...]
                        + jnp.log(jnp.maximum(l_scr[...], 1e-37)))[:, 0]
        ll_out[...] = ll_scr[...][:, 0]


def _ce_fwd_sample_kernel(np_ref, seed_ref, h_ref, w_ref, lse_out, ll_out,
                          yhat_out, m_scr, l_scr, zm_scr, zi_scr, zl_scr, *,
                          bn, bv, vocab, vp, n_v, transpose_w, softcap, norm,
                          eps):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        zm_scr[...] = jnp.full_like(zm_scr[...], NEG_INF)
        zi_scr[...] = jnp.zeros_like(zi_scr[...])
        zl_scr[...] = jnp.zeros_like(zl_scr[...])

    hn = apply_norm(h_ref[...], np_ref[...], norm, eps)
    z, _ = _tile_logits(hn, w_ref[...], transpose_w, softcap)
    cols = _tile_cols(j, bn, bv)
    valid = None if vocab == vp else cols < vocab
    s = z if valid is None else jnp.where(valid, z, NEG_INF)

    m_new, l_new = online_lse_step(m_scr[...][:, 0], l_scr[...][:, 0], s,
                                   valid)
    m_scr[...] = m_new[:, None]
    l_scr[...] = l_new[:, None]

    # online Gumbel-argmax: perturb this chunk, keep the running best,
    # remembering the winning column's RAW logit so the sampled-label NLL
    # needs no second pass
    rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, bv), 0)
    g = hash_gumbel(seed_ref[...], rows, cols)
    zp = s + g if valid is None else jnp.where(valid, s + g, NEG_INF)
    zm, zi, zl = online_argmax_step(
        (zm_scr[...][:, 0], zi_scr[...][:, 0], zl_scr[...][:, 0]),
        s, zp, j * bv)
    zm_scr[...] = zm[:, None]
    zi_scr[...] = zi[:, None]
    zl_scr[...] = zl[:, None]

    @pl.when(j == n_v - 1)
    def _flush():
        lse_out[...] = (m_scr[...]
                        + jnp.log(jnp.maximum(l_scr[...], 1e-37)))[:, 0]
        ll_out[...] = zl_scr[...][:, 0]
        yhat_out[...] = zi_scr[...][:, 0]


# ---------------------------------------------------------------------------
# backward kernels (shared by the labeled and sampled paths)


def _dlogits_tile(hn, w, lab, rs, lse, j, *, bn, bv, vocab, vp, transpose_w,
                  softcap, interpret):
    """Recompute one logits tile (from the already-normed hidden tile) and
    return d_logits_raw (bn, bv) fp32: ``(softmax - onehot(lab)) *
    rowscale``, softcap chain rule applied, exactly zero on padded columns
    (p = 0 and onehot = 0 there).  Interpret mode subtracts the onehot
    term with a (bn,)-sized scatter-add instead of materializing the
    (bn, bv) compare (cheap on CPU, not Mosaic-lowerable on TPU)."""
    z, dcap = _tile_logits(hn, w, transpose_w, softcap)
    s, _ = _masked_tile(z, j, bn, bv, vocab, vp)
    p = jnp.exp(s - lse[:, None])
    if interpret:
        d = p * rs[:, None]
        idx = lab - j * bv
        ok = (idx >= 0) & (idx < bv)
        d = d.at[jnp.arange(bn), jnp.clip(idx, 0, bv - 1)].add(
            jnp.where(ok, -rs, 0.0))
    else:
        onehot = (_tile_cols(j, bn, bv) == lab[:, None]).astype(_f32)
        d = (p - onehot) * rs[:, None]
    if dcap is not None:
        d = d * dcap
    return d


def _ce_bwd_dh_kernel(np_ref, lab_ref, rs_ref, lse_ref, h_ref, w_ref, dh_out,
                      acc_scr, *, bn, bv, vocab, vp, n_v, transpose_w,
                      softcap, norm, eps, interpret):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    hn = apply_norm(h_ref[...], np_ref[...], norm, eps)
    d = _dlogits_tile(hn, w_ref[...], lab_ref[...], rs_ref[...],
                      lse_ref[...], j, bn=bn, bv=bv, vocab=vocab, vp=vp,
                      transpose_w=transpose_w, softcap=softcap,
                      interpret=interpret)
    w32 = w_ref[...].astype(_f32)
    if transpose_w:                       # w tile (D, bv): dh = d @ w^T
        acc_scr[...] += jnp.dot(d, w32.T, preferred_element_type=_f32)
    else:                                 # w tile (bv, D): dh = d @ w
        acc_scr[...] += jnp.dot(d, w32, preferred_element_type=_f32)

    @pl.when(j == n_v - 1)
    def _flush():
        dh_out[...] = acc_scr[...].astype(dh_out.dtype)


def _ce_bwd_dw_kernel(np_ref, lab_ref, rs_ref, lse_ref, h_ref, w_ref, dw_out,
                      acc_scr, *, bn, bv, vocab, vp, n_r, transpose_w,
                      softcap, norm, eps, interpret):
    # grid (chunks, rows): the dW block for chunk j accumulates across the
    # inner row sweep in an fp32 VMEM scratch (accumulating in the output
    # dtype would round the partial sum per row tile — per-mille error for
    # bf16 weights at real tile counts) and rounds ONCE at the flush.
    j = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    hn = apply_norm(h_ref[...], np_ref[...], norm, eps)
    d = _dlogits_tile(hn, w_ref[...], lab_ref[...], rs_ref[...],
                      lse_ref[...], j, bn=bn, bv=bv, vocab=vocab, vp=vp,
                      transpose_w=transpose_w, softcap=softcap,
                      interpret=interpret)
    h32 = hn.astype(_f32)
    if transpose_w:                       # dW tile (D, bv) = h^T @ d
        acc_scr[...] += jnp.dot(h32.T, d, preferred_element_type=_f32)
    else:                                 # dW tile (bv, D) = d^T @ h
        acc_scr[...] += jnp.dot(d.T, h32, preferred_element_type=_f32)

    @pl.when(i == n_r - 1)
    def _flush():
        dw_out[...] = acc_scr[...].astype(dw_out.dtype)


def _ce_bwd_fused_kernel(np_ref, lab_ref, rs_ref, lse_ref, h_ref, w_ref,
                         dh_out, dw_out, dh_scr, dw_scr, *,
                         bn, bv, vocab, vp, n_r, n_v, transpose_w, softcap,
                         norm, eps, interpret):
    """Combined d_hidden + d_W in ONE sweep: the logits tile is recomputed
    once per grid step and feeds both products (the split schedule
    recomputes it twice).  Requires min(n_r, n_v) == 1 — then the dh scratch
    is either flushed per step (n_v == 1: each row block's sweep is a single
    step) or accumulated over the whole inner-j sweep of the only row block
    (n_r == 1), and symmetrically for dW, so neither output block is ever
    revisited after a different block was written (Pallas TPU pipelining
    does not re-fetch non-consecutively revisited output blocks)."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    hn = apply_norm(h_ref[...], np_ref[...], norm, eps)
    d = _dlogits_tile(hn, w_ref[...], lab_ref[...], rs_ref[...],
                      lse_ref[...], j, bn=bn, bv=bv, vocab=vocab, vp=vp,
                      transpose_w=transpose_w, softcap=softcap,
                      interpret=interpret)
    w32 = w_ref[...].astype(_f32)
    h32 = hn.astype(_f32)

    @pl.when(j == 0)
    def _init_dh():
        dh_scr[...] = jnp.zeros_like(dh_scr[...])

    @pl.when(i == 0)
    def _init_dw():
        dw_scr[...] = jnp.zeros_like(dw_scr[...])

    if transpose_w:                       # w tile (D, bv)
        dh_scr[...] += jnp.dot(d, w32.T, preferred_element_type=_f32)
        dw_scr[...] += jnp.dot(h32.T, d, preferred_element_type=_f32)
    else:                                 # w tile (bv, D)
        dh_scr[...] += jnp.dot(d, w32, preferred_element_type=_f32)
        dw_scr[...] += jnp.dot(d.T, h32, preferred_element_type=_f32)

    @pl.when(j == n_v - 1)
    def _flush_dh():
        dh_out[...] = dh_scr[...].astype(dh_out.dtype)

    @pl.when(i == n_r - 1)
    def _flush_dw():
        dw_out[...] = dw_scr[...].astype(dw_out.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers


def _specs(bn, bv, D, transpose_w):
    h_spec = pl.BlockSpec((bn, D), lambda i, j: (i, 0))
    w_spec = (pl.BlockSpec((D, bv), lambda i, j: (0, j)) if transpose_w
              else pl.BlockSpec((bv, D), lambda i, j: (j, 0)))
    vec_spec = pl.BlockSpec((bn,), lambda i, j: (i,))
    np_spec = pl.BlockSpec((2, D), lambda i, j: (0, 0))
    return h_spec, w_spec, vec_spec, np_spec


def _vp_of(w, transpose_w):
    return w.shape[1] if transpose_w else w.shape[0]


def _no_normp(D, normp=None):
    return jnp.zeros((2, D), _f32) if normp is None else normp


def _ce_forward(h2, w, normp, labels, *, vocab, transpose_w, softcap, norm,
                eps, bn, bv, interpret):
    KERNEL_CALLS["fwd"] += 1
    N, D = h2.shape
    n_r, n_v = N // bn, _vp_of(w, transpose_w) // bv
    h_spec, w_spec, vec_spec, np_spec = _specs(bn, bv, D, transpose_w)
    kern = functools.partial(_ce_fwd_kernel, bn=bn, bv=bv, vocab=vocab,
                             vp=n_v * bv, n_v=n_v, transpose_w=transpose_w,
                             softcap=softcap, norm=norm, eps=eps,
                             interpret=interpret)
    return pl.pallas_call(
        kern,
        grid=(n_r, n_v),
        in_specs=[np_spec, vec_spec, h_spec, w_spec],
        out_specs=[vec_spec, vec_spec],
        out_shape=[jax.ShapeDtypeStruct((N,), _f32),
                   jax.ShapeDtypeStruct((N,), _f32)],
        scratch_shapes=[pltpu.VMEM((bn, 1), _f32)] * 3,
        interpret=interpret,
    )(_no_normp(D, normp), labels, h2, w)


def _ce_forward_sampled(h2, w, normp, seed, *, vocab, transpose_w, softcap,
                        norm, eps, bn, bv, interpret):
    KERNEL_CALLS["fwd_sample"] += 1
    N, D = h2.shape
    n_r, n_v = N // bn, _vp_of(w, transpose_w) // bv
    h_spec, w_spec, vec_spec, np_spec = _specs(bn, bv, D, transpose_w)
    seed_spec = pl.BlockSpec((2,), lambda i, j: (0,))
    kern = functools.partial(_ce_fwd_sample_kernel, bn=bn, bv=bv,
                             vocab=vocab, vp=n_v * bv, n_v=n_v,
                             transpose_w=transpose_w, softcap=softcap,
                             norm=norm, eps=eps)
    return pl.pallas_call(
        kern,
        grid=(n_r, n_v),
        in_specs=[np_spec, seed_spec, h_spec, w_spec],
        out_specs=[vec_spec, vec_spec, vec_spec],
        out_shape=[jax.ShapeDtypeStruct((N,), _f32),
                   jax.ShapeDtypeStruct((N,), _f32),
                   jax.ShapeDtypeStruct((N,), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bn, 1), _f32),
                        pltpu.VMEM((bn, 1), _f32),
                        pltpu.VMEM((bn, 1), _f32),
                        pltpu.VMEM((bn, 1), jnp.int32),
                        pltpu.VMEM((bn, 1), _f32)],
        interpret=interpret,
    )(_no_normp(D, normp), seed, h2, w)


def _ce_backward(h2, w, normp, labels, rs, lse, *, vocab, transpose_w,
                 softcap, norm, eps, bn, bv, schedule, interpret):
    """(d_hidden_normed, d_W) via vocab re-sweeps (no [N, V] buffer).

    With a fused norm the returned d_hidden is the cotangent w.r.t. the
    NORMED hidden (fp32); the caller pulls it back through the norm with
    ``jax.vjp(apply_norm, ...)``."""
    N, D = h2.shape
    Vp = _vp_of(w, transpose_w)
    n_r, n_v = N // bn, Vp // bv
    dh_dtype = _f32 if norm is not None else h2.dtype
    h_spec, w_spec, vec_spec, np_spec = _specs(bn, bv, D, transpose_w)
    normp = _no_normp(D, normp)
    dw_scr = pltpu.VMEM((D, bv) if transpose_w else (bv, D), _f32)

    if schedule == "fused":
        assert n_r == 1 or n_v == 1, (n_r, n_v)
        KERNEL_CALLS["bwd_fused"] += 1
        kern = functools.partial(
            _ce_bwd_fused_kernel, bn=bn, bv=bv, vocab=vocab, vp=Vp, n_r=n_r,
            n_v=n_v, transpose_w=transpose_w, softcap=softcap, norm=norm,
            eps=eps, interpret=interpret)
        dh, dw = pl.pallas_call(
            kern,
            grid=(n_r, n_v),
            in_specs=[np_spec, vec_spec, vec_spec, vec_spec, h_spec, w_spec],
            out_specs=[pl.BlockSpec((bn, D), lambda i, j: (i, 0)), w_spec],
            out_shape=[jax.ShapeDtypeStruct((N, D), dh_dtype),
                       jax.ShapeDtypeStruct(w.shape, w.dtype)],
            scratch_shapes=[pltpu.VMEM((bn, D), _f32), dw_scr],
            interpret=interpret,
        )(normp, labels, rs, lse, h2, w)
        return dh, dw

    assert schedule == "split", schedule
    KERNEL_CALLS["bwd_split"] += 1
    kern_h = functools.partial(_ce_bwd_dh_kernel, bn=bn, bv=bv, vocab=vocab,
                               vp=Vp, n_v=n_v, transpose_w=transpose_w,
                               softcap=softcap, norm=norm, eps=eps,
                               interpret=interpret)
    dh = pl.pallas_call(
        kern_h,
        grid=(n_r, n_v),
        in_specs=[np_spec, vec_spec, vec_spec, vec_spec, h_spec, w_spec],
        out_specs=pl.BlockSpec((bn, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), dh_dtype),
        scratch_shapes=[pltpu.VMEM((bn, D), _f32)],
        interpret=interpret,
    )(normp, labels, rs, lse, h2, w)

    # rows innermost so each dW chunk block accumulates while resident
    hT_spec = pl.BlockSpec((bn, D), lambda j, i: (i, 0))
    wT_spec = (pl.BlockSpec((D, bv), lambda j, i: (0, j)) if transpose_w
               else pl.BlockSpec((bv, D), lambda j, i: (j, 0)))
    vT_spec = pl.BlockSpec((bn,), lambda j, i: (i,))
    npT_spec = pl.BlockSpec((2, D), lambda j, i: (0, 0))
    kern_w = functools.partial(_ce_bwd_dw_kernel, bn=bn, bv=bv, vocab=vocab,
                               vp=Vp, n_r=n_r, transpose_w=transpose_w,
                               softcap=softcap, norm=norm, eps=eps,
                               interpret=interpret)
    dw = pl.pallas_call(
        kern_w,
        grid=(n_v, n_r),
        in_specs=[npT_spec, vT_spec, vT_spec, vT_spec, hT_spec, wT_spec],
        out_specs=wT_spec,
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        scratch_shapes=[dw_scr],
        interpret=interpret,
    )(normp, labels, rs, lse, h2, w)
    return dh, dw


# ---------------------------------------------------------------------------
# custom_vjp plumbing


def _float0(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


_NONDIFF = (5, 6, 7, 8, 9, 10, 11, 12, 13)
#           vocab, transpose_w, softcap, norm, eps, bn, bv, schedule,
#           interpret


@functools.partial(jax.custom_vjp, nondiff_argnums=_NONDIFF)
def _fused_nll(h2, w, normp, labels, rowscale, vocab, transpose_w, softcap,
               norm, eps, bn, bv, schedule, interpret):
    """sum(rowscale * nll) with labels fixed; logits never materialize."""
    loss, _ = _fused_nll_fwd(h2, w, normp, labels, rowscale, vocab,
                             transpose_w, softcap, norm, eps, bn, bv,
                             schedule, interpret)
    return loss


def _fused_nll_fwd(h2, w, normp, labels, rowscale, vocab, transpose_w,
                   softcap, norm, eps, bn, bv, schedule, interpret):
    lse, ll = _ce_forward(h2, w, normp, labels, vocab=vocab,
                          transpose_w=transpose_w, softcap=softcap,
                          norm=norm, eps=eps, bn=bn, bv=bv,
                          interpret=interpret)
    loss = jnp.sum(rowscale * (lse - ll))
    return loss, (h2, w, normp, labels, rowscale, lse, ll)


def _norm_pullback(h2, normp, norm, eps, dhn):
    """Pull the kernel's d(normed hidden) back through the norm producer
    with the differentiable twin of the in-kernel math (exact: same fp32
    statistics, same cast)."""
    if norm is None:
        return dhn.astype(h2.dtype), jnp.zeros_like(normp)
    _, pull = jax.vjp(lambda x, p: apply_norm(x, p, norm, eps).astype(_f32),
                      h2, normp)
    return pull(dhn)


def _fused_nll_bwd(vocab, transpose_w, softcap, norm, eps, bn, bv, schedule,
                   interpret, res, g):
    h2, w, normp, labels, rowscale, lse, ll = res
    rs = (rowscale * g).astype(_f32)
    dhn, dw = _ce_backward(h2, w, normp, labels, rs, lse, vocab=vocab,
                           transpose_w=transpose_w, softcap=softcap,
                           norm=norm, eps=eps, bn=bn, bv=bv,
                           schedule=schedule, interpret=interpret)
    dh, dnormp = _norm_pullback(h2, normp, norm, eps, dhn)
    return dh, dw, dnormp, _float0(labels), (lse - ll) * g


_fused_nll.defvjp(_fused_nll_fwd, _fused_nll_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=_NONDIFF)
def _fused_sampled_nll(h2, w, normp, seed, rowscale, vocab, transpose_w,
                       softcap, norm, eps, bn, bv, schedule, interpret):
    """sum(rowscale * nll) against in-sweep sampled labels (GNB path)."""
    loss, _ = _fused_sampled_nll_fwd(h2, w, normp, seed, rowscale, vocab,
                                     transpose_w, softcap, norm, eps, bn, bv,
                                     schedule, interpret)
    return loss


def _fused_sampled_nll_fwd(h2, w, normp, seed, rowscale, vocab, transpose_w,
                           softcap, norm, eps, bn, bv, schedule, interpret):
    lse, ll, yhat = _ce_forward_sampled(
        h2, w, normp, seed, vocab=vocab, transpose_w=transpose_w,
        softcap=softcap, norm=norm, eps=eps, bn=bn, bv=bv,
        interpret=interpret)
    loss = jnp.sum(rowscale * (lse - ll))
    return loss, (h2, w, normp, seed, yhat, rowscale, lse, ll)


def _fused_sampled_nll_bwd(vocab, transpose_w, softcap, norm, eps, bn, bv,
                           schedule, interpret, res, g):
    h2, w, normp, seed, yhat, rowscale, lse, ll = res
    rs = (rowscale * g).astype(_f32)
    dhn, dw = _ce_backward(h2, w, normp, yhat, rs, lse, vocab=vocab,
                           transpose_w=transpose_w, softcap=softcap,
                           norm=norm, eps=eps, bn=bn, bv=bv,
                           schedule=schedule, interpret=interpret)
    dh, dnormp = _norm_pullback(h2, normp, norm, eps, dhn)
    return dh, dw, dnormp, _float0(seed), (lse - ll) * g


_fused_sampled_nll.defvjp(_fused_sampled_nll_fwd, _fused_sampled_nll_bwd)


# ---------------------------------------------------------------------------
# custom_jvp twin: the Hutchinson HVP path
#
# ``jax.jvp(jax.grad(f))`` cannot cross a custom_vjp (no JVP rule for the
# residual application), and the Pallas backward kernels can never sit
# inside an HVP anyway (forward-mode would have to differentiate them).
# This twin keeps the Pallas forward as the primal and defines the tangent
# as ONE checkpointed chunked jnp sweep that also recomputes the
# (lse, label-logit) coefficients online — linear in (dh, dw, drs), so
# JAX's transpose machinery derives a chunked jnp backward, and because the
# rule is built from differentiable jnp (plus a recursive primal self-call
# that re-enters this boundary), it composes to arbitrary order.


@functools.partial(jax.custom_jvp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _fused_nll_jvp(h2, w, labels, rowscale, vocab, transpose_w, softcap,
                   bn, bv, interpret):
    lse, ll = _ce_forward(h2, w, None, labels, vocab=vocab,
                          transpose_w=transpose_w, softcap=softcap,
                          norm=None, eps=0.0, bn=bn, bv=bv,
                          interpret=interpret)
    return jnp.sum(rowscale * (lse - ll))


def _chunk_z(h2, w, dh2, dw, c, bvt, *, transpose_w, softcap, vocab):
    """One vocab chunk's masked logits ``s`` and (optionally) their
    tangent ``ds`` in the unembed convention.  Pass dh2=dw=None for the
    primal-only variant."""
    cdt = h2.dtype
    axis = 1 if transpose_w else 0
    wc = jax.lax.dynamic_slice_in_dim(w, c * bvt, bvt, axis=axis)
    wc = wc.astype(cdt) if transpose_w else wc.astype(cdt).T
    raw = jnp.dot(h2, wc, preferred_element_type=_f32)
    draw = None
    if dh2 is not None:
        dwc = jax.lax.dynamic_slice_in_dim(dw, c * bvt, bvt, axis=axis)
        dwc = dwc.astype(cdt) if transpose_w else dwc.astype(cdt).T
        draw = (jnp.dot(dh2, wc, preferred_element_type=_f32)
                + jnp.dot(h2, dwc, preferred_element_type=_f32))
    if softcap is not None:
        t = jnp.tanh(raw / softcap)
        z = softcap * t
        dz = None if draw is None else (1.0 - t * t) * draw
    else:
        z, dz = raw, draw
    cols = c * bvt + jnp.arange(bvt, dtype=jnp.int32)[None, :]
    valid = cols < vocab
    s = jnp.where(valid, z, NEG_INF)
    return s, dz, cols


@_fused_nll_jvp.defjvp
def _fused_nll_jvp_rule(vocab, transpose_w, softcap, bn, bv, interpret,
                        primals, tangents):
    h2, w, labels, rowscale = primals
    dh2, dw, _dlab, drs = tangents
    KERNEL_CALLS["jvp_rule"] += 1
    # primal through the custom_jvp boundary itself: at higher orders the
    # rule re-enters here instead of hitting a bare (non-differentiable)
    # pallas_call
    loss = _fused_nll_jvp(h2, w, labels, rowscale, vocab, transpose_w,
                          softcap, bn, bv, interpret)

    # Two chunked jnp sweeps.  Sweep A (a checkpointed scan, primal-only)
    # recomputes the online (lse, label-logit) coefficients; sweep B is
    # LINEAR in (dh2, dw) and accumulates the tangent reductions
    # dlse = sum_c p_c . dz_c (p = exp(s - lse)) and d(label-logit).
    # Splitting matters: mixing primal and tangent work in one scan leaves
    # the scan untransposable (lax.scan partial-eval cannot separate the
    # linear part when tangents enter as body constants — the transpose
    # asserts on undefined-primal residuals), while this layout transposes
    # into the standard chunked CE backward and stays jvp-able for higher
    # orders.  Sweep B must also be an UNROLLED Python loop rather than a
    # scan, for the same constants reason; its chunk count is small
    # (Vp / 2048) and under jax.grad its per-chunk softmax residuals live
    # only on the Hutchinson sub-batch (hess_subbatch rows), never on the
    # training batch, which keeps the custom_vjp kernels.
    N, D = h2.shape
    Vp = _vp_of(w, transpose_w)
    bvt = vocab_chunk(Vp, 2048, 128)
    n_c = Vp // bvt
    lab = labels.reshape(-1)
    dh2 = dh2.astype(h2.dtype)

    def body_primal(carry, c):
        m, l, ll = carry
        s, _, cols = _chunk_z(h2, w, None, None, c, bvt,
                              transpose_w=transpose_w, softcap=softcap,
                              vocab=vocab)
        m_new, l_new = online_lse_step(m, l, s, cols < vocab)
        ll = ll + jnp.where(cols == lab[:, None], s, 0.0).sum(-1)
        return (m_new, l_new, ll), None

    init = (jnp.full((N,), NEG_INF, _f32), jnp.zeros((N,), _f32),
            jnp.zeros((N,), _f32))
    (m, l, ll), _ = jax.lax.scan(jax.checkpoint(body_primal), init,
                                 jnp.arange(n_c))
    lse = m + jnp.log(jnp.maximum(l, 1e-37))

    u = jnp.zeros((N,), _f32)
    dll = jnp.zeros((N,), _f32)
    for c in range(n_c):
        s, dz, cols = _chunk_z(h2, w, dh2, dw, c, bvt,
                               transpose_w=transpose_w, softcap=softcap,
                               vocab=vocab)
        p = jnp.exp(s - lse[:, None])        # 0 on padded cols (s=NEG_INF)
        u = u + (p * dz).sum(-1)
        dll = dll + jnp.where(cols == lab[:, None], dz, 0.0).sum(-1)
    dloss = jnp.sum(rowscale * (u - dll)) \
        + jnp.sum(jnp.asarray(drs, _f32) * (lse - ll))
    return loss, dloss


# ---------------------------------------------------------------------------
# public entry points


def _pick_block(n, want, quantum):
    """Largest multiple of ``quantum`` <= want dividing n, else (quantum,
    pad) where pad rounds n up to a quantum multiple."""
    want = max(quantum, min(want, n))
    b = (want // quantum) * quantum
    while b >= quantum:
        if n % b == 0:
            return b, 0
        b -= quantum
    return quantum, (-n) % quantum


def _prep(hidden, labels_or_none, mask, block_n):
    """Flatten leading dims and pad rows to a block multiple (padded rows
    carry rowscale 0, so they contribute nothing to loss or gradients)."""
    D = hidden.shape[-1]
    h2 = hidden.reshape(-1, D)
    N = h2.shape[0]
    rs, n_valid = rowscale(N, mask)
    bn, pad = _pick_block(N, block_n, 8)
    if pad:
        h2 = jnp.pad(h2, ((0, pad), (0, 0)))
        rs = jnp.pad(rs, (0, pad))
    lab = None
    if labels_or_none is not None:
        lab = labels_or_none.reshape(-1).astype(jnp.int32)
        if pad:
            lab = jnp.pad(lab, (0, pad))
    return h2, lab, rs, n_valid, bn


def _pick_bv(Vp, block_v, interpret=False):
    """Vocab chunk for an *explicit* request; interpret mode (CPU CI)
    clamps to the whole padded vocab at small Vp so an over-chunked request
    cannot unroll a pathological number of interpreter grid cells."""
    assert Vp % 128 == 0, f"padded vocab {Vp} not a multiple of 128"
    if interpret and Vp // vocab_chunk(Vp, block_v, 128) > 64:
        return vocab_chunk(Vp, max(block_v, Vp // 64), 128)
    return vocab_chunk(Vp, block_v, 128)


def _resolve_blocks(hidden, Vp, *, transpose_w, softcap, norm, block_n,
                    block_v, schedule, interpret):
    """(bn, bv, schedule): explicit blocks pass through (legacy/unit-test
    path, DEFAULT_BN/BV filling the unset one); both-None routes through
    the shape-keyed autotuner."""
    D = hidden.shape[-1]
    N = 1
    for s in hidden.shape[:-1]:
        N *= s
    n_pad = N + ((-N) % 8)
    if block_n is None and block_v is None:
        from .autotune import get_tuned
        t = get_tuned(n_pad, D, Vp, dtype=hidden.dtype,
                      transpose_w=transpose_w, softcap=softcap, norm=norm,
                      interpret=interpret)
        bn, bv = t.bn, t.bv
        schedule = schedule or t.schedule
    else:
        bn, _ = _pick_block(N, block_n or DEFAULT_BN, 8)
        bv = _pick_bv(Vp, block_v or DEFAULT_BV, interpret)
    n_r, n_v = n_pad // bn, Vp // bv
    if schedule is None:
        schedule = "fused" if (n_r == 1 or n_v == 1) else "split"
    if schedule == "fused" and not (n_r == 1 or n_v == 1):
        schedule = "split"
    return bn, bv, schedule


def _pack_norm(norm_kind, norm_scale, norm_bias, D):
    if norm_kind is None:
        return None, None
    assert norm_kind in ("rms", "ln"), norm_kind
    scale = jnp.asarray(norm_scale, _f32)
    bias = (jnp.zeros((D,), _f32) if norm_bias is None
            else jnp.asarray(norm_bias, _f32))
    return norm_kind, jnp.stack([scale, bias])


def fused_lm_loss(hidden, w, labels, mask=None, *, vocab_size,
                  transpose_w=False, softcap=None, block_n=None,
                  block_v=None, schedule=None, norm_kind=None,
                  norm_scale=None, norm_bias=None, norm_eps=1e-6,
                  interpret=None):
    """Masked-mean LM cross-entropy without materializing logits.

    hidden (..., D); w (Vp, D) tied or (D, Vp) untied (``transpose_w``);
    labels (...) int; mask (...) optional.  Returns ``(loss, n_valid)`` —
    the batch factor the GNB refresh folds into the Hessian-EMA.
    Differentiable in ``hidden``, ``w`` and the norm parameters via the
    fused backward sweeps.  With ``norm_kind`` ("rms"/"ln") ``hidden`` is
    PRE-final-norm and the norm applies inside the kernel (producer
    fusion); block sizes default to the autotuner's pick for this shape.
    """
    softcap = float(softcap) if softcap else None
    interpret = _interpret_default() if interpret is None else interpret
    norm, normp = _pack_norm(norm_kind, norm_scale, norm_bias,
                             hidden.shape[-1])
    bn, bv, schedule = _resolve_blocks(
        hidden, _vp_of(w, transpose_w), transpose_w=bool(transpose_w),
        softcap=softcap, norm=norm, block_n=block_n, block_v=block_v,
        schedule=schedule, interpret=bool(interpret))
    h2, lab, rs, n_valid, bn = _prep(hidden, labels, mask, bn)
    loss = _fused_nll(h2, w, _no_normp(h2.shape[1], normp), lab, rs,
                      int(vocab_size), bool(transpose_w), softcap, norm,
                      float(norm_eps), bn, bv, schedule, bool(interpret))
    return loss, n_valid


def fused_lm_loss_sampled(hidden, w, rng, mask=None, *, vocab_size,
                          transpose_w=False, softcap=None, block_n=None,
                          block_v=None, schedule=None, norm_kind=None,
                          norm_scale=None, norm_bias=None, norm_eps=1e-6,
                          interpret=None):
    """GNB sampled-label CE in one sweep: draws ``yhat ~ softmax(logits)``
    by online chunked Gumbel-argmax *inside* the forward kernel and returns
    the masked-mean NLL against it (``(loss, n_valid)``).  The gradient of
    ``loss`` is Algorithm 2's ``ghat`` contribution through this stage —
    logits-free in both directions."""
    softcap = float(softcap) if softcap else None
    interpret = _interpret_default() if interpret is None else interpret
    norm, normp = _pack_norm(norm_kind, norm_scale, norm_bias,
                             hidden.shape[-1])
    bn, bv, schedule = _resolve_blocks(
        hidden, _vp_of(w, transpose_w), transpose_w=bool(transpose_w),
        softcap=softcap, norm=norm, block_n=block_n, block_v=block_v,
        schedule=schedule, interpret=bool(interpret))
    h2, _, rs, n_valid, bn = _prep(hidden, None, mask, bn)
    seed = seed_from_key(rng)
    loss = _fused_sampled_nll(h2, w, _no_normp(h2.shape[1], normp), seed, rs,
                              int(vocab_size), bool(transpose_w), softcap,
                              norm, float(norm_eps), bn, bv, schedule,
                              bool(interpret))
    return loss, n_valid


def fused_lm_loss_jvp(hidden, w, labels, mask=None, *, vocab_size,
                      transpose_w=False, softcap=None, block_n=None,
                      block_v=None, interpret=None):
    """The labeled NLL through the custom_jvp twin: Pallas forward primal,
    chunked-jnp linear tangent (transposable -> chunked backward), composes
    under ``jax.jvp(jax.grad(.))`` — the Hutchinson estimator's path.  No
    kernel-fused norm here (apply it in jnp first: the tangent must flow
    through the norm, which the chunked rule handles for free)."""
    softcap = float(softcap) if softcap else None
    interpret = _interpret_default() if interpret is None else interpret
    bn, bv, _ = _resolve_blocks(
        hidden, _vp_of(w, transpose_w), transpose_w=bool(transpose_w),
        softcap=softcap, norm=None, block_n=block_n, block_v=block_v,
        schedule="split", interpret=bool(interpret))
    h2, lab, rs, n_valid, bn = _prep(hidden, labels, mask, bn)
    loss = _fused_nll_jvp(h2, w, lab, rs, int(vocab_size),
                          bool(transpose_w), softcap, bn, bv,
                          bool(interpret))
    return loss, n_valid


def fused_lm_sample(hidden, w, rng, *, vocab_size, transpose_w=False,
                    softcap=None, block_n=None, block_v=None,
                    interpret=None):
    """The sampled labels alone (tests / diagnostics): yhat shaped like
    ``hidden[..., 0]``."""
    softcap = float(softcap) if softcap else None
    interpret = _interpret_default() if interpret is None else interpret
    shp = hidden.shape[:-1]
    bn, bv, _ = _resolve_blocks(
        hidden, _vp_of(w, transpose_w), transpose_w=bool(transpose_w),
        softcap=softcap, norm=None, block_n=block_n, block_v=block_v,
        schedule="split", interpret=bool(interpret))
    h2, _, _, _, bn = _prep(hidden, None, None, bn)
    _, _, yhat = _ce_forward_sampled(
        h2, w, None, seed_from_key(rng), vocab=int(vocab_size),
        transpose_w=bool(transpose_w), softcap=softcap, norm=None, eps=0.0,
        bn=bn, bv=bv, interpret=bool(interpret))
    n = 1
    for s in shp:
        n *= s
    return yhat[:n].reshape(shp)


# ---------------------------------------------------------------------------
# analytic HBM traffic (roofline overlay, analogous to
# flash_attention.attention_hbm_bytes_flash)


def lm_loss_hbm_bytes_fused(N, D, V, *, bytes_h=2, bytes_w=4,
                            norm_fused=False) -> int:
    """Fused path: hidden and W stream once per sweep (1 forward + 2
    backward), outputs are d_hidden + d_W + four (N,) vectors.  No term
    scales with N*V.  ``norm_fused`` removes the separate final-norm pass's
    (N, D) write + read — the kernel consumes pre-norm tiles and norms in
    VMEM."""
    h = N * D * bytes_h
    wb = V * D * bytes_w
    vecs = 4 * N * 4
    total = 3 * (h + wb) + h + wb + vecs
    if not norm_fused:
        total += 2 * h  # standalone norm: write normed (N, D), re-read it
    return total


def lm_loss_hbm_bytes_unfused(N, D, V, *, bytes_h=2, bytes_w=4,
                              passes=5) -> int:
    """Unfused XLA path: the fp32 [N, V] logits cross HBM ~``passes``
    times (projection write, log_softmax read/write, NLL gather read,
    backward softmax read) on top of the projection operands."""
    return N * V * 4 * passes + 2 * (N * D * bytes_h + V * D * bytes_w)
