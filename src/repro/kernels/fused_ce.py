"""Logits-free fused LM cross-entropy Pallas kernels (chunked vocab sweep).

The training hot path used to materialize the full ``[B*T, V]`` logits
tensor in HBM — and the GNB Hessian refresh (Algorithm 2) materialized it
twice (Gumbel-max sampling + an fp32 ``log_softmax`` copy).  At GPT-2-and-up
vocab sizes that buffer dominates the step's memory peak and its ~5 HBM
crossings dominate loss-stage bandwidth.  These kernels stream ``lm_head``
weight *tiles* through VMEM instead, fusing the final projection with an
online-softmax cross-entropy:

  forward   one (rows, vocab-chunks) grid sweep; per row tile the kernel
            keeps running (max, sum-exp, label-logit) in VMEM scratch and
            emits only ``lse`` and ``label_logit`` vectors — ``(N,)`` each.
  backward  ``custom_vjp``: two more vocab sweeps recompute each logits
            tile and emit ``d_hidden`` (chunks inner, accumulated in VMEM)
            and ``d_W`` (rows inner, accumulated in the resident output
            block) directly from ``softmax - onehot``.  The ``[N, V]``
            logits (and the fp32 log-probs copy) never touch HBM.
  sampling  the same forward sweep optionally draws ``yhat ~
            softmax(logits)`` by online chunked Gumbel-argmax (counter-based
            hash noise, pure function of ``(seed, row, col)``) and records
            the chosen column's raw logit, so the Algorithm-2 GNB refresh
            goes logits-free too: ``nll = lse - logit[yhat]`` with the
            identical backward.

Compute convention (matches ``models.layers.unembed``): W is cast to the
hidden dtype, the projection accumulates in fp32
(``preferred_element_type``), softcap (gemma2) applies in fp32, and
``padded_vocab`` columns are masked to ``NEG_INF`` — they contribute
nothing to the CE denominator, are never sampled, and receive exactly zero
gradient.  Tied embeddings pass W as ``(Vp, D)`` (``transpose_w=False``);
untied as ``(D, Vp)`` (``transpose_w=True``) — the BlockSpecs stream the
right tile either way, no host-side transpose.

Validated under ``interpret=True`` against the kernels/ref.py closed-form
oracles (``lm_loss_grads_ref`` / ``lm_loss_sampled_ref``) to <=3e-6 in
tests/test_fused_ce.py; on a real TPU the same pallas_call compiles
natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BN = 256    # rows (B*T positions) per tile
DEFAULT_BV = 1024   # vocab columns per chunk (multiple of 128)
NEG_INF = -1e30

_f32 = jnp.float32
_u32 = jnp.uint32


# ---------------------------------------------------------------------------
# counter-based Gumbel noise (shared by the kernel and the ref.py oracle)


def _mix32(x):
    """lowbias32-style finalizer: uint32 -> well-mixed uint32."""
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(0x7FEB352D)
    x = x ^ (x >> np.uint32(15))
    x = x * np.uint32(0x846CA68B)
    x = x ^ (x >> np.uint32(16))
    return x


def hash_gumbel(seed, rows, cols):
    """Gumbel(0, 1) noise as a pure function of ``(seed, row, col)``.

    ``seed``: (2,) uint32 (derived from a PRNG key); ``rows``/``cols``:
    broadcastable int32 global indices.  Chunk-shape independent by
    construction, so any vocab chunking of the sweep draws the *same*
    perturbation per (row, column) — online chunked Gumbel-argmax over this
    noise equals the monolithic argmax, hence a categorical draw.
    """
    r = _mix32(rows.astype(_u32) ^ seed[0])
    x = _mix32(r ^ (cols.astype(_u32) * np.uint32(0x9E3779B9)) ^ seed[1])
    u = (x >> np.uint32(8)).astype(_f32) * np.float32(1.0 / (1 << 24))
    u = jnp.clip(u, 1e-7, 1.0 - 1e-7)
    return -jnp.log(-jnp.log(u))


def seed_from_key(rng) -> jnp.ndarray:
    """(2,) uint32 noise seed derived from a JAX PRNG key."""
    return jax.random.bits(rng, (2,), _u32)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# the online-reduction rules, shared verbatim by the Pallas kernels, the
# chunked jnp loss (models/loss.py) and the chunked GNB reference
# (core/estimators.chunked_sampled_stats) — ONE copy of the trickiest
# numerics (running-max rescale, masked-chunk guard, strict-> tie handling)


def online_lse_step(m, l, s, valid=None):
    """One vocab chunk of a running log-sum-exp.

    m, l: (rows,) running max / rescaled sum; s: (rows, chunk) fp32 logits;
    ``valid`` masks columns (without it an all-masked chunk would add
    exp(0)=1 per column while m sits at the -inf sentinel).  Returns
    (m_new, l_new); the final lse is ``m + log(l)``."""
    m_new = jnp.maximum(m, s.max(-1))
    e = jnp.exp(s - m_new[:, None])
    if valid is not None:
        e = jnp.where(valid, e, 0.0)
    return m_new, l * jnp.exp(m - m_new) + e.sum(-1)


def online_argmax_step(best, s, z, c0):
    """One vocab chunk of a running Gumbel-argmax.

    best = (zm, zi, zl): running perturbed max, its global column index,
    and the RAW logit at that column; s/z: (rows, chunk) raw / perturbed
    logits; c0: the chunk's first global column.  Strict ``>`` keeps the
    earliest index on ties and argmax picks the first within the chunk, so
    any chunking reproduces the monolithic first-argmax exactly."""
    zm, zi, zl = best
    zmax = z.max(-1)
    zarg = jnp.argmax(z, axis=-1)
    hit = jax.lax.broadcasted_iota(jnp.int32, z.shape, z.ndim - 1) \
        == zarg[..., None]
    chunk_logit = jnp.where(hit, s, 0.0).sum(-1)
    upd = zmax > zm
    return (jnp.where(upd, zmax, zm),
            jnp.where(upd, c0 + zarg, zi),
            jnp.where(upd, chunk_logit, zl))


def vocab_chunk(v: int, want: int, quantum: int = 1) -> int:
    """Largest multiple of ``quantum`` <= want dividing ``v`` (static)."""
    b = max(quantum, min(want, v))
    b -= b % quantum
    while b >= quantum:
        if v % b == 0:
            return b
        b -= quantum
    return quantum


def rowscale(n_rows: int, mask):
    """(per-row scale, n_valid): the masked-mean weights ``mask/Σmask``
    flattened to (n_rows,), or uniform 1/N when unmasked.  ``n_valid`` is
    the GNB batch factor B."""
    if mask is None:
        return jnp.full((n_rows,), 1.0 / n_rows, _f32), \
            jnp.asarray(float(n_rows), _f32)
    m = mask.reshape(-1).astype(_f32)
    n_valid = jnp.maximum(m.sum(), 1.0)
    return m / n_valid, n_valid


# ---------------------------------------------------------------------------
# shared tile math


def _tile_logits(h, w, transpose_w, softcap):
    """One logits tile in the unembed convention: W cast to the hidden
    dtype, fp32 accumulation, softcap in fp32.  Returns (z, dcap) with
    ``dcap`` the softcap derivative factor (None when uncapped)."""
    wc = w.astype(h.dtype)
    if transpose_w:                       # w tile (D, bv)
        raw = jnp.dot(h, wc, preferred_element_type=_f32)
    else:                                 # w tile (bv, D)
        raw = jnp.dot(h, wc.T, preferred_element_type=_f32)
    if softcap is not None:
        t = jnp.tanh(raw / softcap)
        return softcap * t, 1.0 - t * t
    return raw, None


def _tile_cols(j, bn, bv):
    return j * bv + jax.lax.broadcasted_iota(jnp.int32, (bn, bv), 1)


# ---------------------------------------------------------------------------
# forward kernels


def _ce_fwd_kernel(lab_ref, h_ref, w_ref, lse_out, ll_out,
                   m_scr, l_scr, ll_scr, *,
                   bn, bv, vocab, n_v, transpose_w, softcap):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        ll_scr[...] = jnp.zeros_like(ll_scr[...])

    z, _ = _tile_logits(h_ref[...], w_ref[...], transpose_w, softcap)
    cols = _tile_cols(j, bn, bv)
    valid = cols < vocab
    s = jnp.where(valid, z, NEG_INF)

    m_new, l_new = online_lse_step(m_scr[...][:, 0], l_scr[...][:, 0], s,
                                   valid)
    m_scr[...] = m_new[:, None]
    l_scr[...] = l_new[:, None]

    hit = cols == lab_ref[...][:, None]
    ll_scr[...] += jnp.where(hit, s, 0.0).sum(-1, keepdims=True)

    @pl.when(j == n_v - 1)
    def _flush():
        lse_out[...] = (m_scr[...]
                        + jnp.log(jnp.maximum(l_scr[...], 1e-37)))[:, 0]
        ll_out[...] = ll_scr[...][:, 0]


def _ce_fwd_sample_kernel(seed_ref, h_ref, w_ref, lse_out, ll_out, yhat_out,
                          m_scr, l_scr, zm_scr, zi_scr, zl_scr, *,
                          bn, bv, vocab, n_v, transpose_w, softcap):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        zm_scr[...] = jnp.full_like(zm_scr[...], NEG_INF)
        zi_scr[...] = jnp.zeros_like(zi_scr[...])
        zl_scr[...] = jnp.zeros_like(zl_scr[...])

    z, _ = _tile_logits(h_ref[...], w_ref[...], transpose_w, softcap)
    cols = _tile_cols(j, bn, bv)
    valid = cols < vocab
    s = jnp.where(valid, z, NEG_INF)

    m_new, l_new = online_lse_step(m_scr[...][:, 0], l_scr[...][:, 0], s,
                                   valid)
    m_scr[...] = m_new[:, None]
    l_scr[...] = l_new[:, None]

    # online Gumbel-argmax: perturb this chunk, keep the running best,
    # remembering the winning column's RAW logit so the sampled-label NLL
    # needs no second pass
    rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, bv), 0)
    g = hash_gumbel(seed_ref[...], rows, cols)
    zp = jnp.where(valid, s + g, NEG_INF)
    zm, zi, zl = online_argmax_step(
        (zm_scr[...][:, 0], zi_scr[...][:, 0], zl_scr[...][:, 0]),
        s, zp, j * bv)
    zm_scr[...] = zm[:, None]
    zi_scr[...] = zi[:, None]
    zl_scr[...] = zl[:, None]

    @pl.when(j == n_v - 1)
    def _flush():
        lse_out[...] = (m_scr[...]
                        + jnp.log(jnp.maximum(l_scr[...], 1e-37)))[:, 0]
        ll_out[...] = zl_scr[...][:, 0]
        yhat_out[...] = zi_scr[...][:, 0]


# ---------------------------------------------------------------------------
# backward kernels (shared by the labeled and sampled paths)


def _dlogits_tile(h, w, lab, rs, lse, j, *, bn, bv, vocab, transpose_w,
                  softcap):
    """Recompute one logits tile and return d_logits_raw (bn, bv) fp32:
    ``(softmax - onehot(lab)) * rowscale``, softcap chain rule applied,
    exactly zero on padded columns (p = 0 and onehot = 0 there)."""
    z, dcap = _tile_logits(h, w, transpose_w, softcap)
    cols = _tile_cols(j, bn, bv)
    valid = cols < vocab
    s = jnp.where(valid, z, NEG_INF)
    p = jnp.exp(s - lse[:, None])
    onehot = (cols == lab[:, None]).astype(_f32)
    d = (p - onehot) * rs[:, None]
    if dcap is not None:
        d = d * dcap
    return d


def _ce_bwd_dh_kernel(lab_ref, rs_ref, lse_ref, h_ref, w_ref, dh_out,
                      acc_scr, *, bn, bv, vocab, n_v, transpose_w, softcap):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    d = _dlogits_tile(h_ref[...], w_ref[...], lab_ref[...], rs_ref[...],
                      lse_ref[...], j, bn=bn, bv=bv, vocab=vocab,
                      transpose_w=transpose_w, softcap=softcap)
    w32 = w_ref[...].astype(_f32)
    if transpose_w:                       # w tile (D, bv): dh = d @ w^T
        acc_scr[...] += jnp.dot(d, w32.T, preferred_element_type=_f32)
    else:                                 # w tile (bv, D): dh = d @ w
        acc_scr[...] += jnp.dot(d, w32, preferred_element_type=_f32)

    @pl.when(j == n_v - 1)
    def _flush():
        dh_out[...] = acc_scr[...].astype(dh_out.dtype)


def _ce_bwd_dw_kernel(lab_ref, rs_ref, lse_ref, h_ref, w_ref, dw_out,
                      acc_scr, *, bn, bv, vocab, n_r, transpose_w, softcap):
    # grid (chunks, rows): the dW block for chunk j accumulates across the
    # inner row sweep in an fp32 VMEM scratch (accumulating in the output
    # dtype would round the partial sum per row tile — per-mille error for
    # bf16 weights at real tile counts) and rounds ONCE at the flush.
    j = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    d = _dlogits_tile(h_ref[...], w_ref[...], lab_ref[...], rs_ref[...],
                      lse_ref[...], j, bn=bn, bv=bv, vocab=vocab,
                      transpose_w=transpose_w, softcap=softcap)
    h32 = h_ref[...].astype(_f32)
    if transpose_w:                       # dW tile (D, bv) = h^T @ d
        acc_scr[...] += jnp.dot(h32.T, d, preferred_element_type=_f32)
    else:                                 # dW tile (bv, D) = d^T @ h
        acc_scr[...] += jnp.dot(d.T, h32, preferred_element_type=_f32)

    @pl.when(i == n_r - 1)
    def _flush():
        dw_out[...] = acc_scr[...].astype(dw_out.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers


def _specs(bn, bv, D, transpose_w):
    h_spec = pl.BlockSpec((bn, D), lambda i, j: (i, 0))
    w_spec = (pl.BlockSpec((D, bv), lambda i, j: (0, j)) if transpose_w
              else pl.BlockSpec((bv, D), lambda i, j: (j, 0)))
    vec_spec = pl.BlockSpec((bn,), lambda i, j: (i,))
    return h_spec, w_spec, vec_spec


def _vp_of(w, transpose_w):
    return w.shape[1] if transpose_w else w.shape[0]


def _ce_forward(h2, w, labels, *, vocab, transpose_w, softcap, bn, bv,
                interpret):
    N, D = h2.shape
    n_r, n_v = N // bn, _vp_of(w, transpose_w) // bv
    h_spec, w_spec, vec_spec = _specs(bn, bv, D, transpose_w)
    kern = functools.partial(_ce_fwd_kernel, bn=bn, bv=bv, vocab=vocab,
                             n_v=n_v, transpose_w=transpose_w,
                             softcap=softcap)
    return pl.pallas_call(
        kern,
        grid=(n_r, n_v),
        in_specs=[vec_spec, h_spec, w_spec],
        out_specs=[vec_spec, vec_spec],
        out_shape=[jax.ShapeDtypeStruct((N,), _f32),
                   jax.ShapeDtypeStruct((N,), _f32)],
        scratch_shapes=[pltpu.VMEM((bn, 1), _f32)] * 3,
        interpret=interpret,
    )(labels, h2, w)


def _ce_forward_sampled(h2, w, seed, *, vocab, transpose_w, softcap, bn, bv,
                        interpret):
    N, D = h2.shape
    n_r, n_v = N // bn, _vp_of(w, transpose_w) // bv
    h_spec, w_spec, vec_spec = _specs(bn, bv, D, transpose_w)
    seed_spec = pl.BlockSpec((2,), lambda i, j: (0,))
    kern = functools.partial(_ce_fwd_sample_kernel, bn=bn, bv=bv, vocab=vocab,
                             n_v=n_v, transpose_w=transpose_w,
                             softcap=softcap)
    return pl.pallas_call(
        kern,
        grid=(n_r, n_v),
        in_specs=[seed_spec, h_spec, w_spec],
        out_specs=[vec_spec, vec_spec, vec_spec],
        out_shape=[jax.ShapeDtypeStruct((N,), _f32),
                   jax.ShapeDtypeStruct((N,), _f32),
                   jax.ShapeDtypeStruct((N,), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bn, 1), _f32),
                        pltpu.VMEM((bn, 1), _f32),
                        pltpu.VMEM((bn, 1), _f32),
                        pltpu.VMEM((bn, 1), jnp.int32),
                        pltpu.VMEM((bn, 1), _f32)],
        interpret=interpret,
    )(seed, h2, w)


def _ce_backward(h2, w, labels, rs, lse, *, vocab, transpose_w, softcap,
                 bn, bv, interpret):
    """(d_hidden, d_W) from two more vocab sweeps (no [N, V] buffer)."""
    N, D = h2.shape
    Vp = _vp_of(w, transpose_w)
    n_r, n_v = N // bn, Vp // bv
    h_spec, w_spec, vec_spec = _specs(bn, bv, D, transpose_w)
    kern_h = functools.partial(_ce_bwd_dh_kernel, bn=bn, bv=bv, vocab=vocab,
                               n_v=n_v, transpose_w=transpose_w,
                               softcap=softcap)
    dh = pl.pallas_call(
        kern_h,
        grid=(n_r, n_v),
        in_specs=[vec_spec, vec_spec, vec_spec, h_spec, w_spec],
        out_specs=pl.BlockSpec((bn, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), h2.dtype),
        scratch_shapes=[pltpu.VMEM((bn, D), _f32)],
        interpret=interpret,
    )(labels, rs, lse, h2, w)

    # rows innermost so each dW chunk block accumulates while resident
    hT_spec = pl.BlockSpec((bn, D), lambda j, i: (i, 0))
    wT_spec = (pl.BlockSpec((D, bv), lambda j, i: (0, j)) if transpose_w
               else pl.BlockSpec((bv, D), lambda j, i: (j, 0)))
    vT_spec = pl.BlockSpec((bn,), lambda j, i: (i,))
    kern_w = functools.partial(_ce_bwd_dw_kernel, bn=bn, bv=bv, vocab=vocab,
                               n_r=n_r, transpose_w=transpose_w,
                               softcap=softcap)
    dw = pl.pallas_call(
        kern_w,
        grid=(n_v, n_r),
        in_specs=[vT_spec, vT_spec, vT_spec, hT_spec, wT_spec],
        out_specs=wT_spec,
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        scratch_shapes=[pltpu.VMEM((D, bv) if transpose_w else (bv, D),
                                   _f32)],
        interpret=interpret,
    )(labels, rs, lse, h2, w)
    return dh, dw


# ---------------------------------------------------------------------------
# custom_vjp plumbing


def _float0(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _fused_nll(h2, w, labels, rowscale, vocab, transpose_w, softcap, bn, bv,
               interpret):
    """sum(rowscale * nll) with labels fixed; logits never materialize."""
    loss, _ = _fused_nll_fwd(h2, w, labels, rowscale, vocab, transpose_w,
                             softcap, bn, bv, interpret)
    return loss


def _fused_nll_fwd(h2, w, labels, rowscale, vocab, transpose_w, softcap, bn,
                   bv, interpret):
    lse, ll = _ce_forward(h2, w, labels, vocab=vocab, transpose_w=transpose_w,
                          softcap=softcap, bn=bn, bv=bv, interpret=interpret)
    loss = jnp.sum(rowscale * (lse - ll))
    return loss, (h2, w, labels, rowscale, lse, ll)


def _fused_nll_bwd(vocab, transpose_w, softcap, bn, bv, interpret, res, g):
    h2, w, labels, rowscale, lse, ll = res
    rs = (rowscale * g).astype(_f32)
    dh, dw = _ce_backward(h2, w, labels, rs, lse, vocab=vocab,
                          transpose_w=transpose_w, softcap=softcap,
                          bn=bn, bv=bv, interpret=interpret)
    return dh, dw, _float0(labels), (lse - ll) * g


_fused_nll.defvjp(_fused_nll_fwd, _fused_nll_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _fused_sampled_nll(h2, w, seed, rowscale, vocab, transpose_w, softcap,
                       bn, bv, interpret):
    """sum(rowscale * nll) against in-sweep sampled labels (GNB path)."""
    loss, _ = _fused_sampled_nll_fwd(h2, w, seed, rowscale, vocab,
                                     transpose_w, softcap, bn, bv, interpret)
    return loss


def _fused_sampled_nll_fwd(h2, w, seed, rowscale, vocab, transpose_w,
                           softcap, bn, bv, interpret):
    lse, ll, yhat = _ce_forward_sampled(
        h2, w, seed, vocab=vocab, transpose_w=transpose_w, softcap=softcap,
        bn=bn, bv=bv, interpret=interpret)
    loss = jnp.sum(rowscale * (lse - ll))
    return loss, (h2, w, seed, yhat, rowscale, lse, ll)


def _fused_sampled_nll_bwd(vocab, transpose_w, softcap, bn, bv, interpret,
                           res, g):
    h2, w, seed, yhat, rowscale, lse, ll = res
    rs = (rowscale * g).astype(_f32)
    dh, dw = _ce_backward(h2, w, yhat, rs, lse, vocab=vocab,
                          transpose_w=transpose_w, softcap=softcap,
                          bn=bn, bv=bv, interpret=interpret)
    return dh, dw, _float0(seed), (lse - ll) * g


_fused_sampled_nll.defvjp(_fused_sampled_nll_fwd, _fused_sampled_nll_bwd)


# ---------------------------------------------------------------------------
# public entry points


def _pick_block(n, want, quantum):
    """Largest multiple of ``quantum`` <= want dividing n, else (quantum,
    pad) where pad rounds n up to a quantum multiple."""
    want = max(quantum, min(want, n))
    b = (want // quantum) * quantum
    while b >= quantum:
        if n % b == 0:
            return b, 0
        b -= quantum
    return quantum, (-n) % quantum


def _prep(hidden, labels_or_none, mask, block_n):
    """Flatten leading dims and pad rows to a block multiple (padded rows
    carry rowscale 0, so they contribute nothing to loss or gradients)."""
    D = hidden.shape[-1]
    h2 = hidden.reshape(-1, D)
    N = h2.shape[0]
    rs, n_valid = rowscale(N, mask)
    bn, pad = _pick_block(N, block_n, 8)
    if pad:
        h2 = jnp.pad(h2, ((0, pad), (0, 0)))
        rs = jnp.pad(rs, (0, pad))
    lab = None
    if labels_or_none is not None:
        lab = labels_or_none.reshape(-1).astype(jnp.int32)
        if pad:
            lab = jnp.pad(lab, (0, pad))
    return h2, lab, rs, n_valid, bn


def _pick_bv(Vp, block_v):
    assert Vp % 128 == 0, f"padded vocab {Vp} not a multiple of 128"
    return vocab_chunk(Vp, block_v, 128)


def fused_lm_loss(hidden, w, labels, mask=None, *, vocab_size,
                  transpose_w=False, softcap=None, block_n=DEFAULT_BN,
                  block_v=DEFAULT_BV, interpret=None):
    """Masked-mean LM cross-entropy without materializing logits.

    hidden (..., D); w (Vp, D) tied or (D, Vp) untied (``transpose_w``);
    labels (...) int; mask (...) optional.  Returns ``(loss, n_valid)`` —
    the batch factor the GNB refresh folds into the Hessian-EMA.
    Differentiable in ``hidden`` and ``w`` via the fused backward sweeps.
    """
    h2, lab, rs, n_valid, bn = _prep(hidden, labels, mask, block_n)
    bv = _pick_bv(_vp_of(w, transpose_w), block_v)
    softcap = float(softcap) if softcap else None
    interpret = _interpret_default() if interpret is None else interpret
    loss = _fused_nll(h2, w, lab, rs, int(vocab_size), bool(transpose_w),
                      softcap, bn, bv, bool(interpret))
    return loss, n_valid


def fused_lm_loss_sampled(hidden, w, rng, mask=None, *, vocab_size,
                          transpose_w=False, softcap=None, block_n=DEFAULT_BN,
                          block_v=DEFAULT_BV, interpret=None):
    """GNB sampled-label CE in one sweep: draws ``yhat ~ softmax(logits)``
    by online chunked Gumbel-argmax *inside* the forward kernel and returns
    the masked-mean NLL against it (``(loss, n_valid)``).  The gradient of
    ``loss`` is Algorithm 2's ``ghat`` contribution through this stage —
    logits-free in both directions."""
    h2, _, rs, n_valid, bn = _prep(hidden, None, mask, block_n)
    bv = _pick_bv(_vp_of(w, transpose_w), block_v)
    softcap = float(softcap) if softcap else None
    interpret = _interpret_default() if interpret is None else interpret
    seed = seed_from_key(rng)
    loss = _fused_sampled_nll(h2, w, seed, rs, int(vocab_size),
                              bool(transpose_w), softcap, bn, bv,
                              bool(interpret))
    return loss, n_valid


def fused_lm_sample(hidden, w, rng, *, vocab_size, transpose_w=False,
                    softcap=None, block_n=DEFAULT_BN, block_v=DEFAULT_BV,
                    interpret=None):
    """The sampled labels alone (tests / diagnostics): yhat shaped like
    ``hidden[..., 0]``."""
    shp = hidden.shape[:-1]
    h2, _, _, _, bn = _prep(hidden, None, None, block_n)
    bv = _pick_bv(_vp_of(w, transpose_w), block_v)
    softcap = float(softcap) if softcap else None
    interpret = _interpret_default() if interpret is None else interpret
    _, _, yhat = _ce_forward_sampled(
        h2, w, seed_from_key(rng), vocab=int(vocab_size),
        transpose_w=bool(transpose_w), softcap=softcap, bn=bn, bv=bv,
        interpret=bool(interpret))
    n = 1
    for s in shp:
        n *= s
    return yhat[:n].reshape(shp)


# ---------------------------------------------------------------------------
# analytic HBM traffic (roofline overlay, analogous to
# flash_attention.attention_hbm_bytes_flash)


def lm_loss_hbm_bytes_fused(N, D, V, *, bytes_h=2, bytes_w=4) -> int:
    """Fused path: hidden and W stream once per sweep (1 forward + 2
    backward), outputs are d_hidden + d_W + four (N,) vectors.  No term
    scales with N*V."""
    h = N * D * bytes_h
    wb = V * D * bytes_w
    vecs = 4 * N * 4
    return 3 * (h + wb) + h + wb + vecs


def lm_loss_hbm_bytes_unfused(N, D, V, *, bytes_h=2, bytes_w=4,
                              passes=5) -> int:
    """Unfused XLA path: the fp32 [N, V] logits cross HBM ~``passes``
    times (projection write, log_softmax read/write, NLL gather read,
    backward softmax read) on top of the projection operands."""
    return N * V * 4 * passes + 2 * (N * D * bytes_h + V * D * bytes_w)
