"""Shape-keyed block autotuner for the fused CE kernels.

Picking (block_rows ``bn``, block_v ``bv``, backward schedule) for
``kernels/fused_ce.py`` is a classic tiling problem: the kernels are
correct for ANY divisor pair, but wall-clock swings several-fold with the
tile shape (weight-tile re-reads scale with the row-grid size, the
combined backward schedule is only legal on single-axis grids, and in
interpret mode per-grid-cell dispatch overhead dwarfs the arithmetic).
This module resolves it in three stages:

1. **candidates** — every (bn, bv) with bn | N (multiple of 8), bv | Vp
   (multiple of 128), filtered by a VMEM working-set budget (real TPU) or
   a tile-size sanity cap (interpret) and by the logits-residency cap
   ``bn * bv <= max(N * Vp / 2, 8 * 128)`` so the tuner can never pick the
   degenerate whole-[N, V]-tile config that the memory audit exists to
   forbid.  Each tiling carries its legal schedules ("fused" iff one grid
   axis is 1).
2. **predict** — the analytic cost model (``predict_seconds``): per-pass
   ``max(flops/PEAK_FLOPS, bytes/HBM_BW)`` on the roofline constants from
   ``launch/roofline.py`` for a real backend; for interpret mode a
   CPU model ``flops/CPU_FLOPS + cells * CELL_OVERHEAD_S`` (the
   interpreter unrolls the grid, so cell count — not bandwidth — is the
   first-order term).  Candidates are ranked by predicted time.
3. **measure (optional refinement)** — ``measure=True`` times
   ``value_and_grad`` of the real kernel at the top ``MEASURE_TOP_K``
   predicted candidates and keeps the fastest.  Only *measured* winners are
   persisted to the on-disk cache; roofline-only picks stay in-memory so
   CI stays hermetic and deterministic.

The cache is keyed on ``(N, D, Vp, dtype, transpose_w, softcap?, norm,
backend)`` and lives at ``$REPRO_FUSED_CE_CACHE`` (default
``~/.cache/repro/fused_ce_autotune.json``), written atomically.  Lookup
(``get_tuned``) is pure host-side Python on static shapes — safe to call
at trace time from inside ``jit``; measurement only ever runs eagerly
(benchmarks, ``launch/train.py --retune``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time

import numpy as np

CACHE_VERSION = 1
MEASURE_TOP_K = 4
MEASURE_REPS = 3

# interpret-mode cost model, calibrated on the loss_memory bench host:
# a grid cell of the unrolled interpreter costs ~2.5 ms of dispatch +
# discharge overhead regardless of tile size, and the jnp arithmetic
# inside sustains ~50 GFLOP/s
CPU_FLOPS = 5.0e10
CELL_OVERHEAD_S = 2.5e-3

# VMEM working-set budget for a real TPU backend (per-core VMEM is
# ~16 MiB; leave headroom for pipelining double-buffers)
VMEM_BUDGET_BYTES = 12 << 20
# interpret mode has no VMEM, but a tile of jnp intermediates still costs
# host RAM — cap the fp32 logits tile at 2^24 elements (64 MiB)
INTERPRET_TILE_ELEMS = 1 << 24

_LOCK = threading.Lock()
_MEM: dict = {}          # key -> TunedCE (both measured and roofline picks)
_DISK_LOADED = False


@dataclasses.dataclass(frozen=True)
class TunedCE:
    """One tuning decision: the block sizes and backward schedule for a
    fused-CE shape, plus provenance ("seed" | "roofline" | "measured")."""
    bn: int
    bv: int
    schedule: str                 # "split" | "fused"
    source: str
    predicted_ms: float = 0.0
    measured_ms: float | None = None


def cache_path() -> str:
    return os.environ.get(
        "REPRO_FUSED_CE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "fused_ce_autotune.json"))


def cache_key(N, D, Vp, *, dtype, transpose_w, softcap, norm,
              backend) -> str:
    dt = np.dtype(dtype).name if not isinstance(dtype, str) else dtype
    return (f"N{N}-D{D}-V{Vp}-{dt}-"
            f"{'untied' if transpose_w else 'tied'}-"
            f"cap{softcap if softcap else 0}-norm{norm or 'none'}-"
            f"{backend}")


def _dtype_bytes(dtype) -> int:
    return np.dtype(dtype).itemsize


def residency_cap(N: int, Vp: int) -> int:
    """Max legal logits-tile elements: half the full [N, Vp] buffer (so the
    no-materialization audit keeps meaning something), floored at one
    minimal (8, 128) tile for tiny shapes."""
    return max((N * Vp) // 2, 8 * 128)


def _divisors(n: int, quantum: int, cap: int) -> list:
    """Multiples of ``quantum`` dividing n, geometrically thinned (each
    kept divisor at least ~1.4x the previous) so huge shapes don't explode
    the search, always keeping quantum and n itself (if <= cap)."""
    ds = [d for d in range(quantum, min(n, cap) + 1, quantum) if n % d == 0]
    out = []
    for d in ds:
        if not out or d >= out[-1] * 1.4 or d == ds[-1]:
            out.append(d)
    return out or [quantum]


def candidate_blocks(N: int, D: int, Vp: int, *, bytes_h: int,
                     interpret: bool) -> list:
    """All legal (bn, bv, schedule) triples for the shape, budget- and
    residency-filtered.  ``schedule="fused"`` appears only for tilings
    where one grid axis is 1 (the combined backward kernel's legality
    condition — no non-consecutive output-block revisits)."""
    cap = residency_cap(N, Vp)
    cands = []
    for bn in _divisors(N, 8, max(N, 8)):
        for bv in _divisors(Vp, 128, Vp):
            if bn * bv > cap:
                continue
            if interpret:
                if bn * bv > INTERPRET_TILE_ELEMS:
                    continue
            else:
                # working set: h tile + w tile + fp32 logits tile + the
                # larger backward scratch, double-buffered inputs
                ws = 2 * (bn * D * bytes_h + bv * D * 4) \
                    + bn * bv * 4 + max(bn, bv) * D * 4
                if ws > VMEM_BUDGET_BYTES:
                    continue
            n_r, n_v = N // bn, Vp // bv
            cands.append((bn, bv, "split"))
            if n_r == 1 or n_v == 1:
                cands.append((bn, bv, "fused"))
    return cands


def predict_seconds(N: int, D: int, Vp: int, bn: int, bv: int,
                    schedule: str, *, bytes_h: int, bytes_w: int,
                    interpret: bool) -> float:
    """Analytic cost of one fwd+bwd of the fused NLL at this tiling.

    Real backend: per-pass ``max(compute, memory)`` against the
    ``launch/roofline.py`` constants.  The memory terms are exact DMA
    counts from the BlockSpecs: the forward re-reads the full W once per
    row block (``n_r * w_bytes``), the split backward adds a second full
    logits recompute plus an h re-stream per vocab chunk, and the fused
    schedule reads each operand exactly once.  Interpret: grid cells are
    unrolled by the interpreter, so cost = flops/CPU_FLOPS + cells *
    CELL_OVERHEAD_S (memory ignored — everything is host RAM)."""
    from ..launch.roofline import HBM_BW, PEAK_FLOPS

    n_r, n_v = N // bn, Vp // bv
    mm = 2.0 * N * D * Vp                    # one full-projection matmul
    h_b = N * D * bytes_h
    w_b = Vp * D * bytes_w

    if schedule == "fused":
        passes = [
            (mm, h_b + n_r * w_b),                    # forward
            (3.0 * mm, h_b + w_b + h_b + w_b),        # combined backward
        ]
        cells = n_r * n_v * 2
    else:
        passes = [
            (mm, h_b + n_r * w_b),                    # forward
            (2.0 * mm, h_b + n_r * w_b + h_b),        # d_hidden sweep
            (2.0 * mm, n_v * h_b + w_b + w_b),        # d_W sweep
        ]
        cells = n_r * n_v * 3

    if interpret:
        flops = sum(f for f, _ in passes)
        return flops / CPU_FLOPS + cells * CELL_OVERHEAD_S
    return sum(max(f / PEAK_FLOPS, b / HBM_BW) for f, b in passes)


# ---------------------------------------------------------------------------
# persistent cache


def _load_disk() -> None:
    global _DISK_LOADED
    if _DISK_LOADED:
        return
    _DISK_LOADED = True
    try:
        with open(cache_path()) as f:
            blob = json.load(f)
    except (OSError, ValueError):
        return
    if blob.get("version") != CACHE_VERSION:
        return
    for k, e in blob.get("entries", {}).items():
        _MEM.setdefault(k, TunedCE(**e))


def _save_disk() -> None:
    """Persist the *measured* entries atomically (tmp + rename).  Roofline
    picks are deliberately not written: they are cheap to recompute and
    letting them pin the cache would freeze a model-based guess as if it
    were ground truth."""
    path = cache_path()
    entries = {k: dataclasses.asdict(t) for k, t in _MEM.items()
               if t.source == "measured"}
    blob = {"version": CACHE_VERSION, "entries": entries}
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + f".tmp{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass                     # read-only FS: run with the in-memory pick


def clear_memory_cache() -> None:
    """Forget in-process picks (tests); the disk cache is untouched."""
    global _DISK_LOADED, _ATTN_DISK_LOADED
    with _LOCK:
        _MEM.clear()
        _DISK_LOADED = False
        _MEM_ATTN.clear()
        _ATTN_DISK_LOADED = False


def drop_entry(key: str) -> None:
    with _LOCK:
        _load_disk()
        if _MEM.pop(key, None) is not None:
            _save_disk()


# ---------------------------------------------------------------------------
# measurement


def _measure_ms(N, D, Vp, bn, bv, schedule, *, dtype, transpose_w, softcap,
                norm, interpret) -> float:
    """Median wall-clock (ms) of one jitted value_and_grad of the fused
    NLL at this tiling, on synthetic operands of the keyed shape."""
    import jax
    import jax.numpy as jnp

    from . import fused_ce

    k = jax.random.PRNGKey(0)
    kh, kw, kl = jax.random.split(k, 3)
    h = (jax.random.normal(kh, (N, D), jnp.float32) * 0.02).astype(dtype)
    wshape = (D, Vp) if transpose_w else (Vp, D)
    w = (jax.random.normal(kw, wshape, jnp.float32) * 0.02)
    labels = jax.random.randint(kl, (N,), 0, Vp)
    kwargs = dict(vocab_size=Vp, transpose_w=transpose_w, softcap=softcap,
                  block_n=bn, block_v=bv, schedule=schedule,
                  interpret=interpret)
    if norm:
        kwargs.update(norm_kind=norm, norm_scale=jnp.zeros((D,)),
                      norm_bias=jnp.zeros((D,)))

    def f(h, w):
        return fused_ce.fused_lm_loss(h, w, labels, **kwargs)[0]

    g = jax.jit(jax.value_and_grad(f, argnums=(0, 1)))
    jax.block_until_ready(g(h, w))            # compile
    ts = []
    for _ in range(MEASURE_REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(g(h, w))
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))


# ---------------------------------------------------------------------------
# entry points


def get_tuned(N: int, D: int, Vp: int, *, dtype, transpose_w: bool,
              softcap, norm, interpret: bool, measure: bool = False,
              refresh: bool = False) -> TunedCE:
    """The (bn, bv, schedule) to use for this fused-CE shape.

    Deterministic host-side Python (trace-safe).  Order of precedence:
    in-memory hit -> disk hit (measured entries only) -> roofline-ranked
    search, optionally refined by measurement (``measure=True``, eager
    contexts only).  ``refresh=True`` ignores caches and re-tunes."""
    backend = "interpret" if interpret else "tpu"
    key = cache_key(N, D, Vp, dtype=dtype, transpose_w=transpose_w,
                    softcap=softcap, norm=norm, backend=backend)
    with _LOCK:
        _load_disk()
        if not refresh and key in _MEM:
            hit = _MEM[key]
            if hit.source == "measured" or not measure:
                return hit

    bytes_h = _dtype_bytes(dtype)
    cands = candidate_blocks(N, D, Vp, bytes_h=bytes_h, interpret=interpret)
    if not cands:
        t = TunedCE(8, 128, "split", "seed")
        with _LOCK:
            _MEM[key] = t
        return t
    scored = sorted(
        cands,
        key=lambda c: (predict_seconds(N, D, Vp, c[0], c[1], c[2],
                                       bytes_h=bytes_h, bytes_w=4,
                                       interpret=interpret), c))
    best = scored[0]
    pred = predict_seconds(N, D, Vp, *best, bytes_h=bytes_h, bytes_w=4,
                           interpret=interpret)

    if not measure:
        t = TunedCE(best[0], best[1], best[2], "roofline",
                    predicted_ms=pred * 1e3)
        with _LOCK:
            _MEM[key] = t
        return t

    timed = []
    for c in scored[:MEASURE_TOP_K]:
        ms = _measure_ms(N, D, Vp, c[0], c[1], c[2], dtype=dtype,
                         transpose_w=transpose_w, softcap=softcap,
                         norm=norm, interpret=interpret)
        timed.append((ms, c))
    ms, win = min(timed, key=lambda t: (t[0], t[1]))
    t = TunedCE(win[0], win[1], win[2], "measured",
                predicted_ms=predict_seconds(
                    N, D, Vp, *win, bytes_h=bytes_h, bytes_w=4,
                    interpret=interpret) * 1e3,
                measured_ms=ms)
    with _LOCK:
        _MEM[key] = t
        _save_disk()
    return t


def tune_shape(N: int, D: int, Vp: int, *, dtype="float32",
               transpose_w=False, softcap=None, norm=None,
               interpret=None, refresh: bool = False) -> TunedCE:
    """Eager measured tuning for one shape (benchmarks, ``--retune``)."""
    if interpret is None:
        from .fused_ce import _interpret_default
        interpret = _interpret_default()
    return get_tuned(N, D, Vp, dtype=dtype, transpose_w=transpose_w,
                     softcap=softcap, norm=norm, interpret=interpret,
                     measure=True, refresh=refresh)


# ===========================================================================
# flash-attention tuning (kernels/flash_attention.py)
#
# Same three-stage design as the CE tuner: divisor candidates filtered by a
# VMEM working-set budget, the analytic roofline (with an exact causal
# block-band count, since the "skip" schedule prunes out-of-band cells),
# optional measured refinement, and a separate persistent cache (only
# measured winners are written).  Keys deliberately exclude the sliding
# window: it is traced at call time (transformer.layer_windows), so one
# tuning decision per (shape, causal, softcap, dtype, backend) serves every
# window the layer stack produces.

ATTN_CACHE_VERSION = 1
_MEM_ATTN: dict = {}
_ATTN_DISK_LOADED = False


@dataclasses.dataclass(frozen=True)
class TunedAttn:
    """One attention tuning decision: (block_q, block_k, schedule) plus
    provenance ("seed" | "roofline" | "measured")."""
    bq: int
    bk: int
    schedule: str                 # "skip" | "dense"
    source: str
    predicted_ms: float = 0.0
    measured_ms: float | None = None


def attn_cache_path() -> str:
    return os.environ.get(
        "REPRO_FLASH_ATTN_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "flash_attn_autotune.json"))


def attn_cache_key(B, H, Hkv, Sq, Sk, hd, *, dtype, causal, softcap,
                   backend) -> str:
    dt = np.dtype(dtype).name if not isinstance(dtype, str) else dtype
    return (f"B{B}-H{H}-Hkv{Hkv}-Sq{Sq}-Sk{Sk}-hd{hd}-{dt}-"
            f"{'causal' if causal else 'bidi'}-"
            f"cap{softcap if softcap else 0}-{backend}")


def attn_candidate_blocks(Sq: int, Sk: int, hd: int, *, bytes_el: int,
                          interpret: bool) -> list:
    """All legal (bq, bk, schedule) triples: divisor blocks, VMEM
    working-set filtered (real backend) or tile-size capped (interpret)."""
    qq = 8 if Sq % 8 == 0 else 1
    qk = 8 if Sk % 8 == 0 else 1
    cands = []
    for bq in _divisors(Sq, qq, Sq):
        for bk in _divisors(Sk, qk, Sk):
            if interpret:
                if bq * bk > INTERPRET_TILE_ELEMS:
                    continue
            else:
                # double-buffered q/k/v tiles + fp32 score tile + the
                # larger of the fwd/bwd fp32 accumulators
                ws = 2 * bytes_el * (bq + 2 * bk) * hd \
                    + 4 * (bq * bk + (bq + 2 * bk) * hd)
                if ws > VMEM_BUDGET_BYTES:
                    continue
            cands.append((bq, bk, "dense"))
            cands.append((bq, bk, "skip"))
    if interpret:
        # never the whole score matrix in one tile: a (Sq, Sk) block is
        # exactly the residency the kernel exists to avoid, and measured
        # interpret wall time shows sub-matrix tiles cost nothing (band
        # skipping pays for the extra dispatches).  Keep the full tile
        # only when it is the sole legal choice (tiny Sq/Sk).
        sub = [c for c in cands if c[0] * c[1] < Sq * Sk]
        if sub:
            cands = sub
    return cands


def _attn_band_cells(Sq, Sk, bq, bk, causal) -> float:
    """In-band (i, j) grid cells per (batch, head) for the causal band
    (window unknown at tune time -> not narrowed)."""
    n_q, n_k = Sq // bq, Sk // bk
    if not causal:
        return float(n_q * n_k)
    i = np.arange(n_q)
    hi = np.minimum(n_k - 1, ((i + 1) * bq - 1) // bk)
    return float(np.sum(hi + 1))


def attn_predict_seconds(B, H, Hkv, Sq, Sk, hd, bq, bk, schedule, *,
                         bytes_el, causal, interpret) -> float:
    """Analytic cost of one fused fwd + bwd (dQ + dKV) at this tiling.

    "skip" computes (and DMAs) only in-band cells; "dense" streams and
    computes the full grid, relying on masking.  Interpret mode charges
    per-cell dispatch overhead for every grid cell of all three kernels —
    pl.when saves arithmetic but not dispatch."""
    from ..launch.roofline import HBM_BW, PEAK_FLOPS

    n_q, n_k = Sq // bq, Sk // bk
    band = _attn_band_cells(Sq, Sk, bq, bk, causal)
    full = float(n_q * n_k)
    cells = band if schedule == "skip" else full
    tile = float(bq * bk * hd)
    f_fwd = B * H * 4.0 * tile * cells
    f_dq = B * H * 6.0 * tile * cells
    f_dkv = B * H * 8.0 * tile * cells

    if interpret:
        flops = f_fwd + f_dq + f_dkv
        grid_cells = 3 * B * H * n_q * n_k
        return flops / CPU_FLOPS + grid_cells * CELL_OVERHEAD_S

    be = bytes_el
    q_pl = B * H * Sq * hd * be            # one (B, H, Sq, hd) plane
    kv_pl = B * Hkv * Sk * hd * be         # one (B, Hkv, Sk, hd) plane
    lse_b = 4 * B * H * Sq
    kv_stream = 2 * be * B * H * cells * bk * hd       # k+v per in-band cell
    q_stream = 2 * be * B * H * cells * bq * hd        # q+do per in-band cell
    passes = [
        (f_fwd, 2 * q_pl + kv_stream + lse_b),
        (f_dq, 3 * q_pl + kv_stream + 2 * lse_b),
        (f_dkv, 4 * kv_pl + q_stream + 2 * lse_b),
    ]
    return sum(max(f / PEAK_FLOPS, b / HBM_BW) for f, b in passes)


def _load_attn_disk() -> None:
    global _ATTN_DISK_LOADED
    if _ATTN_DISK_LOADED:
        return
    _ATTN_DISK_LOADED = True
    try:
        with open(attn_cache_path()) as f:
            blob = json.load(f)
    except (OSError, ValueError):
        return
    if blob.get("version") != ATTN_CACHE_VERSION:
        return
    for k, e in blob.get("entries", {}).items():
        _MEM_ATTN.setdefault(k, TunedAttn(**e))


def _save_attn_disk() -> None:
    path = attn_cache_path()
    entries = {k: dataclasses.asdict(t) for k, t in _MEM_ATTN.items()
               if t.source == "measured"}
    blob = {"version": ATTN_CACHE_VERSION, "entries": entries}
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + f".tmp{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass


def _measure_attn_ms(B, H, Hkv, Sq, Sk, hd, bq, bk, schedule, *, dtype,
                     causal, softcap, interpret) -> float:
    """Median wall-clock (ms) of one jitted value_and_grad through the
    flash kernel at this tiling, on synthetic operands."""
    import jax
    import jax.numpy as jnp

    from .flash_attention import flash_attention

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, hd),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, hd),
                          jnp.float32).astype(dtype)

    def f(q, k, v):
        o = flash_attention(q, k, v, causal=causal, softcap=softcap,
                            block_q=bq, block_k=bk, schedule=schedule,
                            interpret=interpret)
        return jnp.sum(o.astype(jnp.float32) ** 2)

    g = jax.jit(jax.value_and_grad(f, argnums=(0, 1, 2)))
    jax.block_until_ready(g(q, k, v))          # compile
    ts = []
    for _ in range(MEASURE_REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(g(q, k, v))
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))


def get_tuned_attn(B, H, Hkv, Sq, Sk, hd, *, dtype, causal, softcap,
                   interpret, measure: bool = False,
                   refresh: bool = False) -> TunedAttn:
    """The (bq, bk, schedule) to use for this attention shape.

    Deterministic host-side Python (trace-safe), same precedence as
    :func:`get_tuned`: in-memory -> disk (measured only) -> roofline
    ranking, optionally measure-refined."""
    backend = "interpret" if interpret else "tpu"
    key = attn_cache_key(B, H, Hkv, Sq, Sk, hd, dtype=dtype, causal=causal,
                         softcap=softcap, backend=backend)
    with _LOCK:
        _load_attn_disk()
        if not refresh and key in _MEM_ATTN:
            hit = _MEM_ATTN[key]
            if hit.source == "measured" or not measure:
                return hit

    bytes_el = _dtype_bytes(dtype)
    cands = attn_candidate_blocks(Sq, Sk, hd, bytes_el=bytes_el,
                                  interpret=interpret)
    if interpret:
        # prefer candidates the interpret grid clamp wouldn't rewrite
        from .flash_attention import INTERPRET_CELL_CAP
        fit = [c for c in cands
               if B * H * (Sq // c[0]) * (Sk // c[1])
               <= INTERPRET_CELL_CAP]
        cands = fit or cands
    if not cands:
        t = TunedAttn(min(Sq, 128), min(Sk, 128),
                      "skip" if causal else "dense", "seed")
        with _LOCK:
            _MEM_ATTN[key] = t
        return t

    def _pred(c):
        return attn_predict_seconds(B, H, Hkv, Sq, Sk, hd, c[0], c[1],
                                    c[2], bytes_el=bytes_el, causal=causal,
                                    interpret=interpret)

    scored = sorted(cands, key=lambda c: (_pred(c), c))
    best = scored[0]

    if not measure:
        t = TunedAttn(best[0], best[1], best[2], "roofline",
                      predicted_ms=_pred(best) * 1e3)
        with _LOCK:
            _MEM_ATTN[key] = t
        return t

    timed = []
    for c in scored[:MEASURE_TOP_K]:
        ms = _measure_attn_ms(B, H, Hkv, Sq, Sk, hd, c[0], c[1], c[2],
                              dtype=dtype, causal=causal, softcap=softcap,
                              interpret=interpret)
        timed.append((ms, c))
    ms, win = min(timed, key=lambda t: (t[0], t[1]))
    t = TunedAttn(win[0], win[1], win[2], "measured",
                  predicted_ms=_pred(win) * 1e3, measured_ms=ms)
    with _LOCK:
        _MEM_ATTN[key] = t
        _save_attn_disk()
    return t


def tune_attn_shape(B, H, Hkv, Sq, Sk, hd, *, dtype="float32", causal=True,
                    softcap=None, interpret=None,
                    refresh: bool = False) -> TunedAttn:
    """Eager measured attention tuning (benchmarks, ``--retune``)."""
    if interpret is None:
        from .fused_ce import _interpret_default
        interpret = _interpret_default()
    return get_tuned_attn(B, H, Hkv, Sq, Sk, hd, dtype=dtype, causal=causal,
                          softcap=softcap, interpret=interpret,
                          measure=True, refresh=refresh)
