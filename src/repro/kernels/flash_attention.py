"""Training-path flash attention: fused forward + custom_vjp backward.

The training hot path used to run ``models/layers.py:full_attention``,
which materializes an fp32 ``(B, Hkv, G, S, S)`` score tensor every layer.
This module replaces it with a production Pallas kernel family that keeps
the online softmax entirely in VMEM:

* **forward** — grid ``(B, H, Sq/BQ, Sk/BK)`` with the KV axis innermost
  ("arbitrary"): VMEM scratch carries the running max/denominator and an
  fp32 accumulator, initialized at ``j == 0`` and flushed at the last KV
  block.  Emits the logsumexp ``(B, H, Sq)`` as a second output — the
  backward residual.  GQA maps ``h -> h // G`` in the KV BlockSpecs.
* **backward** (``custom_vjp``) — two kernels with the delta/lse recompute
  trick (``delta = rowsum(dO * O)`` precomputed outside): dQ iterates KV
  innermost with an fp32 ``(BQ, hd)`` accumulator; dK/dV iterate
  ``(group, q-block)`` pairs innermost over ``(B, Hkv, Sk/BK)`` so the
  GQA group-sum lands in one fp32 ``(BK, hd)`` scratch — no
  ``(B, H, Sk, hd)`` intermediate.
* **custom_jvp twin** (``use_jvp=True``) — same Pallas forward for the
  primal; the tangent is a chunked fp32 jnp sweep, *linear* in the input
  tangents, so JAX can both push Hutchinson's forward-over-reverse HVP
  through it and transpose it for reverse mode.

Masking covers causal, sliding-window and the gemma2 logit softcap.  The
window rides in as a scalar-prefetch operand (sentinel ``1 << 30`` = no
window) so the *traced* per-layer windows from ``transformer.layer_windows``
work, and — because ``PrefetchScalarGridSpec`` index maps receive the
scalar ref — the ``schedule="skip"`` variant clamps the streamed block
index into the live band: fully-masked ``j > i`` (causal) and
out-of-window grid cells neither DMA fresh tiles nor compute.
``schedule="dense"`` streams every block and relies on masking alone.

Block sizes and the schedule come from ``kernels/autotune.py``
(``get_tuned_attn``) unless given explicitly; ``interpret=None`` resolves
to "not on a real TPU" (the repo convention, ``fused_ce._interpret_default``)
and interpret-mode grids are auto-clamped to <= ``INTERPRET_CELL_CAP``
cells so CPU CI never unrolls huge grids.

Parity: ``kernels/ref.py`` closed-form oracles mirror every fp32 rounding
point (<= 3e-6, tests/test_flash_attention.py); ``KERNEL_CALLS`` counts
``attn_fwd`` / ``attn_bwd_dq`` / ``attn_bwd_dkv`` / ``attn_jvp_rule`` at
trace time so tests can assert nothing silently fell back.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .fused_ce import KERNEL_CALLS, _interpret_default

NEG_INF = -1e30
WINDOW_NONE = 1 << 30          # sentinel window: larger than any context
INTERPRET_CELL_CAP = 64        # max unrolled grid cells under interpret


# ---------------------------------------------------------------------------
# block bands (shared by index maps and kernels; jnp int arithmetic so the
# window may be a traced scalar from the prefetch ref)


def _kv_band(i, win, *, causal, q_offset, block_q, block_k, n_k):
    """Inclusive [lo, hi] range of KV blocks attended by q-block ``i``."""
    if causal:
        hi = jnp.minimum(n_k - 1,
                         ((i + 1) * block_q - 1 + q_offset) // block_k)
    else:
        hi = n_k - 1
    lo = jnp.maximum(0, (i * block_q + q_offset - win + 1) // block_k)
    return lo, hi


def _q_band(j, win, *, causal, q_offset, block_q, block_k, n_q):
    """Inclusive [lo, hi] range of q blocks attending KV block ``j``."""
    if causal:
        lo = jnp.maximum(0, (j * block_k - q_offset) // block_q)
    else:
        lo = 0
    hi = jnp.minimum(n_q - 1,
                     ((j + 1) * block_k - 2 + win - q_offset) // block_q)
    return lo, hi


def _tile_mask(i, j, win, *, causal, q_offset, block_q, block_k):
    """(BQ, BK) bool attend-mask for grid cell (i, j), global positions."""
    qpos = q_offset + i * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = j * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    m = kpos > qpos - win
    if causal:
        m = m & (kpos <= qpos)
    return m


def _dotT(a, b):
    """a (M, D) x b (N, D) -> (M, N) fp32 contraction over the last axis."""
    return jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# forward kernel


def _fwd_kernel(win_ref, q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
                acc_scr, *, scale, causal, softcap, q_offset, block_q,
                block_k, n_k, schedule):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = _dotT(q, k) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = _tile_mask(i, j, win_ref[0], causal=causal, q_offset=q_offset,
                          block_q=block_q, block_k=block_k)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        # where-guard: a fully-masked tile has m_new == NEG_INF and
        # exp(s - m_new) == 1 — the mask zeroes it instead
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)

    if schedule == "skip":
        lo, hi = _kv_band(i, win_ref[0], causal=causal, q_offset=q_offset,
                          block_q=block_q, block_k=block_k, n_k=n_k)
        pl.when((j >= lo) & (j <= hi))(_step)
    else:
        _step()

    @pl.when(j == n_k - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[...] + jnp.log(l))[:, 0]


def _forward(q, k, v, win, *, causal, scale, softcap, q_offset, block_q,
             block_k, schedule, interpret):
    """Raw fwd pallas_call -> (o, lse); no autodiff wiring."""
    B, H, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    group = H // Hkv
    n_q, n_k = Sq // block_q, Sk // block_k
    skip = schedule == "skip"

    def kv_index(b, h, i, j, w):
        if skip:
            lo, hi = _kv_band(i, w[0], causal=causal, q_offset=q_offset,
                              block_q=block_q, block_k=block_k, n_k=n_k)
            j = jnp.clip(j, lo, hi)
        return (b, h // group, j, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, i, j, w: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), kv_index),
            pl.BlockSpec((1, 1, block_k, hd), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, i, j, w: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j, w: (b, h, i)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),     # running max
            pltpu.VMEM((block_q, 1), jnp.float32),     # running denominator
            pltpu.VMEM((block_q, hd), jnp.float32),    # output accumulator
        ],
    )
    kern = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, softcap=softcap,
        q_offset=q_offset, block_q=block_q, block_k=block_k, n_k=n_k,
        schedule=schedule)
    KERNEL_CALLS["attn_fwd"] += 1
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        interpret=interpret,
    )(win, q, k, v)


# ---------------------------------------------------------------------------
# backward kernels (delta/lse recompute: p = exp(z - lse) per tile, no
# stored probabilities)


def _bwd_tile(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, i, j, win, *,
              scale, causal, softcap, q_offset, block_q, block_k):
    """Shared per-tile recompute: (p, ds, do32) with ds already
    softcap-chained; all fp32."""
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].reshape(block_q, 1)
    delta = dl_ref[0, 0].reshape(block_q, 1)
    s = _dotT(q, k) * scale
    if softcap is not None:
        t = jnp.tanh(s / softcap)
        z, dcap = softcap * t, 1.0 - t * t
    else:
        z, dcap = s, None
    mask = _tile_mask(i, j, win, causal=causal, q_offset=q_offset,
                      block_q=block_q, block_k=block_k)
    p = jnp.where(mask, jnp.exp(z - lse), 0.0)
    ds = p * (_dotT(do, v) - delta)
    if dcap is not None:
        ds = ds * dcap
    return q, k, do, p, ds


def _dq_kernel(win_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
               dq_ref, dq_scr, *, scale, causal, softcap, q_offset, block_q,
               block_k, n_k, schedule):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr[...])

    def _step():
        _, k, _, _, ds = _bwd_tile(
            q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, i, j, win_ref[0],
            scale=scale, causal=causal, softcap=softcap, q_offset=q_offset,
            block_q=block_q, block_k=block_k)
        dq_scr[...] += jax.lax.dot(
            ds, k, preferred_element_type=jnp.float32) * scale

    if schedule == "skip":
        lo, hi = _kv_band(i, win_ref[0], causal=causal, q_offset=q_offset,
                          block_q=block_q, block_k=block_k, n_k=n_k)
        pl.when((j >= lo) & (j <= hi))(_step)
    else:
        _step()

    @pl.when(j == n_k - 1)
    def _flush():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(win_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, scale, causal, softcap,
                q_offset, block_q, block_k, n_q, n_inner, schedule):
    j, t = pl.program_id(2), pl.program_id(3)
    i = t % n_q                         # q-block; t // n_q is the GQA group

    @pl.when(t == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr[...])
        dv_scr[...] = jnp.zeros_like(dv_scr[...])

    def _step():
        q, _, do, p, ds = _bwd_tile(
            q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, i, j, win_ref[0],
            scale=scale, causal=causal, softcap=softcap, q_offset=q_offset,
            block_q=block_q, block_k=block_k)
        dv_scr[...] += _dotT(p.T, do.T)
        dk_scr[...] += _dotT(ds.T, q.T) * scale

    if schedule == "skip":
        lo, hi = _q_band(j, win_ref[0], causal=causal, q_offset=q_offset,
                         block_q=block_q, block_k=block_k, n_q=n_q)
        pl.when((i >= lo) & (i <= hi))(_step)
    else:
        _step()

    @pl.when(t == n_inner - 1)
    def _flush():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def _backward(q, k, v, win, do, lse, delta, *, causal, scale, softcap,
              q_offset, block_q, block_k, schedule, interpret):
    B, H, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    group = H // Hkv
    n_q, n_k = Sq // block_q, Sk // block_k
    skip = schedule == "skip"
    band = dict(causal=causal, q_offset=q_offset, block_q=block_q,
                block_k=block_k)

    # --- dQ: grid (B, H, n_q, n_k), KV innermost --------------------------
    def kv_index(b, h, i, j, w):
        if skip:
            lo, hi = _kv_band(i, w[0], n_k=n_k, **band)
            j = jnp.clip(j, lo, hi)
        return (b, h // group, j, 0)

    q_spec = pl.BlockSpec((1, 1, block_q, hd),
                          lambda b, h, i, j, w: (b, h, i, 0))
    row_spec = pl.BlockSpec((1, 1, block_q), lambda b, h, i, j, w: (b, h, i))
    kv_spec = pl.BlockSpec((1, 1, block_k, hd), kv_index)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, n_q, n_k),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
    )
    kern = functools.partial(
        _dq_kernel, scale=scale, causal=causal, softcap=softcap,
        q_offset=q_offset, block_q=block_q, block_k=block_k, n_k=n_k,
        schedule=schedule)
    KERNEL_CALLS["attn_bwd_dq"] += 1
    dq = pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(win, q, k, v, do, lse, delta)

    # --- dK/dV: grid (B, Hkv, n_k, G * n_q), (group, q-block) innermost ---
    n_inner = group * n_q

    def q_index(b, kv, j, t, w):
        i = t % n_q
        if skip:
            lo, hi = _q_band(j, w[0], n_q=n_q, **band)
            i = jnp.clip(i, lo, hi)
        return (b, kv * group + t // n_q, i, 0)

    def row_index(b, kv, j, t, w):
        i = t % n_q
        if skip:
            lo, hi = _q_band(j, w[0], n_q=n_q, **band)
            i = jnp.clip(i, lo, hi)
        return (b, kv * group + t // n_q, i)

    qg_spec = pl.BlockSpec((1, 1, block_q, hd), q_index)
    rowg_spec = pl.BlockSpec((1, 1, block_q), row_index)
    kvb_spec = pl.BlockSpec((1, 1, block_k, hd),
                            lambda b, kv, j, t, w: (b, kv, j, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, n_k, n_inner),
        in_specs=[qg_spec, kvb_spec, kvb_spec, qg_spec, rowg_spec,
                  rowg_spec],
        out_specs=[kvb_spec, kvb_spec],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
    )
    kern = functools.partial(
        _dkv_kernel, scale=scale, causal=causal, softcap=softcap,
        q_offset=q_offset, block_q=block_q, block_k=block_k, n_q=n_q,
        n_inner=n_inner, schedule=schedule)
    KERNEL_CALLS["attn_bwd_dkv"] += 1
    dk, dv = pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        interpret=interpret,
    )(win, q, k, v, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp wiring


def _float0(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


_NONDIFF = (4, 5, 6, 7, 8, 9, 10, 11)
#           causal, scale, softcap, q_offset, block_q, block_k, schedule,
#           interpret


@functools.partial(jax.custom_vjp, nondiff_argnums=_NONDIFF)
def _flash(q, k, v, win, causal, scale, softcap, q_offset, block_q,
           block_k, schedule, interpret):
    o, _ = _forward(q, k, v, win, causal=causal, scale=scale,
                    softcap=softcap, q_offset=q_offset, block_q=block_q,
                    block_k=block_k, schedule=schedule, interpret=interpret)
    return o


def _flash_fwd(q, k, v, win, causal, scale, softcap, q_offset, block_q,
               block_k, schedule, interpret):
    o, lse = _forward(q, k, v, win, causal=causal, scale=scale,
                      softcap=softcap, q_offset=q_offset, block_q=block_q,
                      block_k=block_k, schedule=schedule,
                      interpret=interpret)
    return o, (q, k, v, win, o, lse)


def _flash_bwd(causal, scale, softcap, q_offset, block_q, block_k, schedule,
               interpret, res, g):
    q, k, v, win, o, lse = res
    delta = (g.astype(jnp.float32) * o.astype(jnp.float32)).sum(-1)
    dq, dk, dv = _backward(
        q, k, v, win, g, lse, delta, causal=causal, scale=scale,
        softcap=softcap, q_offset=q_offset, block_q=block_q,
        block_k=block_k, schedule=schedule, interpret=interpret)
    return dq, dk, dv, _float0(win)


_flash.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# custom_jvp twin (Hutchinson's forward-over-reverse HVP route)


def _chunk_len(S: int, cap: int = 512) -> int:
    c = min(S, cap)
    while S % c:
        c -= 1
    return c


@functools.partial(jax.custom_jvp, nondiff_argnums=_NONDIFF)
def _flash_jvp(q, k, v, win, causal, scale, softcap, q_offset, block_q,
               block_k, schedule, interpret):
    o, _ = _forward(q, k, v, win, causal=causal, scale=scale,
                    softcap=softcap, q_offset=q_offset, block_q=block_q,
                    block_k=block_k, schedule=schedule, interpret=interpret)
    return o


@_flash_jvp.defjvp
def _flash_jvp_rule(causal, scale, softcap, q_offset, block_q, block_k,
                    schedule, interpret, primals, tangents):
    """o-tangent of attention, linear in (dq, dk, dv) so JAX can transpose
    it: with row-normalized p and z the (softcapped, scaled) logits,
    ``do = (p * dz) @ v - rowsum(p * dz) * o + p @ dv``."""
    q, k, v, win = primals
    dq, dk, dv, _ = tangents
    KERNEL_CALLS["attn_jvp_rule"] += 1
    # The primal is recomputed below by the checkpointed jnp scan, NOT by
    # re-entering the Pallas forward: inside ``lax.scan`` (the layer loop)
    # linearization inlines the known side of a staged custom_jvp call, so
    # a Pallas primal here would surface as a bare pallas_call to the
    # OUTER jvp of Hutchinson's forward-over-reverse HVP and die in
    # ``_pallas_call_jvp_rule``.  An all-jnp rule stays differentiable at
    # every order; the Pallas forward still serves the undifferentiated
    # ``use_jvp=True`` call (the twin's own body).

    B, H, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    G = H // Hkv
    f32 = jnp.float32
    q32 = q.astype(f32).reshape(B, Hkv, G, Sq, hd)
    dq32 = dq.astype(f32).reshape(B, Hkv, G, Sq, hd)
    k32, v32 = k.astype(f32), v.astype(f32)
    dk32, dv32 = dk.astype(f32), dv.astype(f32)
    win32 = win[0]
    c = _chunk_len(Sk)
    n_c = Sk // c
    qpos = q_offset + jnp.arange(Sq)[:, None]

    def _z(kc, kpos):
        s = jnp.einsum("bkgsh,bkth->bkgst", q32, kc,
                       preferred_element_type=f32) * scale
        if softcap is not None:
            t = jnp.tanh(s / softcap)
            z, dcap = softcap * t, 1.0 - t * t
        else:
            z, dcap = s, None
        mask = kpos[None, :] > qpos - win32
        if causal:
            mask = mask & (kpos[None, :] <= qpos)
        return jnp.where(mask[None, None, None], z, NEG_INF), dcap, mask

    # primal-only online (m, l, acc) over KV chunks — checkpointed scan so
    # the HVP's reverse sweep re-derives rather than stores the chunks
    def body(carry, ci):
        m, l, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(k32, ci * c, c, 2)
        vc = jax.lax.dynamic_slice_in_dim(v32, ci * c, c, 2)
        kpos = ci * c + jnp.arange(c)
        z, _, mask = _z(kc, kpos)
        m_new = jnp.maximum(m, z.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(mask[None, None, None],
                      jnp.exp(z - m_new[..., None]), 0.0)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgst,bkth->bkgsh", p, vc, preferred_element_type=f32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((B, Hkv, G, Sq), NEG_INF, f32),
            jnp.zeros((B, Hkv, G, Sq), f32),
            jnp.zeros((B, Hkv, G, Sq, hd), f32))
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), init,
                                  jnp.arange(n_c))
    l = jnp.maximum(l, 1e-30)
    lse = m + jnp.log(l)
    o32 = acc / l[..., None]

    # tangent accumulation: an unrolled Python loop (a scan closing over
    # tangents is untransposable), each term linear in (dq, dk, dv)
    u = jnp.zeros((B, Hkv, G, Sq), f32)
    t_pv = jnp.zeros((B, Hkv, G, Sq, hd), f32)
    for ci in range(n_c):
        kc = k32[:, :, ci * c:(ci + 1) * c]
        vc = v32[:, :, ci * c:(ci + 1) * c]
        dkc = dk32[:, :, ci * c:(ci + 1) * c]
        dvc = dv32[:, :, ci * c:(ci + 1) * c]
        kpos = ci * c + jnp.arange(c)
        z, dcap, mask = _z(kc, kpos)
        # where-guard, not bare exp: a fully-masked row has lse == NEG_INF
        p = jnp.where(mask[None, None, None], jnp.exp(z - lse[..., None]),
                      0.0)
        dz = (jnp.einsum("bkgsh,bkth->bkgst", dq32, kc,
                         preferred_element_type=f32)
              + jnp.einsum("bkgsh,bkth->bkgst", q32, dkc,
                           preferred_element_type=f32)) * scale
        if dcap is not None:
            dz = dz * dcap
        pdz = p * dz
        u = u + pdz.sum(-1)
        t_pv = t_pv + jnp.einsum("bkgst,bkth->bkgsh", pdz, vc,
                                 preferred_element_type=f32) \
            + jnp.einsum("bkgst,bkth->bkgsh", p, dvc,
                         preferred_element_type=f32)
    do32 = t_pv - u[..., None] * o32
    o = o32.reshape(B, H, Sq, hd).astype(q.dtype)
    do = do32.reshape(B, H, Sq, hd).astype(q.dtype)
    return o, do


# ---------------------------------------------------------------------------
# public entry


def _fit_block(n: int, want: int) -> int:
    b = int(max(1, min(n, want)))
    while n % b:
        b -= 1
    return b


def _clamp_interpret_grid(Sq, Sk, bq, bk, outer, cap=INTERPRET_CELL_CAP):
    """Grow blocks until the unrolled grid has <= cap cells (best effort:
    the B*H outer product alone may exceed the cap)."""
    def _grow(S, b):
        nb = b + 1
        while nb <= S and S % nb:
            nb += 1
        return min(nb, S)

    while outer * (Sq // bq) * (Sk // bk) > cap and (bq < Sq or bk < Sk):
        if (Sk // bk) >= (Sq // bq) and bk < Sk:
            bk = _grow(Sk, bk)
        else:
            bq = _grow(Sq, bq)
    return bq, bk


def flash_attention(q, k, v, *, causal=True, scale=None, window=None,
                    softcap=None, q_offset=0, block_q=None, block_k=None,
                    schedule=None, interpret=None, use_jvp=False):
    """Fused attention: q (B, H, Sq, hd), k/v (B, Hkv, Sk, hd) -> o like q.

    ``window`` may be None, a static int, or a traced int32 scalar (the
    per-layer windows from ``transformer.layer_windows``); ``scale``
    defaults to 1/sqrt(hd); ``q_offset`` shifts the query positions for
    chunked-prefill-style calls.  ``use_jvp=True`` selects the custom_jvp
    twin (forward-mode capable, jnp tangent); the default custom_vjp path
    runs the Pallas dQ / dKV kernels in reverse mode.  Unset blocks /
    schedule come from ``kernels/autotune.get_tuned_attn``.
    """
    B, H, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    assert H % Hkv == 0, (H, Hkv)
    assert k.shape == v.shape, (k.shape, v.shape)
    assert q_offset >= 0, q_offset
    if interpret is None:
        interpret = _interpret_default()
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    scale = float(scale)
    if softcap is not None:
        softcap = float(softcap)

    if block_q is None or block_k is None or schedule is None:
        from .autotune import get_tuned_attn
        t = get_tuned_attn(B, H, Hkv, Sq, Sk, hd, dtype=q.dtype,
                           causal=causal, softcap=softcap,
                           interpret=interpret)
        block_q = block_q or t.bq
        block_k = block_k or t.bk
        schedule = schedule or t.schedule
    block_q = _fit_block(Sq, block_q)
    block_k = _fit_block(Sk, block_k)
    if interpret:
        block_q, block_k = _clamp_interpret_grid(Sq, Sk, block_q, block_k,
                                                 B * H)

    win = jnp.reshape(
        jnp.asarray(WINDOW_NONE if window is None else window, jnp.int32),
        (1,))
    fn = _flash_jvp if use_jvp else _flash
    return fn(q, k, v, win, causal, scale, softcap, int(q_offset),
              block_q, block_k, schedule, bool(interpret))


# ---------------------------------------------------------------------------
# analytic HBM byte models (roofline overlays, launch/roofline.py)


def attention_hbm_bytes_flash(B, H, Hkv, S, hd, bytes_per_el=2) -> int:
    """HBM floor of the fused forward: Q + O per head, K + V per KV head
    (the VMEM online softmax adds no score traffic)."""
    q_o = 2 * B * H * S * hd * bytes_per_el
    kv = 2 * B * Hkv * S * hd * bytes_per_el
    return q_o + kv


def attention_hbm_bytes_train_flash(B, H, Hkv, S, hd,
                                    bytes_per_el=2) -> int:
    """Fused fwd + bwd traffic floor: forward (Q, K, V reads; O, lse
    writes) plus dQ (re-reads + dO, writes dQ) plus dK/dV (re-reads,
    writes dK/dV).  KV tile re-streaming across q blocks is a block-size
    term deliberately excluded from the floor."""
    q_like = B * H * S * hd * bytes_per_el          # one (B, H, S, hd) plane
    kv_like = B * Hkv * S * hd * bytes_per_el
    lse = 4 * B * H * S
    fwd = 2 * q_like + 2 * kv_like + lse
    d_q = 3 * q_like + 2 * kv_like + 2 * lse
    d_kv = 2 * q_like + 4 * kv_like + 2 * lse
    return fwd + d_q + d_kv


def attention_hbm_bytes_unfused(B, H, S, hd, block_k=1024, passes=5,
                                bytes_per_el=4) -> int:
    """XLA materialized-scores traffic model: each (S, block_k) fp32 score
    tile makes ~``passes`` HBM round-trips (scores, mask, softmax
    normalize, weight, matmul operand re-reads)."""
    tiles = max(S // block_k, 1)
    return B * H * S * block_k * tiles * passes * bytes_per_el
