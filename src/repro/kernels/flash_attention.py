"""Flash attention Pallas TPU kernel (beyond-paper §Perf lever).

The dry-run showed every 32k prefill cell is memory-bound on attention:
XLA materializes each (S, kv_block) score tile through HBM (~5 passes per
tile), so attention traffic is O(S^2) bytes.  This kernel keeps the online
softmax entirely in VMEM scratch — HBM traffic becomes Q+K+V+O only.

Layout: q (B, H, S, hd), k/v (B, Hkv, S, hd) with GQA mapping h -> h//G in
the BlockSpec index map.  Grid (B, H, S/BQ, S/BK); the KV dimension is the
innermost ("arbitrary") axis and accumulates via VMEM scratch, initialized
at ki == 0 and flushed to the output block at the last ki.  Causal masking
uses global block offsets; fully-masked tiles short-circuit.

Validated under interpret=True against kernels/ref.py (flash_attention_ref)
over a shape/GQA/causality sweep in tests/test_flash_attention.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, block_q, block_k, n_k):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q = q_ref[0, 0].astype(jnp.float32)                  # (BQ, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (BK, hd)
    s = jnp.dot(q, k.T) * scale                          # (BQ, BK) fp32

    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v_ref[0, 0].astype(jnp.float32))
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _flush():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, scale=None,
                    block_q=DEFAULT_BQ, block_k=DEFAULT_BK, interpret=True):
    """q: (B, H, S, hd); k, v: (B, Hkv, S, hd) with H % Hkv == 0.

    Returns (B, H, S, hd).  HBM traffic: one read of q/k/v + one write of o.
    """
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    n_q = S // block_q
    n_k = S // block_k
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             block_q=block_q, block_k=block_k, n_k=n_k)
    grid = (B, H, n_q, n_k)
    q_spec = pl.BlockSpec((1, 1, block_q, hd),
                          lambda b, h, i, j: (b, h, i, 0))
    kv_spec = pl.BlockSpec((1, 1, block_k, hd),
                           lambda b, h, i, j: (b, h // G, j, 0))
    o_spec = pl.BlockSpec((1, 1, block_q, hd),
                          lambda b, h, i, j: (b, h, i, 0))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),  # accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def attention_hbm_bytes_flash(B, H, Hkv, S, hd, bytes_per_el=2) -> int:
    """Analytic HBM traffic of the fused kernel (the roofline overlay)."""
    q = B * H * S * hd
    kv = 2 * B * Hkv * S * hd
    o = B * H * S * hd
    return (q + kv + o) * bytes_per_el


def attention_hbm_bytes_unfused(B, H, S, hd, block_k, passes=5,
                                bytes_per_el=4) -> int:
    """Approximate traffic of the XLA chunked path: every (S, block_k)
    score tile crosses HBM ~``passes`` times (write + softmax read/write +
    AV read), fp32."""
    tiles = S // block_k
    return B * H * S * block_k * tiles * passes * bytes_per_el
