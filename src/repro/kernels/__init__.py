# TPU hot-spot kernels for the paper's contribution: the fused Sophia
# optimizer step (pl.pallas_call + BlockSpec VMEM tiling).  ops.py = jit'd
# wrappers, ref.py = pure-jnp oracles, sophia_update.py = the kernels.
from . import ops, ref
