# TPU hot-spot kernels for the paper's contribution: the fused Sophia
# optimizer step (pl.pallas_call + BlockSpec VMEM tiling).
#   sophia_update.py    = the kernels (flat-shard granularity, all families)
#   ref.py              = pure-jnp oracles (the engine's reference backend)
#   ops.py              = per-tensor wrappers for kernel unit tests
#   flash_attention.py  = fused prefill attention (serve/train long-S path)
#   decode_attention.py = fused serve decode step over the slot ring cache
#   fused_ce.py         = logits-free chunked-vocab LM loss + in-sweep GNB
#                         sampling (custom_vjp; the [B*T, V] logits never
#                         touch HBM)
# The production entry point is core/engine.py, which drives the kernels
# over dtype-homogeneous flat shards (one pallas_call grid sweep per shard).
from . import decode_attention, fused_ce, ops, ref, sophia_update
