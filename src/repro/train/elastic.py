"""Failure handling + elasticity at the launcher level.

JAX SPMD is a single program over a fixed mesh, so the production recipe for
node failure / stragglers / preemption at 1000+ nodes is
checkpoint-and-reconfigure:

  * ``StragglerDetector`` — EWMA step-time z-score; a persistent straggler
    triggers a checkpoint + mesh reconfiguration rather than letting one
    slow host gate every collective.
  * ``PreemptionGuard`` — SIGTERM flips a flag; the train loop checkpoints
    and exits cleanly at the next step boundary.
  * ``run_resumable`` — retry wrapper: on failure, restore the latest
    complete checkpoint (possibly onto a *different* mesh via
    checkpoint.restore(shardings=...)) and continue.  The stateless data
    pipeline guarantees exact batch replay.
"""
from __future__ import annotations

import signal
import time
from typing import Callable, Optional


class MeshDegraded(RuntimeError):
    """Raised by the elastic driver after checkpointing to request a
    restart on a smaller mesh (persistent straggler / lost nodes).  Caught
    by ``run_resumable``, whose ``restore_latest`` rebuilds the mesh from
    the surviving device set and re-shards the checkpoint onto it."""


class NodeLoss(RuntimeError):
    """A peer PROCESS died mid-run (multi-host ``jax.distributed``).

    Unlike :class:`MeshDegraded` — an in-process mesh shrink over devices
    this process can still see — node loss is unrecoverable in-process:
    once a peer is gone, the distributed runtime cannot re-form a mesh
    from inside the survivors (collectives against the dead peer hang or
    fault, and the coordination service has lost a member).
    ``run_resumable`` therefore RE-RAISES NodeLoss instead of retrying:
    the process exits non-zero, the job manager relaunches the survivors
    with ``--num-processes`` = the surviving count, and ``restore_latest``
    resumes from the last complete manifest (validated cross-process by
    ``checkpoint.restore_resharded``).  tests/test_multiprocess.py walks
    exactly this relaunch-and-resume path."""


#: substrings that mark a runtime error as a *distributed* failure — a dead
#: or unreachable peer — rather than a local bug.  Matched case-insensitively
#: against the message of XlaRuntimeError-shaped exceptions.
_DISTRIBUTED_TOKENS = ("deadline", "barrier", "heartbeat", "connection",
                       "unavailable", "peer", "broken pipe", "timed out",
                       "timeout", "gloo", "socket", "unreachable")


def is_distributed_failure(exc: BaseException) -> bool:
    """True when ``exc`` looks like a lost/unreachable peer process.

    Name-based (not isinstance): XlaRuntimeError's import path moved
    across jax versions, and gRPC/gloo surface errors under several
    types.  Tokens are deliberately broad — misclassifying a local bug as
    NodeLoss costs one relaunch; misclassifying a dead peer as local makes
    ``run_resumable`` retry into a hang against a ghost."""
    name = type(exc).__name__
    if name not in ("XlaRuntimeError", "JaxRuntimeError", "RuntimeError",
                    "InternalError", "UnavailableError", "DeadlineExceeded"):
        return False
    msg = str(exc).lower()
    return any(tok in msg for tok in _DISTRIBUTED_TOKENS)


class StragglerDetector:
    """Flags steps whose duration deviates from the EWMA by > z_thresh
    sigma.  At scale, per-host step-time telemetry feeds this; a flagged
    host => checkpoint-and-reconfigure."""

    def __init__(self, alpha: float = 0.1, z_thresh: float = 4.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.z = z_thresh
        self.warmup = warmup
        self.mean = None
        self.var = 0.0
        self.n = 0           # deviation samples seen (excludes the baseline)
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        if self.mean is None:
            # baseline sample: seeds the EWMA, contributes no deviation —
            # it must NOT count toward warmup (counting it made the
            # detector eligible to flag one deviation-sample early)
            self.mean = dt
            return False
        self.n += 1
        delta = dt - self.mean
        # sigma floor: 1% of the mean, so perfectly steady step times
        # (var -> 0) still flag an obvious outlier instead of dividing by 0
        sigma = max(self.var ** 0.5, 0.01 * abs(self.mean), 1e-9)
        is_straggler = self.n > self.warmup and delta / sigma > self.z
        self.mean += self.alpha * delta
        self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        if is_straggler:
            self.flagged += 1
        return is_straggler


class PreemptionGuard:
    """SIGTERM/SIGINT -> graceful checkpoint at the next step boundary."""

    def __init__(self, install: bool = True):
        self.requested = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
            except ValueError:
                pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True

    def request(self):  # testable without a real signal
        self.requested = True


def run_resumable(make_state: Callable[[], object],
                  run: Callable[[object, int], object],
                  restore_latest: Callable[[], Optional[tuple]],
                  max_restarts: int = 3):
    """Generic retry-with-restore driver.

    make_state() -> fresh state; restore_latest() -> (state, step) or None;
    run(state, start_step) raises on failure, returns final state on success.

    A ``restore_latest`` raising FileNotFoundError (no checkpoint written
    yet) falls back to a fresh state instead of killing the retry loop — a
    crash *before* the first checkpoint must still be retried.  Any other
    restore error (layout mismatch, corrupt leaf files) propagates: starting
    fresh would overwrite the checkpoints it failed to read.

    ``MeshDegraded`` is a deliberate checkpoint-and-reconfigure request,
    not a failure: it triggers a restore without consuming the restart
    budget.  ``NodeLoss`` is the opposite extreme: in-process retry cannot
    recover a dead peer, so it propagates immediately — the relaunch (with
    fewer processes) happens OUTSIDE this process, and the next incarnation
    resumes via ``restore_latest``.
    """
    attempts = 0
    while True:
        try:
            restored = restore_latest()
        except FileNotFoundError:
            restored = None
        if restored is not None:
            state, start = restored
        else:
            state, start = make_state(), 0
        try:
            return run(state, start)
        except MeshDegraded:
            continue
        except NodeLoss:
            raise
        except Exception:
            attempts += 1
            if attempts > max_restarts:
                raise
            time.sleep(0.1)
