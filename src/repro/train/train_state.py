"""TrainState pytree + constructors."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class TrainState(NamedTuple):
    step: jnp.ndarray          # int32 scalar
    params: PyTree
    opt_state: PyTree          # EngineState: flat dtype-homogeneous shards
    clip_state: PyTree         # global-norm clip telemetry (paper Fig 7a)
    rng: jax.Array             # folded per step for estimator sampling
    comp_state: PyTree = ()    # grad-compression error feedback (if enabled)
