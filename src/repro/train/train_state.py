"""TrainState pytree + constructors + partition specs."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class TrainState(NamedTuple):
    step: jnp.ndarray          # int32 scalar
    params: PyTree
    opt_state: PyTree          # EngineState: flat dtype-homogeneous shards
    clip_state: PyTree         # global-norm clip telemetry (paper Fig 7a)
    rng: jax.Array             # folded per step for estimator sampling
    comp_state: PyTree = ()    # FlatCompressionState: error-feedback flat
    #                            shards, same layout as opt_state.m (if
    #                            grad compression is enabled)


def state_partition_specs(state_shape: TrainState, pspecs,
                          mesh=None) -> TrainState:
    """PartitionSpecs for a TrainState.

    The engine's flat optimizer shards — and the compressor's error-feedback
    shards, which share their layout — are 1-D and block-padded, so with a
    ``mesh`` they shard over the ``data`` axis (FSDP-style) whenever the
    size divides; without a mesh they replicate."""
    from jax.sharding import PartitionSpec as P

    from ..core.engine import (EngineState, engine_partition_specs,
                               flat_shard_spec)
    from ..distributed.compression import FlatCompressionState

    scalar = P()
    opt = state_shape.opt_state
    if isinstance(opt, EngineState):
        opt_specs = engine_partition_specs(opt, mesh)
    else:  # generic: scalar-replicate unknown optimizer state
        opt_specs = jax.tree.map(lambda _: scalar, opt)
    comp = state_shape.comp_state
    if isinstance(comp, FlatCompressionState):
        comp_specs = FlatCompressionState(
            error=tuple(flat_shard_spec(a, mesh) for a in comp.error))
    else:
        comp_specs = jax.tree.map(lambda _: scalar, comp)
    return TrainState(step=scalar, params=pspecs, opt_state=opt_specs,
                      clip_state=jax.tree.map(lambda _: scalar,
                                              state_shape.clip_state),
                      rng=scalar, comp_state=comp_specs)
