"""Fault-tolerant checkpointing: sharded, async, atomic, elastic.

Format: one directory per step —

    ckpt_dir/step_00001000/
        manifest.json     {step, n_leaves, paths, shapes, dtypes}
        leaf_00000.npy ... leaf_NNNNN.npy

* saves go to ``.tmp-step_X`` and are atomically renamed — a crashed save
  can never shadow a complete one (restart safety);
* ``async_save`` runs the serialization on a background thread after a
  synchronous device_get snapshot, hiding write latency behind compute;
* ``restore`` accepts target shardings, so a checkpoint written under one
  mesh restores under ANY other mesh (elastic re-scaling): arrays are
  device_put against the new NamedShardings;
* restore also returns the step, and the stateless data pipeline
  (data/pipeline.py) makes mid-run resume exact.

Multi-process (``jax.distributed``) rules, all no-ops at process_count==1:

* the device->host snapshot is COLLECTIVE — non-fully-addressable leaves
  are materialized via ``process_allgather``, so every process must call
  ``save`` together — but only process 0 writes files (writes are forced
  synchronous: an async thread racing the cross-process barrier could
  publish a half-written step to peers);
* ``restore`` builds leaves with ``jax.make_array_from_callback`` when the
  target sharding spans non-addressable devices (plain device_put only
  works process-locally);
* ``restore_resharded`` barriers first (process 0's rename must be
  visible) and then cross-validates the manifest digest across processes —
  two processes silently restoring *different* steps (skewed filesystems,
  a stale NFS cache) would otherwise train a frankenstate.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, _MANIFEST)):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def _write(tree_np, step: int, ckpt_dir: str, extra: Optional[dict] = None):
    tmp = os.path.join(ckpt_dir, f".tmp-step_{step:08d}")
    final = _step_dir(ckpt_dir, step)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree_np)
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), leaf)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(l)) for l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
    }
    if extra:
        # e.g. the optimizer engine's flat-shard layout (block size, shard
        # dtypes/sizes) so tooling can interpret the flat leaves offline
        manifest["extra"] = extra
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)


_pending: list = []


def _sync(tag: str) -> None:
    """Cross-process barrier (no-op single-process)."""
    if jax.process_count() > 1:
        from jax.experimental import multihost_utils
        multihost_utils.sync_global_devices(f"ckpt_{tag}")


def _host_leaf(x):
    """Device -> host for one leaf.  Non-fully-addressable leaves (multi-
    process shardings) are gathered collectively: process_allgather returns
    the fully-replicated global value on every process."""
    if isinstance(x, jax.Array) and not x.is_fully_addressable:
        from jax.experimental import multihost_utils
        return np.asarray(multihost_utils.process_allgather(x))
    return np.asarray(jax.device_get(x))


def save(ckpt_dir: str, step: int, state: PyTree, *, async_: bool = False,
         keep: int = 3, extra: Optional[dict] = None) -> None:
    """Snapshot ``state`` (device -> host) and persist it.

    ``extra`` is a JSON-serializable dict stored in the manifest (the
    launcher records the engine's flat-shard layout here).  Multi-process:
    collective — call on every process; process 0 writes, synchronously."""
    multiproc = jax.process_count() > 1
    tree_np = jax.tree.map(_host_leaf, state)
    if multiproc and jax.process_index() != 0:
        _sync("save")  # pairs with process 0's post-write barrier
        return
    os.makedirs(ckpt_dir, exist_ok=True)
    if async_ and not multiproc:
        t = threading.Thread(target=_write,
                             args=(tree_np, step, ckpt_dir, extra),
                             daemon=True)
        t.start()
        _pending.append(t)
    else:
        _write(tree_np, step, ckpt_dir, extra)
    _gc(ckpt_dir, keep)
    if multiproc:
        _sync("save")


def read_manifest(ckpt_dir: str, step: Optional[int] = None) -> dict:
    """Load a checkpoint's manifest (layout metadata lives under 'extra')."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    with open(os.path.join(_step_dir(ckpt_dir, step), _MANIFEST)) as f:
        return json.load(f)


def wait_for_pending() -> None:
    for t in _pending:
        t.join()
    _pending.clear()


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(_step_dir(ckpt_dir, s), ignore_errors=True)


def _check_layout(recorded: dict, expected: dict) -> None:
    """Refuse a cross-mesh restore when the flat-shard layout differs.

    The layout (block size, shard dtypes/sizes) is a function of the model
    and engine config only — never of the mesh — so any mismatch means the
    checkpoint was written by an incompatible engine and the flat m/h
    buffers would be silently reinterpreted."""
    for key in ("block", "shards"):
        if key in recorded and key in expected \
                and recorded[key] != expected[key]:
            raise ValueError(
                f"checkpoint flat-shard layout mismatch on {key!r}: "
                f"checkpoint has {recorded[key]!r}, engine expects "
                f"{expected[key]!r} (incompatible engine config; "
                f"use a fresh ckpt dir)")


def manifest_digest(ckpt_dir: str, step: Optional[int] = None) -> str:
    """Content digest of a checkpoint's manifest — the cross-process
    agreement token: two processes restoring the same step from the same
    bytes produce the same digest."""
    blob = json.dumps(read_manifest(ckpt_dir, step), sort_keys=True).encode()
    return hashlib.sha1(blob).hexdigest()


def _validate_digest_cross_process(digest_hex: str) -> None:
    """Assert every process resolved the SAME manifest (no-op at
    process_count==1)."""
    if jax.process_count() <= 1:
        return
    from jax.experimental import multihost_utils
    local = np.frombuffer(bytes.fromhex(digest_hex), dtype=np.uint8)
    gathered = np.asarray(multihost_utils.process_allgather(local))
    if not (gathered == gathered[0]).all():
        raise ValueError(
            "checkpoint manifest differs across processes — processes "
            "would restore different checkpoints (skewed filesystem?); "
            f"local digest {digest_hex}")


def restore_resharded(ckpt_dir: str, like: PyTree, *,
                      shardings: Optional[PyTree] = None,
                      expect_layout: Optional[dict] = None,
                      step: Optional[int] = None) -> tuple[PyTree, int]:
    """Elastic cross-mesh restore: re-shard flat shards onto a *different*
    device count.

    The engine's flat shards are 1-D, block-padded at init, and
    mesh-independent, so a checkpoint written on N devices restores onto
    any M-device mesh by device_put-ting the same buffers against the new
    mesh's NamedShardings.  ``expect_layout`` (the engine's
    ``ShardLayout.manifest()``, as recorded in the checkpoint manifest's
    ``extra``) is verified against the recorded layout first.  Multi-
    process: barriers so the writer's rename is visible, then verifies all
    processes agree on the manifest digest before any leaf is loaded."""
    _sync("pre_restore")
    manifest = read_manifest(ckpt_dir, step)
    _validate_digest_cross_process(manifest_digest(ckpt_dir, manifest["step"]))
    if expect_layout is not None:
        _check_layout(manifest.get("extra") or {}, expect_layout)
    return restore(ckpt_dir, like, step=manifest["step"], shardings=shardings)


def restore(ckpt_dir: str, like: PyTree, *, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> tuple[PyTree, int]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  With ``shardings`` (same structure), each leaf is
    device_put against the target sharding — elastic mesh change."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = _step_dir(ckpt_dir, step)
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree.flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), \
        f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves_like)}"
    loaded = [np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
              for i in range(manifest["n_leaves"])]
    if shardings is not None:
        sh_leaves = jax.tree.leaves(shardings)

        def put(x, s):
            if getattr(s, "is_fully_addressable", True):
                return jax.device_put(x, s)
            # sharding spans other processes' devices: build the global
            # array from the (identical-on-every-process) host value
            return jax.make_array_from_callback(np.shape(x), s,
                                                lambda idx: x[idx])

        loaded = [put(x, s) for x, s in zip(loaded, sh_leaves)]
    else:
        loaded = [jax.numpy.asarray(x) for x in loaded]
    return jax.tree.unflatten(treedef, loaded), step
