"""Training loop: two jitted steps (plain / hessian-refresh), Algorithm 3.

The host alternates:

    t % k == 0  ->  train_step_hess   (grad step + Hessian-EMA refresh on a
                                       reduced estimator sub-batch)
    otherwise   ->  train_step        (grad step only)

keeping the hot step's HLO free of estimator code (clean rooflines, and the
levanter-style production structure).  Both steps share:
  grad accumulation (microbatch scan) -> global-norm clip (threshold 1.0,
  trigger telemetry) -> ravel to flat fp32 shards -> [optional in-collective
  int8 compression over the fsdp axis, error feedback persisted as flat
  shards] -> flat-buffer optimizer engine step.

The optimizer update itself is one ``engine.step(state, grads, lr)`` call
for *every* optimizer: the engine (core/engine.py) keeps m/h as flat
dtype-homogeneous shards and executes the whole update as a single fused
Pallas grid sweep per shard (``fused_kernel=True``) or the identical-layout
pure-jnp reference.  The LR schedule is evaluated once per step and handed
to the engine as a traced scalar.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..core import (OptimizerEngine, clip_by_global_norm,
                    empirical_fisher_estimator, gnb_estimator_sq,
                    hutchinson_estimator, linear_warmup_cosine, constant,
                    subsample_batch)
from ..distributed.compression import GradCompressor
from ..models import ModelConfig, get_model
from .train_state import TrainState

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    optimizer: str = "sophia_g"
    peak_lr: float = 4e-4
    total_steps: int = 10_000
    warmup_steps: int = 2_000
    schedule: str = "cosine"           # cosine | constant
    weight_decay: float = 0.2
    beta1: float = 0.96
    beta2: float = 0.99
    gamma: float = 0.05
    eps: float = 1e-12
    hess_interval: int = 10            # k in Algorithm 3
    hess_subbatch: int = 240           # paper: 240/480 (G), 32/480 (H)
    estimator: str = "gnb"             # gnb | hutchinson | empirical_fisher
    grad_clip: float = 1.0
    clip_threshold: float = 1.0        # Sophia rho (1e9 = ablation: no clip)
    grad_accum: int = 1
    remat: str = "none"                # none | full | dots
    attn_impl: str = "auto"
    fused_kernel: bool = False         # Pallas backend for the engine
    compress_grads: bool = False       # int8 + error feedback (beyond-paper)
    state_dtype: str = "float32"       # optimizer m/h dtype ("bfloat16" at 400B)
    seed: int = 0


def make_schedule(tc: TrainerConfig):
    if tc.schedule == "constant":
        return constant(tc.peak_lr)
    return linear_warmup_cosine(tc.peak_lr, tc.total_steps, tc.warmup_steps)


def make_engine(tc: TrainerConfig) -> OptimizerEngine:
    """Engine for ``tc.optimizer`` with the paper's per-optimizer hypers."""
    name = tc.optimizer
    if name in ("sophia_g", "sophia_h"):
        hypers = dict(beta1=tc.beta1, beta2=tc.beta2, gamma=tc.gamma,
                      eps=tc.eps, weight_decay=tc.weight_decay,
                      clip_threshold=tc.clip_threshold)
    elif name == "adamw":
        hypers = dict(beta1=0.9, beta2=0.95, eps=1e-8,
                      weight_decay=tc.weight_decay)
    elif name == "lion":
        hypers = dict(beta1=0.95, beta2=0.98, weight_decay=tc.weight_decay)
    elif name == "signgd":
        hypers = dict(beta1=tc.beta1, weight_decay=tc.weight_decay)
    elif name == "adahessian":
        hypers = dict(beta1=0.92, beta2=0.99, eps=1e-8,
                      weight_decay=tc.weight_decay)
    elif name == "sgd":
        hypers = dict(momentum=0.0)
    else:
        raise ValueError(f"unknown optimizer {name!r}")
    sdt = jnp.bfloat16 if tc.state_dtype == "bfloat16" else jnp.float32
    return OptimizerEngine(name, hypers=hypers,
                           backend="pallas" if tc.fused_kernel
                           else "reference",
                           state_dtype=sdt)


# ---------------------------------------------------------------------------


def _accum_grads(loss_fn, params, batch, accum: int):
    """Microbatch gradient accumulation via scan (mean over microbatches)."""
    if accum <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    micro = jax.tree.map(
        lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
        batch)

    def body(carry, mb):
        loss_acc, g_acc = carry
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        return (loss_acc + loss,
                jax.tree.map(lambda a, b: a + b, g_acc, g)), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), micro)
    inv = 1.0 / accum
    return loss * inv, {"ce": loss * inv, "aux": jnp.zeros(())}, \
        jax.tree.map(lambda g: g * inv, grads)


def make_train_fns(cfg: ModelConfig, tc: TrainerConfig):
    """Returns (init_fn, train_step, train_step_hess).

    All three are pure (jit-able with shardings by the launcher).
    """
    model = get_model(cfg)
    engine = make_engine(tc)
    schedule = make_schedule(tc)
    clipper = clip_by_global_norm(tc.grad_clip)
    compressor = GradCompressor() if tc.compress_grads else None

    def loss_fn(params, batch):
        return model.loss_fn(cfg, params, batch, remat=tc.remat,
                             attn_impl=tc.attn_impl)

    def init_fn(rng) -> TrainState:
        p_rng, s_rng = jax.random.split(jax.random.PRNGKey(tc.seed)
                                        if rng is None else rng)
        params = model.init_params(cfg, p_rng)
        return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                          opt_state=engine.init(params),
                          clip_state=clipper.init(params), rng=s_rng,
                          comp_state=(compressor.init_shards(
                              engine.layout(params))
                              if compressor is not None else ()))

    def _apply(state: TrainState, grads, metrics):
        grads, clip_state = clipper.update(grads, state.clip_state)
        g_sh = engine.ravel_grads(state.params, grads)
        comp_state = state.comp_state
        if compressor is not None:
            # in-collective int8 all-reduce over the flat shards: picks up
            # the fsdp axis from the launcher-installed activation mesh
            # (mesh-less runs use the identical math on the whole shard)
            crng = jax.random.fold_in(state.rng, state.step + (1 << 20))
            g_sh, comp_state = compressor.allreduce_shards(g_sh, comp_state,
                                                           crng)
        lr = schedule(state.opt_state.count)
        params, opt_state = engine.step_shards(state.opt_state, state.params,
                                               g_sh, lr)
        metrics = dict(metrics,
                       grad_norm=clip_state.last_norm,
                       clip_triggers=clip_state.triggers,
                       lr=lr)
        if engine.tracks_clip_fraction:
            metrics["sophia_clip_fraction"] = opt_state.clip_fraction
        return TrainState(step=state.step + 1, params=params,
                          opt_state=opt_state, clip_state=clip_state,
                          rng=state.rng, comp_state=comp_state), metrics

    def train_step(state: TrainState, batch):
        loss, metrics, grads = _accum_grads(loss_fn, state.params, batch,
                                            tc.grad_accum)
        metrics = {"loss": loss, **metrics}
        return _apply(state, grads, metrics)

    def _estimate_hessian(params, batch, rng):
        """Returns (estimate_tree, scale) — the engine folds ``scale`` into
        the Hessian-EMA kernel (GNB's batch factor B, Algorithm 2 line 6)."""
        sub = subsample_batch(batch, tc.hess_subbatch) \
            if tc.hess_subbatch else batch
        if tc.estimator == "gnb":
            def lf(p):
                return model.logits_fn(cfg, p, sub, remat=tc.remat,
                                       attn_impl=tc.attn_impl)
            mask = sub.get("mask")
            return gnb_estimator_sq(lf, params, rng, mask=mask)
        if tc.estimator == "hutchinson":
            def sf(p):
                return model.loss_fn(cfg, p, sub, remat=tc.remat,
                                     attn_impl=tc.attn_impl)[0]
            return hutchinson_estimator(sf, params, rng), 1.0
        if tc.estimator == "empirical_fisher":
            def sf(p):
                return model.loss_fn(cfg, p, sub, remat=tc.remat,
                                     attn_impl=tc.attn_impl)[0]
            n = jax.tree.leaves(sub)[0].shape[0] * \
                (jax.tree.leaves(sub)[0].shape[1]
                 if jax.tree.leaves(sub)[0].ndim > 1 else 1)
            return empirical_fisher_estimator(sf, params, n), 1.0
        raise ValueError(tc.estimator)

    def train_step_hess(state: TrainState, batch):
        """Gradient step + Hessian-EMA refresh (Algorithm 3 lines 7-9)."""
        rng = jax.random.fold_in(state.rng, state.step)
        if engine.hessian_aware:
            est, scale = _estimate_hessian(state.params, batch, rng)
            opt_state = engine.update_hessian(state.opt_state, est,
                                              scale=scale,
                                              params=state.params)
            state = state._replace(opt_state=opt_state)
        return train_step(state, batch)

    return init_fn, train_step, train_step_hess


def train_loop(cfg: ModelConfig, tc: TrainerConfig, source, *,
               num_steps: int, state: Optional[TrainState] = None,
               jit: bool = True, callback: Optional[Callable] = None,
               start_step: int = 0, donate: bool = False):
    """Single-host reference loop (tests/benchmarks; launch/train.py is the
    production multi-device driver).

    With ``donate=True`` (and a backend that implements donation — CPU
    doesn't), the input TrainState is donated to the jitted step: the flat
    params/m/h buffers update in place, halving optimizer-state peak
    memory.  Opt-in here because it consumes the caller's ``state``
    argument; the production driver always donates."""
    init_fn, train_step, hess_step = make_train_fns(cfg, tc)
    if jit:
        dn = (0,) if donate and jax.default_backend() != "cpu" else ()
        train_step = jax.jit(train_step, donate_argnums=dn)
        hess_step = jax.jit(hess_step, donate_argnums=dn)
    if state is None:
        state = init_fn(jax.random.PRNGKey(tc.seed))
    needs_hess = tc.optimizer in ("sophia_g", "sophia_h", "adahessian")
    k = tc.hess_interval
    history = []
    for t in range(start_step, start_step + num_steps):
        batch = {k2: jnp.asarray(v) for k2, v in source.batch_at(t).items()}
        if needs_hess and t % k == 0:
            state, metrics = hess_step(state, batch)
        else:
            state, metrics = train_step(state, batch)
        history.append({k2: float(v) for k2, v in metrics.items()})
        if callback is not None:
            callback(t, state, metrics)
    return state, history
