"""Training loop: ONE jitted step, Algorithm 3 under a traced refresh flag.

The host calls a single compiled ``train_step(state, batch, do_refresh)``
every step and flips the flag at the Hessian cadence (t % k == 0).  The
estimator sub-graph — and the optimizer dispatch — live inside the step
under ``lax.cond``: the refresh branch draws the diagonal-Hessian estimate
(GNB / Hutchinson / empirical-Fisher) on the reduced sub-batch directly as
the engine's flat fp32 shards and folds its EMA into the *same* Pallas
grid sweep that applies the update (``engine.step_with_refresh`` — h read
and written exactly once); the other branch is the ordinary fused step, so
the hot path's HBM traffic is byte-identical to a never-refreshing run.
Because the flag is traced there is exactly one XLA program per mesh
configuration — the elastic driver no longer compiles and caches a hot
step *and* a refresh step.

Every step shares:
  grad accumulation (microbatch scan, aux metrics ride the carry) ->
  global-norm clip (threshold 1.0, trigger telemetry) -> ravel to flat fp32
  shards -> [optional in-collective int8 compression over the fsdp axis,
  error feedback persisted as flat shards] -> fused engine update
  (+ flag-gated Hessian-EMA refresh; the estimator sub-batch gradient can
  optionally ride the same int8 collective, stateless — no error feedback
  at refresh sparsity).

The optimizer update itself is one engine call for *every* optimizer: the
engine (core/engine.py) keeps m/h as flat dtype-homogeneous shards and
executes the whole update as a single fused Pallas grid sweep per shard
(``fused_kernel=True``) or the identical-layout pure-jnp reference.  The
LR schedule is evaluated once per step and handed to the engine as a
traced scalar; the GNB batch factor B stays a traced scalar too.

The hot-path LM loss is logits-free: ``loss_fn`` routes the trunk's
*pre-norm* hidden states through ``models.loss.lm_loss`` (the Pallas
fused kernel by default — autotuned block sizes, the final norm applied
in VMEM inside the sweep; ``fused_loss=False`` falls back to the chunked
jnp sweep), so the ``[B*T, V]`` logits tensor never materializes on
ordinary steps.  The GNB refresh branch is logits-free too by default:
``yhat ~ softmax(logits)`` is drawn *inside* the kernel's vocab sweep
(``sampled_loss_fn`` -> ``gnb_ghat_flat_from_loss``) and B = the sweep's
valid-position count folds into the fused Hessian-EMA as a traced
scalar; the chunked fallback's refresh materializes the estimator
*sub-batch*'s logits once via ``logits_fn`` (its single chunked sweep
eliminates the second fp32 ``log_softmax`` copy, not the buffer itself).
The Hutchinson refresh crosses the fused loss through its ``custom_jvp``
twin, so the HVP no longer falls back to the chunked path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..core import (OptimizerEngine, clip_by_global_norm,
                    empirical_fisher_ghat_flat, gnb_ghat_flat,
                    gnb_ghat_flat_from_loss, hessian_aware_optimizer,
                    hutchinson_estimator_flat, linear_warmup_cosine,
                    constant, subsample_batch)
from ..distributed.compression import GradCompressor
from ..models import ModelConfig, get_model
from .train_state import TrainState

PyTree = Any

# Per-purpose RNG stream tags.  Every consumer derives its stream as
# fold_in(fold_in(rng, TAG), step) — never an arithmetic offset of the bare
# step: the old ``step + (1 << 20)`` compression offset collided with the
# estimator stream as soon as step >= 2**20.
RNG_TAG_HESS = 1           # estimator label sampling / probe draws
RNG_TAG_COMPRESS = 2       # gradient-compression stochastic rounding
RNG_TAG_HESS_COMPRESS = 3  # estimator-compression stochastic rounding


def _fold_rng(state: TrainState, tag: int) -> jax.Array:
    """Domain-separated per-step stream: (purpose tag, then step)."""
    return jax.random.fold_in(jax.random.fold_in(state.rng, tag), state.step)


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    optimizer: str = "sophia_g"
    peak_lr: float = 4e-4
    total_steps: int = 10_000
    warmup_steps: int = 2_000
    schedule: str = "cosine"           # cosine | constant
    weight_decay: float = 0.2
    beta1: float = 0.96
    beta2: float = 0.99
    gamma: float = 0.05
    eps: float = 1e-12
    hess_interval: int = 10            # k in Algorithm 3
    hess_subbatch: int = 240           # paper: 240/480 (G), 32/480 (H)
    estimator: str = "gnb"             # gnb | hutchinson | empirical_fisher
    grad_clip: float = 1.0
    clip_threshold: float = 1.0        # Sophia rho (1e9 = ablation: no clip)
    grad_accum: int = 1
    remat: str = "none"                # none | full | dots
    attn_impl: str = "auto"
    fused_attn: bool = True            # Pallas flash attention on the train
    #                                    path (kernels/flash_attention.py,
    #                                    autotuned blocks; the Hutchinson
    #                                    HVP rides its custom_jvp twin).
    #                                    Only consulted while attn_impl is
    #                                    "auto" — an explicit impl wins.
    fused_kernel: bool = False         # Pallas backend for the engine
    fused_loss: bool = True            # Pallas logits-free LM loss + GNB
    #                                    (kernels/fused_ce.py, autotuned
    #                                    block sizes); False falls back to
    #                                    the chunked jnp sweep — both keep
    #                                    the [B*T, V] logits out of HBM
    compress_grads: bool = False       # int8 + error feedback (beyond-paper)
    compress_hess: bool = False        # int8 for the estimator sub-batch
    #                                    gradient too (stateless: no error
    #                                    feedback at refresh sparsity)
    comm_bucket_elems: Optional[int] = None  # gradient-collective bucketing
    #                                    (distributed/overlap.py): None=auto
    #                                    (roofline; monolithic off-mesh),
    #                                    0=monolithic, N=explicit elements
    comm_telemetry: bool = False       # per-step comm/compute host stamps:
    #                                    metrics gain comm_seconds /
    #                                    step_seconds / exposed_comm_fraction
    state_dtype: str = "float32"       # optimizer m/h dtype ("bfloat16" at 400B)
    seed: int = 0


def make_schedule(tc: TrainerConfig):
    if tc.schedule == "constant":
        return constant(tc.peak_lr)
    return linear_warmup_cosine(tc.peak_lr, tc.total_steps, tc.warmup_steps)


def make_engine(tc: TrainerConfig) -> OptimizerEngine:
    """Engine for ``tc.optimizer`` with the paper's per-optimizer hypers."""
    name = tc.optimizer
    if name in ("sophia_g", "sophia_h"):
        hypers = dict(beta1=tc.beta1, beta2=tc.beta2, gamma=tc.gamma,
                      eps=tc.eps, weight_decay=tc.weight_decay,
                      clip_threshold=tc.clip_threshold)
    elif name == "adamw":
        hypers = dict(beta1=0.9, beta2=0.95, eps=1e-8,
                      weight_decay=tc.weight_decay)
    elif name == "lion":
        hypers = dict(beta1=0.95, beta2=0.98, weight_decay=tc.weight_decay)
    elif name == "signgd":
        hypers = dict(beta1=tc.beta1, weight_decay=tc.weight_decay)
    elif name == "adahessian":
        hypers = dict(beta1=0.92, beta2=0.99, eps=1e-8,
                      weight_decay=tc.weight_decay)
    elif name == "sgd":
        hypers = dict(momentum=0.0)
    else:
        raise ValueError(f"unknown optimizer {name!r}")
    sdt = jnp.bfloat16 if tc.state_dtype == "bfloat16" else jnp.float32
    return OptimizerEngine(name, hypers=hypers,
                           backend="pallas" if tc.fused_kernel
                           else "reference",
                           state_dtype=sdt)


# ---------------------------------------------------------------------------


def _accum_grads(loss_fn, params, batch, accum: int):
    """Microbatch gradient accumulation via scan (mean over microbatches).

    Aux metrics ride the scan carry alongside the loss and grads, so
    ``grad_accum > 1`` reports the same (averaged) metrics as
    ``grad_accum == 1`` — the old carry kept only the final microbatch's ce
    and zeroed aux, silently skewing logged metrics with accumulation on."""
    if accum <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    micro = jax.tree.map(
        lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
        batch)
    met0 = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        jax.eval_shape(loss_fn, params,
                       jax.tree.map(lambda x: x[0], micro))[1])

    def body(carry, mb):
        loss_acc, met_acc, g_acc = carry
        (loss, met), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        return (loss_acc + loss,
                jax.tree.map(lambda a, b: a + b, met_acc, met),
                jax.tree.map(lambda a, b: a + b, g_acc, g)), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, mets, grads), _ = jax.lax.scan(
        body, (jnp.zeros(()), met0, zeros), micro)
    inv = 1.0 / accum
    return loss * inv, jax.tree.map(lambda m: m * inv, mets), \
        jax.tree.map(lambda g: g * inv, grads)


def make_train_fns(cfg: ModelConfig, tc: TrainerConfig):
    """Returns ``(init_fn, train_step)``.

    ``train_step(state, batch, do_refresh)`` is the single compiled program
    (jit-able with shardings by the launcher): the estimator sub-graph runs
    under ``lax.cond`` on the *traced* ``do_refresh`` flag and its EMA is
    fused into the optimizer update, so flipping the flag at the Algorithm-3
    cadence never triggers a second compilation.
    """
    model = get_model(cfg)
    engine = make_engine(tc)
    schedule = make_schedule(tc)
    clipper = clip_by_global_norm(tc.grad_clip)
    compressor = GradCompressor() if tc.compress_grads else None
    hess_compressor = GradCompressor() if tc.compress_hess else None

    loss_impl = "fused" if tc.fused_loss else None  # None -> module default
    # fused_attn only applies while attn_impl is "auto"; an explicit impl
    # ("full", "chunked", "flash", ...) always wins.  The Hutchinson HVP
    # cannot differentiate through custom_vjp, so it rides the custom_jvp
    # twin of the same kernel — mirroring fused_loss's "fused_jvp" route.
    attn_impl = (tc.attn_impl if tc.attn_impl != "auto"
                 else ("flash" if tc.fused_attn else "auto"))
    hvp_attn_impl = "flash_jvp" if attn_impl == "flash" else attn_impl

    def loss_fn(params, batch):
        return model.loss_fn(cfg, params, batch, remat=tc.remat,
                             attn_impl=attn_impl, loss_impl=loss_impl)

    def init_fn(rng) -> TrainState:
        p_rng, s_rng = jax.random.split(jax.random.PRNGKey(tc.seed)
                                        if rng is None else rng)
        params = model.init_params(cfg, p_rng)
        return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                          opt_state=engine.init(params),
                          clip_state=clipper.init(params), rng=s_rng,
                          comp_state=(compressor.init_shards(
                              engine.layout(params))
                              if compressor is not None else ()))

    def _estimate_flat(params, batch, rng, crng):
        """(est_shards, scale): diagonal-Hessian estimate as flat fp32
        shards in the engine layout — the engine folds ``scale`` into the
        fused Hessian-EMA (GNB's batch factor B, Algorithm 2 line 6).

        With ``compress_hess``, the int8 collective quantizes the
        *gradient-valued* pieces — GNB/E-F's ghat BEFORE squaring (the
        quantity a real data-parallel reduction puts on the wire; squaring
        first would square the per-block dynamic range and zero every
        coordinate below ~max/16 of its scale block), and Hutchinson's
        u ⊙ Hu estimate (the HVP reduction's wire form, u replicated)."""
        lay = engine.layout(params)
        sub = subsample_batch(batch, tc.hess_subbatch) \
            if tc.hess_subbatch else batch
        compress = (hess_compressor.allreduce_shards_stateless
                    if hess_compressor is not None else lambda s, _: s)
        if tc.estimator == "gnb":
            if tc.fused_loss:
                # logits-free Algorithm 2: the label draw happens inside
                # the fused loss kernel's vocab sweep and B rides out as
                # the sweep's valid-position count
                def slf(p):
                    return model.sampled_loss_fn(
                        cfg, p, sub, rng, remat=tc.remat,
                        attn_impl=attn_impl, loss_impl="fused")
                g_sh, scale = gnb_ghat_flat_from_loss(slf, params, lay)
            else:
                def lf(p):
                    return model.logits_fn(cfg, p, sub, remat=tc.remat,
                                           attn_impl=attn_impl)
                g_sh, scale = gnb_ghat_flat(lf, params, rng, lay,
                                            mask=sub.get("mask"))
            g_sh = compress(g_sh, crng)
            return tuple(g * g for g in g_sh), scale
        if tc.estimator == "hutchinson":
            # forward-over-reverse HVP crosses the fused loss through its
            # custom_jvp twin ("fused_jvp": same Pallas forward, linear
            # tangent swept chunk-by-chunk — kernels/fused_ce.py); without
            # fused_loss the chunked jnp loss supports both modes natively
            hvp_impl = "fused_jvp" if tc.fused_loss else "chunked"
            def sf(p):
                return model.loss_fn(cfg, p, sub, remat=tc.remat,
                                     attn_impl=hvp_attn_impl,
                                     loss_impl=hvp_impl)[0]
            est = hutchinson_estimator_flat(sf, params, rng, lay)
            return compress(est, crng), 1.0
        if tc.estimator == "empirical_fisher":
            def sf(p):
                return model.loss_fn(cfg, p, sub, remat=tc.remat,
                                     attn_impl=attn_impl,
                                     loss_impl=loss_impl)[0]
            lead = jax.tree.leaves(sub)[0]
            n = lead.shape[0] * (lead.shape[1] if lead.ndim > 1 else 1)
            g_sh = compress(empirical_fisher_ghat_flat(sf, params, lay),
                            crng)
            return tuple(g * g for g in g_sh), float(n)
        raise ValueError(tc.estimator)

    def train_step(state: TrainState, batch, do_refresh=False):
        """One unified step (Algorithm 3 lines 6-13, refresh flag-gated)."""
        telemetry = tc.comm_telemetry
        t_step0 = None
        if telemetry:
            # stamp step start on a batch leaf and thread the stamped leaf
            # back in, so forward compute provably follows the stamp
            from ..distributed import overlap as _ov
            leaves, treedef = jax.tree.flatten(batch)
            t_step0, l0 = _ov.stamp(leaves[0], 0)
            batch = jax.tree.unflatten(treedef, [l0] + leaves[1:])
        loss, metrics, grads = _accum_grads(loss_fn, state.params, batch,
                                            tc.grad_accum)
        metrics = {"loss": loss, **metrics}
        grads, clip_state = clipper.update(grads, state.clip_state)
        g_sh = engine.ravel_grads(state.params, grads)
        comp_state = state.comp_state
        comm_tele = None
        if compressor is not None:
            # in-collective int8 all-reduce over the flat shards: picks up
            # the fsdp axis from the launcher-installed activation mesh
            # (mesh-less runs use the identical math on the whole shard);
            # bucketed per comm_bucket_elems so the per-bucket collectives
            # can overlap backward compute (distributed/overlap.py)
            out = compressor.allreduce_shards(
                g_sh, comp_state, _fold_rng(state, RNG_TAG_COMPRESS),
                bucket_elems=tc.comm_bucket_elems, telemetry=telemetry)
            if telemetry:
                g_sh, comp_state, comm_tele = out
            else:
                g_sh, comp_state = out
        lr = schedule(state.opt_state.count)

        if engine.hessian_aware:
            # the whole engine dispatch sits under the cond, not just the
            # estimator: the hot branch runs the plain fused step (4 reads +
            # 2 writes per element) and only the refresh branch pays for the
            # estimate operand and the h write — inside that branch the
            # refresh flag is constant True, so the kernel's select folds
            # away and the fused sweep still touches h exactly once
            def _refresh_step():
                est_sh, scale = _estimate_flat(
                    state.params, batch, _fold_rng(state, RNG_TAG_HESS),
                    _fold_rng(state, RNG_TAG_HESS_COMPRESS))
                return engine.step_with_refresh(
                    state.opt_state, state.params, g_sh, lr, est_sh,
                    jnp.asarray(scale, jnp.float32), True)

            def _plain_step():
                return engine.step_shards(state.opt_state, state.params,
                                          g_sh, lr)

            params, opt_state = jax.lax.cond(
                jnp.asarray(do_refresh, bool), _refresh_step, _plain_step)
        else:
            params, opt_state = engine.step_shards(state.opt_state,
                                                   state.params, g_sh, lr)

        metrics = dict(metrics,
                       grad_norm=clip_state.last_norm,
                       clip_triggers=clip_state.triggers,
                       lr=lr)
        if engine.tracks_clip_fraction:
            metrics["sophia_clip_fraction"] = opt_state.clip_fraction
        if telemetry:
            # step end stamped on an updated-params leaf (dataflow pins it
            # after the optimizer write).  comm_seconds is the wall span of
            # the comm *window* (first bucket issued -> last completed) —
            # an upper bound on exposed comm, exact when nothing overlaps;
            # the differential measurement lives in benchmarks/comm_overlap
            from ..distributed import overlap as _ov
            t_step1, _ = _ov.stamp(jax.tree.leaves(params)[0], 1)
            step_s = _ov.delta_seconds(t_step0, t_step1)
            comm_s = (comm_tele["comm_seconds"] if comm_tele is not None
                      else jnp.float32(0))
            metrics["comm_seconds"] = comm_s
            metrics["step_seconds"] = step_s
            metrics["exposed_comm_fraction"] = \
                comm_s / jnp.maximum(step_s, jnp.float32(1e-9))
        return TrainState(step=state.step + 1, params=params,
                          opt_state=opt_state, clip_state=clip_state,
                          rng=state.rng, comp_state=comp_state), metrics

    return init_fn, train_step


def train_loop(cfg: ModelConfig, tc: TrainerConfig, source, *,
               num_steps: int, state: Optional[TrainState] = None,
               jit: bool = True, callback: Optional[Callable] = None,
               start_step: int = 0, donate: bool = False):
    """Single-host reference loop (tests/benchmarks; launch/train.py is the
    production multi-device driver).

    With ``donate=True`` (and a backend that implements donation — CPU
    doesn't), the input TrainState is donated to the jitted step: the flat
    params/m/h buffers update in place, halving optimizer-state peak
    memory.  Opt-in here because it consumes the caller's ``state``
    argument; the production driver always donates."""
    init_fn, train_step = make_train_fns(cfg, tc)
    if jit:
        dn = (0,) if donate and jax.default_backend() != "cpu" else ()
        train_step = jax.jit(train_step, donate_argnums=dn)
    if state is None:
        state = init_fn(jax.random.PRNGKey(tc.seed))
    # the engine registry knows which families refresh curvature
    # out-of-band — a hardcoded optimizer-name tuple here silently skipped
    # refresh for any newly registered curvature family
    needs_hess = hessian_aware_optimizer(tc.optimizer)
    k = tc.hess_interval
    history = []
    for t in range(start_step, start_step + num_steps):
        batch = {k2: jnp.asarray(v) for k2, v in source.batch_at(t).items()}
        flag = jnp.asarray(needs_hess and t % k == 0)
        state, metrics = train_step(state, batch, flag)
        history.append({k2: float(v) for k2, v in metrics.items()})
        if callback is not None:
            callback(t, state, metrics)
    return state, history
