from .train_state import TrainState
from .trainer import (TrainerConfig, make_engine, make_schedule,
                      make_train_fns, train_loop)
from . import checkpoint, elastic
