"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent
per-channel decay.

TPU adaptation (see DESIGN.md): the WKV recurrence

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (per head, S in R^{K x V})
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

is computed CHUNKWISE (chunk = 32 tokens) so the inner work is MXU matmuls
instead of a 4096-step sequential scan.  Within a chunk, the intra-chunk
term uses a per-channel mid-point shift of the cumulative log-decay so all
exponentials stay within fp32 range (|exponent| <= clamp * chunk / 2 = 64).
``wkv_scan`` is the exact sequential reference used by unit tests; decode
uses the O(1) single-step recurrence.

Simplifications vs the released checkpoint (noted per DESIGN.md): static
token-shift mixing coefficients (Finch's ddlerp LoRA on the *mixing* weights
is dropped); the data-dependent decay LoRA — the headline Finch mechanism —
is kept in full.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .common import ModelConfig
from .layers import dense_init, embed, embed_init, rms_norm, unembed

HEAD_DIM = 64
DECAY_LORA = 64
CHUNK = 32
LOG_DECAY_CLAMP = 4.0  # per-step log-decay clamped to [-4, -1e-6]


def n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // HEAD_DIM


def init_layer(cfg: ModelConfig, key):
    D, F = cfg.d_model, cfg.d_ff
    H = n_heads(cfg)
    ks = jax.random.split(key, 12)
    return {
        "ln1": {"scale": jnp.zeros((D,), jnp.float32)},
        "ln2": {"scale": jnp.zeros((D,), jnp.float32)},
        "tm": {
            "mu": 0.5 * jnp.ones((5, D), jnp.float32),  # r,k,v,g,w shift mix
            "wr": dense_init(ks[0], (D, D)),
            "wk": dense_init(ks[1], (D, D)),
            "wv": dense_init(ks[2], (D, D)),
            "wg": dense_init(ks[3], (D, D)),
            "wo": dense_init(ks[4], (D, D), in_axis=0),
            "w0": -5.0 + jnp.zeros((D,), jnp.float32),   # base decay (slow)
            "wa": dense_init(ks[5], (D, DECAY_LORA)) * 0.1,
            "wb": dense_init(ks[6], (DECAY_LORA, D), in_axis=0) * 0.1,
            "u": (jax.random.normal(ks[7], (H, HEAD_DIM)) * 0.1).astype(jnp.float32),
            "ln_x": {"scale": jnp.zeros((D,), jnp.float32)},
        },
        "cm": {
            "mu_k": 0.5 * jnp.ones((D,), jnp.float32),
            "mu_r": 0.5 * jnp.ones((D,), jnp.float32),
            "wk": dense_init(ks[8], (D, F)),
            "wv": dense_init(ks[9], (F, D), in_axis=0),
            "wr": dense_init(ks[10], (D, D)),
        },
    }


def init_params(cfg: ModelConfig, key) -> dict:
    kemb, klay = jax.random.split(key)
    keys = jax.random.split(klay, cfg.n_layers)
    return {
        "embed": init_embedding_rwkv(kemb, cfg),
        "layers": jax.vmap(lambda k: init_layer(cfg, k))(keys),
        "final_norm": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
    }


def init_embedding_rwkv(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    p = {"tok": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model))}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.padded_vocab))
    return p


def _token_shift(x, prev):
    """prev: (B, D) state of the previous token; returns shifted x."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _decay(tm, xw):
    """Data-dependent per-channel log-decay, clamped for fp32 chunk math."""
    dt = xw.dtype
    lora = jnp.tanh(xw @ tm["wa"].astype(dt)) @ tm["wb"].astype(dt)
    raw = tm["w0"].astype(jnp.float32) + lora.astype(jnp.float32)
    logw = -jnp.exp(raw)                       # always negative
    return jnp.clip(logw, -LOG_DECAY_CLAMP, -1e-6)


# ---------------------------------------------------------------------------
# WKV kernels


def wkv_scan(r, k, v, logw, u, state):
    """Exact sequential WKV (reference / oracle).

    r,k,v: (B, S, H, K); logw: (B, S, H, K); u: (H, K);
    state: (B, H, K, V_dim).  Returns (out (B,S,H,K), final_state).
    """
    def step(S, inp):
        r_t, k_t, v_t, lw_t = inp                       # (B,H,K)...
        kv = k_t[..., :, None] * v_t[..., None, :]      # (B,H,K,V)
        o = jnp.einsum("bhk,bhkv->bhv", r_t,
                       S + u[None, :, :, None] * kv)
        S = jnp.exp(lw_t)[..., None] * S + kv
        return S, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, logw))
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1), state


def wkv_chunked(r, k, v, logw, u, state, chunk: int = CHUNK):
    """Chunkwise-parallel WKV (TPU path; matmuls on the MXU).

    Same signature/semantics as :func:`wkv_scan` (allclose-tested).
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    assert S % chunk == 0, f"seq {S} % chunk {chunk} != 0"
    n = S // chunk
    f32 = jnp.float32

    def reshape(t):
        return t.astype(f32).reshape(B, n, chunk, H, K).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, lwc = map(reshape, (r, k, v, logw))     # (n, B, H, C, K)

    def body(S_in, inp):
        rt, kt, vt, lw = inp                            # (B, H, C, K)
        LP = jnp.cumsum(lw, axis=2)                     # inclusive log-prods
        LP_prev = LP - lw                               # exclusive
        mid = LP[:, :, chunk // 2, :][:, :, None, :]    # per-channel shift
        # intra-chunk: A[t,i] = sum_c r[t,c] k[i,c] exp(LP_prev[t,c]-LP[i,c])
        r_sh = rt * jnp.exp(LP_prev - mid)
        k_sh = kt * jnp.exp(mid - LP)
        A = jnp.einsum("bhtk,bhik->bhti", r_sh, k_sh)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        A = jnp.where(tri[None, None], A, 0.0)
        # current-token bonus via the diagonal
        bonus = jnp.einsum("bhtk,bhtk->bht", rt * u[None, :, None, :], kt)
        A = A + jnp.eye(chunk)[None, None] * bonus[..., None]
        o_intra = jnp.einsum("bhti,bhiv->bhtv", A, vt)
        # inter-chunk: r~_t = r_t exp(LP_prev) reads the carried state
        o_state = jnp.einsum("bhtk,bhkv->bhtv", rt * jnp.exp(LP_prev), S_in)
        # state update: S_out = diag(exp(LP_end)) S_in + sum_i (exp(LP_end-LP_i) k_i) v_i
        LP_end = LP[:, :, -1:, :]
        k_dec = kt * jnp.exp(LP_end - LP)
        S_out = (jnp.exp(LP_end.squeeze(2))[..., None] * S_in
                 + jnp.einsum("bhik,bhiv->bhkv", k_dec, vt))
        return S_out, o_intra + o_state

    state, out = jax.lax.scan(body, state.astype(f32), (rc, kc, vc, lwc))
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, S, H, V)
    return out, state


# ---------------------------------------------------------------------------
# blocks


def time_mix(tm, x, cfg: ModelConfig, prev_tok, wkv_state, *,
             chunked: bool = True):
    """x: (B,S,D) normed input.  Returns (out, last_tok, new_wkv_state)."""
    dt = x.dtype
    B, S, D = x.shape
    H = n_heads(cfg)
    xs = _token_shift(x, prev_tok)
    mu = tm["mu"].astype(dt)
    xr, xk, xv, xg, xw = (x + (xs - x) * mu[i] for i in range(5))
    r = (xr @ tm["wr"].astype(dt)).reshape(B, S, H, HEAD_DIM)
    k = (xk @ tm["wk"].astype(dt)).reshape(B, S, H, HEAD_DIM)
    v = (xv @ tm["wv"].astype(dt)).reshape(B, S, H, HEAD_DIM)
    g = jax.nn.silu(xg @ tm["wg"].astype(dt))
    logw = _decay(tm, xw).reshape(B, S, H, HEAD_DIM)
    u = tm["u"].astype(jnp.float32)
    fn = wkv_chunked if (chunked and S % CHUNK == 0) else wkv_scan
    o, new_state = fn(r.astype(jnp.float32), k.astype(jnp.float32),
                      v.astype(jnp.float32), logw, u, wkv_state)
    o = o.reshape(B, S, D)
    # per-head group norm (RWKV "ln_x")
    o = o.reshape(B, S, H, HEAD_DIM)
    o = o * jax.lax.rsqrt(jnp.mean(jnp.square(o), -1, keepdims=True) + 1e-5)
    o = o.reshape(B, S, D) * (1.0 + tm["ln_x"]["scale"].astype(jnp.float32))
    out = (o.astype(dt) * g) @ tm["wo"].astype(dt)
    return out, x[:, -1], new_state


def channel_mix(cm, x, prev_tok):
    dt = x.dtype
    xs = _token_shift(x, prev_tok)
    xk = x + (xs - x) * cm["mu_k"].astype(dt)
    xr = x + (xs - x) * cm["mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(xk @ cm["wk"].astype(dt)))
    return jax.nn.sigmoid(xr @ cm["wr"].astype(dt)) * (kk @ cm["wv"].astype(dt)), x[:, -1]


def _zero_layer_state(cfg: ModelConfig, B: int):
    H = n_heads(cfg)
    return {"tm_shift": jnp.zeros((B, cfg.d_model), cfg.compute_dtype),
            "cm_shift": jnp.zeros((B, cfg.d_model), cfg.compute_dtype),
            "wkv": jnp.zeros((B, H, HEAD_DIM, HEAD_DIM), jnp.float32)}


def init_state(cfg: ModelConfig, batch_size: int) -> dict:
    """Stacked per-layer recurrent state (the rwkv 'KV cache')."""
    one = _zero_layer_state(cfg, batch_size)
    return jax.tree.map(
        lambda z: jnp.broadcast_to(z[None], (cfg.n_layers,) + z.shape), one)


def forward_hidden(cfg: ModelConfig, params, tokens, *, state=None,
                   remat="none", chunked=True, last_only=False,
                   final_norm=True, **_):
    """Trunk -> (final-norm hidden, aux, new_state); the loss paths skip
    the unembedding projection entirely (models/loss.py)."""
    B, S = tokens.shape
    x = embed(params["embed"], tokens, cfg)
    if state is None:
        state = init_state(cfg, B)

    def body(x, layer):
        p, st = layer
        h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        o, last_tm, wkv = time_mix(p["tm"], h, cfg, st["tm_shift"],
                                   st["wkv"], chunked=chunked)
        x = x + o
        h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        o, last_cm = channel_mix(p["cm"], h, st["cm_shift"])
        from ..distributed.sharding import residual_axes
        x = constrain(x + o, *residual_axes())
        return x, {"tm_shift": last_tm, "cm_shift": last_cm, "wkv": wkv}

    if remat == "full":
        body = jax.checkpoint(body)
    x, new_state = jax.lax.scan(body, x, (params["layers"], state))
    if last_only:
        x = x[:, -1:]
    if final_norm:
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32), new_state


def forward(cfg: ModelConfig, params, tokens, *, state=None, remat="none",
            chunked=True, last_only=False, **_):
    x, aux, new_state = forward_hidden(cfg, params, tokens, state=state,
                                       remat=remat, chunked=chunked,
                                       last_only=last_only)
    return unembed(params["embed"], x, cfg), aux, new_state


def loss_fn(cfg: ModelConfig, params, batch, *, remat="none",
            loss_impl=None, **_):
    from .loss import lm_loss
    hidden, aux, _ = forward_hidden(cfg, params, batch["tokens"],
                                    remat=remat, final_norm=False)
    ce, _ = lm_loss(cfg, params, hidden, batch["labels"],
                    batch.get("mask"), impl=loss_impl, pre_norm="rms")
    return ce, {"ce": ce, "aux": aux}


def sampled_loss_fn(cfg: ModelConfig, params, batch, rng, *, remat="none",
                    loss_impl=None, **_):
    from .loss import lm_loss_sampled
    hidden, _, _ = forward_hidden(cfg, params, batch["tokens"], remat=remat,
                                  final_norm=False)
    return lm_loss_sampled(cfg, params, hidden, rng, batch.get("mask"),
                           impl=loss_impl, pre_norm="rms")


def logits_fn(cfg: ModelConfig, params, batch, **_):
    return forward(cfg, params, batch["tokens"])[0]


def decode_step(cfg: ModelConfig, params, state, tokens, position=None):
    """O(1) decode: state carries shift tokens + WKV matrices per layer.

    ``position`` is accepted for signature uniformity with the attention
    families and ignored — the recurrence is position-free.
    """
    logits, _, state = forward(cfg, params, tokens, state=state,
                               chunked=False)
    return logits, state


# ---------------------------------------------------------------------------
# slot protocol (continuous-batching serve engine; see serve/engine.py)
#
# The recurrent state is already slot-major: every leaf carries the batch
# axis at position 1 under the layer axis, so slots are independent rows.
# Unlike the ring KV cache, recurrent state MUST be zeroed on slot reuse —
# there is no mask to hide a previous request's recurrence.


def init_slots(cfg: ModelConfig, n_slots: int, cache_len: int = 0) -> dict:
    """``cache_len`` ignored — O(1) state regardless of request length."""
    if cfg.kv_dtype != "bf16":
        raise ValueError("kv_dtype=int8 is implemented for the paged-KV "
                         "families (dense/moe); rwkv has no KV cache")
    return init_state(cfg, n_slots)


def reset_slot(cfg: ModelConfig, state, slot):
    """Zero slot ``slot``'s recurrent state (traced slot index)."""
    from .layers import slot_update
    row = jax.tree.map(
        lambda leaf: jnp.zeros((leaf.shape[0], 1) + leaf.shape[2:],
                               leaf.dtype), state)
    return slot_update(state, row, slot)


def decode_slots(cfg: ModelConfig, params, state, tokens, positions):
    """One decode step across all slots.  positions accepted and ignored."""
    logits, _, state = forward(cfg, params, tokens, state=state,
                               chunked=False)
    return logits, state


def prefill_into_slot(cfg: ModelConfig, params, state, slot, tokens, start,
                      n_valid):
    """Chunk-prefill one slot: scan the chunk token-by-token through the
    O(1) recurrence, freezing the state once ``n_valid`` tokens have been
    absorbed (the padded tail must not touch the recurrence).  tokens
    (1, P); returns (new_state, logits (V,) fp32 of the last valid token).
    """
    from .layers import slot_slice, slot_update
    P = tokens.shape[1]
    row = slot_slice(state, slot)

    def step(carry, t):
        st, logits = carry
        lg, _, st_new = forward(cfg, params,
                                jax.lax.dynamic_slice_in_dim(tokens, t, 1,
                                                             axis=1),
                                state=st, chunked=False)
        ok = t < n_valid
        st = jax.tree.map(lambda a, b: jnp.where(ok, b, a), st, st_new)
        logits = jnp.where(ok, lg[0, -1], logits)
        return (st, logits), None

    init_logits = jnp.zeros((cfg.padded_vocab,), jnp.float32)
    (row, logits), _ = jax.lax.scan(step, (row, init_logits),
                                    jnp.arange(P, dtype=jnp.int32))
    return slot_update(state, row, slot), logits
