"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU recurrent blocks
interleaved with local (sliding-window) MQA attention at a 2:1 ratio.

Block pattern: groups of (recurrent, recurrent, local-attn); 26 layers =
8 groups + 2 tail recurrent layers.  The RG-LRU diagonal linear recurrence

    a_t = exp(c * r_t * log sigmoid(Lambda))          (data-dependent decay)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

is evaluated with ``lax.associative_scan`` (TPU log-depth scan) in training
and as an O(1) step in decode.  Bounded state (h + conv tail + 2048-window
KV) makes this arch eligible for the long_500k serve cell.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .common import ModelConfig
from .layers import (chunked_attention, decode_attention,
                     decode_attention_slots, dense_init, embed,
                     full_attention, init_attention, init_embedding,
                     init_mlp, mlp, rms_norm, slot_slice, slot_update,
                     train_attention, unembed)

RG_LRU_C = 8.0


def rnn_width(cfg: ModelConfig) -> int:
    return cfg.rnn_width or cfg.d_model


# ---------------------------------------------------------------------------
# params


def _init_norm(cfg):
    return {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}


def _init_rec_block(cfg: ModelConfig, key):
    D, W = cfg.d_model, rnn_width(cfg)
    ks = jax.random.split(key, 8)
    return {
        "ln": _init_norm(cfg),
        "w_in": dense_init(ks[0], (D, W)),
        "w_gate": dense_init(ks[1], (D, W)),
        "conv_k": (jax.random.normal(ks[2], (cfg.conv_width, W)) * 0.1
                   ).astype(jnp.float32),
        "conv_b": jnp.zeros((W,), jnp.float32),
        "lam": jnp.linspace(2.0, 5.0, W).astype(jnp.float32),  # a in (.88,.99)
        "w_a": dense_init(ks[3], (W, W)),
        "b_a": jnp.zeros((W,), jnp.float32),
        "w_x": dense_init(ks[4], (W, W)),
        "b_x": jnp.zeros((W,), jnp.float32),
        "w_out": dense_init(ks[5], (W, D), in_axis=0),
        "ln_mlp": _init_norm(cfg),
        "mlp": init_mlp(ks[6], cfg),
    }


def _init_attn_block(cfg: ModelConfig, key):
    ks = jax.random.split(key, 2)
    return {"ln": _init_norm(cfg), "attn": init_attention(ks[0], cfg),
            "ln_mlp": _init_norm(cfg), "mlp": init_mlp(ks[1], cfg)}


def n_groups(cfg: ModelConfig) -> int:
    return cfg.n_layers // 3


def n_tail(cfg: ModelConfig) -> int:
    return cfg.n_layers % 3


def init_params(cfg: ModelConfig, key) -> dict:
    kemb, kgrp, ktail = jax.random.split(key, 3)
    gkeys = jax.random.split(kgrp, n_groups(cfg))

    def one_group(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"rec1": _init_rec_block(cfg, k1),
                "rec2": _init_rec_block(cfg, k2),
                "attn": _init_attn_block(cfg, k3)}

    params = {"embed": init_embedding(kemb, cfg),
              "groups": jax.vmap(one_group)(gkeys),
              "final_norm": _init_norm(cfg)}
    if n_tail(cfg):
        tkeys = jax.random.split(ktail, n_tail(cfg))
        params["tail"] = jax.vmap(lambda k: _init_rec_block(cfg, k))(tkeys)
    return params


# ---------------------------------------------------------------------------
# RG-LRU + conv


def causal_conv1d(x, kernel, bias, conv_state=None):
    """Depthwise causal conv.  x (B,S,W), kernel (cw,W).

    conv_state (B, cw-1, W): trailing inputs from the previous segment.
    Returns (y, new_conv_state).
    """
    cw = kernel.shape[0]
    B, S, W = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((B, cw - 1, W), x.dtype)
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, j:j + S] * kernel[j].astype(x.dtype) for j in range(cw))
    return y + bias.astype(x.dtype), xp[:, -(cw - 1):]


def rg_lru(u, r_gate, i_gate, lam, h0=None):
    """u, gates: (B,S,W) fp32; returns (h (B,S,W), h_last (B,W))."""
    log_a = RG_LRU_C * r_gate * jax.nn.log_sigmoid(lam)  # negative
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 0.0, 1.0)) * (i_gate * u)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    # first-order linear recurrence via associative scan over time axis
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    ah, bh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return bh, bh[:, -1]


def rec_block_apply(p, x, cfg: ModelConfig, state=None):
    """Returns (out, new_state dict(conv, h))."""
    dt = x.dtype
    W = rnn_width(cfg)
    h = rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
    gate = jax.nn.gelu(h @ p["w_gate"].astype(dt))
    u = h @ p["w_in"].astype(dt)
    conv_state = state["conv"] if state is not None else None
    u, new_conv = causal_conv1d(u, p["conv_k"], p["conv_b"], conv_state)
    u32 = u.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(u32 @ p["w_a"].astype(jnp.float32)
                            + p["b_a"].astype(jnp.float32))
    i_gate = jax.nn.sigmoid(u32 @ p["w_x"].astype(jnp.float32)
                            + p["b_x"].astype(jnp.float32))
    h0 = state["h"] if state is not None else None
    y, h_last = rg_lru(u32, r_gate, i_gate, p["lam"].astype(jnp.float32), h0)
    y = (y.astype(dt) * gate) @ p["w_out"].astype(dt)
    x = x + y
    hm = rms_norm(x, p["ln_mlp"]["scale"], cfg.norm_eps)
    from ..distributed.sharding import residual_axes
    x = constrain(x + mlp(p["mlp"], hm, cfg), *residual_axes())
    return x, {"conv": new_conv, "h": h_last}


def attn_block_apply(p, x, cfg: ModelConfig, positions, attn_impl="auto"):
    h = rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
    x = x + train_attention(p["attn"], h, cfg, positions,
                            window=cfg.local_window, impl=attn_impl)
    hm = rms_norm(x, p["ln_mlp"]["scale"], cfg.norm_eps)
    from ..distributed.sharding import residual_axes
    return constrain(x + mlp(p["mlp"], hm, cfg), *residual_axes())


# ---------------------------------------------------------------------------
# forward / loss


def forward_hidden(cfg: ModelConfig, params, tokens, *, attn_impl="auto",
                   remat="none", last_only=False, final_norm=True, **_):
    """Trunk -> (final-norm hidden, aux); the loss paths skip the
    unembedding projection entirely (models/loss.py)."""
    B, S = tokens.shape
    x = embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, p):
        x, _ = rec_block_apply(p["rec1"], x, cfg)
        x, _ = rec_block_apply(p["rec2"], x, cfg)
        x = attn_block_apply(p["attn"], x, cfg, positions, attn_impl)
        return x, None

    if remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["groups"])
    if n_tail(cfg):
        def tail_body(x, p):
            x, _ = rec_block_apply(p, x, cfg)
            return x, None
        x, _ = jax.lax.scan(tail_body, x, params["tail"])
    if last_only:
        x = x[:, -1:]
    if final_norm:
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def forward(cfg: ModelConfig, params, tokens, *, attn_impl="auto",
            remat="none", last_only=False, **_):
    x, aux = forward_hidden(cfg, params, tokens, attn_impl=attn_impl,
                            remat=remat, last_only=last_only)
    return unembed(params["embed"], x, cfg), aux


def loss_fn(cfg: ModelConfig, params, batch, *, remat="none",
            loss_impl=None, **_):
    from .loss import lm_loss
    hidden, aux = forward_hidden(cfg, params, batch["tokens"], remat=remat,
                                 final_norm=False)
    ce, _ = lm_loss(cfg, params, hidden, batch["labels"],
                    batch.get("mask"), impl=loss_impl, pre_norm="rms")
    return ce, {"ce": ce, "aux": aux}


def sampled_loss_fn(cfg: ModelConfig, params, batch, rng, *, remat="none",
                    loss_impl=None, **_):
    from .loss import lm_loss_sampled
    hidden, _ = forward_hidden(cfg, params, batch["tokens"], remat=remat,
                               final_norm=False)
    return lm_loss_sampled(cfg, params, hidden, rng, batch.get("mask"),
                           impl=loss_impl, pre_norm="rms")


def logits_fn(cfg: ModelConfig, params, batch, **_):
    return forward(cfg, params, batch["tokens"])[0]


# ---------------------------------------------------------------------------
# decode (bounded state: h + conv tail + rolling window KV)


def init_state(cfg: ModelConfig, batch_size: int) -> dict:
    W = rnn_width(cfg)
    win = cfg.local_window
    g = n_groups(cfg)
    dt = cfg.compute_dtype

    def rec_state(n):
        return {"conv": jnp.zeros((n, batch_size, cfg.conv_width - 1, W), dt),
                "h": jnp.zeros((n, batch_size, W), jnp.float32)}

    state = {
        "rec1": rec_state(g), "rec2": rec_state(g),
        "kv": {"k": jnp.zeros((g, batch_size, win, cfg.n_kv_heads, cfg.hd), dt),
               "v": jnp.zeros((g, batch_size, win, cfg.n_kv_heads, cfg.hd), dt)},
    }
    if n_tail(cfg):
        state["tail"] = rec_state(n_tail(cfg))
    return state


def decode_step(cfg: ModelConfig, params, state, tokens, position):
    """One token with bounded state.  tokens (B,1); position scalar int32."""
    B = tokens.shape[0]
    x = embed(params["embed"], tokens, cfg)
    win = cfg.local_window
    slot = position % win

    def rec_step(p, x, st):
        return rec_block_apply(p, x, cfg, state=st)

    def body(x, layer):
        p, st_r1, st_r2, k_c, v_c = layer
        x, n1 = rec_step(p["rec1"], x, st_r1)
        x, n2 = rec_step(p["rec2"], x, st_r2)
        # local attention over the rolling window
        h = rms_norm(x, p["attn"]["ln"]["scale"], cfg.norm_eps)
        a, k_c, v_c = _rolling_attention(p["attn"]["attn"], h, cfg, k_c, v_c,
                                         position, slot)
        x = x + a
        hm = rms_norm(x, p["attn"]["ln_mlp"]["scale"], cfg.norm_eps)
        x = x + mlp(p["attn"]["mlp"], hm, cfg)
        return x, (n1, n2, k_c, v_c)

    x, (n1, n2, nk, nv) = jax.lax.scan(
        body, x, (params["groups"], state["rec1"], state["rec2"],
                  state["kv"]["k"], state["kv"]["v"]))
    new_state = {"rec1": n1, "rec2": n2, "kv": {"k": nk, "v": nv}}
    if n_tail(cfg):
        def tail_body(x, layer):
            p, st = layer
            x, ns = rec_step(p, x, st)
            return x, ns
        x, nt = jax.lax.scan(tail_body, x, (params["tail"], state["tail"]))
        new_state["tail"] = nt
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return unembed(params["embed"], x, cfg), new_state


# ---------------------------------------------------------------------------
# slot protocol (continuous-batching serve engine; see serve/engine.py)
#
# The rolling-window KV cache IS a ring cache of size local_window, so the
# attention tail reuses layers.decode_attention_slots unchanged (and with
# it the Pallas decode kernel).  The recurrent state (h, conv) must be
# zeroed on slot reuse; the window KV is ring-masked and needs no reset.


def init_slots(cfg: ModelConfig, n_slots: int, cache_len: int = 0) -> dict:
    """``cache_len`` ignored — state is bounded by ``local_window``."""
    if cfg.kv_dtype != "bf16":
        raise ValueError(
            "kv_dtype=int8 targets unbounded paged KV (dense/moe); griffin's "
            f"rolling window is already bounded at {cfg.local_window} tokens")
    return init_state(cfg, n_slots)


def reset_slot(cfg: ModelConfig, state, slot):
    rec = {k: state[k] for k in state if k != "kv"}
    zeros = jax.tree.map(
        lambda leaf: jnp.zeros((leaf.shape[0], 1) + leaf.shape[2:],
                               leaf.dtype), rec)
    return dict(slot_update(rec, zeros, slot), kv=state["kv"])


def decode_slots(cfg: ModelConfig, params, state, tokens, positions):
    """One decode step across all slots.  tokens (N, 1); positions (N,)."""
    positions = positions.astype(jnp.int32)
    x = embed(params["embed"], tokens, cfg)

    def body(x, layer):
        p, st_r1, st_r2, k_c, v_c = layer
        x, n1 = rec_block_apply(p["rec1"], x, cfg, state=st_r1)
        x, n2 = rec_block_apply(p["rec2"], x, cfg, state=st_r2)
        h = rms_norm(x, p["attn"]["ln"]["scale"], cfg.norm_eps)
        a, kv_l = decode_attention_slots(p["attn"]["attn"], h, cfg,
                                         {"k": k_c, "v": v_c}, positions)
        k_c, v_c = kv_l["k"], kv_l["v"]
        x = x + a
        hm = rms_norm(x, p["attn"]["ln_mlp"]["scale"], cfg.norm_eps)
        x = x + mlp(p["attn"]["mlp"], hm, cfg)
        return x, (n1, n2, k_c, v_c)

    x, (n1, n2, nk, nv) = jax.lax.scan(
        body, x, (params["groups"], state["rec1"], state["rec2"],
                  state["kv"]["k"], state["kv"]["v"]))
    new_state = {"rec1": n1, "rec2": n2, "kv": {"k": nk, "v": nv}}
    if n_tail(cfg):
        def tail_body(x, layer):
            p, st = layer
            x, ns = rec_block_apply(p, x, cfg, state=st)
            return x, ns
        x, nt = jax.lax.scan(tail_body, x, (params["tail"], state["tail"]))
        new_state["tail"] = nt
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return unembed(params["embed"], x, cfg), new_state


def prefill_into_slot(cfg: ModelConfig, params, state, slot, tokens, start,
                      n_valid):
    """Chunk-prefill one slot token-by-token through the O(1) recurrence
    (masked past ``n_valid``).  tokens (1, P); returns (new_state,
    logits (V,) fp32 of the last valid token)."""
    P = tokens.shape[1]
    start = jnp.asarray(start, jnp.int32)
    row = slot_slice(state, slot)

    def step(carry, t):
        st, logits = carry
        tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
        lg, st_new = decode_slots(cfg, params, st, tok,
                                  (start + t)[None])
        ok = t < n_valid
        st = jax.tree.map(lambda a, b: jnp.where(ok, b, a), st, st_new)
        logits = jnp.where(ok, lg[0, -1], logits)
        return (st, logits), None

    init_logits = jnp.zeros((cfg.padded_vocab,), jnp.float32)
    (row, logits), _ = jax.lax.scan(step, (row, init_logits),
                                    jnp.arange(P, dtype=jnp.int32))
    return slot_update(state, row, slot), logits


def _rolling_attention(p, x, cfg: ModelConfig, k_cache, v_cache, position,
                       slot):
    """MQA decode over a rolling window cache (size = local_window)."""
    from .layers import _qkv, apply_rope, attention_scores_block
    dt = x.dtype
    B = x.shape[0]
    win = cfg.local_window
    q, k, v = _qkv(p, x, cfg)
    pos = jnp.full((B, 1), position, jnp.int32)
    if cfg.rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)
    scale = 1.0 / math.sqrt(cfg.hd)
    scores = attention_scores_block(q, k_cache, cfg, scale)  # (B,Hkv,G,1,win)
    # slot s holds absolute position  pos - ((pos - s) mod win)
    s_idx = jnp.arange(win)
    abs_pos = position - jnp.mod(position - s_idx, win)
    mask = abs_pos >= 0
    scores = jnp.where(mask[None, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(dt)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v_cache)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
    return out @ p["wo"].astype(dt), k_cache, v_cache
