"""Mixture-of-Experts FFN: token-choice top-k routing, capacity-bounded
sort-based dispatch (TPU-native).

Why not GShard one-hot dispatch: the (T, E, C) combine tensor (or even the
(T*K, E) one-hot cumsum for slot assignment) is O(T*E) memory — at the
assigned train shape (1M tokens, 128 experts) that is hundreds of GB.
Instead we do MEGABLOCKS-style group-local assignment:

  * tokens are viewed as G groups of ``moe_group_size`` (the group axis
    inherits the batch/data sharding — assignment is embarrassingly
    parallel and costs O(S_g log S_g) per group via XLA sort);
  * within a group, a token's slot in its expert = its rank among the
    group's tokens choosing that expert (argsort + searchsorted — no
    one-hot materialization);
  * tokens are scattered into an (E, G*C_g, D) buffer (E sharded over the
    ``model`` axis = expert parallelism; the scatter/gather lowers to
    all-to-alls), batched-matmul'd per expert, and gathered back.

Over-capacity tokens are dropped (their routed contribution is zero);
shared experts are dense and always-on.  Covers both assigned MoE archs:
llama4-maverick (128e top-1 + 1 shared, MoE every other layer) and
deepseek-moe-16b (64e top-6 + 2 shared, fine-grained).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import activation_mesh, batch_axis, constrain
from .common import ModelConfig
from .layers import dense_init

GROUP_SIZE = 4096  # tokens per assignment group

# dispatch implementation: "gspmd" (scatter/gather, compiler-chosen
# collectives — the baseline) or "a2a" (shard_map with explicit all-to-all —
# the EP-optimized path, see EXPERIMENTS.md §Perf hillclimb 1)
_MOE_IMPL = {"impl": "gspmd"}


def set_moe_impl(impl: str) -> None:
    assert impl in ("gspmd", "a2a"), impl
    _MOE_IMPL["impl"] = impl


def get_moe_impl() -> str:
    return _MOE_IMPL["impl"]


def init_moe(key, cfg: ModelConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E)),
        "w_gate": dense_init(ks[1], (E, D, F)),
        "w_up": dense_init(ks[2], (E, D, F)),
        "w_down": dense_init(ks[3], (E, F, D), in_axis=1),
    }
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {"w_gate": dense_init(kk[0], (D, Fs)),
                       "w_up": dense_init(kk[1], (D, Fs)),
                       "w_down": dense_init(kk[2], (Fs, D), in_axis=0)}
    return p


def _group_capacity(sg: int, k: int, n_experts: int, factor: float) -> int:
    c = int(sg * k * factor / n_experts)
    return max(8, (c + 7) // 8 * 8)  # 8-align for TPU layouts


def _slots_in_group(e_g: jnp.ndarray) -> jnp.ndarray:
    """e_g: (N,) expert ids -> slot of each entry within its expert
    (rank among same-expert entries, group-local).  O(N log N), no one-hot.
    """
    order = jnp.argsort(e_g, stable=True)
    e_sorted = e_g[order]
    first = jnp.searchsorted(e_sorted, e_sorted, side="left")
    pos_sorted = jnp.arange(e_g.shape[0], dtype=jnp.int32) - first
    return jnp.zeros_like(e_g).at[order].set(pos_sorted.astype(e_g.dtype))


def moe_ffn(p, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).  Dispatches on the installed impl."""
    mesh = activation_mesh()
    if (_MOE_IMPL["impl"] == "a2a" and mesh is not None
            and "model" in mesh.axis_names
            and cfg.n_experts % mesh.shape["model"] == 0):
        return moe_ffn_a2a(p, x, cfg, mesh)
    return moe_ffn_gspmd(p, x, cfg)


def moe_ffn_gspmd(p, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Baseline dispatch: scatter/gather with compiler-chosen collectives."""
    dt = x.dtype
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    T = B * S
    Gsz = min(GROUP_SIZE, T)
    G = T // Gsz
    Cg = _group_capacity(Gsz, K, E, cfg.capacity_factor)
    xt = x.reshape(T, D)

    # --- router (fp32) ---
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)               # (T, K)
    if K > 1:  # deepseek renormalizes the selected gates
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- load-balance aux (Switch-style): E * sum(me * ce) ---
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (T * K))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # --- group-local slot assignment (sort-based, O(T log Sg) memory O(T)) ---
    flat_e = expert_idx.reshape(G, Gsz * K).astype(jnp.int32)
    slot = jax.vmap(_slots_in_group)(flat_e)                      # (G, Sg*K)
    keep = slot < Cg
    flat_e = flat_e.reshape(-1)
    slot = slot.reshape(-1)
    keep = keep.reshape(-1)
    g_idx = jnp.repeat(jnp.arange(G, dtype=jnp.int32), Gsz * K)
    buf_slot = jnp.where(keep, g_idx * Cg + slot, 0)

    # --- scatter tokens into (E, G*Cg, D) expert buffers ---
    tok_idx = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    xk = jnp.where(keep[:, None], xt[tok_idx], 0).astype(dt)
    buf = jnp.zeros((E, G * Cg, D), dt).at[flat_e, buf_slot].add(xk)
    buf = constrain(buf, "model", None, None)  # expert parallelism

    # --- batched expert FFN on the MXU ---
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dt)))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt))
    eout = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(dt))

    # --- gather back, gate, combine over K ---
    yk = eout[flat_e, buf_slot] * keep[:, None].astype(dt)
    yk = yk * gate_vals.reshape(-1)[:, None].astype(dt)
    out = jnp.zeros((T, D), dt).at[tok_idx].add(yk)

    # --- shared experts (dense, always-on) ---
    if cfg.n_shared_experts:
        sp = p["shared"]
        sg = jax.nn.silu(xt @ sp["w_gate"].astype(dt))
        out = out + (sg * (xt @ sp["w_up"].astype(dt))) @ sp["w_down"].astype(dt)

    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# EP-optimized dispatch: shard_map + explicit all-to-all (hillclimb 1)
#
# The gspmd scatter above has data-dependent indices, which GSPMD cannot turn
# into an all-to-all: it all-gathers the (T*K, D) dispatch tensor to every
# device (~2 x T*K*D bytes/device/layer).  Here the collective schedule is
# written by hand: each (data, model) shard routes 1/M of its local tokens,
# packs per-destination send buffers, and one all_to_all each way moves only
# the tokens themselves (T_loc*K*cf*D / M bytes per device per direction).


@functools.lru_cache(maxsize=1)
def _shard_map_api():
    """(shard_map, kwargs-to-disable-replication-checking), resolved once.

    jax renamed check_rep -> check_vma when shard_map left experimental;
    keying on the actual signature covers both generations."""
    import inspect
    try:
        from jax import shard_map
    except ImportError:  # older jax
        from jax.experimental.shard_map import shard_map
    try:
        params = inspect.signature(shard_map).parameters
        no_check = ({"check_vma": False} if "check_vma" in params
                    else {"check_rep": False})
    except (TypeError, ValueError):  # wrapper with opaque signature
        no_check = {}
    return shard_map, no_check


def moe_ffn_a2a(p, x, cfg: ModelConfig, mesh):
    from jax.sharding import PartitionSpec as P
    shard_map, no_check = _shard_map_api()

    dt = x.dtype
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    M = mesh.shape["model"]
    ep = E // M
    b_ax = batch_axis(mesh)

    def _local(xb, router, wg, wu, wd_, shared):
        """One (data, model) shard.  xb: (B_loc, S, D) replicated over model;
        wg/wu/wd_: this shard's (ep, D, F) expert slice."""
        m_idx = jax.lax.axis_index("model")
        T_loc = xb.shape[0] * S
        xt = xb.reshape(T_loc, D)
        # my 1/M slice of the local tokens (model shards split routing work)
        Tm = T_loc // M
        xm = jax.lax.dynamic_slice_in_dim(xt, m_idx * Tm, Tm, axis=0)

        logits = xm.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)        # (Tm, K)
        if K > 1:
            gate_vals = gate_vals / jnp.maximum(
                gate_vals.sum(-1, keepdims=True), 1e-9)
        me = probs.mean(0)
        ce_ = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
            1.0 / (Tm * K))
        aux = cfg.router_aux_coef * E * jnp.sum(me * ce_)
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))

        # pack per-destination send buffers: dst shard = expert // ep
        flat_e = expert_idx.reshape(-1).astype(jnp.int32)      # (Tm*K,)
        dst = flat_e // ep
        cap = _group_capacity(Tm, K, M, cfg.capacity_factor)
        slot = _slots_in_group(dst)                            # rank per dst
        keep = slot < cap
        slot = jnp.where(keep, slot, 0)
        tok = jnp.repeat(jnp.arange(Tm, dtype=jnp.int32), K)
        send_x = jnp.zeros((M, cap, D), dt).at[dst, slot].add(
            jnp.where(keep[:, None], xm[tok].astype(dt), 0))
        send_e = jnp.full((M, cap), E, jnp.int32).at[dst, slot].set(
            jnp.where(keep, flat_e, E))                        # E = invalid

        # all-to-all over the model axis: tokens travel to expert owners
        recv_x = jax.lax.all_to_all(send_x, "model", 0, 0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e, "model", 0, 0, tiled=False)
        rx = recv_x.reshape(M * cap, D)
        re_ = recv_e.reshape(M * cap) - m_idx * ep             # local expert id
        valid = (re_ >= 0) & (re_ < ep)
        re_c = jnp.where(valid, re_, 0)

        # local capacity dispatch into (ep, C2, D)
        C2 = _group_capacity(M * cap, 1, ep, 1.25)
        slot2 = _slots_in_group(jnp.where(valid, re_c, ep).astype(jnp.int32))
        keep2 = valid & (slot2 < C2)
        slot2 = jnp.where(keep2, slot2, 0)
        buf = jnp.zeros((ep, C2, D), dt).at[re_c, slot2].add(
            jnp.where(keep2[:, None], rx, 0))
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt)))
        u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(dt))
        eout = jnp.einsum("ecf,efd->ecd", g * u, wd_.astype(dt))
        y = eout[re_c, slot2] * keep2[:, None].astype(dt)      # (M*cap, D)

        # return trip + combine
        back = jax.lax.all_to_all(y.reshape(M, cap, D), "model", 0, 0,
                                  tiled=False)
        yk = back[dst, slot] * keep[:, None].astype(dt)
        yk = yk * gate_vals.reshape(-1)[:, None].astype(dt)
        out_m = jnp.zeros((Tm, D), dt).at[tok].add(yk)

        # reassemble the full local token set across model shards
        out_full = jax.lax.all_gather(out_m, "model", axis=0, tiled=True)

        if cfg.n_shared_experts:
            sg = jax.nn.silu(xt @ shared["w_gate"].astype(dt))
            out_full = out_full + (sg * (xt @ shared["w_up"].astype(dt))) \
                @ shared["w_down"].astype(dt)
        return out_full.reshape(xb.shape), aux

    shared = p.get("shared", {"w_gate": jnp.zeros((1, 1)),
                              "w_up": jnp.zeros((1, 1)),
                              "w_down": jnp.zeros((1, 1))})
    x_spec = P(b_ax if B % _bsize(mesh, b_ax) == 0 else None, None, None)
    ew = P("model", None, None)
    shared_spec = jax.tree.map(lambda _: P(None, None), shared)
    fn = shard_map(
        _local, mesh=mesh,
        in_specs=(x_spec, P(None, None), ew, ew, ew, shared_spec),
        out_specs=(x_spec, P()),
        **no_check)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)


def _bsize(mesh, b_ax):
    import numpy as _np
    return (int(_np.prod([mesh.shape[a] for a in b_ax]))
            if isinstance(b_ax, tuple) else mesh.shape[b_ax])
