"""Shared neural-network layers (pure JAX, param pytrees, init + apply).

Conventions:
  * params are nested dicts of jnp arrays; layer-stacked params carry a
    leading ``n_layers`` axis and are consumed by ``lax.scan``.
  * activations run in ``cfg.compute_dtype`` (bf16); norms/softmax/router in
    fp32; params stored in ``cfg.param_dtype`` (fp32).
  * every init function takes an explicit PRNG key (splittable, deterministic).

Training attention has three routes, dispatched by :func:`train_attention`
(``set_train_attn_impl`` sets the process default; the trainer overrides it
per-call via ``TrainerConfig.attn_impl`` / ``fused_attn``):

  * ``"flash"`` (the default train path) — the Pallas kernel family in
    ``kernels/flash_attention.py``: fused online-softmax forward plus a
    custom_vjp backward (dQ and dK/dV kernels), never materializing the
    (S, S) score tensor.  ``"flash_jvp"`` is its custom_jvp twin for
    forward-mode callers (Hutchinson's forward-over-reverse HVP).
  * ``"full"`` — :func:`full_attention`, materialized fp32
    (B, Hkv, G, Sq, Sk) scores; the reference semantics every other route
    is tested against, and the dryrun/debug path.
  * ``"chunked"`` — :func:`chunked_attention`, a lax.scan over KV blocks
    with the same online softmax in jnp; the fallback for very long
    sequences on backends where the kernel is unavailable.

``"auto"`` keeps the historical heuristic: chunked above 4096 tokens,
full otherwise.  All routes share the masking semantics of
:func:`_causal_window_mask` (causal, sliding window, ``q_offset``) and the
gemma2 logit softcap.  Decode-time attention is dispatched separately
(``set_decode_attn_impl``: "xla" | "pallas").
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .common import ModelConfig

# ---------------------------------------------------------------------------
# initializers


def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    """LeCun-normal (fan-in) initialization, the TPU LM default."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    return (jax.random.normal(key, shape) / math.sqrt(fan_in)).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms


def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings (standard + multimodal M-RoPE)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0,
               mrope_sections=None):
    """x: (B, S, H, hd); positions: (B, S) or (B, 3, S) for M-RoPE.

    M-RoPE (Qwen2-VL): the rotary half-dim is split into sections, each
    rotated by its own position stream (temporal / height / width).
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    if mrope_sections is None:
        angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    else:
        # positions: (B, 3, S); sections sum to hd/2
        parts = []
        off = 0
        for i, sec in enumerate(mrope_sections):
            p = positions[:, i, :, None].astype(jnp.float32)       # (B,S,1)
            parts.append(p * freqs[off:off + sec])
            off += sec
        angles = jnp.concatenate(parts, axis=-1)                   # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention


def init_attention(key, cfg: ModelConfig, d_in: Optional[int] = None):
    D = d_in or cfg.d_model
    hd, H, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    pdt = jnp.float32
    p = {
        "wq": dense_init(ks[0], (D, H * hd), dtype=pdt),
        "wk": dense_init(ks[1], (D, Hkv * hd), dtype=pdt),
        "wv": dense_init(ks[2], (D, Hkv * hd), dtype=pdt),
        "wo": dense_init(ks[3], (H * hd, D), in_axis=0, dtype=pdt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), pdt)
        p["bk"] = jnp.zeros((Hkv * hd,), pdt)
        p["bv"] = jnp.zeros((Hkv * hd,), pdt)
    return p


def _qkv(p, x, cfg: ModelConfig):
    dt = x.dtype
    B, S, _ = x.shape
    hd, H, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    return q, k, v


def _softcap(scores, cap):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def attention_scores_block(q, k, cfg: ModelConfig, scale):
    """q: (B,Sq,H,hd), k: (B,Sk,Hkv,hd) -> (B,Hkv,G,Sq,Sk) fp32 scores."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    return _softcap(scores, cfg.attn_logit_softcap)


def _causal_window_mask(Sq, Sk, q_offset, window):
    """(Sq, Sk) bool mask: True = attend.  Window in *key* distance."""
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m


def full_attention(p, x, cfg: ModelConfig, positions, *, window=None,
                   layer_scale=1.0, causal=True, kv_override=None,
                   q_offset=0):
    """Materialized-scores attention (reference/debug path).

    kv_override: (k, v) for cross-attention.
    """
    dt = x.dtype
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    if cfg.rope and kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    if kv_override is not None:
        k, v = kv_override
    scale = layer_scale / math.sqrt(cfg.hd)
    scores = attention_scores_block(q, k, cfg, scale)   # (B,Hkv,G,S,Sk)
    if causal:
        mask = _causal_window_mask(S, k.shape[1], q_offset, window)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(dt)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return out @ p["wo"].astype(dt)


def chunked_attention(p, x, cfg: ModelConfig, positions, *, window=None,
                      layer_scale=1.0, kv_block: int = 1024, causal=True,
                      q_offset=0):
    """Online-softmax attention, scanning KV blocks (32k+ prefill path).

    Never materializes the (S, S) score matrix: peak temp is
    (B, Hkv, G, S, kv_block).  Causal (+ optional sliding window) or
    bidirectional (encoder).
    """
    dt = x.dtype
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    scale = layer_scale / math.sqrt(cfg.hd)
    Hkv, G, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd
    qg = q.reshape(B, S, Hkv, G, hd)

    kv_block = min(kv_block, S)           # short sequences: one block
    while S % kv_block:                   # largest divisor <= requested
        kv_block -= 1
    nb = S // kv_block
    k_blocks = k.reshape(B, nb, kv_block, Hkv, hd).transpose(1, 0, 2, 3, 4)
    v_blocks = v.reshape(B, nb, kv_block, Hkv, hd).transpose(1, 0, 2, 3, 4)

    qpos = jnp.arange(S)[:, None] + q_offset

    def body(carry, blk):
        m_run, l_run, acc = carry
        kb, vb, bidx = blk
        scores = jnp.einsum("bskgh,btkh->bkgst", qg, kb,
                            preferred_element_type=jnp.float32) * scale
        scores = _softcap(scores, cfg.attn_logit_softcap)
        kpos = bidx * kv_block + jnp.arange(kv_block)[None, :]
        if causal:
            mask = kpos <= qpos
            if window is not None:
                mask = mask & (kpos > qpos - window)
            scores = jnp.where(mask[None, None, None], scores, -1e30)
        m_blk = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m_run, m_blk)
        alpha = jnp.exp(m_run - m_new)
        pexp = jnp.exp(scores - m_new[..., None])
        l_new = l_run * alpha + pexp.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", pexp.astype(dt), vb).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, G, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, S), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, G, S, hd), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (k_blocks, v_blocks, jnp.arange(nb)))
    out = (acc / jnp.maximum(l_f, 1e-30)[..., None]).astype(dt)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, cfg.n_heads * cfg.hd)
    return out @ p["wo"].astype(dt)


_TRAIN_ATTN_IMPLS = ("auto", "full", "chunked", "flash", "flash_jvp")
_TRAIN_ATTN_IMPL = {"impl": "auto"}


def set_train_attn_impl(impl: str) -> None:
    """Process-default training attention route (see module docstring):
    "flash" (Pallas custom_vjp kernel) | "flash_jvp" (custom_jvp twin) |
    "full" | "chunked" | "auto" (S-heuristic).  Per-call ``impl`` /
    ``attn_impl`` arguments other than "auto" take precedence."""
    assert impl in _TRAIN_ATTN_IMPLS, impl
    _TRAIN_ATTN_IMPL["impl"] = impl


def get_train_attn_impl() -> str:
    return _TRAIN_ATTN_IMPL["impl"]


def _flash_attention_proj(p, x, cfg: ModelConfig, positions, *, window,
                          layer_scale, causal, kv_override, q_offset,
                          use_jvp):
    """qkv -> rope -> fused Pallas attention -> output projection.

    A traced ``layer_scale`` (attn_temperature_by_layer under scan) is
    folded into q in fp32 so the kernel's scale stays static."""
    from ..kernels.flash_attention import flash_attention

    dt = x.dtype
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    if cfg.rope and kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    if kv_override is not None:
        k, v = kv_override
    qt = q.transpose(0, 2, 1, 3)          # (B, H, S, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if isinstance(layer_scale, (int, float)):
        scale = float(layer_scale) / math.sqrt(cfg.hd)
    else:
        qt = (qt.astype(jnp.float32)
              * jnp.asarray(layer_scale, jnp.float32)).astype(dt)
        scale = 1.0 / math.sqrt(cfg.hd)
    o = flash_attention(qt, kt, vt, causal=causal, scale=scale,
                        window=window if causal else None,
                        softcap=cfg.attn_logit_softcap, q_offset=q_offset,
                        use_jvp=use_jvp)
    out = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.hd)
    return out @ p["wo"].astype(dt)


def train_attention(p, x, cfg: ModelConfig, positions, *, window=None,
                    layer_scale=1.0, causal=True, kv_override=None,
                    q_offset=0, impl=None):
    """Route one training attention call (see module docstring).

    ``impl`` None or "auto" defers to the process default
    (:func:`set_train_attn_impl`); an "auto" default keeps the historical
    heuristic (chunked above 4096 tokens, else full)."""
    if impl in (None, "auto"):
        impl = _TRAIN_ATTN_IMPL["impl"]
    assert impl in _TRAIN_ATTN_IMPLS, impl
    if impl in ("flash", "flash_jvp"):
        return _flash_attention_proj(
            p, x, cfg, positions, window=window, layer_scale=layer_scale,
            causal=causal, kv_override=kv_override, q_offset=q_offset,
            use_jvp=impl == "flash_jvp")
    if kv_override is not None:       # chunked has no cross-attention path
        return full_attention(p, x, cfg, positions, window=window,
                              layer_scale=layer_scale, causal=causal,
                              kv_override=kv_override, q_offset=q_offset)
    if impl == "chunked" or (impl == "auto" and x.shape[1] > 4096):
        return chunked_attention(p, x, cfg, positions, window=window,
                                 layer_scale=layer_scale, causal=causal,
                                 q_offset=q_offset)
    return full_attention(p, x, cfg, positions, window=window,
                          layer_scale=layer_scale, causal=causal,
                          q_offset=q_offset)


def decode_attention(p, x, cfg: ModelConfig, k_cache, v_cache, position, *,
                     window=None, layer_scale=1.0):
    """Single-token decode: x (B,1,D); cache (B,Smax,Hkv,hd).

    Returns (out, new_k_cache, new_v_cache).  Attends to cache[:position+1]
    via masking (static shapes — XLA-friendly).
    """
    dt = x.dtype
    B = x.shape[0]
    q, k, v = _qkv(p, x, cfg)
    pos = jnp.full((B, 1), position, jnp.int32)
    if cfg.rope:
        mp = jnp.broadcast_to(position, (B, 3, 1)) if cfg.mrope_sections else pos
        q = apply_rope(q, mp if cfg.mrope_sections else pos, cfg.rope_theta,
                       cfg.mrope_sections)
        k = apply_rope(k, mp if cfg.mrope_sections else pos, cfg.rope_theta,
                       cfg.mrope_sections)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, position, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, position, axis=1)
    scale = layer_scale / math.sqrt(cfg.hd)
    scores = attention_scores_block(q, k_cache, cfg, scale)  # (B,Hkv,G,1,S)
    S = k_cache.shape[1]
    kpos = jnp.arange(S)
    mask = kpos <= position
    if window is not None:
        mask = mask & (kpos > position - window)
    scores = jnp.where(mask[None, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(dt)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v_cache)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
    return out @ p["wo"].astype(dt), k_cache, v_cache


# ---------------------------------------------------------------------------
# slot-major ring-cache decode (the serve engine's per-slot decode path)
#
# Cache layout per layer: (N, C, Hkv, hd) with N = slots, C = n_pages *
# page_len ring entries.  A token at per-slot position p is written at ring
# index p % C; ring index s therefore holds absolute position
# p - ((p - s) mod C), which the mask uses to hide unwritten / overwritten /
# out-of-window entries.  When C covers the whole request the ring
# degenerates to a linear cache and the mask to the causal prefix, and a
# freshly reused slot needs no cache reset: every stale index solves to a
# negative absolute position.

_DECODE_ATTN_IMPL = {"impl": "xla"}


def set_decode_attn_impl(impl: str) -> None:
    """"xla" (jnp masked softmax) or "pallas" (fused page-streaming kernel,
    kernels/decode_attention.py — interpret-mode on CPU)."""
    assert impl in ("xla", "pallas"), impl
    _DECODE_ATTN_IMPL["impl"] = impl


def get_decode_attn_impl() -> str:
    return _DECODE_ATTN_IMPL["impl"]


def slot_slice(tree_, slot):
    """Slice one slot's row from a slot-major state pytree (batch axis 1,
    under the stacked-layer axis); ``slot`` may be traced."""
    slot = jnp.asarray(slot, jnp.int32)
    return jax.tree.map(
        lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, axis=1), tree_)


def slot_update(tree_, row, slot):
    """Write a single-slot row pytree back at ``slot`` (inverse of
    :func:`slot_slice`)."""
    slot = jnp.asarray(slot, jnp.int32)
    return jax.tree.map(
        lambda l, r: jax.lax.dynamic_update_slice_in_dim(
            l, r.astype(l.dtype), slot, axis=1), tree_, row)


def ring_write(cache, val, positions):
    """cache (N, C, Hkv, hd) <- val (N, 1, Hkv, hd) at positions % C."""
    N, C = cache.shape[0], cache.shape[1]
    idx = jnp.mod(positions.astype(jnp.int32), C)
    return cache.at[jnp.arange(N), idx].set(val[:, 0].astype(cache.dtype))


def ring_mask(positions, C, window=None):
    """(N, C) bool validity of each slot's ring entries at ``positions``."""
    pos = positions.astype(jnp.int32)[:, None]          # (N, 1)
    idx = jnp.arange(C, dtype=jnp.int32)[None, :]       # (1, C)
    abs_pos = pos - jnp.mod(pos - idx, C)
    valid = abs_pos >= 0
    if window is not None:
        valid = valid & (abs_pos > pos - window)
    return valid


def kv_is_quantized(kv) -> bool:
    """True when a slot-cache pytree carries int8 payloads + scale planes."""
    return "k_scale" in kv


def _dequant_cache(q8, scale, dt):
    """int8 cache (..., C, Hkv, hd) + scales (..., C) -> compute dtype.

    Dequantizes in fp32 (exact for int8 * fp32) then rounds once into the
    compute dtype — the same rounding the Pallas kernel applies per page,
    so XLA and kernel read paths see identical values."""
    from ..quant import dequantize_kv
    return dequantize_kv(q8, scale, dt)


def decode_attention_slots(p, x, cfg: ModelConfig, kv, positions, *,
                           window=None, layer_scale=1.0):
    """Per-slot decode: x (N, 1, D); ``kv`` the per-layer slot cache —
    {"k", "v"} (N, C, Hkv, hd), plus {"k_scale", "v_scale"} (N, C) fp32
    when ``cfg.kv_dtype == "int8"``; positions (N,).

    Returns (out (N, 1, D), new_kv).  Unlike :func:`decode_attention`
    every slot carries its own position, so a continuous batch mixes
    requests at arbitrary depths in one program.  ``window`` and
    ``layer_scale`` may be traced (per-layer scan values).  Quantized
    caches write the new token as int8 + per-token scale (round-to-nearest,
    repro.quant) and dequantize on read — the ring/mask math is unchanged.
    """
    dt = x.dtype
    N = x.shape[0]
    k_cache, v_cache = kv["k"], kv["v"]
    C = k_cache.shape[1]
    quant = kv_is_quantized(kv)
    q, k, v = _qkv(p, x, cfg)
    pos2 = positions.astype(jnp.int32)[:, None]          # (N, 1)
    if cfg.rope:
        rp = (jnp.broadcast_to(pos2[:, None], (N, 3, 1))
              if cfg.mrope_sections else pos2)
        q = apply_rope(q, rp, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, rp, cfg.rope_theta, cfg.mrope_sections)
    if quant:
        from ..quant import quantize_kv
        k8, ks = quantize_kv(k)                          # (N,1,Hkv,hd),(N,1)
        v8, vs = quantize_kv(v)
        new_kv = {"k": ring_write(k_cache, k8, positions),
                  "v": ring_write(v_cache, v8, positions),
                  "k_scale": ring_write(kv["k_scale"], ks, positions),
                  "v_scale": ring_write(kv["v_scale"], vs, positions)}
    else:
        new_kv = {"k": ring_write(k_cache, k, positions),
                  "v": ring_write(v_cache, v, positions)}
    scale = layer_scale / math.sqrt(cfg.hd)
    if _DECODE_ATTN_IMPL["impl"] == "pallas":
        from ..kernels.decode_attention import decode_attention_pallas
        qs = (q[:, 0].astype(jnp.float32) * scale).astype(q.dtype)
        out = decode_attention_pallas(
            qs, new_kv["k"], new_kv["v"], positions, scale=1.0,
            window=window, softcap=cfg.attn_logit_softcap,
            k_scale=new_kv.get("k_scale"), v_scale=new_kv.get("v_scale"))
        out = out.reshape(N, 1, cfg.n_heads * cfg.hd).astype(dt)
    else:
        if quant:
            k_read = _dequant_cache(new_kv["k"], new_kv["k_scale"], dt)
            v_read = _dequant_cache(new_kv["v"], new_kv["v_scale"], dt)
        else:
            k_read, v_read = new_kv["k"], new_kv["v"]
        scores = attention_scores_block(q, k_read, cfg, scale)  # (N,Hkv,G,1,C)
        valid = ring_mask(positions, C, window)
        scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(dt)
        out = jnp.einsum("bkgst,btkh->bskgh", w, v_read)
        out = out.reshape(N, 1, cfg.n_heads * cfg.hd)
    return out @ p["wo"].astype(dt), new_kv


def prefill_chunk_attention(p, h, cfg: ModelConfig, kv, slot, start,
                            qpos, *, window=None, layer_scale=1.0):
    """Chunk-prefill attention for one slot (shared by the transformer and
    encdec ``prefill_into_slot``): h (1, P, D) normed chunk; ``kv`` the
    per-layer slot cache ({"k", "v"} (N, C, Hkv, hd) [+ scale planes
    (N, C) when quantized]); ``slot``/``start`` traced scalars; qpos (P,)
    the chunk's absolute positions.

    Writes the chunk's K/V at [slot, start:start+P] and attends the chunk
    queries against the slot's full ring row under :func:`ring_mask` —
    entries past the chunk's valid tokens may be written freely, they stay
    masked until decode overwrites them.  Quantized caches store the chunk
    as int8 + per-token scales, and the chunk attends the *dequantized*
    row (its own tokens included), so page-aligned cache state is a pure
    function of the token prefix — the bit-exactness the shared-prefix
    page reuse in serve/prefix_cache.py relies on.  Returns
    (out (1, P, D), new_kv).
    """
    dt = h.dtype
    P = h.shape[1]
    k_l, v_l = kv["k"], kv["v"]
    C = k_l.shape[1]
    quant = kv_is_quantized(kv)
    q, k, v = _qkv(p, h, cfg)
    if cfg.rope:
        rp = (jnp.broadcast_to(qpos[None, None], (1, 3, P))
              if cfg.mrope_sections else qpos[None])
        q = apply_rope(q, rp, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, rp, cfg.rope_theta, cfg.mrope_sections)
    if quant:
        from ..quant import quantize_kv
        k8, ks = quantize_kv(k)                          # (1,P,Hkv,hd),(1,P)
        v8, vs = quantize_kv(v)
        new_kv = {
            "k": jax.lax.dynamic_update_slice(k_l, k8, (slot, start, 0, 0)),
            "v": jax.lax.dynamic_update_slice(v_l, v8, (slot, start, 0, 0)),
            "k_scale": jax.lax.dynamic_update_slice(
                kv["k_scale"], ks, (slot, start)),
            "v_scale": jax.lax.dynamic_update_slice(
                kv["v_scale"], vs, (slot, start)),
        }
    else:
        new_kv = {
            "k": jax.lax.dynamic_update_slice(k_l, k.astype(k_l.dtype),
                                              (slot, start, 0, 0)),
            "v": jax.lax.dynamic_update_slice(v_l, v.astype(v_l.dtype),
                                              (slot, start, 0, 0)),
        }
    row = {name: jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=0)
           for name, leaf in new_kv.items()}
    if quant:
        row_k = _dequant_cache(row["k"], row["k_scale"], dt)
        row_v = _dequant_cache(row["v"], row["v_scale"], dt)
    else:
        row_k, row_v = row["k"], row["v"]
    scale = layer_scale / math.sqrt(cfg.hd)
    scores = attention_scores_block(q, row_k, cfg, scale)   # (1,Hkv,G,P,C)
    mask = ring_mask(qpos, C, window)                       # (P, C)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(dt)
    out = jnp.einsum("bkgst,btkh->bskgh", w, row_v)
    out = out.reshape(1, P, cfg.n_heads * cfg.hd)
    return out @ p["wo"].astype(dt), new_kv


# ---------------------------------------------------------------------------
# MLP


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None,
             d_in: Optional[int] = None):
    D = d_in or cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], (D, F)),
                "w_up": dense_init(ks[1], (D, F)),
                "w_down": dense_init(ks[2], (F, D), in_axis=0)}
    return {"w_up": dense_init(ks[0], (D, F)),
            "b_up": jnp.zeros((F,), jnp.float32),
            "w_down": dense_init(ks[1], (F, D), in_axis=0),
            "b_down": jnp.zeros((D,), jnp.float32)}


def mlp(p, x, cfg: ModelConfig):
    dt = x.dtype
    if cfg.activation == "swiglu":
        g = jax.nn.silu(x @ p["w_gate"].astype(dt))
        return (g * (x @ p["w_up"].astype(dt))) @ p["w_down"].astype(dt)
    if cfg.activation == "geglu":
        g = jax.nn.gelu(x @ p["w_gate"].astype(dt))
        return (g * (x @ p["w_up"].astype(dt))) @ p["w_down"].astype(dt)
    h = jax.nn.gelu(x @ p["w_up"].astype(dt) + p["b_up"].astype(dt))
    return h @ p["w_down"].astype(dt) + p["b_down"].astype(dt)


# ---------------------------------------------------------------------------
# embedding / unembedding


def init_embedding(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    p = {"tok": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model))}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.padded_vocab))
    if cfg.learned_pos:
        p["pos"] = embed_init(ks[1], (cfg.max_position_embeddings,
                                      cfg.d_model))
    return p


def embed(p, tokens, cfg: ModelConfig, positions=None):
    x = jnp.take(p["tok"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.learned_pos:
        assert positions is not None
        x = x + jnp.take(p["pos"], positions, axis=0).astype(x.dtype)
    return constrain(x, "batch", None, None)


NEG_INF_LOGIT = -1e30  # masked-column sentinel (exp -> 0, argmax-proof)


def unembed(p, x, cfg: ModelConfig):
    """hidden -> fp32 logits over ``padded_vocab``, with the padding
    columns masked to :data:`NEG_INF_LOGIT` so they never enter a CE
    denominator, never win an argmax, are never sampled, and receive
    exactly zero gradient (``where`` routes their cotangent to the zero
    branch).  The projection accumulates in fp32 even for bf16 activations
    (``preferred_element_type``) — the same convention as the fused loss
    kernel (kernels/fused_ce.py), so fused and unfused paths move bytes,
    not math."""
    dt = x.dtype
    if cfg.tie_embeddings:
        logits = jnp.matmul(x, p["tok"].T.astype(dt),
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.matmul(x, p["unembed"].astype(dt),
                            preferred_element_type=jnp.float32)
    if cfg.final_logit_softcap:
        logits = _softcap(logits, cfg.final_logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        cols = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(cols < cfg.vocab_size, logits, NEG_INF_LOGIT)
    return constrain(logits, "batch", None, "model")


# ---------------------------------------------------------------------------
# loss


def cross_entropy(logits, labels, mask=None):
    """Token-level CE; logits fp32 (B,S,V), labels (B,S) int32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
