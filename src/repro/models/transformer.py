"""Decoder-only transformer LM (dense + MoE families), scan-over-layers.

Covers: qwen1.5-110b, yi-6b, gemma2-9b, stablelm-1.6b, qwen2-vl-7b (backbone),
llama4-maverick (interleaved MoE), deepseek-moe-16b, and the paper's GPT-2
family.  One stacked-parameter scan keeps 80-layer configs compiling fast and
makes remat policies per-layer.

Entry points:
    init_params(cfg, key)                       -> params
    forward(cfg, params, tokens, ...)           -> logits, aux
    prefill(cfg, params, tokens, ...)           -> logits, kv_cache
    init_cache(cfg, batch, max_len)             -> kv_cache
    decode_step(cfg, params, cache, tok, pos)   -> logits, kv_cache
    loss_fn / logits_fn                         -> CE loss plumbing (GNB-ready)
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig
from .layers import (apply_rope, chunked_attention,
                     decode_attention, decode_attention_slots, dense_init,
                     embed, embed_init, full_attention, init_attention,
                     init_embedding, init_mlp, layer_norm, mlp,
                     prefill_chunk_attention, rms_norm, train_attention,
                     unembed)
from .moe import init_moe, moe_ffn

# ---------------------------------------------------------------------------
# params


def _init_norm(cfg: ModelConfig):
    if cfg.norm_type == "ln":
        return {"scale": jnp.ones((cfg.d_model,), jnp.float32),
                "bias": jnp.zeros((cfg.d_model,), jnp.float32)}
    return {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}


def _norm(p, x, cfg: ModelConfig):
    if cfg.norm_type == "ln":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def _init_dense_layer(cfg: ModelConfig, key, d_ff=None):
    ks = jax.random.split(key, 2)
    p = {"ln1": _init_norm(cfg), "attn": init_attention(ks[0], cfg),
         "ln2": _init_norm(cfg), "mlp": init_mlp(ks[1], cfg, d_ff=d_ff)}
    if cfg.post_norms:
        p["ln1_post"] = _init_norm(cfg)
        p["ln2_post"] = _init_norm(cfg)
    return p


def _init_moe_layer(cfg: ModelConfig, key):
    ks = jax.random.split(key, 2)
    p = {"ln1": _init_norm(cfg), "attn": init_attention(ks[0], cfg),
         "ln2": _init_norm(cfg), "moe": init_moe(ks[1], cfg)}
    if cfg.post_norms:
        p["ln1_post"] = _init_norm(cfg)
        p["ln2_post"] = _init_norm(cfg)
    return p


def n_scan_groups(cfg: ModelConfig) -> int:
    if cfg.family == "moe" and cfg.moe_every > 1:
        return cfg.n_layers // cfg.moe_every
    return cfg.n_layers


def init_params(cfg: ModelConfig, key) -> dict:
    kemb, klay, kfin = jax.random.split(key, 3)
    params = {"embed": init_embedding(kemb, cfg),
              "final_norm": _init_norm(cfg)}
    if cfg.family == "moe" and cfg.moe_every > 1:
        ngroups = cfg.n_layers // cfg.moe_every
        keys = jax.random.split(klay, ngroups)

        def one_group(k):
            k1, k2 = jax.random.split(k)
            return {"dense": _init_dense_layer(cfg, k1, d_ff=cfg.dense_d_ff),
                    "moe": _init_moe_layer(cfg, k2)}

        params["layers"] = jax.vmap(one_group)(keys)
    elif cfg.family == "moe":
        keys = jax.random.split(klay, cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: _init_moe_layer(cfg, k))(keys)
    else:
        keys = jax.random.split(klay, cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: _init_dense_layer(cfg, k))(keys)
    if cfg.patch_embed_input:
        params["patch_proj"] = dense_init(kfin, (cfg.d_model, cfg.d_model))
    return params


# ---------------------------------------------------------------------------
# per-layer flags (sliding-window pattern, attention temperature)


def layer_windows(cfg: ModelConfig, seq_len: int) -> jnp.ndarray:
    """Per-layer effective window (traced into masks; > seq = global)."""
    big = jnp.asarray(1 << 30, jnp.int32)
    n = n_scan_groups(cfg)
    if cfg.local_global_pattern == "alternating" and cfg.local_window:
        idx = jnp.arange(n)
        return jnp.where(idx % 2 == 0, cfg.local_window, big)
    if cfg.local_window:  # all-local
        return jnp.full((n,), cfg.local_window, jnp.int32)
    return jnp.full((n,), big, jnp.int32)


def layer_scales(cfg: ModelConfig) -> jnp.ndarray:
    n = n_scan_groups(cfg)
    if cfg.attn_temperature_by_layer:
        return 1.0 / (1.0 + jnp.arange(n, dtype=jnp.float32))
    return jnp.ones((n,), jnp.float32)


# ---------------------------------------------------------------------------
# forward


def _attn_dispatch(p, x, cfg, positions, window, scale, attn_impl):
    return train_attention(p, x, cfg, positions, window=window,
                           layer_scale=scale, impl=attn_impl)


def _dense_block(p, x, cfg, positions, window, scale, attn_impl):
    h = _norm(p["ln1"], x, cfg)
    a = _attn_dispatch(p["attn"], h, cfg, positions, window, scale, attn_impl)
    if cfg.post_norms:
        a = _norm(p["ln1_post"], a, cfg)
    x = x + a
    h = _norm(p["ln2"], x, cfg)
    f = mlp(p["mlp"], h, cfg)
    if cfg.post_norms:
        f = _norm(p["ln2_post"], f, cfg)
    from ..distributed.sharding import constrain, residual_axes
    return constrain(x + f, *residual_axes())


def _moe_block(p, x, cfg, positions, window, scale, attn_impl):
    h = _norm(p["ln1"], x, cfg)
    a = _attn_dispatch(p["attn"], h, cfg, positions, window, scale, attn_impl)
    if cfg.post_norms:
        a = _norm(p["ln1_post"], a, cfg)
    x = x + a
    h = _norm(p["ln2"], x, cfg)
    f, aux = moe_ffn(p["moe"], h, cfg)
    if cfg.post_norms:
        f = _norm(p["ln2_post"], f, cfg)
    from ..distributed.sharding import constrain, residual_axes
    return constrain(x + f, *residual_axes()), aux


def _embed_inputs(cfg, params, tokens, positions, patch_embeds):
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(positions[:, None], (B, 3, S))
    rope_pos = positions
    emb_pos = positions if positions.ndim == 2 else positions[:, 0]
    x = embed(params["embed"], tokens, cfg, emb_pos)
    if cfg.patch_embed_input and patch_embeds is not None:
        # stub modality frontend: first P positions are image patches
        P = patch_embeds.shape[1]
        pe = patch_embeds.astype(x.dtype) @ params["patch_proj"].astype(x.dtype)
        x = jnp.concatenate([pe, x[:, P:]], axis=1)
    return x, rope_pos


def forward_hidden(cfg: ModelConfig, params, tokens, *, positions=None,
                   patch_embeds=None, attn_impl: str = "auto",
                   remat: str = "none", final_norm: bool = True):
    """tokens (B, S) -> (final-norm hidden (B, S, D), aux).  The trunk
    shared by :func:`forward` and the logits-free loss paths — the
    unembedding projection happens inside ``models.loss.lm_loss`` (or not
    at all, for the fused kernel).  ``final_norm=False`` returns the
    PRE-norm hidden so ``lm_loss(..., pre_norm=cfg.norm_type)`` can fuse
    the norm producer into the loss sweep (one less (B, S, D) HBM
    round-trip)."""
    x, positions = _embed_inputs(cfg, params, tokens, positions, patch_embeds)
    windows = layer_windows(cfg, tokens.shape[1])
    scales = layer_scales(cfg)

    if cfg.family == "moe" and cfg.moe_every > 1:
        def body(carry, layer):
            x, aux = carry
            p, w, s = layer
            x = _dense_block(p["dense"], x, cfg, positions, w, s, attn_impl)
            x, a = _moe_block(p["moe"], x, cfg, positions, w, s, attn_impl)
            return (x, aux + a), None
    elif cfg.family == "moe":
        def body(carry, layer):
            x, aux = carry
            p, w, s = layer
            x, a = _moe_block(p, x, cfg, positions, w, s, attn_impl)
            return (x, aux + a), None
    else:
        def body(carry, layer):
            x, aux = carry
            p, w, s = layer
            return (_dense_block(p, x, cfg, positions, w, s, attn_impl),
                    aux), None

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    n = n_scan_groups(cfg)
    if remat == "scan2" and n >= 4:
        # nested-scan remat: the OUTER scan checkpoints every g-th carry
        # (long-lived residuals shrink g x); the INNER body is checkpointed
        # too, so the group recompute during backward saves only g layer
        # inputs transiently — never a full layer's intermediates x g.
        g = next(d for d in (8, 5, 4, 2) if n % d == 0)
        xs = jax.tree.map(
            lambda a: a.reshape((n // g, g) + a.shape[1:]),
            (params["layers"], windows, scales))
        inner_body = jax.checkpoint(body)

        def outer(carry, group):
            return jax.lax.scan(inner_body, carry, group)

        (x, aux), _ = jax.lax.scan(jax.checkpoint(outer),
                                   (x, jnp.zeros((), jnp.float32)), xs)
    else:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   (params["layers"], windows, scales))
    if final_norm:
        x = _norm(params["final_norm"], x, cfg)
    return x, aux


def forward(cfg: ModelConfig, params, tokens, *, positions=None,
            patch_embeds=None, attn_impl: str = "auto",
            remat: str = "none"):
    """tokens (B, S) -> logits (B, S, V) fp32, aux (MoE load-balance loss)."""
    x, aux = forward_hidden(cfg, params, tokens, positions=positions,
                            patch_embeds=patch_embeds, attn_impl=attn_impl,
                            remat=remat)
    return unembed(params["embed"], x, cfg), aux


def loss_fn(cfg: ModelConfig, params, batch, *, attn_impl="auto",
            remat="none", loss_impl=None):
    """batch: {tokens, labels, [mask], [patch_embeds]} -> (loss, metrics).

    The CE runs through ``models.loss.lm_loss`` (fused / chunked /
    unfused per ``loss_impl``) — the default never materializes the
    [B, S, V] logits."""
    from .loss import lm_loss
    hidden, aux = forward_hidden(cfg, params, batch["tokens"],
                                 patch_embeds=batch.get("patch_embeds"),
                                 positions=batch.get("positions"),
                                 attn_impl=attn_impl, remat=remat,
                                 final_norm=False)
    ce, _ = lm_loss(cfg, params, hidden, batch["labels"],
                    batch.get("mask"), impl=loss_impl,
                    pre_norm=cfg.norm_type)
    return ce + aux, {"ce": ce, "aux": aux}


def sampled_loss_fn(cfg: ModelConfig, params, batch, rng, *,
                    attn_impl="auto", remat="none", loss_impl=None):
    """GNB sampled-label NLL (Algorithm 2): ``(nll, n_valid)`` with labels
    drawn from the model's own softmax inside the loss sweep."""
    from .loss import lm_loss_sampled
    hidden, _ = forward_hidden(cfg, params, batch["tokens"],
                               patch_embeds=batch.get("patch_embeds"),
                               positions=batch.get("positions"),
                               attn_impl=attn_impl, remat=remat,
                               final_norm=False)
    return lm_loss_sampled(cfg, params, hidden, rng, batch.get("mask"),
                           impl=loss_impl, pre_norm=cfg.norm_type)


def logits_fn(cfg: ModelConfig, params, batch, **kw):
    """Logits view for the GNB estimator (Algorithm 2 line 3)."""
    kw.pop("loss_impl", None)
    logits, _ = forward(cfg, params, batch["tokens"],
                        patch_embeds=batch.get("patch_embeds"),
                        positions=batch.get("positions"), **kw)
    return logits


# ---------------------------------------------------------------------------
# serving: prefill + KV-cache decode


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    L = cfg.n_layers  # caches are per *attention* layer (flat, not grouped)
    shape = (L, batch_size, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, cfg.compute_dtype),
            "v": jnp.zeros(shape, cfg.compute_dtype)}


def _flat_layer_params(cfg: ModelConfig, params):
    """Interleaved MoE groups -> flat per-attention-layer view for decode."""
    if cfg.family == "moe" and cfg.moe_every > 1:
        return params["layers"]  # handled group-wise in decode scan
    return params["layers"]


def decode_step(cfg: ModelConfig, params, cache, tokens, position):
    """One decode step.  tokens (B, 1) int32; position: scalar int32.

    Returns (logits (B, 1, V), new_cache).  Static cache length; the causal
    mask hides positions > ``position``.
    """
    B = tokens.shape[0]
    x = embed(params["embed"], tokens, cfg,
              jnp.full((B, 1), position, jnp.int32))
    windows = layer_windows(cfg, cache["k"].shape[2])
    scales = layer_scales(cfg)

    grouped = cfg.family == "moe" and cfg.moe_every > 1
    if grouped:
        ng = n_scan_groups(cfg)
        kc = cache["k"].reshape((ng, cfg.moe_every) + cache["k"].shape[1:])
        vc = cache["v"].reshape((ng, cfg.moe_every) + cache["v"].shape[1:])
    else:
        kc, vc = cache["k"], cache["v"]

    def attn_sub(p, x, k_l, v_l, w, s):
        h = _norm(p["ln1"], x, cfg)
        a, k_l, v_l = decode_attention(p["attn"], h, cfg, k_l, v_l, position,
                                       window=w, layer_scale=s)
        if cfg.post_norms:
            a = _norm(p["ln1_post"], a, cfg)
        return x + a, k_l, v_l

    def ffn_sub(p, x):
        h = _norm(p["ln2"], x, cfg)
        if "moe" in p:
            f, _ = moe_ffn(p["moe"], h, cfg)
        else:
            f = mlp(p["mlp"], h, cfg)
        if cfg.post_norms:
            f = _norm(p["ln2_post"], f, cfg)
        return x + f

    if grouped:
        def body(x, layer):
            p, k_g, v_g, w, s = layer
            x, k0, v0 = attn_sub(p["dense"], x, k_g[0], v_g[0], w, s)
            x = ffn_sub(p["dense"], x)
            x, k1, v1 = attn_sub(p["moe"], x, k_g[1], v_g[1], w, s)
            x = ffn_sub(p["moe"], x)
            return x, (jnp.stack([k0, k1]), jnp.stack([v0, v1]))

        x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], kc, vc,
                                             windows, scales))
        new_cache = {"k": nk.reshape(cache["k"].shape),
                     "v": nv.reshape(cache["v"].shape)}
    else:
        def body(x, layer):
            p, k_l, v_l, w, s = layer
            x, k_l, v_l = attn_sub(p, x, k_l, v_l, w, s)
            x = ffn_sub(p, x)
            return x, (k_l, v_l)

        x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], kc, vc,
                                             windows, scales))
        new_cache = {"k": nk, "v": nv}

    x = _norm(params["final_norm"], x, cfg)
    return unembed(params["embed"], x, cfg), new_cache


# ---------------------------------------------------------------------------
# slot protocol (continuous-batching serve engine; see serve/engine.py)
#
# Slot-major ring KV cache: (L, N, C, Hkv, hd) with C = n_pages * page_len.
# Ring index s of a slot at position p holds absolute position
# p - ((p - s) mod C); the mask (layers.ring_mask) hides unwritten, stale
# and out-of-window entries, so reusing a slot needs no cache reset and a
# prefill chunk may write its padded tail unmasked — those indices stay
# invisible until a later decode overwrites them with real tokens.


def init_slots(cfg: ModelConfig, n_slots: int, cache_len: int) -> dict:
    L = cfg.n_layers
    shape = (L, n_slots, cache_len, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_dtype == "int8":
        # int8 payloads + one fp32 scale per written token per K/V plane
        # (repro.quant.quantize_kv): ~2x slots per HBM byte vs bf16
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros((L, n_slots, cache_len), jnp.float32),
                "v_scale": jnp.zeros((L, n_slots, cache_len), jnp.float32)}
    return {"k": jnp.zeros(shape, cfg.compute_dtype),
            "v": jnp.zeros(shape, cfg.compute_dtype)}


def reset_slot(cfg: ModelConfig, cache, slot):
    """Ring masking hides stale entries — nothing to clear for attention."""
    return cache


def _slot_layer_sweep(cfg: ModelConfig, params, cache, x, attn_fn):
    """Layer sweep shared by :func:`decode_slots` and
    :func:`prefill_into_slot` — the grouped-MoE reshape, attention/FFN
    residual plumbing and both scan bodies live once, parameterized by the
    inner attention call ``attn_fn(p_attn, h, kv_l, window, scale) ->
    (a, kv_l)``.  The per-layer ``kv_l`` dict carries whatever leaves the
    cache holds ({"k", "v"} [+ the int8 path's scale planes]) — the sweep
    never enumerates them, so new cache layouts thread through without
    touching the scan.  Returns (hidden, new_cache)."""
    windows = layer_windows(cfg, cache["k"].shape[2])
    scales = layer_scales(cfg)

    grouped = cfg.family == "moe" and cfg.moe_every > 1
    if grouped:
        ng = n_scan_groups(cfg)
        kvs = {name: leaf.reshape((ng, cfg.moe_every) + leaf.shape[1:])
               for name, leaf in cache.items()}
    else:
        kvs = dict(cache)

    def attn_sub(p, x, kv_l, w, s):
        h = _norm(p["ln1"], x, cfg)
        a, kv_l = attn_fn(p["attn"], h, kv_l, w, s)
        if cfg.post_norms:
            a = _norm(p["ln1_post"], a, cfg)
        return x + a, kv_l

    def ffn_sub(p, x):
        h = _norm(p["ln2"], x, cfg)
        if "moe" in p:
            f, _ = moe_ffn(p["moe"], h, cfg)
        else:
            f = mlp(p["mlp"], h, cfg)
        if cfg.post_norms:
            f = _norm(p["ln2_post"], f, cfg)
        return x + f

    if grouped:
        def body(x, layer):
            p, kv_g, w, s = layer
            x, kv0 = attn_sub(p["dense"], x,
                              jax.tree.map(lambda l: l[0], kv_g), w, s)
            x = ffn_sub(p["dense"], x)
            x, kv1 = attn_sub(p["moe"], x,
                              jax.tree.map(lambda l: l[1], kv_g), w, s)
            x = ffn_sub(p["moe"], x)
            return x, jax.tree.map(lambda a, b: jnp.stack([a, b]), kv0, kv1)

        x, nkv = jax.lax.scan(body, x, (params["layers"], kvs,
                                        windows, scales))
        return x, {name: leaf.reshape(cache[name].shape)
                   for name, leaf in nkv.items()}

    def body(x, layer):
        p, kv_l, w, s = layer
        x, kv_l = attn_sub(p, x, kv_l, w, s)
        x = ffn_sub(p, x)
        return x, kv_l

    x, nkv = jax.lax.scan(body, x, (params["layers"], kvs, windows, scales))
    return x, nkv


def decode_slots(cfg: ModelConfig, params, cache, tokens, positions):
    """One decode step across all slots.  tokens (N, 1); positions (N,).

    Returns (logits (N, 1, V), new_cache).  Identical math to
    :func:`decode_step` when every slot sits at the same position, but each
    slot carries its own position so a continuous batch mixes requests at
    arbitrary depths in one compiled program.
    """
    positions = positions.astype(jnp.int32)
    x = embed(params["embed"], tokens, cfg, positions[:, None])

    def attn_fn(p, h, kv_l, w, s):
        return decode_attention_slots(p, h, cfg, kv_l, positions,
                                      window=w, layer_scale=s)

    x, new_cache = _slot_layer_sweep(cfg, params, cache, x, attn_fn)
    x = _norm(params["final_norm"], x, cfg)
    return unembed(params["embed"], x, cfg), new_cache


def prefill_into_slot(cfg: ModelConfig, params, cache, slot, tokens, start,
                      n_valid):
    """Chunk-prefill one slot.  tokens (1, P) int32; ``slot``, ``start``
    and ``n_valid`` are traced scalars, so one compiled program serves every
    chunk of every request.

    Writes K/V for the chunk's positions into the slot's cache row and
    returns (new_cache, logits (V,) fp32 of the last *valid* token — the
    next-token distribution once the final chunk lands).  Entries past
    ``n_valid`` are written but stay ring-masked until decode overwrites
    them; queries past ``n_valid`` compute garbage that nothing reads.
    """
    P = tokens.shape[1]
    slot = jnp.asarray(slot, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    qpos = start + jnp.arange(P, dtype=jnp.int32)       # (P,)
    x = embed(params["embed"], tokens, cfg, qpos[None])

    def attn_fn(p, h, kv_l, w, s):
        return prefill_chunk_attention(p, h, cfg, kv_l, slot, start,
                                       qpos, window=w, layer_scale=s)

    x, new_cache = _slot_layer_sweep(cfg, params, cache, x, attn_fn)
    # only the last valid token's logits matter (next-token distribution)
    last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
    last = _norm(params["final_norm"], last, cfg)
    return new_cache, unembed(params["embed"], last, cfg)[0, 0]


def prefill(cfg: ModelConfig, params, tokens, *, attn_impl="auto",
            patch_embeds=None):
    """Forward pass that also fills a KV cache (prefill_32k serve path)."""
    x, positions = _embed_inputs(cfg, params, tokens, None, patch_embeds)
    windows = layer_windows(cfg, tokens.shape[1])
    scales = layer_scales(cfg)
    grouped = cfg.family == "moe" and cfg.moe_every > 1

    def kv_of(p, h):
        dt = h.dtype
        B, S, _ = h.shape
        k = (h @ p["wk"].astype(dt))
        v = (h @ p["wv"].astype(dt))
        if cfg.qkv_bias:
            k = k + p["bk"].astype(dt)
            v = v + p["bv"].astype(dt)
        k = k.reshape(B, S, cfg.n_kv_heads, cfg.hd)
        if cfg.rope:
            k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        return k, v.reshape(B, S, cfg.n_kv_heads, cfg.hd)

    def dense_with_kv(p, x, w, s):
        h = _norm(p["ln1"], x, cfg)
        kv = kv_of(p["attn"], h)
        x = _dense_block(p, x, cfg, positions, w, s, attn_impl)
        return x, kv

    def moe_with_kv(p, x, w, s):
        h = _norm(p["ln1"], x, cfg)
        kv = kv_of(p["attn"], h)
        x, _ = _moe_block(p, x, cfg, positions, w, s, attn_impl)
        return x, kv

    if grouped:
        def body(x, layer):
            p, w, s = layer
            x, kv0 = dense_with_kv(p["dense"], x, w, s)
            x, kv1 = moe_with_kv(p["moe"], x, w, s)
            return x, (jnp.stack([kv0[0], kv1[0]]), jnp.stack([kv0[1], kv1[1]]))
    elif cfg.family == "moe":
        def body(x, layer):
            p, w, s = layer
            x, kv = moe_with_kv(p, x, w, s)
            return x, kv
    else:
        def body(x, layer):
            p, w, s = layer
            x, kv = dense_with_kv(p, x, w, s)
            return x, kv

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], windows, scales))
    if grouped:
        L = cfg.n_layers
        ks = ks.reshape((L,) + ks.shape[2:])
        vs = vs.reshape((L,) + vs.shape[2:])
    x = _norm(params["final_norm"], x[:, -1:], cfg)
    # serving prefill only needs the LAST token's logits (the next-token
    # distribution); unembedding all S positions would build a (B,S,V)
    # buffer that cannot exist at 32k x 152k vocab.
    return unembed(params["embed"], x, cfg), {"k": ks, "v": vs}
