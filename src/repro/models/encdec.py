"""Encoder-decoder backbone (seamless-m4t-medium, arXiv:2308.11596).

The modality frontend (speech feature extractor) is a STUB per the
assignment: ``input_specs()`` feeds precomputed frame embeddings
(B, S_src, d_model) straight into the encoder.  The decoder is a standard
causal transformer with cross-attention; training loss is CE over target
text tokens; decode_step serves one token against cached encoder output +
decoder KV cache.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .common import ModelConfig
from .layers import (decode_attention,
                     decode_attention_slots, dense_init, embed,
                     full_attention, init_attention, init_embedding,
                     init_mlp, mlp, prefill_chunk_attention, rms_norm,
                     train_attention, unembed)


def _init_norm(cfg):
    return {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}


def _init_enc_layer(cfg: ModelConfig, key):
    ks = jax.random.split(key, 2)
    return {"ln1": _init_norm(cfg), "attn": init_attention(ks[0], cfg),
            "ln2": _init_norm(cfg), "mlp": init_mlp(ks[1], cfg)}


def _init_dec_layer(cfg: ModelConfig, key):
    ks = jax.random.split(key, 3)
    return {"ln1": _init_norm(cfg), "self_attn": init_attention(ks[0], cfg),
            "ln_x": _init_norm(cfg), "cross_attn": init_attention(ks[1], cfg),
            "ln2": _init_norm(cfg), "mlp": init_mlp(ks[2], cfg)}


def init_params(cfg: ModelConfig, key) -> dict:
    kemb, kenc, kdec, kin = jax.random.split(key, 4)
    ekeys = jax.random.split(kenc, cfg.n_encoder_layers)
    dkeys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": init_embedding(kemb, cfg),
        "frame_proj": dense_init(kin, (cfg.d_model, cfg.d_model)),
        "encoder": jax.vmap(lambda k: _init_enc_layer(cfg, k))(ekeys),
        "enc_norm": _init_norm(cfg),
        "decoder": jax.vmap(lambda k: _init_dec_layer(cfg, k))(dkeys),
        "final_norm": _init_norm(cfg),
    }


def encode(cfg: ModelConfig, params, frames, *, remat="none",
           attn_impl="auto"):
    """frames: (B, S_src, d_model) stub embeddings -> encoder output."""
    dt = cfg.compute_dtype
    x = frames.astype(dt) @ params["frame_proj"].astype(dt)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, p):
        h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        # bidirectional; never materializes (S, S) at 32k frames on the
        # flash/chunked routes
        x = x + train_attention(p["attn"], h, cfg, positions, causal=False,
                                impl=attn_impl)
        h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        from ..distributed.sharding import residual_axes
        return constrain(x + mlp(p["mlp"], h, cfg), *residual_axes()), None

    if remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)


def _cross_kv(p, enc_out, cfg: ModelConfig):
    dt = enc_out.dtype
    B, S, _ = enc_out.shape
    k = (enc_out @ p["wk"].astype(dt)).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = (enc_out @ p["wv"].astype(dt)).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    return k, v


def decode_train_hidden(cfg: ModelConfig, params, tokens, enc_out, *,
                        remat="none", final_norm=True, attn_impl="auto"):
    """Teacher-forced decoder trunk. tokens (B, S_tgt) -> final-norm
    hidden (the loss paths skip the unembedding; models/loss.py)."""
    B, S = tokens.shape
    x = embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, p):
        h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        x = x + train_attention(p["self_attn"], h, cfg, positions,
                                causal=True, impl=attn_impl)
        h = rms_norm(x, p["ln_x"]["scale"], cfg.norm_eps)
        kv = _cross_kv(p["cross_attn"], enc_out, cfg)
        x = x + train_attention(p["cross_attn"], h, cfg, positions,
                                causal=False, kv_override=kv,
                                impl=attn_impl)
        h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        from ..distributed.sharding import residual_axes
        return constrain(x + mlp(p["mlp"], h, cfg), *residual_axes()), None

    if remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    if final_norm:
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return x


def decode_train(cfg: ModelConfig, params, tokens, enc_out, *, remat="none"):
    """Teacher-forced decoder pass. tokens (B, S_tgt) -> logits."""
    x = decode_train_hidden(cfg, params, tokens, enc_out, remat=remat)
    return unembed(params["embed"], x, cfg)


def forward_hidden(cfg: ModelConfig, params, tokens, *, frames=None,
                   remat="none", final_norm=True, attn_impl="auto", **_):
    enc_out = encode(cfg, params, frames, remat=remat, attn_impl=attn_impl)
    return decode_train_hidden(cfg, params, tokens, enc_out, remat=remat,
                               final_norm=final_norm,
                               attn_impl=attn_impl), \
        jnp.zeros((), jnp.float32)


def forward(cfg: ModelConfig, params, tokens, *, frames=None, remat="none",
            **_):
    hidden, aux = forward_hidden(cfg, params, tokens, frames=frames,
                                 remat=remat)
    return unembed(params["embed"], hidden, cfg), aux


def loss_fn(cfg: ModelConfig, params, batch, *, remat="none",
            loss_impl=None, attn_impl="auto", **_):
    from .loss import lm_loss
    hidden, aux = forward_hidden(cfg, params, batch["tokens"],
                                 frames=batch["frames"], remat=remat,
                                 final_norm=False, attn_impl=attn_impl)
    ce, _ = lm_loss(cfg, params, hidden, batch["labels"],
                    batch.get("mask"), impl=loss_impl, pre_norm="rms")
    return ce + aux, {"ce": ce, "aux": aux}


def sampled_loss_fn(cfg: ModelConfig, params, batch, rng, *, remat="none",
                    loss_impl=None, attn_impl="auto", **_):
    from .loss import lm_loss_sampled
    hidden, _ = forward_hidden(cfg, params, batch["tokens"],
                               frames=batch["frames"], remat=remat,
                               final_norm=False, attn_impl=attn_impl)
    return lm_loss_sampled(cfg, params, hidden, rng, batch.get("mask"),
                           impl=loss_impl, pre_norm="rms")


def logits_fn(cfg: ModelConfig, params, batch, **_):
    return forward(cfg, params, batch["tokens"], frames=batch["frames"])[0]


# ---------------------------------------------------------------------------
# serving


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               src_len: int) -> dict:
    L = cfg.n_layers
    kv = (L, batch_size, max_len, cfg.n_kv_heads, cfg.hd)
    xkv = (L, batch_size, src_len, cfg.n_kv_heads, cfg.hd)
    dt = cfg.compute_dtype
    return {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
            "xk": jnp.zeros(xkv, dt), "xv": jnp.zeros(xkv, dt)}


def prefill_encoder(cfg: ModelConfig, params, frames, cache):
    """Run the encoder once and cache per-layer cross-attention K/V."""
    enc_out = encode(cfg, params, frames)

    def body(_, p):
        return None, _cross_kv(p["cross_attn"], enc_out, cfg)

    _, (xk, xv) = jax.lax.scan(body, None, params["decoder"])
    return dict(cache, xk=xk, xv=xv)


def decode_step(cfg: ModelConfig, params, cache, tokens, position):
    """One target token. tokens (B,1)."""
    B = tokens.shape[0]
    x = embed(params["embed"], tokens, cfg,
              jnp.full((B, 1), position, jnp.int32))

    def body(x, layer):
        p, k_l, v_l, xk_l, xv_l = layer
        h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        a, k_l, v_l = decode_attention(p["self_attn"], h, cfg, k_l, v_l,
                                       position)
        x = x + a
        h = rms_norm(x, p["ln_x"]["scale"], cfg.norm_eps)
        a = full_attention(p["cross_attn"], h, cfg, None, causal=False,
                           kv_override=(xk_l, xv_l))
        x = x + a
        h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        x = x + mlp(p["mlp"], h, cfg)
        return x, (k_l, v_l)

    x, (nk, nv) = jax.lax.scan(body, x, (params["decoder"], cache["k"],
                                         cache["v"], cache["xk"],
                                         cache["xv"]))
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return unembed(params["embed"], x, cfg), dict(cache, k=nk, v=nv)


# ---------------------------------------------------------------------------
# slot protocol (continuous-batching serve engine; see serve/engine.py)
#
# Self-attention uses the same slot-major ring cache as the transformer
# family; cross-attention K/V are per-slot rows written once at admission
# by prefill_encoder_slot (the "prompt" of an encdec request is its frame
# stream plus a decoder prefix, usually just BOS).


def init_slots(cfg: ModelConfig, n_slots: int, cache_len: int,
               src_len: int = 0) -> dict:
    if cfg.kv_dtype != "bf16":
        raise ValueError("kv_dtype=int8 is implemented for the paged-KV "
                         "families (dense/moe); encdec keeps bf16 slots")
    L = cfg.n_layers
    dt = cfg.compute_dtype
    kv = (L, n_slots, cache_len, cfg.n_kv_heads, cfg.hd)
    xkv = (L, n_slots, src_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
            "xk": jnp.zeros(xkv, dt), "xv": jnp.zeros(xkv, dt)}


def reset_slot(cfg: ModelConfig, cache, slot):
    """Ring masking hides stale self-attn entries; xk/xv are overwritten by
    prefill_encoder_slot before the slot decodes."""
    return cache


def prefill_encoder_slot(cfg: ModelConfig, params, cache, slot, frames):
    """Run the encoder for one request and write its per-layer cross K/V
    into slot ``slot``.  frames (1, S_src, d_model)."""
    slot = jnp.asarray(slot, jnp.int32)
    enc_out = encode(cfg, params, frames)

    def body(_, p):
        return None, _cross_kv(p["cross_attn"], enc_out, cfg)

    _, (xk, xv) = jax.lax.scan(body, None, params["decoder"])  # (L,1,S,.. )
    xk_new = jax.lax.dynamic_update_slice(
        cache["xk"], xk.astype(cache["xk"].dtype), (0, slot, 0, 0, 0))
    xv_new = jax.lax.dynamic_update_slice(
        cache["xv"], xv.astype(cache["xv"].dtype), (0, slot, 0, 0, 0))
    return dict(cache, xk=xk_new, xv=xv_new)


def decode_slots(cfg: ModelConfig, params, cache, tokens, positions):
    """One decode step across all slots.  tokens (N, 1); positions (N,)."""
    N = tokens.shape[0]
    positions = positions.astype(jnp.int32)
    x = embed(params["embed"], tokens, cfg, positions[:, None])

    def body(x, layer):
        p, k_l, v_l, xk_l, xv_l = layer
        h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        a, kv_l = decode_attention_slots(p["self_attn"], h, cfg,
                                         {"k": k_l, "v": v_l}, positions)
        k_l, v_l = kv_l["k"], kv_l["v"]
        x = x + a
        h = rms_norm(x, p["ln_x"]["scale"], cfg.norm_eps)
        a = full_attention(p["cross_attn"], h, cfg, None, causal=False,
                           kv_override=(xk_l, xv_l))
        x = x + a
        h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        x = x + mlp(p["mlp"], h, cfg)
        return x, (k_l, v_l)

    x, (nk, nv) = jax.lax.scan(body, x, (params["decoder"], cache["k"],
                                         cache["v"], cache["xk"],
                                         cache["xv"]))
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return unembed(params["embed"], x, cfg), dict(cache, k=nk, v=nv)


def prefill_into_slot(cfg: ModelConfig, params, cache, slot, tokens, start,
                      n_valid):
    """Chunk-prefill one slot's decoder prefix (teacher-forced).  tokens
    (1, P); returns (new_cache, logits (V,) fp32 of the last valid token).
    The encoder must already have been prefilled via prefill_encoder_slot.
    """
    P = tokens.shape[1]
    slot = jnp.asarray(slot, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    qpos = start + jnp.arange(P, dtype=jnp.int32)
    x = embed(params["embed"], tokens, cfg, qpos[None])

    def body(x, layer):
        p, k_l, v_l, xk_l, xv_l = layer
        h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        a, kv_l = prefill_chunk_attention(p["self_attn"], h, cfg,
                                          {"k": k_l, "v": v_l}, slot,
                                          start, qpos)
        k_l, v_l = kv_l["k"], kv_l["v"]
        x = x + a
        h = rms_norm(x, p["ln_x"]["scale"], cfg.norm_eps)
        row_xk = jax.lax.dynamic_slice_in_dim(xk_l, slot, 1, axis=0)
        row_xv = jax.lax.dynamic_slice_in_dim(xv_l, slot, 1, axis=0)
        x = x + full_attention(p["cross_attn"], h, cfg, None, causal=False,
                               kv_override=(row_xk, row_xv))
        h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        x = x + mlp(p["mlp"], h, cfg)
        return x, (k_l, v_l)

    x, (nk, nv) = jax.lax.scan(body, x, (params["decoder"], cache["k"],
                                         cache["v"], cache["xk"],
                                         cache["xv"]))
    last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
    last = rms_norm(last, params["final_norm"]["scale"], cfg.norm_eps)
    return (dict(cache, k=nk, v=nv),
            unembed(params["embed"], last, cfg)[0, 0])
