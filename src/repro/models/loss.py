"""One LM-loss entry point for every model family (logits-free by default).

All four families' ``loss_fn`` route their final-norm hidden states through
:func:`lm_loss` (and the GNB refresh through :func:`lm_loss_sampled`), which
honors ``padded_vocab`` masking, tied/untied embeddings and the gemma2
final-logit softcap in every implementation:

  fused     Pallas chunked-vocab kernel (kernels/fused_ce.py): lm_head
            weight tiles stream through VMEM, the [B*T, V] logits never
            touch HBM, and the sampled-label GNB draw happens inside the
            same sweep (online chunked Gumbel-argmax).  Block sizes come
            from the shape-keyed autotuner (kernels/autotune.py).  With
            ``pre_norm`` the final-norm producer fuses into the sweep too
            (the kernel reads pre-norm tiles, norms in VMEM — one less
            (N, D) HBM round-trip).  The default.
  fused_jvp the fused kernel's ``custom_jvp`` twin (Pallas primal, linear
            chunked-jnp tangent): the ONLY fused path that composes under
            ``jax.jvp(jax.grad(.))`` — the Hutchinson estimator's HVP —
            because a custom_vjp cannot be forward-differentiated.
  chunked   pure-jnp vocab-chunk scan with a checkpointed body — the
            compiled logits-free reference (backward recomputes each chunk
            instead of saving [N, V] residuals).
  unfused   the legacy materialized-logits path (unembed + cross_entropy /
            jax.random.categorical) — the memory-hungry oracle the
            benchmarks compare against.

All three share one compute convention (see ``layers.unembed``): W cast to
the hidden dtype, fp32 accumulation, softcap then padded-column masking in
fp32 — so swapping implementations moves bytes, not math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.fused_ce import (fused_lm_loss, fused_lm_loss_jvp,
                                fused_lm_loss_sampled, online_argmax_step,
                                online_lse_step, rowscale, vocab_chunk)
from .common import ModelConfig
from .layers import (NEG_INF_LOGIT, cross_entropy, layer_norm, rms_norm,
                     unembed)

_LM_LOSS_IMPL = {"impl": "chunked"}
_IMPLS = ("fused", "fused_jvp", "chunked", "unfused")
_CHUNK = 2048  # vocab columns per jnp chunk (multiple of 128)


def set_lm_loss_impl(impl: str) -> None:
    """Select the process-wide default loss implementation."""
    assert impl in _IMPLS, impl
    _LM_LOSS_IMPL["impl"] = impl


def get_lm_loss_impl() -> str:
    return _LM_LOSS_IMPL["impl"]


def unembed_weights(cfg: ModelConfig, params):
    """(w, transpose_w): the unembedding matrix in its stored layout —
    (Vp, D) for tied embeddings, (D, Vp) untied — no host-side transpose."""
    emb = params["embed"]
    if cfg.tie_embeddings:
        return emb["tok"], False
    return emb["unembed"], True


def _norm_args(cfg: ModelConfig, params, pre_norm):
    """Kernel kwargs for the fused final-norm producer: the family's
    ``params["final_norm"]`` in the packed scale/bias convention."""
    p = params["final_norm"]
    return dict(norm_kind=pre_norm, norm_scale=p["scale"],
                norm_bias=p.get("bias"), norm_eps=cfg.norm_eps)


def _apply_final_norm(cfg: ModelConfig, params, hidden, pre_norm):
    """The jnp final norm for the non-kernel impls (identical math to the
    in-kernel producer — models.layers formulas)."""
    if pre_norm is None:
        return hidden
    p = params["final_norm"]
    if pre_norm == "ln":
        return layer_norm(hidden, p["scale"], p["bias"], cfg.norm_eps)
    assert pre_norm == "rms", pre_norm
    return rms_norm(hidden, p["scale"], cfg.norm_eps)


def _rowscale(hidden, mask):
    n = 1
    for s in hidden.shape[:-1]:
        n *= s
    return rowscale(n, mask)


def _chunked_sweep(cfg: ModelConfig, hidden, w, transpose_w, labels=None,
                   rng=None):
    """One checkpointed vocab-chunk scan: (lse, label_or_sampled_logit,
    yhat) per position.  With ``labels`` the gathered logit is the label's;
    with ``rng`` the sweep draws yhat ~ softmax(logits) by online chunked
    Gumbel-argmax (per-chunk ``fold_in`` noise) and gathers the winner's
    raw logit — one pass serves both sampling and logp (no fp32 [N, V]
    log_softmax copy).  The online reductions are the shared
    ``kernels.fused_ce.online_lse_step`` / ``online_argmax_step`` rules."""
    D = hidden.shape[-1]
    h2 = hidden.reshape(-1, D)
    N = h2.shape[0]
    vp = cfg.padded_vocab
    bv = vocab_chunk(vp, _CHUNK, 128)
    n_c = vp // bv
    vocab = cfg.vocab_size
    softcap = cfg.final_logit_softcap
    wdt = w.astype(hidden.dtype)
    sample = rng is not None
    lab = None if sample else labels.reshape(-1)

    def body(carry, c):
        m, l, ll, zm, zi = carry
        if transpose_w:
            wc = jax.lax.dynamic_slice_in_dim(wdt, c * bv, bv, axis=1)
            raw = jnp.dot(h2, wc, preferred_element_type=jnp.float32)
        else:
            wc = jax.lax.dynamic_slice_in_dim(wdt, c * bv, bv, axis=0)
            raw = jnp.dot(h2, wc.T, preferred_element_type=jnp.float32)
        if softcap:
            raw = softcap * jnp.tanh(raw / softcap)
        cols = c * bv + jnp.arange(bv, dtype=jnp.int32)[None, :]
        valid = cols < vocab
        s = jnp.where(valid, raw, NEG_INF_LOGIT)
        m, l = online_lse_step(m, l, s, valid)
        if sample:
            g = jax.random.gumbel(jax.random.fold_in(rng, c), s.shape,
                                  jnp.float32)
            z = jnp.where(valid, s + g, NEG_INF_LOGIT)
            zm, zi, ll = online_argmax_step((zm, zi, ll), s, z, c * bv)
        else:
            ll = ll + jnp.where(cols == lab[:, None], s, 0.0).sum(-1)
        return (m, l, ll, zm, zi), None

    init = (jnp.full((N,), NEG_INF_LOGIT, jnp.float32),
            jnp.zeros((N,), jnp.float32),
            jnp.zeros((N,), jnp.float32),
            jnp.full((N,), NEG_INF_LOGIT, jnp.float32),
            jnp.zeros((N,), jnp.int32))
    (m, l, ll, _, zi), _ = jax.lax.scan(
        jax.checkpoint(body), init, jnp.arange(n_c))
    return m + jnp.log(jnp.maximum(l, 1e-37)), ll, zi


def lm_loss(cfg: ModelConfig, params, hidden, labels, mask=None, *,
            impl=None, pre_norm=None):
    """Masked-mean LM cross-entropy from final hidden states.

    Returns ``(ce, n_valid)``; ``n_valid`` is the valid-position count (the
    GNB batch factor B).  ``impl`` overrides the module default.  With
    ``pre_norm`` ("rms" | "ln"), ``hidden`` is PRE-final-norm and the norm
    (``params["final_norm"]``) is applied here — fused into the kernel
    sweep for the fused impl, in jnp for the rest."""
    impl = impl or _LM_LOSS_IMPL["impl"]
    assert impl in _IMPLS, impl
    if impl == "fused":
        w, tw = unembed_weights(cfg, params)
        kw = _norm_args(cfg, params, pre_norm) if pre_norm else {}
        return fused_lm_loss(hidden, w, labels, mask,
                             vocab_size=cfg.vocab_size, transpose_w=tw,
                             softcap=cfg.final_logit_softcap, **kw)
    hidden = _apply_final_norm(cfg, params, hidden, pre_norm)
    if impl == "unfused":
        logits = unembed(params["embed"], hidden, cfg)
        _, n_valid = _rowscale(hidden, mask)
        return cross_entropy(logits, labels, mask), n_valid
    w, tw = unembed_weights(cfg, params)
    if impl == "fused_jvp":
        return fused_lm_loss_jvp(hidden, w, labels, mask,
                                 vocab_size=cfg.vocab_size, transpose_w=tw,
                                 softcap=cfg.final_logit_softcap)
    lse, ll, _ = _chunked_sweep(cfg, hidden, w, tw, labels=labels)
    rs, n_valid = _rowscale(hidden, mask)
    return jnp.sum(rs * (lse - ll)), n_valid


def lm_loss_sampled(cfg: ModelConfig, params, hidden, rng, mask=None, *,
                    impl=None, pre_norm=None):
    """GNB sampled-label CE (Algorithm 2 lines 3-5) from hidden states:
    draws ``yhat ~ softmax(logits)`` and returns the masked-mean NLL
    against it as ``(nll, n_valid)`` — differentiate this for ``ghat``.

    fused: sampling happens inside the kernel's vocab sweep; chunked: one
    jnp sweep serves sampling and logp; unfused: the legacy two-pass
    (categorical + log_softmax) path, kept as the oracle."""
    impl = impl or _LM_LOSS_IMPL["impl"]
    assert impl in _IMPLS, impl
    if impl == "fused_jvp":     # sampling has no HVP path; same kernels
        impl = "fused"
    w, tw = unembed_weights(cfg, params)
    if impl == "fused":
        kw = _norm_args(cfg, params, pre_norm) if pre_norm else {}
        return fused_lm_loss_sampled(hidden, w, rng, mask,
                                     vocab_size=cfg.vocab_size,
                                     transpose_w=tw,
                                     softcap=cfg.final_logit_softcap, **kw)
    hidden = _apply_final_norm(cfg, params, hidden, pre_norm)
    if impl == "unfused":
        logits = unembed(params["embed"], hidden, cfg)
        yhat = jax.random.categorical(rng, jax.lax.stop_gradient(logits),
                                      axis=-1)
        _, n_valid = _rowscale(hidden, mask)
        return cross_entropy(logits, yhat, mask), n_valid
    lse, ll, _ = _chunked_sweep(cfg, hidden, w, tw, rng=rng)
    rs, n_valid = _rowscale(hidden, mask)
    return jnp.sum(rs * (lse - ll)), n_valid
