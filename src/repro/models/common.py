"""Model configuration shared by every architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config type covering all 10 assigned families + the paper's GPT-2.

    family:
      dense    — decoder-only transformer (GQA/MQA/MHA, RoPE or learned pos)
      moe      — dense attention + mixture-of-experts FFN (token-choice top-k)
      rwkv     — RWKV-6 "Finch" (attention-free, data-dependent decay)
      griffin  — RecurrentGemma (RG-LRU recurrent blocks : local attention, 2:1)
      encdec   — encoder-decoder (seamless-m4t backbone)
    """
    name: str
    family: str                       # dense | moe | rwkv | griffin | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    # attention options
    qkv_bias: bool = False            # qwen1.5
    rope: bool = True
    rope_theta: float = 10000.0
    learned_pos: bool = False         # GPT-2 family
    max_position_embeddings: int = 1 << 20
    local_window: Optional[int] = None       # sliding-window size when local
    local_global_pattern: Optional[str] = None  # "alternating" (gemma2)
    attn_logit_softcap: Optional[float] = None  # gemma2: 50.0
    final_logit_softcap: Optional[float] = None  # gemma2: 30.0
    attn_temperature_by_layer: bool = False  # Karamcheti/Mistral trick (Fig 7b)
    # MLP
    activation: str = "swiglu"        # swiglu | gelu | geglu
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 1
    moe_every: int = 1                # llama4: MoE every other layer (=2)
    dense_d_ff: Optional[int] = None  # d_ff of interleaved dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # enc-dec
    n_encoder_layers: int = 0
    # VLM / multimodal
    mrope_sections: Optional[Tuple[int, ...]] = None  # qwen2-vl M-RoPE
    patch_embed_input: bool = False   # stub frontend injects patch embeddings
    frame_embed_input: bool = False   # stub frontend feeds encoder directly
    # griffin
    rnn_width: Optional[int] = None   # RG-LRU recurrence width
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rec","rec","attn")
    # embeddings / head
    tie_embeddings: bool = True
    embed_scale: bool = False         # gemma-style sqrt(d_model) scaling
    # norms
    norm_type: str = "rms"            # rms | ln (GPT-2)
    post_norms: bool = False          # gemma2 sandwich norms
    # numerics
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"
    norm_eps: float = 1e-6
    # serving KV cache storage: "bf16" stores cache entries in the compute
    # dtype; "int8" stores int8 payloads + one fp32 scale per written token
    # (repro.quant.quantize_kv), roughly doubling slots per HBM byte.
    # Implemented for the paged-KV families (dense/moe); bounded-state
    # families (rwkv/griffin) and encdec reject "int8" at init_slots.
    kv_dtype: str = "bf16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a 128-lane multiple so the embedding/unembedding
        shard cleanly over the model axis (e.g. seamless 256206 -> 256256).
        Padding rows are never targeted by labels; standard practice."""
        return -(-self.vocab_size // 128) * 128

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6*N*D rooflines)."""
        D, H, Hkv, hd, F, V, L = (self.d_model, self.n_heads, self.n_kv_heads,
                                  self.hd, self.d_ff, self.vocab_size,
                                  self.n_layers)
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "rwkv":
            # time-mix: r,k,v,g,o (5 D*D) + decay lora + channel-mix (2 D*F)
            per = 5 * D * D + 2 * (D * 64 + 64 * D) + 2 * D * F + 4 * D
            return emb + L * per
        if self.family == "griffin":
            W = self.rnn_width or D
            rec = 2 * D * W + W * D + 2 * W * self.conv_width + 4 * W  # in/out + gates
            att = D * (H * hd) + 2 * D * (self.n_kv_heads * hd) + (H * hd) * D
            mlp = 3 * D * F
            n_attn = L // 3
            n_rec = L - n_attn
            return emb + n_rec * (rec + mlp) + n_attn * (att + mlp)
        att = D * (H * hd) + 2 * D * (Hkv * hd) + (H * hd) * D
        if self.activation in ("swiglu", "geglu"):
            mlp = 3 * D * F
        else:
            mlp = 2 * D * F
        if self.family == "moe":
            n_moe = L // self.moe_every
            n_dense = L - n_moe
            dense_ff = self.dense_d_ff or F
            mlp_dense = 3 * D * dense_ff
            experts = (self.n_experts + self.n_shared_experts) * 3 * D * F
            router = D * self.n_experts
            return (emb + L * att + n_dense * mlp_dense
                    + n_moe * (experts + router))
        if self.family == "encdec":
            Le = self.n_encoder_layers
            cross = att  # decoder cross-attention
            return emb + Le * (att + mlp) + L * (att + cross + mlp)
        return emb + L * (att + mlp)

    def active_param_count(self) -> int:
        """Active params per token (MoE: 6*N_active*D rooflines)."""
        if self.family != "moe":
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        hd = self.hd
        att = D * (self.n_heads * hd) + 2 * D * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * D
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        n_moe = L // self.moe_every
        n_dense = L - n_moe
        dense_ff = self.dense_d_ff or F
        active_mlp = (self.moe_top_k + self.n_shared_experts) * 3 * D * F
        return (emb + L * att + n_dense * 3 * D * dense_ff
                + n_moe * (active_mlp + D * self.n_experts))
