"""Family dispatch: ModelConfig -> the module implementing it."""
from __future__ import annotations

from types import SimpleNamespace

from . import encdec, griffin, rwkv, transformer
from .common import ModelConfig


def get_model(cfg: ModelConfig) -> SimpleNamespace:
    """Returns a namespace of the family's functions:
    init_params, forward, forward_hidden (trunk -> final-norm hidden, the
    logits-free loss entry), loss_fn, sampled_loss_fn (GNB sampled-label
    NLL -> (nll, n_valid); see models/loss.py), logits_fn, decode_step,
    the family-appropriate cache/state constructor, and the serve-engine
    slot protocol (uniform across families — callers never branch on
    family):

        init_slots(cfg, n_slots, cache_len)            -> slot state pytree
        prefill_into_slot(cfg, params, state, slot,
                          tokens, start, n_valid)      -> (state, logits (V,))
        decode_slots(cfg, params, state, tok, pos)     -> (logits, state)
        reset_slot(cfg, state, slot)                   -> state

    ``slot``/``start``/``n_valid`` and the per-slot ``pos`` vector are
    traced, so each arch compiles exactly one prefill and one decode
    program regardless of batch composition or request lengths.

    ``cfg.kv_dtype`` threads through the whole protocol: ``"int8"`` makes
    ``init_slots`` allocate int8 K/V payloads plus fp32 per-token scale
    planes, and prefill/decode quantize at write time and dequantize at
    read time (models/layers.py, repro.quant).  The paged-KV families
    (dense/moe) implement it; rwkv/griffin (bounded recurrent state) and
    encdec raise at ``init_slots``.  Since the config keys the compiled
    programs, the dtype forks compilation per config — never per batch.
    """
    if cfg.family in ("dense", "moe"):
        return SimpleNamespace(
            init_params=transformer.init_params,
            forward=transformer.forward,
            forward_hidden=transformer.forward_hidden,
            loss_fn=transformer.loss_fn,
            sampled_loss_fn=transformer.sampled_loss_fn,
            logits_fn=transformer.logits_fn,
            decode_step=transformer.decode_step,
            prefill=transformer.prefill,
            init_cache=transformer.init_cache,
            init_slots=transformer.init_slots,
            prefill_into_slot=transformer.prefill_into_slot,
            decode_slots=transformer.decode_slots,
            reset_slot=transformer.reset_slot,
        )
    if cfg.family == "rwkv":
        return SimpleNamespace(
            init_params=rwkv.init_params,
            forward=rwkv.forward,
            forward_hidden=rwkv.forward_hidden,
            loss_fn=rwkv.loss_fn,
            sampled_loss_fn=rwkv.sampled_loss_fn,
            logits_fn=rwkv.logits_fn,
            decode_step=rwkv.decode_step,
            init_cache=lambda c, b, _len=None: rwkv.init_state(c, b),
            init_slots=rwkv.init_slots,
            prefill_into_slot=rwkv.prefill_into_slot,
            decode_slots=rwkv.decode_slots,
            reset_slot=rwkv.reset_slot,
        )
    if cfg.family == "griffin":
        return SimpleNamespace(
            init_params=griffin.init_params,
            forward=griffin.forward,
            forward_hidden=griffin.forward_hidden,
            loss_fn=griffin.loss_fn,
            sampled_loss_fn=griffin.sampled_loss_fn,
            logits_fn=griffin.logits_fn,
            decode_step=griffin.decode_step,
            init_cache=lambda c, b, _len=None: griffin.init_state(c, b),
            init_slots=griffin.init_slots,
            prefill_into_slot=griffin.prefill_into_slot,
            decode_slots=griffin.decode_slots,
            reset_slot=griffin.reset_slot,
        )
    if cfg.family == "encdec":
        return SimpleNamespace(
            init_params=encdec.init_params,
            forward=encdec.forward,
            forward_hidden=encdec.forward_hidden,
            loss_fn=encdec.loss_fn,
            sampled_loss_fn=encdec.sampled_loss_fn,
            logits_fn=encdec.logits_fn,
            decode_step=encdec.decode_step,
            init_cache=encdec.init_cache,
            prefill_encoder=encdec.prefill_encoder,
            init_slots=encdec.init_slots,
            prefill_into_slot=encdec.prefill_into_slot,
            prefill_encoder_slot=encdec.prefill_encoder_slot,
            decode_slots=encdec.decode_slots,
            reset_slot=encdec.reset_slot,
        )
    raise ValueError(f"unknown family: {cfg.family}")
