"""Family dispatch: ModelConfig -> the module implementing it."""
from __future__ import annotations

from types import SimpleNamespace

from . import encdec, griffin, rwkv, transformer
from .common import ModelConfig


def get_model(cfg: ModelConfig) -> SimpleNamespace:
    """Returns a namespace of the family's functions:
    init_params, forward, loss_fn, logits_fn, decode_step, and the
    family-appropriate cache/state constructor.
    """
    if cfg.family in ("dense", "moe"):
        return SimpleNamespace(
            init_params=transformer.init_params,
            forward=transformer.forward,
            loss_fn=transformer.loss_fn,
            logits_fn=transformer.logits_fn,
            decode_step=transformer.decode_step,
            prefill=transformer.prefill,
            init_cache=transformer.init_cache,
        )
    if cfg.family == "rwkv":
        return SimpleNamespace(
            init_params=rwkv.init_params,
            forward=rwkv.forward,
            loss_fn=rwkv.loss_fn,
            logits_fn=rwkv.logits_fn,
            decode_step=rwkv.decode_step,
            init_cache=lambda c, b, _len=None: rwkv.init_state(c, b),
        )
    if cfg.family == "griffin":
        return SimpleNamespace(
            init_params=griffin.init_params,
            forward=griffin.forward,
            loss_fn=griffin.loss_fn,
            logits_fn=griffin.logits_fn,
            decode_step=griffin.decode_step,
            init_cache=lambda c, b, _len=None: griffin.init_state(c, b),
        )
    if cfg.family == "encdec":
        return SimpleNamespace(
            init_params=encdec.init_params,
            forward=encdec.forward,
            loss_fn=encdec.loss_fn,
            logits_fn=encdec.logits_fn,
            decode_step=encdec.decode_step,
            init_cache=encdec.init_cache,
            prefill_encoder=encdec.prefill_encoder,
        )
    raise ValueError(f"unknown family: {cfg.family}")
