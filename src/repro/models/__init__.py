from .common import ModelConfig
from .registry import get_model
from .layers import (get_decode_attn_impl, get_train_attn_impl,
                     set_decode_attn_impl, set_train_attn_impl)
from .loss import (get_lm_loss_impl, lm_loss, lm_loss_sampled,
                   set_lm_loss_impl, unembed_weights)
