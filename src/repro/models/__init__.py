from .common import ModelConfig
from .registry import get_model
