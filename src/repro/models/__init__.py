from .common import ModelConfig
from .registry import get_model
from .loss import (get_lm_loss_impl, lm_loss, lm_loss_sampled,
                   set_lm_loss_impl, unembed_weights)
