"""stablelm-1.6b [dense] — 24L d_model=2048 32H (MHA kv=32) d_ff=5632
vocab=100352.  [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab_size=100352, head_dim=64,
    rope=True, norm_type="ln", activation="swiglu", tie_embeddings=False,
)

SMOKE_CONFIG = ModelConfig(
    name="stablelm-1.6b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=8, d_ff=160,
    vocab_size=512, head_dim=8,
    rope=True, norm_type="ln", activation="swiglu", tie_embeddings=False,
)
