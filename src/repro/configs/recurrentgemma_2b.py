"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1, head 256)
d_ff=7680 vocab=256000; RG-LRU recurrent : local attention (window 2048)
at 2:1 (groups of rec,rec,attn; 26 = 8 groups + 2 tail recurrent).
[arXiv:2402.19427; hf]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="griffin",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab_size=256000, head_dim=256,
    rope=True, local_window=2048, rnn_width=2560, conv_width=4,
    activation="geglu", tie_embeddings=True, embed_scale=True,
)

SMOKE_CONFIG = ModelConfig(
    name="recurrentgemma-smoke", family="griffin",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, d_ff=160,
    vocab_size=512, head_dim=16,
    rope=True, local_window=16, rnn_width=64, conv_width=4,
    activation="geglu", tie_embeddings=True, embed_scale=True,
)
