"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064; M-RoPE (sections 16/24/24), dynamic-resolution vision
frontend STUBBED: input_specs feeds precomputed patch embeddings.
[arXiv:2409.12191; hf]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab_size=152064, head_dim=128,
    qkv_bias=True, rope=True, rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24), patch_embed_input=True,
    activation="swiglu", tie_embeddings=False,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-vl-7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=512, head_dim=16,
    qkv_bias=True, rope=True, mrope_sections=(2, 3, 3),
    patch_embed_input=True, activation="swiglu", tie_embeddings=False,
)
