"""The paper's own model family (Table 2): GPT-2 30M-770M (nanoGPT style:
learned positions, GELU, LayerNorm, no biasless tricks, context 1024) and
GPT-NeoX 1.5B/6.6B (rope, context 2048).  Used by the reproduction
benchmarks (steps-to-loss, overhead, ablations)."""
from ..models.common import ModelConfig


def _gpt2(name, d, L, H, ctx=1024, vocab=50304):
    return ModelConfig(
        name=name, family="dense", n_layers=L, d_model=d, n_heads=H,
        n_kv_heads=H, d_ff=4 * d, vocab_size=vocab,
        rope=False, learned_pos=True, max_position_embeddings=ctx,
        norm_type="ln", activation="gelu", tie_embeddings=True,
    )


def _neox(name, d, L, H, ctx=2048, vocab=50432):
    return ModelConfig(
        name=name, family="dense", n_layers=L, d_model=d, n_heads=H,
        n_kv_heads=H, d_ff=4 * d, vocab_size=vocab,
        rope=True, norm_type="ln", activation="gelu", tie_embeddings=False,
    )


GPT2_30M = _gpt2("gpt2-30m", 384, 6, 6)
GPT2_SMALL = _gpt2("gpt2-small-125m", 768, 12, 12)
GPT2_MEDIUM = _gpt2("gpt2-medium-355m", 1024, 24, 16)
GPT2_540M = _gpt2("gpt2-540m", 1152, 30, 18)
GPT2_LARGE = _gpt2("gpt2-large-770m", 1280, 36, 20)
NEOX_1_5B = _neox("neox-1.5b", 1536, 48, 24)
NEOX_6_6B = _neox("neox-6.6b", 4096, 32, 32)

# tiny variant for fast CPU benchmarks/tests (paper uses 30M for HP search)
GPT2_TINY = _gpt2("gpt2-tiny", 128, 4, 4, ctx=256, vocab=512)

CONFIG = GPT2_SMALL
SMOKE_CONFIG = GPT2_TINY
