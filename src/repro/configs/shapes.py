"""Assigned input shapes + per-(arch, shape) input_specs.

Shapes (LM family, seq_len x global_batch):
    train_4k     4,096 x 256   train_step
    prefill_32k  32,768 x 32   prefill step (forward + KV-cache fill)
    decode_32k   32,768 x 128  serve_step: 1 new token, cache of seq_len
    long_500k    524,288 x 1   serve_step; ONLY bounded-state archs
                               (rwkv6, recurrentgemma) — full-attention archs
                               are skipped with reason (DESIGN.md §4)

``input_specs`` returns jax.ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, zero allocation), plus which step function the
cell lowers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..models import ModelConfig
from ..models import encdec as encdec_mod
from ..models import griffin as griffin_mod
from ..models import rwkv as rwkv_mod
from ..models import transformer as tf_mod

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

BOUNDED_STATE_FAMILIES = ("rwkv", "griffin")
N_PATCHES = 256          # qwen2-vl stub: one 256-patch image per sequence


def applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape_name == "long_500k" and cfg.family not in BOUNDED_STATE_FAMILIES:
        return False, ("full-attention KV state is unbounded at 524k; "
                       "long_500k runs only for SSM/hybrid archs "
                       "(DESIGN.md §4)")
    return True, ""


@dataclasses.dataclass
class Cell:
    kind: str                     # train | prefill | decode
    specs: dict                   # kwargs of the step function (SDS trees)
    note: str = ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _token_batch(cfg, B, S):
    return {"tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32)}


def input_specs(cfg: ModelConfig, shape_name: str) -> Optional[Cell]:
    """Build the dry-run cell for (arch, shape); None if inapplicable."""
    ok, _ = applicable(cfg, shape_name)
    if not ok:
        return None
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    kind = sh["kind"]
    i32 = jnp.int32

    if kind == "train":
        batch = _token_batch(cfg, B, S)
        if cfg.patch_embed_input:
            batch["patch_embeds"] = _sds((B, N_PATCHES, cfg.d_model),
                                         jnp.float32)
            batch["mask"] = _sds((B, S), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = _sds((B, S, cfg.d_model), jnp.float32)
        return Cell(kind="train", specs={"batch": batch})

    if kind == "prefill":
        specs = {"tokens": _sds((B, S), i32)}
        if cfg.patch_embed_input:
            specs["patch_embeds"] = _sds((B, N_PATCHES, cfg.d_model),
                                         jnp.float32)
        if cfg.family == "encdec":
            specs = {"frames": _sds((B, S, cfg.d_model), jnp.float32),
                     "cache": jax.eval_shape(
                         lambda: encdec_mod.init_cache(cfg, B, S, S))}
        if cfg.family == "rwkv":
            specs["state"] = jax.eval_shape(
                lambda: rwkv_mod.init_state(cfg, B))
        return Cell(kind="prefill", specs=specs)

    # decode
    tokens = _sds((B, 1), i32)
    if cfg.family in ("dense", "moe"):
        cache = jax.eval_shape(lambda: tf_mod.init_cache(cfg, B, S))
        return Cell(kind="decode",
                    specs={"cache": cache, "tokens": tokens,
                           "position": S - 1})
    if cfg.family == "rwkv":
        state = jax.eval_shape(lambda: rwkv_mod.init_state(cfg, B))
        return Cell(kind="decode",
                    specs={"cache": state, "tokens": tokens,
                           "position": S - 1},
                    note="O(1) recurrent state; cache size independent of "
                         f"context {S}")
    if cfg.family == "griffin":
        state = jax.eval_shape(lambda: griffin_mod.init_state(cfg, B))
        return Cell(kind="decode",
                    specs={"cache": state, "tokens": tokens,
                           "position": S - 1},
                    note=f"bounded state: RG-LRU h + {cfg.local_window}-token "
                         "rolling window")
    if cfg.family == "encdec":
        cache = jax.eval_shape(lambda: encdec_mod.init_cache(cfg, B, S, S))
        return Cell(kind="decode",
                    specs={"cache": cache, "tokens": tokens,
                           "position": S - 1})
    raise ValueError(cfg.family)
