"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
vocab=202048; MoE 128 routed experts top-1 + 1 shared, interleaved every
other layer (dense layers d_ff=16384, expert d_ff=8192) — the interleaving
and shared expert follow the released Llama-4 recipe so that total ~400B /
active ~17B match the assignment id.  [hf:meta-llama/Llama-4-*; unverified]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202048, head_dim=128,
    rope=True, rope_theta=500_000.0,
    n_experts=128, n_shared_experts=1, moe_top_k=1, moe_every=2,
    dense_d_ff=16384, capacity_factor=1.25,
    activation="swiglu", tie_embeddings=False,
)

SMOKE_CONFIG = ModelConfig(
    name="llama4-maverick-smoke", family="moe",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=512, head_dim=16,
    n_experts=8, n_shared_experts=1, moe_top_k=1, moe_every=2,
    dense_d_ff=192, activation="swiglu", tie_embeddings=False,
)
