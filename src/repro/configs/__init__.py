"""Architecture registry: --arch <id> -> (CONFIG, SMOKE_CONFIG)."""
from . import (deepseek_moe_16b, gemma2_9b, gpt2, llama4_maverick_400b_a17b,
               qwen1_5_110b, qwen2_vl_7b, recurrentgemma_2b, rwkv6_7b,
               seamless_m4t_medium, stablelm_1_6b, yi_6b)
from .shapes import SHAPES, Cell, applicable, input_specs

ARCHS = {
    "qwen1.5-110b": qwen1_5_110b,
    "yi-6b": yi_6b,
    "gemma2-9b": gemma2_9b,
    "stablelm-1.6b": stablelm_1_6b,
    "qwen2-vl-7b": qwen2_vl_7b,
    "rwkv6-7b": rwkv6_7b,
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "recurrentgemma-2b": recurrentgemma_2b,
    # paper's own family
    "gpt2-small": gpt2,
}

ASSIGNED = [k for k in ARCHS if k != "gpt2-small"]


def get_config(arch: str, smoke: bool = False):
    mod = ARCHS[arch]
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG
