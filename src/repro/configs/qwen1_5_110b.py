"""qwen1.5-110b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-0.5B family; hf]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=49152,
    vocab_size=152064, head_dim=128,
    qkv_bias=True, rope=True, rope_theta=1_000_000.0,
    activation="swiglu", tie_embeddings=False,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen1.5-110b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160,
    vocab_size=512, head_dim=8,
    qkv_bias=True, rope=True, activation="swiglu", tie_embeddings=False,
)
