"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000; local+global alternating attention (window 4096), logit
softcaps (attn 50, final 30), sandwich norms, GeGLU.  [arXiv:2408.00118; hf]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_ff=14336,
    vocab_size=256000, head_dim=256,
    rope=True, local_global_pattern="alternating", local_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0, post_norms=True,
    activation="geglu", tie_embeddings=True, embed_scale=True,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma2-9b-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=512, head_dim=16,
    rope=True, local_global_pattern="alternating", local_window=16,
    attn_logit_softcap=50.0, final_logit_softcap=30.0, post_norms=True,
    activation="geglu", tie_embeddings=True, embed_scale=True,
)
