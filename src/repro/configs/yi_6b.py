"""yi-6b [dense] — 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-arch GQA.  [arXiv:2403.04652; hf]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11008,
    vocab_size=64000, head_dim=128,
    rope=True, rope_theta=5_000_000.0,
    activation="swiglu", tie_embeddings=False,
)

SMOKE_CONFIG = ModelConfig(
    name="yi-6b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160,
    vocab_size=512, head_dim=8, rope=True,
    activation="swiglu", tie_embeddings=False,
)
