"""seamless-m4t-medium [audio] — enc-dec backbone: 12L encoder + 12L decoder
d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=256206.  The speech/text
modality frontend is a STUB: input_specs feeds precomputed frame embeddings
(B, S_src, d_model) to the encoder.  [arXiv:2308.11596; hf]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_encoder_layers=12, d_model=1024, n_heads=16,
    n_kv_heads=16, d_ff=4096, vocab_size=256206, head_dim=64,
    rope=True, activation="gelu", tie_embeddings=True,
    frame_embed_input=True,
)

SMOKE_CONFIG = ModelConfig(
    name="seamless-m4t-smoke", family="encdec",
    n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16,
    rope=True, activation="gelu", tie_embeddings=True,
    frame_embed_input=True,
)
