"""rwkv6-7b [ssm] — Finch: 32L d_model=4096 (attn-free, 64 heads x 64)
d_ff=14336 vocab=65536; data-dependent decay.  [arXiv:2404.05892; hf]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_ff=14336,
    vocab_size=65536, head_dim=64,
    rope=False, tie_embeddings=False,
)

SMOKE_CONFIG = ModelConfig(
    name="rwkv6-7b-smoke", family="rwkv",
    n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, d_ff=256,
    vocab_size=512, head_dim=64,
    rope=False, tie_embeddings=False,
)
