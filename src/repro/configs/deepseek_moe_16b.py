"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (MHA kv=16) expert
d_ff=1408 vocab=102400; fine-grained 64 routed experts top-6 + 2 shared.
[arXiv:2401.06066; hf]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=102400, head_dim=128,
    rope=True,
    n_experts=64, n_shared_experts=2, moe_top_k=6, moe_every=1,
    capacity_factor=1.25,
    activation="swiglu", tie_embeddings=False,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
    vocab_size=512, head_dim=16,
    n_experts=8, n_shared_experts=2, moe_top_k=3, moe_every=1,
    activation="swiglu", tie_embeddings=False,
)
