"""Sophia-JAX: production-scale reproduction of 'Sophia: A Scalable
Stochastic Second-order Optimizer for Language Model Pre-training'
(Liu, Li, Hall, Liang, Ma — ICLR 2024) as a multi-pod JAX framework.

Subpackages: core (the optimizer), models (10-arch zoo), distributed
(sharding/EP/compression), train, serve, kernels (Pallas), configs, launch.
"""
__version__ = "1.0.0"
