import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Roofline overlay for the flash-attention Pallas kernel (§Perf, yi_prefill
round 2).

The kernel cannot appear in the dry-run HLO (a Pallas call is opaque to the
cost model and the CPU backend can't lower TPU kernels natively), so the
overlay is measured structurally:

  1. lower ONE yi-6b transformer layer at the prefill shape on the
     production mesh, (a) with real chunked attention, (b) with the
     attention middle (scores/softmax/AV) replaced by an identity on v —
     same projections, same shapes;
  2. attention-middle HBM bytes per layer = bytes(a) - bytes(b);
  3. fused-kernel bytes per layer = Q+K+V+O exactly (kernel reads each
     input once, writes the output once — kernels/flash_attention.py);
  4. overlay t_memory = measured cell t_memory - n_layers * (middle -
     fused) / HBM_BW.

    PYTHONPATH=src python -m repro.launch.flash_overlay
"""
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import get_config
from ..distributed.sharding import (batch_specs, partition_params,
                                    set_activation_mesh)
from ..kernels.flash_attention import attention_hbm_bytes_flash
from ..launch.hlo_analysis import analyze_hlo
from ..launch.mesh import make_production_mesh
from ..launch.roofline import HBM_BW
from ..models.layers import chunked_attention, init_attention
from ..models.transformer import _norm


def measure(arch="yi-6b", shape_B=32, shape_S=32768):
    cfg = get_config(arch)
    mesh = make_production_mesh()
    set_activation_mesh(mesh)
    pshape = jax.eval_shape(
        lambda k: {"attn": init_attention(k, cfg),
                   "ln": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}},
        jax.random.PRNGKey(0))
    pspecs = partition_params(pshape, mesh, fsdp=False)
    x_sds = jax.ShapeDtypeStruct((shape_B, shape_S, cfg.d_model),
                                 jnp.bfloat16)
    xspec = batch_specs({"x": x_sds}, mesh)["x"]
    pos = jnp.broadcast_to(jnp.arange(shape_S)[None], (shape_B, shape_S))

    def layer_real(p, x):
        h = _norm(p["ln"], x, cfg)
        return x + chunked_attention(p["attn"], h, cfg, pos)

    def layer_identity_mid(p, x):
        """Same projections; scores/softmax/AV replaced by v pass-through."""
        from repro.models.layers import _qkv, apply_rope
        h = _norm(p["ln"], x, cfg)
        q, k, v = _qkv(p["attn"], h, cfg)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        G = cfg.n_heads // cfg.n_kv_heads
        o = (jnp.repeat(v, G, axis=2)
             + 0 * q).reshape(x.shape[0], x.shape[1], -1)
        return x + o @ p["attn"]["wo"].astype(x.dtype)

    out = {}
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda s: isinstance(s, P))
    for name, fn in (("real", layer_real), ("identity", layer_identity_mid)):
        c = jax.jit(fn, in_shardings=(ns(pspecs), ns(xspec))).lower(
            pshape, x_sds).compile()
        acc = analyze_hlo(c.as_text())
        out[name] = acc["bytes"]
    middle = out["real"] - out["identity"]
    # fused kernel traffic per device: heads shard over model(16), batch
    # over data(16)
    chips = mesh.devices.size
    fused = attention_hbm_bytes_flash(shape_B, cfg.n_heads, cfg.n_kv_heads,
                                      shape_S, cfg.hd) / chips
    return {
        "arch": arch,
        "bytes_per_layer_middle_measured": middle,
        "bytes_per_layer_flash_analytic": fused,
        "reduction_x": middle / max(fused, 1),
        "t_mem_savings_per_layer_s": (middle - fused) / HBM_BW,
        "n_layers": cfg.n_layers,
        "t_mem_savings_total_s": cfg.n_layers * (middle - fused) / HBM_BW,
    }


if __name__ == "__main__":
    res = measure()
    print(json.dumps(res, indent=1, default=float))
    with open("results/flash_overlay.json", "w") as f:
        json.dump(res, f, indent=1, default=float)
