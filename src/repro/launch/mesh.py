"""Production meshes, multi-host initialization, and scheduler flags.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax device query.
"""
from __future__ import annotations

import os

import jax

try:  # jax >= 0.5 names explicit/auto axis types; older jax has only Auto
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version compat
    AxisType = None


def _mesh_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def latency_hiding_flags(platform: str) -> tuple:
    """XLA flags that let the scheduler overlap the bucketed gradient
    collectives (distributed/overlap.py) with backward compute.

    Keyed on platform because XLA treats *unknown* flags as fatal — a
    ``--xla_tpu_*`` flag crashes a CPU-only build at first compile.  CPU
    gets the empty set: the thunk runtime already executes independent
    per-bucket collective chains concurrently with compute, no flag
    needed."""
    if platform == "tpu":
        return (
            "--xla_tpu_enable_latency_hiding_scheduler=true",
            "--xla_tpu_enable_async_collective_fusion=true",
            "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
            "--xla_tpu_overlap_compute_collective_tc=true",
        )
    if platform == "gpu":
        return ("--xla_gpu_enable_latency_hiding_scheduler=true",)
    return ()


def enable_latency_hiding(platform: str = "tpu") -> bool:
    """Append :func:`latency_hiding_flags` to ``XLA_FLAGS`` in the
    environment.  Must run before the first jax device query (same rule as
    the dry-run); flags already present are not duplicated.  Returns True
    if the environment changed."""
    flags = [f for f in latency_hiding_flags(platform)
             if f not in os.environ.get("XLA_FLAGS", "")]
    if not flags:
        return False
    prior = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = (prior + " " + " ".join(flags)).strip()
    return True


def initialize_distributed(coordinator: str, num_processes: int,
                           process_id: int) -> None:
    """Multi-process jax runtime init (idempotent-ish: call once, before
    any jax device use).

    On CPU the default collectives implementation cannot cross processes;
    gloo can, and must be selected *before* ``jax.distributed.initialize``
    touches the backend.  Platform detection is env-only
    (``JAX_PLATFORMS``) because querying the backend here would initialize
    it pre-distributed — the exact bug this helper exists to prevent.  The
    2-process localhost tier (tests/test_multiprocess.py) runs this path."""
    if os.environ.get("JAX_PLATFORMS", "").startswith("cpu"):
        try:
            jax.config.update("jax_cpu_collectives_implementation", "gloo")
        except Exception:  # pragma: no cover - older jax: option absent
            pass
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)


def make_host_spanning_mesh(shape, axes):
    """Mesh over ALL global devices (every process's), for multi-host
    data parallelism.  Identical to :func:`make_mesh` on one process —
    ``jax.devices()`` is the global list either way — but kept as a named
    entry point so call sites document their multi-host intent."""
    return make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod 16x16 = 256 chips (data, model); multi-pod adds a leading
    pod axis: 2 x 16 x 16 = 512 chips (pod, data, model).

    Scaling pods is a shape change only: every PartitionSpec in the tree
    uses the composite ("pod", "data") axis, so (8, 16, 16) = 2048 chips
    works unchanged.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape, axes, devices=None):
    """Generic helper for tests/examples (e.g. (2, 2) on 4 host devices).

    ``devices`` restricts the mesh to an explicit device subset — the
    elastic driver uses this to rebuild a smaller mesh after losing nodes
    (e.g. 8 -> 4 devices) without restarting the process."""
    if devices is not None:
        import numpy as np
        from jax.sharding import Mesh
        return Mesh(np.asarray(devices).reshape(tuple(shape)), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_mesh_kwargs(len(axes)))
