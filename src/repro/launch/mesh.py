"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax device query.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 names explicit/auto axis types; older jax has only Auto
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version compat
    AxisType = None


def _mesh_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod 16x16 = 256 chips (data, model); multi-pod adds a leading
    pod axis: 2 x 16 x 16 = 512 chips (pod, data, model).

    Scaling pods is a shape change only: every PartitionSpec in the tree
    uses the composite ("pod", "data") axis, so (8, 16, 16) = 2048 chips
    works unchanged.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape, axes, devices=None):
    """Generic helper for tests/examples (e.g. (2, 2) on 4 host devices).

    ``devices`` restricts the mesh to an explicit device subset — the
    elastic driver uses this to rebuild a smaller mesh after losing nodes
    (e.g. 8 -> 4 devices) without restarting the process."""
    if devices is not None:
        import numpy as np
        from jax.sharding import Mesh
        return Mesh(np.asarray(devices).reshape(tuple(shape)), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_mesh_kwargs(len(axes)))
