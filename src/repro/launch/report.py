"""Generate the EXPERIMENTS.md roofline/dry-run tables from results JSONs."""
import json
import os
import sys


def load(path):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def fmt_cell(r):
    if r.get("skipped"):
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | "
                f"SKIP: {r['skipped'][:58]} |")
    if r.get("error"):
        return f"| {r['arch']} | {r['shape']} | ERROR {r['error'][:60]} |"
    t = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
    frac = r["t_compute_s"] / t * r.get("useful_flops_ratio", 0) if t else 0
    return ("| {arch} | {shape} | {tc:.3f} | {tm:.3f} | {tcoll:.3f} | "
            "{dom} | {useful:.2f} | {frac:.4f} | {mem:.1f} | {note} |").format(
        arch=r["arch"], shape=r["shape"], tc=r["t_compute_s"],
        tm=r["t_memory_s"], tcoll=r["t_collective_s"], dom=r["dominant"],
        useful=r.get("useful_flops_ratio", 0), frac=frac,
        mem=r["mem_peak_gb"],
        note=f"accum={r.get('grad_accum', 1)}"
             + (",bf16-states" if r.get("state_dtype") == "bfloat16" else ""))


HDR = ("| arch | shape | T_compute (s) | T_memory (s) | T_collective (s) | "
       "dominant | useful (6ND/HLO) | roofline frac | mem/dev (GB) | notes |\n"
       "|---|---|---|---|---|---|---|---|---|---|")


def table(rows):
    return "\n".join([HDR] + [fmt_cell(r) for r in rows])


def hillclimb_table(rows):
    out = ["| cell | variant | T_compute | T_memory | T_collective | "
           "dominant | mem GB | hypothesis |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("error"):
            out.append(f"| {r['cell']} | {r['variant']} | ERROR: "
                       f"{r['error'][:60]} |")
            continue
        out.append(
            f"| {r['cell']} | {r['variant']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['dominant']} | {r['mem_peak_gb']:.1f} | "
            f"{r['hypothesis'][:90]} |")
    return "\n".join(out)


def main():
    base = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "results")
    base = os.path.abspath("results")
    single = load(os.path.join(base, "dryrun_single.json"))
    multi = load(os.path.join(base, "dryrun_multi.json"))
    hc = load(os.path.join(base, "hillclimb.json"))
    print("## Single-pod (16x16 = 256 chips)\n")
    print(table(single))
    print("\n## Multi-pod (2x16x16 = 512 chips)\n")
    print(table(multi))
    if hc:
        print("\n## Hillclimb variants\n")
        print(hillclimb_table(hc))


if __name__ == "__main__":
    main()
