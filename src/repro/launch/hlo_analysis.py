"""HLO-text cost analyzer with correct while-loop accounting.

XLA's built-in ``compiled.cost_analysis()`` counts a while-loop body ONCE —
with scan-over-layers (every model here) that under-counts flops/bytes/
collectives by ~n_layers.  This analyzer parses ``compiled.as_text()`` and:

  * walks the computation call graph (fusion ``calls=``, ``while`` body/
    condition), multiplying while bodies by their trip count (parsed from
    the loop-condition's comparison constant — scans lower to
    ``i < constant(N)`` with i starting at 0);
  * counts dot flops as 2 * numel(output) * prod(contracting dims)
    (parsed from ``lhs_contracting_dims``) — MXU convention;
  * models HBM bytes opcode-aware: fusions count only their boundary
    operands/outputs; a fused operand consumed solely by dynamic-slice
    counts the slice bytes (not the whole stacked array); a fusion rooted
    in dynamic-update-slice counts the updated window (the big buffer is
    updated in place); parameters/GTE/bitcast/tuple/constant are free;
  * sums collective operand bytes per kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), counting async
    ``-start`` once and skipping ``-done`` — multiplied through loops like
    everything else.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_ITEM = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    return sum(_numel(dims) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_ITEM.findall(type_str))


def _type_numel(type_str: str) -> int:
    return sum(_numel(dims) for _dt, dims in _SHAPE_ITEM.findall(type_str))


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    by_name: Dict[str, Instr]


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\(")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HDR.match(s)
            if m and not s.startswith("//"):
                cur = Computation(m.group(1), [], {})
            continue
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        rest = line[m.end() - 1:]
        # operand segment: first balanced (...) after the opcode
        depth = 0
        args = ""
        for ch in rest:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                args += ch
        operands = re.findall(r"%([\w.\-]+)", args)
        instr = Instr(name, type_str, opcode, operands, line)
        cur.instrs.append(instr)
        cur.by_name[name] = instr
    return comps


def _find_entry(text: str, comps: Dict[str, Computation]) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    if m:
        return m.group(1)
    return list(comps)[-1]


def _trip_count(cond: Computation) -> int:
    """Largest s32 constant in the loop condition (scan: i < N, i0 = 0)."""
    best = 1
    for ins in cond.instrs:
        m = re.search(r"s32\[\]\s+constant\((\d+)\)", ins.line)
        if m:
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: Instr, comp: Computation,
               shapes: Dict[str, str]) -> float:
    out_numel = _type_numel(ins.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    lhs_name = ins.operands[0] if ins.operands else None
    lhs_type = shapes.get(lhs_name, "")
    item = _SHAPE_ITEM.search(lhs_type)
    if not (m and item):
        return 2.0 * out_numel  # unknown: degenerate estimate
    lhs_dims = [int(d) for d in item.group(2).split(",") if d]
    contract = 1
    for d in m.group(1).split(","):
        if d and int(d) < len(lhs_dims):
            contract *= lhs_dims[int(d)]
    return 2.0 * out_numel * contract


_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
             "constant", "after-all", "iota", "broadcast", "reshape",
             "partition-id", "replica-id"}


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self.entry = _find_entry(text, self.comps)
        self._memo: Dict[tuple, dict] = {}

    # -- byte model helpers -------------------------------------------------

    def _fusion_param_bytes(self, called: Computation, idx: int,
                            full_bytes: int) -> int:
        """Bytes actually read from fusion parameter ``idx``."""
        pname = None
        for ins in called.instrs:
            if ins.opcode == "parameter" and f"parameter({idx})" in ins.line:
                pname = ins.name
                break
        if pname is None:
            return full_bytes
        consumers = [i for i in called.instrs if pname in i.operands]
        if consumers and all(c.opcode in ("dynamic-slice", "gather")
                             and c.operands and c.operands[0] == pname
                             for c in consumers):
            return sum(_type_bytes(c.type_str) for c in consumers)
        if consumers and all(c.opcode == "dynamic-update-slice"
                             and c.operands and c.operands[0] == pname
                             for c in consumers):
            return 0  # in-place updated buffer: reads nothing
        return full_bytes

    def _fusion_out_bytes(self, called: Computation, out_bytes: int) -> int:
        root = called.instrs[-1] if called.instrs else None
        if root is not None and root.opcode == "dynamic-update-slice":
            # writes only the update window
            upd = root.operands[1] if len(root.operands) > 1 else None
            if upd and upd in called.by_name:
                return _type_bytes(called.by_name[upd].type_str)
        return out_bytes

    # -- main walk ----------------------------------------------------------

    def cost(self, comp_name: Optional[str] = None) -> dict:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        zero = {"flops": 0.0, "bytes": 0.0,
                "coll": {k: 0.0 for k in _COLLECTIVES}}
        if comp is None:
            return zero
        total = {"flops": 0.0, "bytes": 0.0,
                 "coll": {k: 0.0 for k in _COLLECTIVES}}
        shapes = {i.name: i.type_str for i in comp.instrs}

        def add(sub, mult=1.0):
            total["flops"] += mult * sub["flops"]
            total["bytes"] += mult * sub["bytes"]
            for k in _COLLECTIVES:
                total["coll"][k] += mult * sub["coll"][k]

        for ins in comp.instrs:
            op = ins.opcode
            out_b = _type_bytes(ins.type_str)
            if op in _FREE_OPS or op.endswith("-done"):
                continue
            if op == "while":
                body = re.search(r"body=%?([\w.\-]+)", ins.line)
                cond = re.search(r"condition=%?([\w.\-]+)", ins.line)
                trips = _trip_count(self.comps[cond.group(1)]) if cond else 1
                if body:
                    add(self.cost(body.group(1)), mult=max(trips, 1))
                continue
            if op in ("call", "conditional"):
                for m in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)",
                                     ins.line):
                    add(self.cost(m.group(1)))
                continue
            if op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.line)
                called = self.comps.get(m.group(1)) if m else None
                if called is not None:
                    # flops from internal dots; bytes at the boundary
                    inner_shapes = {i.name: i.type_str
                                    for i in called.instrs}
                    for sub in called.instrs:
                        if sub.opcode == "dot":
                            total["flops"] += _dot_flops(sub, called,
                                                         inner_shapes)
                        elif sub.opcode not in _FREE_OPS:
                            total["flops"] += _type_numel(sub.type_str)
                    for idx, oname in enumerate(ins.operands):
                        ob = _type_bytes(shapes.get(oname, ""))
                        total["bytes"] += self._fusion_param_bytes(
                            called, idx, ob)
                    total["bytes"] += self._fusion_out_bytes(called, out_b)
                continue
            kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
            if kind is not None:
                b = sum(_type_bytes(shapes.get(o, "")) for o in ins.operands)
                if b == 0:
                    b = out_b
                total["coll"][kind] += b
                total["bytes"] += b + out_b
                continue
            if op == "dot":
                total["flops"] += _dot_flops(ins, comp, shapes)
            elif op == "custom-call":
                # oneDNN matmul etc.: estimate as dot via operand dims
                total["flops"] += 2.0 * _type_numel(ins.type_str)
            elif op not in ("dynamic-slice", "dynamic-update-slice"):
                total["flops"] += _type_numel(ins.type_str)
            # HBM traffic model per opcode: slicing ops touch only the
            # window (a top-level DUS on a scan-stacked buffer is an
            # in-place write of one slice, NOT a full-buffer copy)
            if op == "dynamic-slice":
                total["bytes"] += 2 * out_b
            elif op == "dynamic-update-slice":
                upd = (_type_bytes(shapes.get(ins.operands[1], ""))
                       if len(ins.operands) > 1 else out_b)
                total["bytes"] += 2 * upd
            elif op == "gather":
                # touches only the gathered rows, not the whole table
                total["bytes"] += 2 * out_b
            elif op == "scatter":
                upd = (_type_bytes(shapes.get(ins.operands[2], ""))
                       if len(ins.operands) > 2 else out_b)
                total["bytes"] += 3 * upd  # read-modify-write of the window
            else:
                total["bytes"] += out_b + sum(
                    _type_bytes(shapes.get(o, "")) for o in ins.operands)

        self._memo[comp_name] = total
        return total


def analyze_hlo(text: str) -> dict:
    """Entry point: {'flops', 'bytes', 'coll': {kind: bytes}, 'coll_total'}."""
    hc = HloCost(text)
    c = hc.cost()
    c["coll_total"] = sum(c["coll"].values())
    return c
