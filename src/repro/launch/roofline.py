"""Roofline-term extraction from compiled dry-run artifacts.

    compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF/s bf16)
    memory     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
    collective = collective_bytes_per_device / link_bw      (~50 GB/s ICI)

``cost_analysis`` reports per-device (post-SPMD) flops and bytes.
Collective bytes are NOT in cost_analysis: we parse the compiled HLO text,
build a symbol table of instruction result sizes, and sum the *operand*
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (counting async ``-start`` once, skipping ``-done``).

Predicted vs measured (the kernel autotuners' pruning model)
------------------------------------------------------------
The same HBM roofline that :func:`loss_stage_seconds` and
:func:`attention_stage_seconds` evaluate per *path* (fused vs unfused) is
evaluated per *candidate block config* by
``kernels.autotune.predict_seconds`` (fused CE) and
``kernels.autotune.attn_predict_seconds`` (flash attention): each kernel
pass contributes ``max(flops / PEAK_FLOPS, bytes / HBM_BW)`` where the
bytes term counts the tiles each grid arrangement actually streams (e.g.
the CE backward re-reads W once per row-block, so shrinking ``bn``
multiplies W traffic; the attention cost counts only in-band tiles under
the causal/window schedule).  The prediction is deliberately coarse — it
only has to *rank* candidates so the top-K survive to measurement
(``MEASURE_TOP_K``); wall-clock timing of the survivors picks the winner,
and ONLY measured entries persist to the on-disk cache.  Roofline-only
mode (``measure=False``, used by the fast CI tier) stops after ranking:
deterministic, hermetic, no timing noise in version control.

A Pallas call is opaque to XLA's cost model, so neither kernel appears in
dry-run ``cost_analysis``; the stage overlays below are the analytic
substitute (the former ``launch/flash_overlay.py`` structural measurement
is folded into :func:`attention_stage_seconds` +
``benchmarks/roofline_report.py``).
"""
from __future__ import annotations

import re
from typing import Dict

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12     # bf16
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*([\w\-]+)\(")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _shape_bytes(type_str: str) -> int:
    """bytes of 'f32[16,32]{1,0}' or tuple '(f32[8], bf16[4,4])'."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _iter_collectives(hlo_text: str):
    """Yield ``(kind, result_dtype, operand_bytes)`` per collective
    instruction in compiled HLO text (async ``-start`` counted once,
    ``-done`` skipped)."""
    sizes: Dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _INSTR_RE.match(line)
        if m:
            name, type_str, _op = m.groups()
            sizes[name] = _shape_bytes(type_str)

    for line in lines:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        if op.endswith("-done"):
            continue  # async completion: counted at -start
        # operand list: everything inside the first (...) after the opcode
        paren = line[line.index(op) + len(op):]
        depth = 0
        args = ""
        for ch in paren:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                args += ch
        operand_names = re.findall(r"%([\w.\-]+)", args)
        b = sum(sizes.get(n, 0) for n in operand_names)
        if b == 0:
            b = _shape_bytes(type_str)  # fallback: result size
        dm = _SHAPE_RE.search(type_str)
        yield kind, (dm.group(1) if dm else "?"), b


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from compiled HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    out["total"] = 0
    for kind, _dtype, b in _iter_collectives(hlo_text):
        out[kind] += b
        out["total"] += b
    return out


def collective_buffer_bytes(hlo_text: str) -> Dict[str, Dict[str, int]]:
    """MAX single-instruction operand bytes per (collective kind, result
    dtype) — the peak-comm-buffer audit for the bucketed reduction: the
    int8 gradient gather shows up as ``["all-gather"]["s8"]``, and
    bucketing must cap it at O(bucket) instead of O(shard) while the fp32
    params/FSDP gathers (f32/bf16 dtypes) stay untouched."""
    out: Dict[str, Dict[str, int]] = {}
    for kind, dtype, b in _iter_collectives(hlo_text):
        d = out.setdefault(kind, {})
        d[dtype] = max(d.get(dtype, 0), b)
    return out


def roofline_terms(cost: dict, coll_bytes: int) -> dict:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    return {
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "collective_bytes_per_device": float(coll_bytes),
        "t_compute_s": flops / PEAK_FLOPS,
        "t_memory_s": byts / HBM_BW,
        "t_collective_s": coll_bytes / ICI_BW,
    }


def dominant_term(terms: dict) -> str:
    t = {"compute": terms["t_compute_s"], "memory": terms["t_memory_s"],
         "collective": terms["t_collective_s"]}
    return max(t, key=t.get)


def loss_stage_seconds(batch_tokens: int, d_model: int, padded_vocab: int,
                       *, fused: bool, bytes_act: int = 2) -> float:
    """HBM-bound time of the LM loss+grad stage (the roofline overlay for
    the fused chunked-vocab CE, analogous to the flash-attention term).

    ``fused=False`` models the legacy path's ~5 HBM crossings of the fp32
    ``[B*T, V]`` logits; ``fused=True`` models the logits-free kernel
    (kernels/fused_ce.py): 3 streams of hidden+W, no N*V term.

    This is the per-path overlay.  The per-block-config variant the
    autotuner ranks candidates with is ``kernels.autotune.predict_seconds``
    (see the module docstring above on the predicted-vs-measured split)."""
    from ..kernels.fused_ce import (lm_loss_hbm_bytes_fused,
                                    lm_loss_hbm_bytes_unfused)
    fn = lm_loss_hbm_bytes_fused if fused else lm_loss_hbm_bytes_unfused
    return fn(batch_tokens, d_model, padded_vocab,
              bytes_h=bytes_act) / HBM_BW


def attention_stage_seconds(B: int, H: int, Hkv: int, S: int, hd: int,
                            *, fused: bool, train: bool = True,
                            bytes_act: int = 2) -> float:
    """HBM-bound time of ONE layer's attention middle (scores/softmax/AV)
    — the roofline overlay for the flash kernel, analogous to
    :func:`loss_stage_seconds` for the fused CE.

    ``fused=False`` models the unfused path's materialized fp32 score
    tiles: the backward re-reads/rewrites them, charged at ~5 crossings of
    ``[B, H, S, block_k]`` strips (kernels/flash_attention.py's
    ``attention_hbm_bytes_unfused``).  ``fused=True`` charges the flash
    kernel's streaming floor: each of fwd/dQ/dKV reads Q,K,V once and
    writes its output once — no ``O(S^2)`` term.  ``train=False`` drops
    the backward passes (serving prefill)."""
    from ..kernels.flash_attention import (attention_hbm_bytes_flash,
                                           attention_hbm_bytes_train_flash,
                                           attention_hbm_bytes_unfused)
    if fused:
        fn = (attention_hbm_bytes_train_flash if train
              else attention_hbm_bytes_flash)
        return fn(B, H, Hkv, S, hd, bytes_per_el=bytes_act) / HBM_BW
    passes = 5 if train else 2
    return attention_hbm_bytes_unfused(B, H, S, hd, passes=passes) / HBM_BW


def kv_cache_slot_bytes(cfg, cache_len: int, *, kv_dtype=None) -> int:
    """HBM bytes one serve slot's KV cache holds across all layers.

    The per-token cost comes from :func:`repro.quant.kv_bytes_per_token`:
    bf16 charges 2 bytes/element, int8 charges 1 byte/element plus two
    fp32 per-token scales (K and V planes) per layer.  This is the
    analytic side of the serve-tier capacity model — at a fixed HBM
    budget the sustainable slot count is ``budget // slot_bytes``, so
    int8 buys ``2E/(E+4)`` more slots for ``E = n_kv_heads * head_dim``
    (~2x once E >> 4).  benchmarks/serve_sustained.py checks this
    prediction against ``jax.Array.nbytes`` of the live engine state."""
    from ..quant import kv_bytes_per_token
    kv_dtype = kv_dtype or cfg.kv_dtype
    return cfg.n_layers * cache_len * kv_bytes_per_token(
        cfg.n_kv_heads, cfg.hd, kv_dtype)


def kv_slots_at_budget(cfg, cache_len: int, hbm_budget_bytes: int,
                       *, kv_dtype=None) -> int:
    """Concurrent slots a fixed HBM budget sustains for the KV cache."""
    return int(hbm_budget_bytes
               // kv_cache_slot_bytes(cfg, cache_len, kv_dtype=kv_dtype))


# ---------------------------------------------------------------------------
# gradient-collective bucket model (distributed/overlap.py)

#: fixed per-collective cost — dispatch + ring latency — that dominates
#: tiny buckets.  ~10us is the TPU-generation ICI ballpark; the value only
#: has to be the right order of magnitude to keep the bucket chooser away
#: from the latency-bound regime.
COLLECTIVE_LAUNCH_S = 10e-6

#: how many buckets the overlap scheduler wants in flight per shard: more
#: buckets = finer backward/comm interleaving, fewer = less launch overhead.
TARGET_OVERLAP_BUCKETS = 8


def ring_collective_seconds(nbytes: int, ndev: int, *,
                            bw: float = ICI_BW,
                            launch: float = COLLECTIVE_LAUNCH_S) -> float:
    """Ring reduce-scatter + all-gather time for ``nbytes`` of payload:
    each phase moves ``(ndev-1)/ndev * nbytes`` per link."""
    if ndev <= 1:
        return 0.0
    return launch + 2.0 * (ndev - 1) / ndev * nbytes / bw


def ring_phase_seconds(nbytes: int, ndev: int, *, bw: float = ICI_BW,
                       launch: float = COLLECTIVE_LAUNCH_S) -> float:
    """ONE ring phase (a reduce-scatter OR an all-gather) of ``nbytes``."""
    if ndev <= 1:
        return 0.0
    return launch + (ndev - 1) / ndev * nbytes / bw


def exposed_comm_seconds(bucket_elems_list, ndev: int,
                         compute_budget_s: float, *, block: int = 256,
                         bw: float = ICI_BW,
                         launch: float = COLLECTIVE_LAUNCH_S) -> float:
    """Event-driven exposed-comm model for a gradient bucket schedule.

    The compressed reduction of bucket ``j`` (fp32 ring reduce-scatter,
    then int8+scales ring all-gather) is enqueued on a single comm channel
    the moment its slice of the backward pass has been produced — XLA
    rewrites slice-of-concatenate to the contributing operands, so bucket
    ``j``'s collective chain really does depend on only a suffix of the
    backward, modeled here as ready at ``compute_budget_s * (j+1) / B``.
    Exposed comm is whatever the channel still owes once compute is done:

        exposed  =  max(0, channel_finish - compute_budget_s)

    The monolithic schedule is the 1-bucket case: ready only when backward
    completes, so its ENTIRE wire time is exposed — while a bucketed
    schedule with ample compute exposes only the tail bucket's wire.  This
    is the quantity ``benchmarks/comm_overlap.py`` reports at ICI
    bandwidth (host CPUs serialize collectives, so wall clock cannot
    express it); the same model gives ``choose_bucket_elems`` its launch
    floor."""
    buckets = [int(n) for n in bucket_elems_list]
    B = len(buckets)
    channel = 0.0
    for j, n in enumerate(buckets):
        ready = compute_budget_s * (j + 1) / B
        wire = (ring_phase_seconds(4 * n, ndev, bw=bw, launch=launch)
                + ring_phase_seconds(n + 4 * (-(-n // block)), ndev,
                                     bw=bw, launch=launch))
        channel = max(channel, ready) + wire
    return max(0.0, channel - compute_budget_s)


def choose_bucket_elems(total_elems: int, ndev: int, *, block: int = 256,
                        bytes_per_elem: float = 1.0 + 4.0 / 256,
                        target_buckets: int = TARGET_OVERLAP_BUCKETS,
                        bw: float = ICI_BW,
                        launch: float = COLLECTIVE_LAUNCH_S) -> int:
    """Bucket size (elements) for the bucketed compressed all-reduce.

    Two pressures, both from the ring model above:

      * overlap granularity wants MANY buckets — the first bucket's
        collective can only hide behind the backward compute of the buckets
        still being produced, so per-shard we aim for
        ``TARGET_OVERLAP_BUCKETS``;
      * launch overhead wants FEW — a bucket whose wire time is dominated
        by ``COLLECTIVE_LAUNCH_S`` burns link time on latency, so the
        bucket floor is the size at which launch is <= 10% of wire time.

    ``bytes_per_elem`` defaults to the int8-plus-scales wire format
    (``1 + 4/256``).  The result is rounded to a multiple of
    ``block * ndev`` so per-device segments stay aligned with the
    quantization scale blocks (the device-count-invariance requirement)."""
    align = block * max(1, ndev)
    if total_elems <= align:
        return total_elems
    # floor: launch <= 10% of the bucket's ring wire time
    wire_bw = bw / max(1, 2 * (ndev - 1)) * max(1, ndev) if ndev > 1 else bw
    floor_bytes = 10.0 * launch * wire_bw
    floor_elems = int(floor_bytes / bytes_per_elem)
    want = max(floor_elems, total_elems // max(1, target_buckets))
    want = min(want, total_elems)
    b = -(-want // align) * align
    return min(b, total_elems)


def model_flops_train(n_params_active: int, tokens: int) -> float:
    """6*N*D per step (fwd+bwd)."""
    return 6.0 * n_params_active * tokens


def model_flops_infer(n_params_active: int, tokens: int) -> float:
    """2*N*D (forward only)."""
    return 2.0 * n_params_active * tokens
