import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
    * build ShapeDtypeStruct stand-ins for every input (zero allocation),
    * jit the step with explicit in/out shardings from the rule table,
    * .lower().compile() against the production mesh,
    * record memory_analysis (fits-per-device proof), cost_analysis
      (FLOPs / bytes), and collective bytes parsed from the compiled HLO.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import ARCHS, ASSIGNED, get_config, input_specs
from ..configs.shapes import SHAPES, applicable
from ..distributed.sharding import (batch_specs, cache_specs,
                                    partition_params, set_activation_mesh,
                                    to_shardings)
from ..models import get_model
from ..train.train_state import TrainState, state_partition_specs  # noqa: F401
# ^^ state_partition_specs lives with TrainState now (the elastic driver
# needs it without this module's XLA_FLAGS side effect); re-exported here
# for existing callers.
from ..train.trainer import TrainerConfig, make_train_fns
from .hlo_analysis import analyze_hlo
from .mesh import make_production_mesh
from .roofline import (dominant_term, model_flops_infer, model_flops_train,
                       roofline_terms)


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _bf16_params(shape_tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 else s, shape_tree)


def lower_cell(arch: str, shape_name: str, mesh, *, opt: str = "sophia_g",
               fsdp: bool = True, remat: str = "full",
               attn_impl: str = "auto", donate: bool = True,
               grad_accum: int = 1, state_dtype: str = "float32",
               moe_impl: str = "gspmd", seq_shard: bool = False,
               fused_loss: bool = False):
    """Returns (lowered, meta) for one (arch, shape) cell on ``mesh``.

    ``fused_loss`` (and ``fused_attn``, below) is explicitly False here
    (overriding the trainer default): this harness lowers on the CPU host
    platform, where the Pallas kernels run in interpret mode and their
    grids unroll at trace time — at production vocab sizes / sequence
    lengths that makes lowering pathological.  Pass True only for
    small-vocab cells."""
    cfg = get_config(arch)
    cell = input_specs(cfg, shape_name)
    assert cell is not None
    model = get_model(cfg)
    set_activation_mesh(mesh)  # pin residual/logits/expert shardings
    from ..distributed.sharding import set_sequence_sharding
    from ..models.moe import set_moe_impl
    set_moe_impl(moe_impl)
    set_sequence_sharding(seq_shard)

    if cell.kind == "train":
        tc = TrainerConfig(optimizer=opt, remat=remat, attn_impl=attn_impl,
                           total_steps=100_000, grad_accum=grad_accum,
                           state_dtype=state_dtype, fused_loss=fused_loss,
                           fused_attn=False)
        init_fn, train_step = make_train_fns(cfg, tc)
        state_shape = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
        pspecs = partition_params(state_shape.params, mesh, fsdp=fsdp)
        sspecs = state_partition_specs(state_shape, pspecs, mesh)
        bspecs = batch_specs(cell.specs["batch"], mesh)
        # the unified step carries the traced refresh flag: one lowered
        # program covers both the hot path and the cond'd estimator branch
        jf = jax.jit(train_step,
                     in_shardings=(_ns(mesh, sspecs), _ns(mesh, bspecs),
                                   None),
                     out_shardings=(_ns(mesh, sspecs), None),
                     donate_argnums=(0,) if donate else ())
        lowered = jf.lower(state_shape, cell.specs["batch"],
                           jax.ShapeDtypeStruct((), jnp.bool_))
        return lowered, {"cfg": cfg, "kind": "train"}

    # serving cells use bf16 weights.  TP-only sharding (weights replicated
    # across the data axis — the low-latency layout) when they fit; models
    # too big for that (400B MoE) shard weights over the data axis too and
    # gather per layer (throughput serving layout).
    params_shape = _bf16_params(
        jax.eval_shape(lambda k: model.init_params(cfg, k),
                       jax.random.PRNGKey(0)))
    tp_resident_gb = cfg.param_count() * 2 / mesh.shape["model"] / 1e9
    serve_fsdp = tp_resident_gb > 10.0
    pspecs = partition_params(params_shape, mesh, fsdp=serve_fsdp)

    if cell.kind == "prefill":
        if cfg.family == "encdec":
            def step(params, frames, cache):
                from ..models import encdec
                return encdec.prefill_encoder(cfg, params, frames, cache)
            cspecs = cache_specs(cell.specs["cache"], mesh)
            fspecs = batch_specs({"f": cell.specs["frames"]}, mesh)["f"]
            jf = jax.jit(step, in_shardings=(
                _ns(mesh, pspecs), _ns(mesh, fspecs), _ns(mesh, cspecs)))
            lowered = jf.lower(params_shape, cell.specs["frames"],
                               cell.specs["cache"])
        elif cfg.family in ("rwkv", "griffin"):
            def step(params, tokens):
                out = model.forward(cfg, params, tokens, last_only=True,
                                    attn_impl=attn_impl)
                return out[0]
            tspecs = batch_specs({"t": cell.specs["tokens"]}, mesh)["t"]
            jf = jax.jit(step, in_shardings=(_ns(mesh, pspecs),
                                             _ns(mesh, tspecs)))
            lowered = jf.lower(params_shape, cell.specs["tokens"])
        else:
            def step(params, tokens, patch_embeds=None):
                kw = {"attn_impl": attn_impl}
                if patch_embeds is not None:
                    kw["patch_embeds"] = patch_embeds
                return model.prefill(cfg, params, tokens, **kw)
            tspecs = batch_specs({"t": cell.specs["tokens"]}, mesh)["t"]
            args = [params_shape, cell.specs["tokens"]]
            in_sh = [_ns(mesh, pspecs), _ns(mesh, tspecs)]
            if "patch_embeds" in cell.specs:
                args.append(cell.specs["patch_embeds"])
                in_sh.append(_ns(
                    mesh, batch_specs({"p": cell.specs["patch_embeds"]},
                                      mesh)["p"]))
            jf = jax.jit(step, in_shardings=tuple(in_sh))
            lowered = jf.lower(*args)
        return lowered, {"cfg": cfg, "kind": "prefill"}

    # decode
    cspecs = cache_specs(cell.specs["cache"], mesh)
    tspecs = batch_specs({"t": cell.specs["tokens"]}, mesh)["t"]
    position = jnp.int32(cell.specs["position"])

    def step(params, cache, tokens):
        # position is uniformly accepted (ignored by stateless families)
        logits, new_cache = model.decode_step(cfg, params, cache, tokens,
                                              position)
        return jnp.argmax(logits[:, -1], -1), new_cache

    jf = jax.jit(step,
                 in_shardings=(_ns(mesh, pspecs), _ns(mesh, cspecs),
                               _ns(mesh, tspecs)),
                 out_shardings=(None, _ns(mesh, cspecs)),
                 donate_argnums=(1,) if donate else ())
    lowered = jf.lower(params_shape, cell.specs["cache"],
                       cell.specs["tokens"])
    return lowered, {"cfg": cfg, "kind": "decode"}


def analyse(lowered, meta, mesh, shape_name: str) -> dict:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # NOTE: XLA's compiled.cost_analysis() counts while-loop bodies once
    # (scan-over-layers => ~n_layers undercount); analyze_hlo walks the
    # call graph and multiplies loop bodies by parsed trip counts.
    acc = analyze_hlo(hlo)
    cost = {"flops": acc["flops"], "bytes accessed": acc["bytes"]}
    coll = dict(acc["coll"])
    coll["total"] = acc["coll_total"]
    terms = roofline_terms(cost, coll["total"])
    cfg = meta["cfg"]
    sh = SHAPES[shape_name]
    chips = mesh.devices.size
    if meta["kind"] == "train":
        tokens = sh["batch"] * sh["seq"]
        mflops = model_flops_train(cfg.active_param_count(), tokens) / chips
    elif meta["kind"] == "prefill":
        tokens = sh["batch"] * sh["seq"]
        mflops = model_flops_infer(cfg.active_param_count(), tokens) / chips
    else:
        tokens = sh["batch"]  # one token per sequence
        mflops = model_flops_infer(cfg.active_param_count(), tokens) / chips
    useful = mflops / terms["flops_per_device"] if terms["flops_per_device"] else 0.0
    dom = dominant_term(terms)
    t_total = max(terms["t_compute_s"], terms["t_memory_s"],
                  terms["t_collective_s"])
    return {
        "arch": cfg.name,
        "shape": shape_name,
        "kind": meta["kind"],
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": int(chips),
        "compile_s": round(compile_s, 1),
        "flops_per_device": terms["flops_per_device"],
        "bytes_per_device": terms["bytes_per_device"],
        "collective_bytes_per_device": terms["collective_bytes_per_device"],
        "coll_breakdown": {k: v for k, v in coll.items()
                           if k != "total" and v},
        "t_compute_s": terms["t_compute_s"],
        "t_memory_s": terms["t_memory_s"],
        "t_collective_s": terms["t_collective_s"],
        "dominant": dom,
        "model_flops_per_device": mflops,
        "useful_flops_ratio": useful,
        "roofline_fraction": (terms["t_compute_s"] / t_total * useful
                              if t_total else 0.0),
        "mem_args_gb": mem.argument_size_in_bytes / 1e9,
        "mem_out_gb": mem.output_size_in_bytes / 1e9,
        "mem_temp_gb": mem.temp_size_in_bytes / 1e9,
        "mem_alias_gb": mem.alias_size_in_bytes / 1e9,
        "mem_peak_gb": (mem.argument_size_in_bytes
                        + mem.output_size_in_bytes
                        + mem.temp_size_in_bytes
                        - mem.alias_size_in_bytes) / 1e9,
    }


def run_cell(arch, shape_name, *, multi_pod=False, opt="sophia_g",
             fsdp=True, remat="full", attn_impl="auto", fused_loss=False):
    cfg = get_config(arch)
    ok, reason = applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered, meta = lower_cell(arch, shape_name, mesh, opt=opt, fsdp=fsdp,
                               remat=remat, attn_impl=attn_impl,
                               fused_loss=fused_loss)
    rec = analyse(lowered, meta, mesh, shape_name)
    rec.update({"opt": opt, "fsdp": fsdp, "remat": remat})
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", default="sophia_g")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--fused-loss", action="store_true",
                    help="lower the Pallas fused loss too (interpret-mode "
                         "trace unrolling is slow at production vocabs; "
                         "off by default in this harness only)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ASSIGNED:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        print(f"=== {arch} x {shape} "
              f"({'multi' if args.multi_pod else 'single'}-pod) ===",
              flush=True)
        try:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                           opt=args.opt, fsdp=not args.no_fsdp,
                           remat=args.remat, fused_loss=args.fused_loss)
        except Exception as e:  # record the failure, keep going
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "error": repr(e)[:500]}
        results.append(rec)
        print(json.dumps(rec, indent=1, default=float), flush=True)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1, default=float)
    return results


if __name__ == "__main__":
    main()
