"""Serving driver: a request stream over the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --requests 8 --slots 4 --prompt-len 32 --max-new 32 --mixed

Submits ``--requests`` generation requests (mixed prompt/output lengths
with ``--mixed``) to a :class:`repro.serve.ServeEngine` and reports
steady-state throughput.  A warmup pass is timed separately so compile
time never pollutes tok/s; per-token p50/p95 latency, TTFT/TPOT/queue-wait
percentiles and slot utilization come from the engine's telemetry.

Serving tier-2 knobs: ``--prefix-cache/--no-prefix-cache`` turns on
shared-prefix KV page reuse (pair with ``--shared-prefix N`` to give the
stream a common preamble), and ``--kv-dtype int8`` switches the KV cache
to int8 payloads + fp32 per-token scales.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCHS, get_config
from ..models import get_model
from ..models.layers import set_decode_attn_impl
from ..serve import Request, ServeEngine

ENC_SRC_LEN = 16  # synthetic frame-stream length for encdec requests


def _make_requests(cfg, n, prompt_len, max_new, mixed, seed,
                   shared_prefix=0):
    """Deterministic request stream; --mixed varies both lengths;
    ``shared_prefix`` prepends a common preamble (exercises the prefix
    cache the way a shared system prompt would)."""
    prefix = (np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed + 7), (shared_prefix,), 0, cfg.vocab_size))
        if shared_prefix else None)
    reqs = []
    for i in range(n):
        if mixed:
            sp = max(1, prompt_len // 2 + (i * 7) % prompt_len)
            mn = max(1, max_new // 2 + (i * 5) % max_new)
        else:
            sp, mn = prompt_len, max_new
        if cfg.family == "encdec":
            frames = jax.random.normal(jax.random.PRNGKey(seed + 100 + i),
                                       (ENC_SRC_LEN, cfg.d_model))
            reqs.append(Request(uid=i, tokens=np.zeros((1,), np.int32),
                                max_new=mn, frames=frames))
        else:
            toks = np.asarray(jax.random.randint(
                jax.random.PRNGKey(seed + 100 + i), (sp,), 0,
                cfg.vocab_size))
            if prefix is not None:
                toks = np.concatenate([prefix, toks])
            reqs.append(Request(uid=i, tokens=toks, max_new=mn))
    return reqs


def _new_engine(cfg, params, args):
    return ServeEngine(cfg, params, n_slots=args.slots,
                       cache_len=2 * (args.prompt_len + args.shared_prefix
                                      + args.max_new),
                       page_len=args.page_len,
                       steps_per_tick=args.steps_per_tick, seed=args.seed,
                       src_len=ENC_SRC_LEN if cfg.family == "encdec" else 0,
                       prefix_cache=args.prefix_cache,
                       prefix_pool_pages=args.prefix_pool_pages,
                       kv_dtype=args.kv_dtype)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mixed", action="store_true",
                    help="vary prompt/output lengths across requests")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="common preamble tokens prepended to every prompt")
    ap.add_argument("--page-len", type=int, default=16)
    ap.add_argument("--steps-per-tick", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--decode-kernel", default="xla",
                    choices=["xla", "pallas"])
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="shared-prefix KV page reuse (dense/moe only)")
    ap.add_argument("--prefix-pool-pages", type=int, default=0,
                    help="device pool size in pages (0 = 4 * slots)")
    ap.add_argument("--kv-dtype", default=None, choices=["bf16", "int8"],
                    help="KV cache dtype; int8 stores 1-byte payloads "
                         "with fp32 per-token scales")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    set_decode_attn_impl(args.decode_kernel)
    cfg = get_config(args.arch, smoke=args.smoke)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(args.seed))

    # --- warmup: compile prefill + decode-burst programs off the clock ---
    t0 = time.perf_counter()
    warm = _new_engine(cfg, params, args)
    for r in _make_requests(cfg, min(2, args.requests), args.prompt_len,
                            args.max_new, args.mixed, args.seed + 999,
                            args.shared_prefix):
        warm.submit(r)
    warm.run()
    compile_s = time.perf_counter() - t0

    # --- measured request stream (steady state: programs already built) ---
    eng = _new_engine(cfg, params, args)
    reqs = _make_requests(cfg, args.requests, args.prompt_len, args.max_new,
                          args.mixed, args.seed, args.shared_prefix)
    for r in reqs:
        r.temperature = args.temperature
        eng.submit(r)
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0

    stats = eng.stats()
    toks = stats["tokens_emitted"]
    print(f"arch={cfg.name} slots={args.slots} requests={args.requests} "
          f"page_len={args.page_len} kernel={args.decode_kernel} "
          f"kv_dtype={eng.cfg.kv_dtype} prefix_cache={args.prefix_cache}")
    print(f"warmup (compile) {compile_s:.2f}s — excluded from tok/s")
    print(f"steady state: {toks} tokens in {dt:.2f}s = {toks / dt:.1f} tok/s")
    print(f"per-token latency p50={stats['token_lat_p50_s'] * 1e3:.2f}ms "
          f"p95={stats['token_lat_p95_s'] * 1e3:.2f}ms  "
          f"slot_utilization={stats['slot_utilization']:.2f}")
    print(f"mean request latency {stats['mean_request_latency_s']:.3f}s  "
          f"mean ttft {stats['mean_ttft_s']:.3f}s")
    print(f"ttft p50/p95/p99 {stats['ttft_p50_s']:.3f}/"
          f"{stats['ttft_p95_s']:.3f}/{stats['ttft_p99_s']:.3f}s  "
          f"tpot p50/p99 {stats['tpot_p50_s'] * 1e3:.2f}/"
          f"{stats['tpot_p99_s'] * 1e3:.2f}ms  "
          f"queue wait p99 {stats['queue_wait_p99_s']:.3f}s")
    if args.prefix_cache:
        print(f"prefix cache: hit_rate={stats['prefix_hit_rate']:.2f} "
              f"pages_reused={stats['prefix_pages_reused']} "
              f"inserts={stats['prefix_inserts']} "
              f"evictions={stats['prefix_evictions']} "
              f"pool={stats['prefix_pool_used']}/"
              f"{stats['prefix_pool_pages']}")
    # results arrive in completion order; sample request 0 specifically
    by_uid = {r.uid: r for r in results}
    print("sample (uid 0):", by_uid[0].tokens[:16])
    return results


if __name__ == "__main__":
    main()
