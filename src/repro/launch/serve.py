"""Serving driver: batched prefill + decode for any registered arch.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --batch 4 --prompt-len 32 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_config
from ..models import get_model
from ..serve import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(args.seed))
    prompt = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    out = generate(cfg, params, prompt, max_new=args.max_new,
                   temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"arch={cfg.name} generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print("sample:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
