import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Baseline dry-run sweep over every (arch x shape) cell.

Train cells pick a per-arch gradient-accumulation factor and adaptively
double it until the per-device memory fits the 16 GB v5e HBM (production
microbatching).  Results stream to JSON so a crash never loses progress.

    PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun_single.json
    PYTHONPATH=src python -m repro.launch.sweep --multi-pod --out results/dryrun_multi.json
"""
import argparse
import json
import time
import traceback

from ..configs import ASSIGNED, get_config
from ..configs.shapes import SHAPES, applicable
from .dryrun import analyse, lower_cell
from .mesh import make_production_mesh

HBM_BUDGET_GB = 15.5

# starting grad-accum for train cells (scaled by layer count x width)
ACCUM0 = {
    "qwen1.5-110b": 16,
    "llama4-maverick-400b-a17b": 32,
    "gemma2-9b": 8,
    "yi-6b": 8,
    "qwen2-vl-7b": 8,
}


def run_one(arch, shape, mesh, multi_pod):
    cfg = get_config(arch)
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "skipped": reason}
    kind = SHAPES[shape]["kind"]
    # microbatches must stay divisible by the dp degree — a microbatch
    # smaller than the data axis replicates compute (silent n-fold waste)
    import numpy as _np
    from ..distributed.sharding import batch_axis
    b_ax = batch_axis(mesh)
    dp = (int(_np.prod([mesh.shape[a] for a in b_ax]))
          if isinstance(b_ax, tuple) else mesh.shape[b_ax])
    max_accum = max(1, SHAPES[shape]["batch"] // dp)
    accum = min(ACCUM0.get(arch, 4), max_accum) if kind == "train" else 1
    # 400B-class: fp32 m/h alone exceed a pod's HBM; bf16 Sophia states
    # (same trick Gopher et al. used for Adam states) are the config here
    sdt = ("bfloat16" if cfg.param_count() > 2e11 and kind == "train"
           else "float32")
    last = None
    while True:
        lowered, meta = lower_cell(arch, shape, mesh, grad_accum=accum,
                                   state_dtype=sdt)
        rec = analyse(lowered, meta, mesh, shape)
        rec.update({"grad_accum": accum, "multi_pod": multi_pod,
                    "state_dtype": sdt})
        last = rec
        if kind != "train" or rec["mem_peak_gb"] <= HBM_BUDGET_GB \
                or accum >= max_accum:
            break
        accum = min(accum * 2, max_accum)
    last["fits_hbm"] = last["mem_peak_gb"] <= 16.0
    return last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", required=True)
    ap.add_argument("--archs", nargs="*", default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    results = []
    archs = args.archs or ASSIGNED
    for arch in archs:
        for shape in SHAPES:
            t0 = time.time()
            tag = f"{arch} x {shape} ({'multi' if args.multi_pod else 'single'})"
            try:
                rec = run_one(arch, shape, mesh, args.multi_pod)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "error": repr(e)[:400]}
            rec["wall_s"] = round(time.time() - t0, 1)
            results.append(rec)
            status = ("SKIP" if rec.get("skipped")
                      else "ERR" if rec.get("error")
                      else f"mem={rec['mem_peak_gb']:.1f}GB dom={rec['dominant']}")
            print(f"[{rec['wall_s']:7.1f}s] {tag}: {status}", flush=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1, default=float)


if __name__ == "__main__":
    main()
