"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gpt2-small \
        --opt sophia_g --steps 400 --global-batch 32 --seq-len 256 \
        --ckpt-dir /tmp/run1

Features: any registered arch (--smoke for the reduced config), any
optimizer, sharded execution over all visible devices (mesh auto-shaped),
Algorithm-3 hessian cadence, gradient accumulation, async checkpointing
with auto-resume, preemption-safe exit, straggler telemetry.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import ARCHS, get_config
from ..data import DataConfig, make_source
from ..distributed.sharding import (batch_specs, partition_params,
                                    set_activation_mesh)
from ..train import TrainerConfig, checkpoint as ckpt, make_engine, \
    make_train_fns
from ..train.elastic import PreemptionGuard, StragglerDetector
from .mesh import make_mesh


def build_mesh():
    n = len(jax.devices())
    if n == 1:
        return None
    # widest data axis that divides, model gets the rest
    model = 1
    for m in (8, 4, 2):
        if n % m == 0:
            model = m
            break
    return make_mesh((n // model, model), ("data", "model"))


def _final_save(ckpt_dir, step, state, extra):
    """Sync save at exit; skips if the periodic async save already wrote this
    step (and drains it first — the tmp dir would otherwise be shared)."""
    ckpt.wait_for_pending()
    if ckpt.latest_step(ckpt_dir) != step:
        ckpt.save(ckpt_dir, step, state, extra=extra)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config")
    ap.add_argument("--opt", default="sophia_g")
    ap.add_argument("--estimator", default="gnb")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--peak-lr", type=float, default=4e-4)
    ap.add_argument("--weight-decay", type=float, default=0.2)
    ap.add_argument("--gamma", type=float, default=0.05)
    ap.add_argument("--hess-interval", type=int, default=10)
    ap.add_argument("--hess-subbatch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--fused-kernel", action="store_true")
    ap.add_argument("--state-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    tc = TrainerConfig(
        optimizer=args.opt, estimator=args.estimator, peak_lr=args.peak_lr,
        total_steps=args.steps, warmup_steps=max(2, args.steps // 20),
        weight_decay=args.weight_decay, gamma=args.gamma,
        hess_interval=args.hess_interval, hess_subbatch=args.hess_subbatch,
        grad_accum=args.grad_accum, remat=args.remat,
        fused_kernel=args.fused_kernel, state_dtype=args.state_dtype,
        seed=args.seed)
    src = make_source(DataConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        vocab_size=cfg.vocab_size, seed=args.seed, source=args.data,
        path=args.data_path))

    init_fn, train_step, hess_step = make_train_fns(cfg, tc)
    mesh = build_mesh()
    if mesh is not None:
        set_activation_mesh(mesh)
        state = init_fn(jax.random.PRNGKey(args.seed))
        pspecs = partition_params(state.params, mesh, fsdp=True)
        from .dryrun import state_partition_specs
        sspecs = state_partition_specs(state, pspecs, mesh)
        ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        state = jax.device_put(state, ns(sspecs))
        sample = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
        bspecs = ns(batch_specs(sample, mesh))
        train_step = jax.jit(train_step, in_shardings=(ns(sspecs), bspecs),
                             out_shardings=(ns(sspecs), None))
        hess_step = jax.jit(hess_step, in_shardings=(ns(sspecs), bspecs),
                            out_shardings=(ns(sspecs), None))
    else:
        state = init_fn(jax.random.PRNGKey(args.seed))
        train_step = jax.jit(train_step)
        hess_step = jax.jit(hess_step)

    # flat-shard layout recorded alongside every checkpoint (restore sanity
    # check + elastic tooling can rebuild the unravel spec without the code)
    layout_meta = dict(make_engine(tc).describe(state.params),
                       optimizer=args.opt, state_dtype=args.state_dtype)

    start = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        prev = (ckpt.read_manifest(args.ckpt_dir).get("extra") or {})
        for field in ("optimizer", "state_dtype"):
            # different optimizer families (and state dtypes) share the flat
            # (m, h) layout, so a silent restore would reinterpret the
            # curvature state — refuse instead
            if prev.get(field) not in (None, layout_meta[field]):
                raise SystemExit(
                    f"[resume] checkpoint in {args.ckpt_dir} was written "
                    f"with {field}={prev[field]!r}; refusing to resume with "
                    f"{layout_meta[field]!r} (use a fresh --ckpt-dir)")
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            state)
        state, start = ckpt.restore(args.ckpt_dir, like)
        print(f"[resume] restored step {start} from {args.ckpt_dir}")

    guard = PreemptionGuard()
    straggler = StragglerDetector()
    needs_hess = args.opt in ("sophia_g", "sophia_h", "adahessian")
    t_start = time.time()
    for t in range(start, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(t).items()}
        fn = hess_step if (needs_hess and t % tc.hess_interval == 0) \
            else train_step
        state, metrics = fn(state, batch)
        dt = time.time() - t0
        if straggler.observe(dt):
            print(f"[straggler] step {t} took {dt:.2f}s "
                  f"(mean {straggler.mean:.2f}s)")
        if t % args.log_every == 0:
            loss = float(metrics["loss"])
            print(f"step {t:6d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt * 1e3:.0f}ms",
                  flush=True)
        if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, t + 1, state, async_=True,
                      extra=layout_meta)
        if guard.requested:
            print(f"[preempt] checkpointing at step {t + 1} and exiting")
            if args.ckpt_dir:
                _final_save(args.ckpt_dir, t + 1, state, layout_meta)
            return state
    if args.ckpt_dir:
        _final_save(args.ckpt_dir, args.steps, state, layout_meta)
    print(f"done: {args.steps - start} steps in {time.time() - t_start:.1f}s "
          f"(straggler flags: {straggler.flagged})")
    return state


if __name__ == "__main__":
    main()
