"""Production training driver — elastic, multi-host.

    PYTHONPATH=src python -m repro.launch.train --arch gpt2-small \
        --opt sophia_g --steps 400 --global-batch 32 --seq-len 256 \
        --ckpt-dir /tmp/run1 --elastic

Multi-host: launch the SAME command on every host, adding

    --coordinator host0:1234 --num-processes N --process-id <rank>

``jax.distributed`` initializes before any device query, the auto mesh
spans every process's devices, checkpoint save/restore is collective
(process 0 writes, manifests cross-validated), and a dead peer surfaces as
``NodeLoss``: the survivors exit non-zero, get relaunched with
``--num-processes`` = the surviving count, and resume from the last
complete manifest.

Features: any registered arch (--smoke for the reduced config), any
optimizer, sharded execution over all visible devices (mesh auto-shaped),
Algorithm-3 hessian cadence, gradient accumulation, buffer donation on the
jitted step (flat params/m/h update in place), async checkpointing with
auto-resume, preemption-safe exit, and an elastic retry loop: every attempt
rebuilds the mesh from the *surviving* device set and re-shards the latest
checkpoint onto it (checkpoint -> shrink mesh -> resume), so node loss or a
persistent straggler degrades capacity instead of killing the run.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from ..configs import ARCHS, get_config

# NOTE: jax is imported lazily-at-top but devices must not be touched until
# main() has had the chance to run jax.distributed.initialize
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..data import DataConfig, make_source
from ..distributed.sharding import (batch_specs, partition_params,
                                    set_activation_mesh)
from ..train import TrainerConfig, checkpoint as ckpt, make_engine, \
    make_train_fns
from ..train.elastic import (MeshDegraded, NodeLoss, PreemptionGuard,
                             StragglerDetector, is_distributed_failure,
                             run_resumable)
from ..train.train_state import state_partition_specs
from .mesh import enable_latency_hiding, initialize_distributed, make_mesh


def build_mesh(devices=None):
    """Auto mesh: the data axis gets at least the model axis's width — it
    carries the gradient reduction, the FSDP flat shards and the
    in-collective compression, so it must not collapse to 1 (the old
    model-first shaping made ``--compress-grads`` silently inert on <= 8
    devices).  ``devices`` restricts to a subset (the elastic driver's
    shrunken mesh); TP-heavy layouts should pass an explicit mesh."""
    devs = list(jax.devices()) if devices is None else list(devices)
    n = len(devs)
    if n == 1:
        return None
    model = 1
    for m in (4, 2):
        if n % m == 0 and n // m >= m:
            model = m
            break
    return make_mesh((n // model, model), ("data", "model"), devices=devs)


def _put_tree(tree, sh_tree):
    """device_put a host pytree against target shardings.  Shardings that
    span other processes' devices (multi-host) need
    ``make_array_from_callback`` — every process holds the identical global
    host value (deterministic init / stateless data pipeline) and
    contributes its addressable slices."""
    if sh_tree is None:
        return tree

    def put(x, s):
        if getattr(s, "is_fully_addressable", True):
            return jax.device_put(x, s)
        x = np.asarray(x)
        return jax.make_array_from_callback(x.shape, s, lambda idx: x[idx])

    return jax.tree.map(put, tree, sh_tree)


def _final_save(ckpt_dir, step, state, extra):
    """Sync save at exit; skips if the periodic async save already wrote this
    step (and drains it first — the tmp dir would otherwise be shared)."""
    ckpt.wait_for_pending()
    if ckpt.latest_step(ckpt_dir) != step:
        ckpt.save(ckpt_dir, step, state, extra=extra)


def compile_train_step(cfg, tc, mesh, sample_batch, state_shape=None):
    """Jit THE train step for ``mesh`` (explicit shardings + buffer
    donation) and return (train_step, init_fn, state_shardings,
    batch_shardings) — state/batch shardings are None on a mesh-less run.

    One program per mesh configuration: the Hessian refresh is a traced
    flag inside ``train_step(state, batch, do_refresh)``, so the elastic
    driver's per-device-set compile cache holds a single XLA executable
    where it used to hold a hot step *and* a refresh step.

    ``state_shape`` (an eval_shape of init_fn, mesh-independent) can be
    passed in to avoid re-tracing the model abstractly."""
    init_fn, train_step = make_train_fns(cfg, tc)
    # donate the TrainState: the flat params/m/h shards alias input->output,
    # halving optimizer-state peak memory (CPU has no donation; skip the
    # warning noise there)
    dn = (0,) if jax.default_backend() != "cpu" else ()
    set_activation_mesh(mesh)
    if mesh is None:
        return jax.jit(train_step, donate_argnums=dn), init_fn, None, None
    if state_shape is None:
        state_shape = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    pspecs = partition_params(state_shape.params, mesh, fsdp=True)
    sspecs = state_partition_specs(state_shape, pspecs, mesh)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    ssh = ns(sspecs)
    bsh = ns(batch_specs(sample_batch, mesh))
    return (jax.jit(train_step, in_shardings=(ssh, bsh, None),
                    out_shardings=(ssh, None), donate_argnums=dn),
            init_fn, ssh, bsh)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config")
    ap.add_argument("--opt", default="sophia_g")
    ap.add_argument("--estimator", default="gnb")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--peak-lr", type=float, default=4e-4)
    ap.add_argument("--weight-decay", type=float, default=0.2)
    ap.add_argument("--gamma", type=float, default=0.05)
    ap.add_argument("--hess-interval", type=int, default=10)
    ap.add_argument("--hess-subbatch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--fused-kernel", action="store_true")
    ap.add_argument("--fused-loss", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="Pallas logits-free LM loss + in-sweep GNB "
                         "sampling (kernels/fused_ce.py, autotuned block "
                         "sizes); --no-fused-loss falls back to the "
                         "chunked jnp sweep")
    ap.add_argument("--fused-attn", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="Pallas flash attention on the train path "
                         "(kernels/flash_attention.py, autotuned blocks; "
                         "the Hutchinson HVP rides its custom_jvp twin); "
                         "--no-fused-attn falls back to the reference "
                         "jnp attention")
    ap.add_argument("--retune", action="store_true",
                    help="re-run measured autotuning (fused-CE loss shape "
                         "and flash-attention shape) for this run before "
                         "training (ignores the on-disk caches; see README "
                         "'Fused loss' / 'Training attention')")
    ap.add_argument("--compress-grads", action="store_true",
                    help="in-collective int8 all-reduce over the fsdp axis")
    ap.add_argument("--comm-bucket-elems", type=int, default=None,
                    help="bucket size (elements) for the bucketed, "
                         "backward-overlapped gradient collective "
                         "(distributed/overlap.py): unset=auto (roofline), "
                         "0=monolithic, N=explicit")
    ap.add_argument("--comm-telemetry", action="store_true",
                    help="per-step comm/compute host stamps: logs "
                         "comm_seconds and exposed_comm_fraction")
    ap.add_argument("--coordinator", default=None,
                    help="host:port of process 0; presence turns on "
                         "multi-process jax.distributed")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--compress-hess", action="store_true",
                    help="int8-compress the estimator sub-batch gradient "
                         "too (stateless: no error feedback at refresh "
                         "sparsity)")
    ap.add_argument("--state-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--devices", type=int, default=None,
                    help="use only the first N visible devices")
    ap.add_argument("--elastic", action="store_true",
                    help="retry-with-restore on failure (run_resumable)")
    ap.add_argument("--max-restarts", type=int, default=None,
                    help="restart budget (default: 3 with --elastic, else 0)")
    ap.add_argument("--degrade-after", type=int, default=0,
                    help="with --elastic + --ckpt-dir: after N straggler "
                         "flags, checkpoint, halve the device set, and "
                         "resume on the smaller mesh (0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # MUST precede every jax device query: scheduler flags only apply at
    # backend init, and distributed init after a device query deadlocks
    enable_latency_hiding(
        (os.environ.get("JAX_PLATFORMS") or "tpu").split(",")[0])
    if args.coordinator:
        initialize_distributed(args.coordinator, args.num_processes,
                               args.process_id)
    p0 = jax.process_index() == 0

    cfg = get_config(args.arch, smoke=args.smoke)
    tc = TrainerConfig(
        optimizer=args.opt, estimator=args.estimator, peak_lr=args.peak_lr,
        total_steps=args.steps, warmup_steps=max(2, args.steps // 20),
        weight_decay=args.weight_decay, gamma=args.gamma,
        hess_interval=args.hess_interval, hess_subbatch=args.hess_subbatch,
        grad_accum=args.grad_accum, remat=args.remat,
        fused_kernel=args.fused_kernel, fused_loss=args.fused_loss,
        fused_attn=args.fused_attn,
        compress_grads=args.compress_grads,
        compress_hess=args.compress_hess,
        comm_bucket_elems=args.comm_bucket_elems,
        comm_telemetry=args.comm_telemetry,
        state_dtype=args.state_dtype, seed=args.seed)
    if args.retune and tc.fused_loss:
        # eager measured tuning for this run's exact hot-path loss shape;
        # the result persists to the on-disk cache so the jitted step's
        # trace picks it up (kernels/autotune.py)
        from ..kernels.autotune import tune_shape
        n_rows = (args.global_batch // max(1, args.grad_accum)) \
            * args.seq_len
        tuned = tune_shape(
            n_rows, cfg.d_model, cfg.padded_vocab, dtype=cfg.dtype,
            transpose_w=not cfg.tie_embeddings,
            softcap=cfg.final_logit_softcap, norm=cfg.norm_type,
            refresh=True)
        if p0:
            print(f"[retune] fused CE {n_rows}x{cfg.d_model}x"
                  f"{cfg.padded_vocab}: bn={tuned.bn} bv={tuned.bv} "
                  f"schedule={tuned.schedule} ({tuned.source})")
    if args.retune and tc.fused_attn and tc.attn_impl == "auto":
        from ..kernels.autotune import tune_attn_shape
        b_local = args.global_batch // max(1, args.grad_accum)
        tuned_a = tune_attn_shape(
            b_local, cfg.n_heads, cfg.n_kv_heads, args.seq_len,
            args.seq_len, cfg.hd, dtype=cfg.dtype, causal=True,
            softcap=cfg.attn_logit_softcap, refresh=True)
        if p0:
            print(f"[retune] flash attn B{b_local} H{cfg.n_heads} "
                  f"S{args.seq_len} hd{cfg.hd}: bq={tuned_a.bq} "
                  f"bk={tuned_a.bk} schedule={tuned_a.schedule} "
                  f"({tuned_a.source})")
    src = make_source(DataConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        vocab_size=cfg.vocab_size, seed=args.seed, source=args.data,
        path=args.data_path))
    sample = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}

    # The TrainState shape and the flat-shard layout are mesh-independent:
    # traced abstractly once, shared by every setup()/restore across mesh
    # reconfigurations.  The layout is recorded alongside every checkpoint
    # (the elastic restore verifies it, and offline tooling can rebuild the
    # unravel spec without the code).
    state_shape = jax.eval_shape(make_train_fns(cfg, tc)[0],
                                 jax.random.PRNGKey(args.seed))
    engine = make_engine(tc)
    layout_meta = dict(engine.describe(state_shape.params),
                       optimizer=args.opt, state_dtype=args.state_dtype,
                       compress_grads=bool(args.compress_grads))

    all_devices = list(jax.devices())
    ctx = {"devices": all_devices[:args.devices] if args.devices
           else all_devices}

    def setup():
        """(Re)build mesh + the single jitted step for the current device
        set.  A retry on an unchanged device set (transient failure, no
        degrade) keeps the compiled step — retraces cost minutes on real
        models."""
        key = tuple(ctx["devices"])
        if ctx.get("setup_key") == key:
            return
        mesh = build_mesh(ctx["devices"])
        sjit, init_fn, ssh, bsh = compile_train_step(cfg, tc, mesh, sample,
                                                     state_shape=state_shape)
        ctx.update(mesh=mesh, sjit=sjit, init_fn=init_fn,
                   ssh=ssh, bsh=bsh, setup_key=key)

    def make_state():
        setup()
        state = ctx["init_fn"](jax.random.PRNGKey(args.seed))
        return _put_tree(state, ctx["ssh"])

    def restore_latest():
        if not args.ckpt_dir or ckpt.latest_step(args.ckpt_dir) is None:
            return None
        prev = (ckpt.read_manifest(args.ckpt_dir).get("extra") or {})
        for field in ("optimizer", "state_dtype", "compress_grads"):
            # different optimizer families (and state dtypes) share the flat
            # (m, h) layout, so a silent restore would reinterpret the
            # curvature state; flipping compress_grads changes the
            # TrainState leaf count — refuse all three instead of dying in
            # restore (SystemExit is deliberately not retried by
            # run_resumable)
            if prev.get(field) not in (None, layout_meta[field]):
                raise SystemExit(
                    f"[resume] checkpoint in {args.ckpt_dir} was written "
                    f"with {field}={prev[field]!r}; refusing to resume with "
                    f"{layout_meta[field]!r} (use a fresh --ckpt-dir)")
        setup()
        state, start = ckpt.restore_resharded(
            args.ckpt_dir, state_shape, shardings=ctx["ssh"],
            expect_layout=layout_meta)
        if p0:
            print(f"[resume] restored step {start} from {args.ckpt_dir} "
                  f"onto {len(ctx['devices'])} device(s) / "
                  f"{jax.process_count()} process(es)")
        return state, start

    guard = PreemptionGuard()
    # the engine knows which families refresh curvature out-of-band (no
    # hardcoded optimizer-name tuple: a new curvature family would have
    # silently skipped its refresh cadence)
    needs_hess = engine.hessian_aware

    def run(state, start):
        straggler = StragglerDetector()
        t_start = time.time()
        for t in range(start, args.steps):
            t0 = time.time()
            # every process computes the identical global batch (stateless
            # deterministic source) and contributes its addressable slices
            batch = _put_tree(
                {k: jnp.asarray(v) for k, v in src.batch_at(t).items()},
                ctx["bsh"])
            flag = jnp.asarray(needs_hess and t % tc.hess_interval == 0)
            try:
                state, metrics = ctx["sjit"](state, batch, flag)
            except Exception as e:
                if jax.process_count() > 1 and is_distributed_failure(e):
                    # a peer died: unrecoverable in-process — propagate as
                    # NodeLoss so run_resumable exits instead of retrying
                    # into a hang; the relauncher resumes the survivors
                    # from the last manifest
                    raise NodeLoss(
                        f"distributed failure at step {t}: {e}") from e
                raise
            dt = time.time() - t0
            if straggler.observe(dt):
                if p0:
                    print(f"[straggler] step {t} took {dt:.2f}s "
                          f"(mean {straggler.mean:.2f}s)")
                if (args.elastic and args.degrade_after and args.ckpt_dir
                        and straggler.flagged >= args.degrade_after
                        and jax.process_count() == 1
                        and len(ctx["devices"]) > 1):
                    # checkpoint -> shrink mesh -> resume: drop the slow
                    # half of the device set and let run_resumable restore
                    # this exact step onto the smaller mesh
                    _final_save(args.ckpt_dir, t + 1, state, layout_meta)
                    ctx["devices"] = ctx["devices"][
                        :max(1, len(ctx["devices"]) // 2)]
                    raise MeshDegraded(
                        f"persistent straggler at step {t}; degrading to "
                        f"{len(ctx['devices'])} device(s)")
            if t % args.log_every == 0 and p0:
                loss = float(metrics["loss"])
                comm = ""
                if "comm_seconds" in metrics:
                    cs = float(metrics["comm_seconds"]) * 1e3
                    cf = float(metrics["exposed_comm_fraction"]) * 100
                    comm = f" comm {cs:.1f}ms ({cf:.0f}% of step)"
                print(f"step {t:6d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt * 1e3:.0f}ms{comm}", flush=True)
            if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, t + 1, state, async_=True,
                          extra=layout_meta)
            if guard.requested:
                if p0:
                    print(f"[preempt] checkpointing at step {t + 1} "
                          "and exiting")
                if args.ckpt_dir:
                    _final_save(args.ckpt_dir, t + 1, state, layout_meta)
                return state
        if args.ckpt_dir:
            _final_save(args.ckpt_dir, args.steps, state, layout_meta)
        if p0:
            print(f"done: {args.steps - start} steps in "
                  f"{time.time() - t_start:.1f}s "
                  f"(straggler flags: {straggler.flagged})")
        return state

    max_restarts = args.max_restarts if args.max_restarts is not None \
        else (3 if args.elastic else 0)
    return run_resumable(make_state, run, restore_latest,
                         max_restarts=max_restarts)


if __name__ == "__main__":
    main()
