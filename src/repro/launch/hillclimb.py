import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""§Perf hillclimbing: hypothesis -> change -> re-lower -> measure.

Three cells (selection rationale in EXPERIMENTS.md §Perf):
  1. deepseek-moe-16b x train_4k   — most collective-bound
  2. qwen1.5-110b    x train_4k   — paper-technique flagship (Sophia train)
  3. yi-6b           x prefill_32k — worst serving roofline fraction

Each variant is a named configuration of the levers the framework exposes
(moe dispatch impl, sequence sharding, grad accum, attention impl, remat,
state dtype).  Results stream to results/hillclimb.json.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell deepseek
"""
import argparse
import json
import time
import traceback

from .dryrun import analyse, lower_cell
from .mesh import make_production_mesh

CELLS = {
    "deepseek": ("deepseek-moe-16b", "train_4k", [
        # (variant name, hypothesis, kwargs)
        ("baseline", "gspmd scatter dispatch: compiler all-gathers the "
         "(T*K, D) dispatch tensors ~ 2x25.8GB/dev/layer", dict(grad_accum=4)),
        ("a2a", "explicit shard_map all-to-all moves only routed tokens: "
         "collective ~ T_loc*K*cf*D/M per dev per direction -> expect "
         ">10x lower collective term", dict(grad_accum=4, moe_impl="a2a")),
        ("a2a+seqshard", "sequence-sharded residuals halve the TP "
         "all-reduce volume on top of a2a", dict(grad_accum=4,
                                                 moe_impl="a2a",
                                                 seq_shard=True)),
    ]),
    "qwen110b": ("qwen1.5-110b", "train_4k", [
        ("baseline", "FSDP+TP, full remat, accum 16", dict(grad_accum=16)),
        ("seqshard", "sequence-parallel residual: remat carries shrink "
         "16x; post-block all-reduce -> reduce-scatter (half volume)",
         dict(grad_accum=16, seq_shard=True)),
        ("seqshard+chunked", "chunked attention on top: no (S,S) score "
         "buffer in HBM", dict(grad_accum=16, seq_shard=True,
                               attn_impl="chunked")),
        ("seqshard+accum8", "fewer, larger microbatches raise arithmetic "
         "intensity per pass (fewer weight re-reads across microbatches)",
         dict(grad_accum=8, seq_shard=True)),
    ]),
    "yi_prefill": ("yi-6b", "prefill_32k", [
        ("baseline", "chunked attention, bf16 weights, TP-only", dict()),
        ("seqshard", "sequence-sharded residuals: activations 1/16 per "
         "device through MLP; attention gathers KV once per layer",
         dict(seq_shard=True)),
    ]),
    # round 2 — informed by round-1 measurements (see EXPERIMENTS.md §Perf)
    "llama4": ("llama4-maverick-400b-a17b", "train_4k", [
        ("a2a", "generality of hillclimb 1: the same shard_map all-to-all "
         "dispatch on the 128-expert top-1 interleaved MoE (collective-"
         "bound at baseline, tcoll 51.4s)", dict(grad_accum=16,
                                                 moe_impl="a2a")),
    ]),
    "llama4_pf": ("llama4-maverick-400b-a17b", "prefill_32k", [
        ("a2a", "prefill is also collective-bound (49.4s): a2a dispatch on "
         "the serving path", dict(moe_impl="a2a")),
    ]),
    "deepseek3": ("deepseek-moe-16b", "train_4k", [
        ("a2a+accum8", "a2a left memory 18.5GB (>HBM): smaller microbatches "
         "shrink dispatch/activation working set under the 16GB budget "
         "without touching the collective win", dict(grad_accum=8,
                                                     moe_impl="a2a")),
    ]),
    "deepseek2": ("deepseek-moe-16b", "train_4k", [
        ("a2a+accum2", "round 1 left a2a memory-bound; halving microbatch "
         "count halves per-step FSDP weight regathers and per-pass fixed "
         "traffic", dict(grad_accum=2, moe_impl="a2a")),
        ("a2a+accum1", "single pass: minimum weight traffic, memory "
         "permitting", dict(grad_accum=1, moe_impl="a2a")),
    ]),
    "qwen110b3": ("qwen1.5-110b", "train_4k", [
        ("accum16+remat2x8fix", "nested remat with the inner body ALSO "
         "checkpointed: long-lived carries 80->10 layers; transient during "
         "group backward = g layer inputs, not g layers' intermediates",
         dict(grad_accum=16, remat="scan2")),
    ]),
    "qwen110b2": ("qwen1.5-110b", "train_4k", [
        ("accum8", "round 1 showed FSDP regathers scale with microbatch "
         "count (accum8+seqshard halved tcoll): accum 8 WITHOUT seqshard "
         "should cut baseline tcoll ~2x", dict(grad_accum=8)),
        ("accum8+remat2x8", "nested-scan remat keeps only every-8th-layer "
         "carry: memory freed by smaller carries pays for accum 8",
         dict(grad_accum=8, remat="scan2")),
        ("accum4+remat2x8", "push further: 4 microbatches = 4x fewer "
         "weight regathers vs baseline", dict(grad_accum=4, remat="scan2")),
    ]),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS), required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/hillclimb.json")
    args = ap.parse_args()

    arch, shape, variants = CELLS[args.cell]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    for name, hypothesis, kw in variants:
        t0 = time.time()
        try:
            lowered, meta = lower_cell(arch, shape, mesh, **kw)
            rec = analyse(lowered, meta, mesh, shape)
        except Exception as e:
            traceback.print_exc()
            rec = {"error": repr(e)[:400]}
        rec.update({"cell": args.cell, "variant": name,
                    "hypothesis": hypothesis, "kwargs": {k: str(v) for k, v
                                                         in kw.items()},
                    "wall_s": round(time.time() - t0, 1)})
        results.append(rec)
        if "error" not in rec:
            print(f"[{args.cell}/{name}] tc={rec['t_compute_s']:.3f} "
                  f"tm={rec['t_memory_s']:.3f} "
                  f"tcoll={rec['t_collective_s']:.3f} dom={rec['dominant']} "
                  f"mem={rec['mem_peak_gb']:.1f}GB "
                  f"useful={rec['useful_flops_ratio']:.2f}", flush=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=float)


if __name__ == "__main__":
    main()
