"""Continuous-batching serve engine: slot scheduler over the compiled
decode burst.

Requests stream in through :meth:`ServeEngine.submit`; the engine admits
them into free slots, chunk-prefills (one ``page_len`` chunk per admitted
request per tick, so in-flight decodes never stall behind a long prompt),
decodes every active slot in compiled bursts of ``steps_per_tick`` tokens,
and evicts finished sequences — freeing their slots for the queue.

Exactly two compiled programs per arch, independent of batch composition:

  * prefill: ``model.prefill_into_slot`` with traced (slot, start,
    n_valid) — every chunk of every request is the same program;
  * decode:  the ``make_decode_burst`` scan — per-slot positions,
    budgets, temperatures and EOS ids are all traced vectors.

Slot state is the family's ``init_slots`` pytree (slot-major ring/paged KV
for attention families, slot-major recurrent state for rwkv/griffin);
slots are fully independent rows, so a *greedy* request's tokens are
identical whatever else shares the batch (pinned by
tests/test_serve_engine.py).  Temperature sampling draws from the engine's
single RNG chain, so sampled tokens depend on scheduling (reproducible
only for a fixed seed + request stream).

Telemetry: per-request queue/prefill/first-token/total latency and
per-tick slot utilization, aggregated by :meth:`stats` (p50/p95/p99
TTFT, TPOT and queue wait).

Serving tier-2 options:

  * ``prefix_cache=True`` — shared-prefix page reuse.  Completed
    page-aligned prefill chunks are copied into a device-side pool and
    indexed by :class:`~repro.serve.prefix_cache.PrefixCache`; admission
    restores the longest cached prefix by copying whole pages back and
    starts chunked prefill at the cache boundary.  Restored pages are
    bit-copies and chunk boundaries are unchanged, so greedy outputs are
    token-identical to a cold prefill.
  * ``kv_dtype="int8"`` — per-token int8 KV payloads with fp32 scales
    (see models/layers.py); roughly halves cache HBM so a fixed budget
    sustains ~2x the slots.  Forks the compiled programs per dtype via
    the config, never per batch composition.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import ModelConfig, get_model
from .decode import NO_EOS, make_decode_burst, sample_tokens
from .prefix_cache import ROOT, PrefixCache

FREE, PREFILL, ACTIVE = 0, 1, 2


def _pct(xs, q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    return float(xs[min(len(xs) - 1, int(len(xs) * q))])


@functools.lru_cache(maxsize=32)
def _compiled_fns(cfg: ModelConfig, steps_per_tick: int):
    """Jitted prefill/reset/burst shared by every engine on this config —
    a fresh ServeEngine (e.g. after a timed warmup run) must not recompile.
    ModelConfig is a frozen dataclass, so it keys the cache directly.

    The slot-state argument is donated everywhere (the caller immediately
    rebinds it): the KV cache is the engine's dominant allocation, and
    without donation every tick would copy it whole."""
    model = get_model(cfg)
    prefill = jax.jit(
        lambda p, s, slot, toks, start, n: model.prefill_into_slot(
            cfg, p, s, slot, toks, start, n), donate_argnums=(1,))
    reset = jax.jit(lambda s, slot: model.reset_slot(cfg, s, slot),
                    donate_argnums=(0,))
    burst = jax.jit(make_decode_burst(cfg, steps_per_tick),
                    donate_argnums=(1,))
    enc = (jax.jit(lambda p, s, slot, fr: model.prefill_encoder_slot(
        cfg, p, s, slot, fr), donate_argnums=(1,))
        if cfg.family == "encdec" else None)
    return prefill, reset, burst, enc


@functools.lru_cache(maxsize=32)
def _page_copy_fns(cfg: ModelConfig, page_len: int):
    """Two jitted one-page copies between a slot cache and the prefix pool.

    slot/start/pool_idx are traced scalars, so each direction compiles
    exactly once per (config, page_len) whatever pages move — the engine's
    one-program-per-family invariant extends to the prefix cache.  The
    slice indexing is generic over leaf rank: 5-D k/v (L, N, C, Hkv, hd)
    and 3-D int8 scale planes (L, N, C) both have (layer, row, position)
    as their leading axes, which is all a page copy touches."""
    del cfg  # jit keys on leaf shapes; cfg keys the lru_cache entry

    def _copy_page(dst, src, dst_row, dst_off, src_row, src_off):
        def leaf(d, s):
            sizes = (s.shape[0], 1, page_len) + s.shape[3:]
            zeros = (0,) * (s.ndim - 3)
            page = jax.lax.dynamic_slice(s, (0, src_row, src_off) + zeros,
                                         sizes)
            return jax.lax.dynamic_update_slice(
                d, page, (0, dst_row, dst_off) + zeros)
        return jax.tree.map(leaf, dst, src)

    pool_to_slot = jax.jit(
        lambda state, pool, slot, start, pidx: _copy_page(
            state, pool, slot, start, pidx, 0), donate_argnums=(0,))
    slot_to_pool = jax.jit(
        lambda pool, state, slot, start, pidx: _copy_page(
            pool, state, pidx, 0, slot, start), donate_argnums=(0,))
    return pool_to_slot, slot_to_pool


@dataclasses.dataclass
class Request:
    """One generation request.  ``tokens`` is the prompt (1-D int32; for
    encdec the decoder prefix, usually just BOS, with ``frames`` carrying
    the encoder input).  ``temperature <= 0`` decodes greedily."""
    uid: Any
    tokens: Any
    max_new: int
    temperature: float = 0.0
    eos_id: Optional[int] = None
    frames: Any = None


@dataclasses.dataclass
class RequestResult:
    uid: Any
    tokens: List[int]
    submitted_t: float
    admitted_t: float
    first_token_t: float
    done_t: float

    @property
    def latency_s(self) -> float:
        return self.done_t - self.submitted_t

    @property
    def ttft_s(self) -> float:
        """Time to first token (queue wait + prefill)."""
        return self.first_token_t - self.submitted_t


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 cache_len: int = 256, page_len: int = 32,
                 steps_per_tick: int = 8, seed: int = 0, src_len: int = 0,
                 prefill_chunks_per_tick: int = 1,
                 prefix_cache: bool = False, prefix_pool_pages: int = 0,
                 kv_dtype: Optional[str] = None):
        if kv_dtype is not None and kv_dtype != cfg.kv_dtype:
            # fork the config so _compiled_fns keys per-dtype programs
            cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
        self.cfg = cfg
        self.params = params
        self.model = get_model(cfg)
        self.n_slots = n_slots
        self.page_len = page_len
        # round the ring up to whole pages so a final prefill chunk's
        # dynamic_update_slice never clamps (start + page_len <= cache_len)
        self.cache_len = -(-cache_len // page_len) * page_len
        self.steps_per_tick = steps_per_tick
        self.prefill_chunks_per_tick = prefill_chunks_per_tick
        self.src_len = src_len
        self._rng = jax.random.PRNGKey(seed)

        if cfg.family == "encdec":
            self.state = self.model.init_slots(cfg, n_slots, self.cache_len,
                                               src_len)
        else:
            self.state = self.model.init_slots(cfg, n_slots, self.cache_len)
        (self._prefill_jit, self._reset_jit, self._burst_jit,
         self._enc_jit) = _compiled_fns(self.cfg, steps_per_tick)

        # shared-prefix page pool: same pytree layout as the slot cache
        # with the slot axis replaced by pool pages of one page_len each
        self._prefix: Optional[PrefixCache] = None
        self._pool = None
        if prefix_cache:
            if self.cfg.family not in ("dense", "moe"):
                raise ValueError(
                    "prefix cache needs a paged KV cache; family "
                    f"{self.cfg.family!r} has none")
            pool_pages = prefix_pool_pages or 4 * n_slots
            self._prefix = PrefixCache(pool_pages, page_len)
            self._pool = jax.tree.map(
                lambda l: jnp.zeros(
                    (l.shape[0], pool_pages, page_len) + l.shape[3:],
                    l.dtype), self.state)
            self._pool_to_slot, self._slot_to_pool = _page_copy_fns(
                self.cfg, page_len)

        # host-side slot table
        self.slot_mode = [FREE] * n_slots
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_cursor = [0] * n_slots          # prefill progress (tokens)
        self.slot_out: List[List[int]] = [[] for _ in range(n_slots)]
        self.slot_meta: List[Optional[dict]] = [None] * n_slots
        # per-slot prefix-cache chain: held nodes + tail key for inserts
        # (None tail = pool exhausted mid-chain, stop inserting)
        self.slot_prefix_nodes: List[list] = [[] for _ in range(n_slots)]
        self.slot_chain_key: List[Optional[str]] = [ROOT] * n_slots
        self._last_tok = np.zeros((n_slots,), np.int32)
        self._pos = np.zeros((n_slots,), np.int32)
        self._rem = np.zeros((n_slots,), np.int32)
        self._temps = np.zeros((n_slots,), np.float32)
        self._eos = np.full((n_slots,), NO_EOS, np.int32)

        self.queue: deque = deque()
        self.results: List[RequestResult] = []
        # telemetry
        self.tick_utilization: List[float] = []
        self.token_latencies: List[float] = []
        self.tokens_emitted = 0
        self.decode_ticks = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        prompt_len = int(np.asarray(req.tokens).shape[0])
        if prompt_len + req.max_new > self.cache_len:
            raise ValueError(
                f"request {req.uid}: prompt {prompt_len} + max_new "
                f"{req.max_new} exceeds cache_len {self.cache_len}")
        if self.cfg.family == "encdec":
            # frames must fill the slot's cross-K/V rows exactly: a shorter
            # stream would leave a previous occupant's (or zero-init) rows
            # attendable — cross-attention has no source-length mask
            frames_len = np.asarray(req.frames).shape[-2]
            if frames_len != self.src_len:
                raise ValueError(
                    f"request {req.uid}: frames length {frames_len} != "
                    f"engine src_len {self.src_len}")
        self.queue.append((req, time.perf_counter()))

    def idle(self) -> bool:
        return not self.queue and all(m == FREE for m in self.slot_mode)

    # ------------------------------------------------------------------
    def _split(self):
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if self.slot_mode[slot] != FREE or not self.queue:
                continue
            req, submitted_t = self.queue.popleft()
            self.state = self._reset_jit(self.state, slot)
            if self.cfg.family == "encdec":
                frames = jnp.asarray(req.frames)
                if frames.ndim == 2:
                    frames = frames[None]
                self.state = self._enc_jit(self.params, self.state, slot,
                                           frames)
            self.slot_mode[slot] = PREFILL
            self.slot_req[slot] = req
            self.slot_cursor[slot] = 0
            self.slot_out[slot] = []
            self.slot_prefix_nodes[slot] = []
            self.slot_chain_key[slot] = ROOT
            if self._prefix is not None and req.frames is None:
                prompt = np.asarray(req.tokens, np.int32).reshape(-1)
                # cap below prompt_len so >= one real chunk still runs and
                # emits the last-token logits _activate samples from
                max_pages = min((prompt.shape[0] - 1) // self.page_len,
                                self.cache_len // self.page_len)
                chain = self._prefix.lookup(prompt, max_pages)
                self._prefix.acquire(chain)
                for i, node in enumerate(chain):
                    self.state = self._pool_to_slot(
                        self.state, self._pool, jnp.int32(slot),
                        jnp.int32(i * self.page_len),
                        jnp.int32(node.pool_idx))
                self.slot_prefix_nodes[slot] = list(chain)
                if chain:
                    self.slot_chain_key[slot] = chain[-1].key
                    self.slot_cursor[slot] = len(chain) * self.page_len
            self.slot_meta[slot] = {"submitted_t": submitted_t,
                                    "admitted_t": time.perf_counter()}
            self._temps[slot] = req.temperature
            self._eos[slot] = NO_EOS if req.eos_id is None else req.eos_id

    def _prefill_tick(self) -> None:
        P = self.page_len
        for slot in range(self.n_slots):
            if self.slot_mode[slot] != PREFILL:
                continue
            req = self.slot_req[slot]
            prompt = np.asarray(req.tokens, np.int32).reshape(-1)
            for _ in range(self.prefill_chunks_per_tick):
                start = self.slot_cursor[slot]
                chunk = prompt[start:start + P]
                n_valid = chunk.shape[0]
                if n_valid < P:
                    chunk = np.pad(chunk, (0, P - n_valid))
                self.state, logits = self._prefill_jit(
                    self.params, self.state, jnp.int32(slot),
                    jnp.asarray(chunk)[None], jnp.int32(start),
                    jnp.int32(n_valid))
                self.slot_cursor[slot] = start + n_valid
                if (self._prefix is not None and req.frames is None
                        and self.slot_chain_key[slot] is not None
                        and n_valid == P and start % P == 0):
                    node, fresh = self._prefix.insert(
                        self.slot_chain_key[slot], prompt[start:start + P])
                    if node is None:
                        self.slot_chain_key[slot] = None
                    else:
                        if fresh:
                            self._pool = self._slot_to_pool(
                                self._pool, self.state, jnp.int32(slot),
                                jnp.int32(start), jnp.int32(node.pool_idx))
                        self.slot_prefix_nodes[slot].append(node)
                        self.slot_chain_key[slot] = node.key
                if self.slot_cursor[slot] >= prompt.shape[0]:
                    self._activate(slot, logits)
                    break

    def _activate(self, slot: int, logits) -> None:
        """Prefill done: sample the first token and open the slot."""
        req = self.slot_req[slot]
        first = int(sample_tokens(self._split(), logits[None],
                                  jnp.asarray(self._temps[slot:slot + 1]))[0])
        now = time.perf_counter()
        self.slot_meta[slot]["first_token_t"] = now
        self.slot_out[slot].append(first)
        self.tokens_emitted += 1
        self._last_tok[slot] = first
        self._pos[slot] = self.slot_cursor[slot]
        hit_eos = self._eos[slot] != NO_EOS and first == self._eos[slot]
        self._rem[slot] = 0 if hit_eos else req.max_new - 1
        self.slot_mode[slot] = ACTIVE
        if self._rem[slot] == 0:
            self._finish(slot)

    def _decode_tick(self) -> None:
        if not any(self.slot_mode[s] == ACTIVE and self._rem[s] > 0
                   for s in range(self.n_slots)):
            return
        t0 = time.perf_counter()
        (self.state, toks, pos, rem, ys, act) = self._burst_jit(
            self.params, self.state, jnp.asarray(self._last_tok[:, None]),
            jnp.asarray(self._pos), jnp.asarray(self._rem),
            jnp.asarray(self._temps), jnp.asarray(self._eos), self._split())
        ys = np.asarray(ys)
        act = np.asarray(act)
        dt = time.perf_counter() - t0
        n_emitted = int(act.sum())
        if n_emitted:
            self.token_latencies.extend([dt / self.steps_per_tick] * n_emitted)
        self.tokens_emitted += n_emitted
        self.decode_ticks += 1
        self.tick_utilization.append(
            sum(m == ACTIVE for m in self.slot_mode) / self.n_slots)
        self._last_tok = np.asarray(toks)[:, 0].copy()
        self._pos = np.asarray(pos).copy()
        self._rem = np.asarray(rem).copy()
        for t in range(ys.shape[0]):
            for slot in range(self.n_slots):
                if act[t, slot]:
                    self.slot_out[slot].append(int(ys[t, slot]))
        for slot in range(self.n_slots):
            if self.slot_mode[slot] == ACTIVE and self._rem[slot] == 0:
                self._finish(slot)

    def _finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        meta = self.slot_meta[slot]
        self.results.append(RequestResult(
            uid=req.uid, tokens=list(self.slot_out[slot]),
            submitted_t=meta["submitted_t"], admitted_t=meta["admitted_t"],
            first_token_t=meta.get("first_token_t", time.perf_counter()),
            done_t=time.perf_counter()))
        self.slot_mode[slot] = FREE
        self.slot_req[slot] = None
        if self._prefix is not None:
            self._prefix.release(self.slot_prefix_nodes[slot])
            self.slot_prefix_nodes[slot] = []
            self.slot_chain_key[slot] = ROOT
        self._rem[slot] = 0
        self._temps[slot] = 0.0
        self._eos[slot] = NO_EOS

    # ------------------------------------------------------------------
    def tick(self) -> None:
        """One scheduler round: admit -> chunk-prefill -> decode burst."""
        self._admit()
        self._prefill_tick()
        self._decode_tick()

    def run(self, max_ticks: int = 100_000) -> List[RequestResult]:
        """Drive ticks until every submitted request has finished."""
        ticks = 0
        while not self.idle():
            self.tick()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("engine did not drain "
                                   f"within {max_ticks} ticks")
        return self.results

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        lat = sorted(self.token_latencies) or [0.0]
        util = self.tick_utilization or [0.0]
        ttft = [r.ttft_s for r in self.results]
        # time-per-output-token after the first (steady decode cadence)
        tpot = [(r.done_t - r.first_token_t) / max(1, len(r.tokens) - 1)
                for r in self.results]
        qwait = [r.admitted_t - r.submitted_t for r in self.results]
        out = {
            "tokens_emitted": self.tokens_emitted,
            "decode_ticks": self.decode_ticks,
            "slot_utilization": float(np.mean(util)),
            "token_lat_p50_s": float(lat[len(lat) // 2]),
            "token_lat_p95_s": float(lat[min(len(lat) - 1,
                                             int(len(lat) * 0.95))]),
            "mean_request_latency_s": float(np.mean(
                [r.latency_s for r in self.results])) if self.results else 0.0,
            "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
            "ttft_p50_s": _pct(ttft, 0.50),
            "ttft_p95_s": _pct(ttft, 0.95),
            "ttft_p99_s": _pct(ttft, 0.99),
            "tpot_p50_s": _pct(tpot, 0.50),
            "tpot_p95_s": _pct(tpot, 0.95),
            "tpot_p99_s": _pct(tpot, 0.99),
            "queue_wait_p50_s": _pct(qwait, 0.50),
            "queue_wait_p95_s": _pct(qwait, 0.95),
            "queue_wait_p99_s": _pct(qwait, 0.99),
        }
        if self._prefix is not None:
            out.update(self._prefix.stats())
        return out
