from .decode import (generate, generate_lockstep, make_decode_burst,
                     make_serve_step)
from .engine import Request, RequestResult, ServeEngine
from .prefix_cache import PrefixCache
