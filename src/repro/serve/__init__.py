from .decode import generate, make_serve_step
