"""Host-side shared-prefix index over page-aligned KV chunks.

Requests in a serving mix frequently share long prompt prefixes (system
prompts, few-shot preambles, chat history).  The engine's chunked prefill
already works in ``page_len`` units, so a completed full-page chunk is a
natural cache entry: its KV rows are a pure function of the token chunk
*and everything before it*.  This module keeps that index on the host —
a radix-style tree over chunk chain-hashes — while the page payloads live
in a device-side pool tree owned by the engine (`ServeEngine` copies pages
pool<->slot with two tiny jitted programs).

Keying: a page is identified by ``sha1(parent_key || chunk_tokens)``, so
the key commits to the whole prefix, not just the local chunk — two
prompts sharing a chunk mid-stream but differing earlier never collide.
The root sentinel ``ROOT`` anchors chains.

Eviction is refcount + LRU, **leaves only**: a node may be evicted only
when no slot holds it (``refcount == 0``) and it has no children.  That
keeps the tree closed under parent-presence — every cached node's full
chain is cached — so ``lookup`` can always walk from ROOT.  When the pool
is exhausted and nothing is evictable, ``insert`` returns ``(None,
False)`` and the engine stops inserting for that slot (preserving the
same invariant from the writer side).

Pure host bookkeeping: no jax imports, trivially testable.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

ROOT = "root"


def chunk_key(parent_key: str, chunk: np.ndarray) -> str:
    """Chain hash: commits to the full prefix through ``parent_key``."""
    h = hashlib.sha1(parent_key.encode())
    h.update(np.ascontiguousarray(chunk, dtype=np.int32).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class PageNode:
    key: str
    parent: str                # parent node key, or ROOT
    pool_idx: int              # page index in the engine's device pool
    refcount: int = 0          # slots currently holding this page
    children: int = 0          # cached nodes chained on this one
    last_use: int = 0          # LRU clock at last acquire/insert


class PrefixCache:
    """Refcounted radix index mapping chunk chains to pool page indices."""

    def __init__(self, pool_pages: int, page_len: int):
        if pool_pages <= 0:
            raise ValueError("prefix cache needs pool_pages > 0")
        self.pool_pages = pool_pages
        self.page_len = page_len
        self.nodes: Dict[str, PageNode] = {}
        self.free: List[int] = list(range(pool_pages - 1, -1, -1))
        self._clock = 0
        # counters (surfaced through ServeEngine.stats)
        self.lookups = 0
        self.hits = 0
        self.pages_reused = 0
        self.inserts = 0
        self.evictions = 0

    # ------------------------------------------------------------------
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def lookup(self, tokens: np.ndarray, max_pages: int) -> List[PageNode]:
        """Longest cached page-aligned prefix of ``tokens``, as the chain of
        nodes from ROOT.  ``max_pages`` caps the walk (the engine passes
        ``(prompt_len - 1) // page_len`` so at least one real prefill chunk
        remains to produce last-token logits)."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        self.lookups += 1
        chain: List[PageNode] = []
        key = ROOT
        P = self.page_len
        for i in range(max_pages):
            nxt = chunk_key(key, tokens[i * P:(i + 1) * P])
            node = self.nodes.get(nxt)
            if node is None:
                break
            chain.append(node)
            key = nxt
        if chain:
            self.hits += 1
            self.pages_reused += len(chain)
        return chain

    def acquire(self, chain: List[PageNode]) -> None:
        now = self._tick()
        for node in chain:
            node.refcount += 1
            node.last_use = now

    def release(self, chain: List[PageNode]) -> None:
        for node in chain:
            if node.refcount <= 0:
                raise RuntimeError(f"double release of page {node.key}")
            node.refcount -= 1

    # ------------------------------------------------------------------
    def _evict_one(self) -> Optional[int]:
        """Free the least-recently-used unreferenced leaf; its pool index."""
        victim = None
        for node in self.nodes.values():
            if node.refcount == 0 and node.children == 0:
                if victim is None or node.last_use < victim.last_use:
                    victim = node
        if victim is None:
            return None
        del self.nodes[victim.key]
        if victim.parent != ROOT:
            self.nodes[victim.parent].children -= 1
        self.evictions += 1
        return victim.pool_idx

    def insert(self, parent_key: str,
               chunk: np.ndarray) -> Tuple[Optional[PageNode], bool]:
        """Register a freshly prefetched full page chained on ``parent_key``.

        Returns ``(node, fresh)``; the node comes back acquired (one
        refcount for the calling slot) either way.  ``fresh=True`` means
        the caller must copy the page slot->pool; ``fresh=False`` means an
        identical chain already holds it.  ``(None, False)`` means the
        pool is full of held/interior pages — stop inserting for this
        chain (a dangling child would break the parent-presence
        invariant)."""
        if parent_key != ROOT and parent_key not in self.nodes:
            raise KeyError(f"parent {parent_key} not cached")
        key = chunk_key(parent_key, chunk)
        node = self.nodes.get(key)
        now = self._tick()
        if node is not None:
            node.refcount += 1
            node.last_use = now
            return node, False
        if self.free:
            pool_idx = self.free.pop()
        else:
            pool_idx = self._evict_one()
            if pool_idx is None:
                return None, False
        node = PageNode(key=key, parent=parent_key, pool_idx=pool_idx,
                        refcount=1, last_use=now)
        self.nodes[key] = node
        if parent_key != ROOT:
            self.nodes[parent_key].children += 1
        self.inserts += 1
        return node, True

    # ------------------------------------------------------------------
    @property
    def pool_used(self) -> int:
        return self.pool_pages - len(self.free)

    def stats(self) -> Dict[str, float]:
        return {
            "prefix_lookups": self.lookups,
            "prefix_hits": self.hits,
            "prefix_hit_rate": self.hits / self.lookups if self.lookups else 0.0,
            "prefix_pages_reused": self.pages_reused,
            "prefix_inserts": self.inserts,
            "prefix_evictions": self.evictions,
            "prefix_pool_used": self.pool_used,
            "prefix_pool_pages": self.pool_pages,
        }
