"""Serving decode paths: the compiled burst loop + legacy lockstep baseline.

``make_decode_burst`` builds the engine's hot loop: a ``lax.scan`` over
decode steps with per-slot position, per-slot remaining-token budget,
EOS/length masking and greedy + temperature sampling all inside the scan —
one compiled program per arch regardless of batch composition or request
lengths (slots ride through as traced vectors).

``generate`` is the end-to-end batched API: a thin wrapper over
``serve.engine.ServeEngine`` so every caller exercises the same slot/ring
path the production engine runs.  ``generate_lockstep`` preserves the
pre-engine Python token loop as the benchmark baseline
(benchmarks/serve_throughput.py) — do not use it for new code.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..models import ModelConfig, get_model

NO_EOS = -1  # sentinel: no EOS id for this slot


def make_serve_step(cfg: ModelConfig, *, temperature: float = 0.0):
    """Returns serve_step(params, cache, tokens, position, rng) ->
    (next_tokens (B,1), logits, cache).  ``position`` is passed to every
    family — stateless ones ignore it (no family branching here)."""
    model = get_model(cfg)

    def serve_step(params, cache, tokens, position, rng):
        logits, cache = model.decode_step(cfg, params, cache, tokens,
                                          position)
        logits = logits[:, -1, :]
        if temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, cache

    return serve_step


# ---------------------------------------------------------------------------
# the compiled decode loop


def sample_tokens(rng, logits, temps):
    """Greedy where temps <= 0, temperature sampling elsewhere.
    logits (N, V) fp32; temps (N,) fp32 -> (N,) int32."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.random.categorical(rng, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0.0, sampled, greedy)


def _select_slots(new, old, active):
    """Per-slot pytree select (slot axis 1 in every leaf): freed/prefilling
    slots must not advance during a decode burst."""
    def sel(a, b):
        m = active.reshape((1, active.shape[0]) + (1,) * (a.ndim - 2))
        return jnp.where(m, a, b)

    return jax.tree.map(sel, new, old)


def make_decode_burst(cfg: ModelConfig, n_steps: int):
    """Builds burst(params, state, tokens, positions, remaining, temps,
    eos_ids, rng) -> (state, tokens, positions, remaining, ys, act).

    One ``lax.scan`` of ``n_steps`` decode steps over all slots:

      * ``remaining[i] > 0`` marks slot i active; inactive slots are frozen
        (state unselected, position pinned, last token re-fed) so admitted-
        but-still-prefilling slots and freed slots ride along inertly;
      * a slot emitting its EOS id (or exhausting its budget) deactivates
        inside the scan — no host round-trip per token;
      * sampling is per-slot: greedy at temps[i] <= 0, temperature
        sampling otherwise, one RNG split per step.

    ``ys`` (n_steps, N) are emitted tokens, ``act`` (n_steps, N) marks
    which entries are real.  Wrap in jax.jit — everything is traced, so
    the jit cache stays at one program per (N, n_steps).
    """
    model = get_model(cfg)

    def burst(params, state, tokens, positions, remaining, temps, eos_ids,
              rng):
        def body(carry, _):
            state, toks, pos, rem, rng = carry
            rng, sub = jax.random.split(rng)
            logits, new_state = model.decode_slots(cfg, params, state, toks,
                                                   pos)
            active = rem > 0
            nxt = sample_tokens(sub, logits[:, -1, :], temps)
            nxt = jnp.where(active, nxt, toks[:, 0])
            hit_eos = active & (nxt == eos_ids)
            rem = jnp.where(active,
                            jnp.where(hit_eos, jnp.zeros_like(rem), rem - 1),
                            rem)
            pos = jnp.where(active, pos + 1, pos)
            state = _select_slots(new_state, state, active)
            return (state, nxt[:, None], pos, rem, rng), (nxt, active)

        (state, tokens, positions, remaining, rng), (ys, act) = jax.lax.scan(
            body, (state, tokens, positions, remaining, rng), None,
            length=n_steps)
        return state, tokens, positions, remaining, ys, act

    return burst


# ---------------------------------------------------------------------------
# public generate API (thin wrapper over the engine)


def generate(cfg: ModelConfig, params, prompt_tokens, *, max_new: int,
             temperature: float = 0.0, seed: int = 0,
             max_len: Optional[int] = None, eos_id: Optional[int] = None,
             page_len: Optional[int] = None):
    """Greedy/temperature batched generation.  prompt (B, S_p) int32 ->
    (B, max_new) int32.  Runs through the continuous-batching engine with
    one slot per row; greedy output is token-identical to the legacy
    lockstep path (pinned by tests/test_serve.py)."""
    from .engine import Request, ServeEngine

    B, Sp = prompt_tokens.shape
    cache_len = max_len or (Sp + max_new)
    eng = ServeEngine(cfg, params, n_slots=B, cache_len=cache_len,
                      page_len=page_len or min(Sp, 32),
                      steps_per_tick=min(8, max(1, max_new - 1)), seed=seed)
    for i in range(B):
        eng.submit(Request(uid=i, tokens=prompt_tokens[i],
                           max_new=max_new, temperature=temperature,
                           eos_id=eos_id))
    results = {r.uid: r for r in eng.run()}
    pad = eos_id if eos_id is not None else 0
    out = jnp.full((B, max_new), pad, jnp.int32)
    for i in range(B):
        toks = jnp.asarray(results[i].tokens, jnp.int32)
        out = out.at[i, :toks.shape[0]].set(toks)
    return out


def generate_lockstep(cfg: ModelConfig, params, prompt_tokens, *,
                      max_new: int, temperature: float = 0.0, seed: int = 0,
                      max_len: Optional[int] = None):
    """Legacy fixed-batch generation: Python token loop, full-``max_len``
    padded caches, every request marches in lockstep.  Kept as the
    benchmark baseline; superseded by :func:`generate`."""
    model = get_model(cfg)
    B, Sp = prompt_tokens.shape
    max_len = max_len or (Sp + max_new)
    serve_step = jax.jit(make_serve_step(cfg, temperature=temperature))
    rng = jax.random.PRNGKey(seed)

    if cfg.family in ("dense", "moe"):
        # prefill then decode
        logits, cache = model.prefill(cfg, params, prompt_tokens)
        pad = max_len - Sp
        cache = jax.tree.map(
            lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            cache)
        last = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        toks = [last]
        pos = Sp
    else:
        # recurrent families: feed the prompt token-by-token
        assert cfg.family in ("rwkv", "griffin"), cfg.family
        cache = model.init_cache(cfg, B, max_len)
        last = None
        for t in range(Sp):
            rng, sub = jax.random.split(rng)
            last, _, cache = serve_step(params, cache,
                                        prompt_tokens[:, t:t + 1],
                                        jnp.int32(t), sub)
        toks = [last]
        pos = Sp

    for i in range(max_new - 1):
        rng, sub = jax.random.split(rng)
        last, _, cache = serve_step(params, cache, toks[-1],
                                    jnp.int32(pos), sub)
        toks.append(last)
        pos += 1
    return jnp.concatenate(toks, axis=1)
