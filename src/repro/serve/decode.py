"""Batched serving: prefill + token-by-token decode with KV caches.

``serve_step`` is the function the decode_32k / long_500k dry-run cells
lower: one new token for every sequence in the batch against a cache of
``seq_len``.  ``generate`` is the end-to-end batched request loop used by
examples/serve.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..models import ModelConfig, get_model


def make_serve_step(cfg: ModelConfig, *, temperature: float = 0.0):
    """Returns serve_step(params, cache, tokens, position, rng) ->
    (next_tokens (B,1), logits, cache)."""
    model = get_model(cfg)

    def serve_step(params, cache, tokens, position, rng):
        if cfg.family == "rwkv":
            logits, cache = model.decode_step(cfg, params, cache, tokens)
        else:
            logits, cache = model.decode_step(cfg, params, cache, tokens,
                                              position)
        logits = logits[:, -1, :]
        if temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, cache

    return serve_step


def generate(cfg: ModelConfig, params, prompt_tokens, *, max_new: int,
             temperature: float = 0.0, seed: int = 0,
             max_len: Optional[int] = None):
    """Greedy/temperature batched generation.  prompt (B, S_p) int32."""
    model = get_model(cfg)
    B, Sp = prompt_tokens.shape
    max_len = max_len or (Sp + max_new)
    serve_step = jax.jit(make_serve_step(cfg, temperature=temperature))
    rng = jax.random.PRNGKey(seed)

    if cfg.family in ("dense", "moe"):
        # prefill then decode
        logits, cache = model.prefill(cfg, params, prompt_tokens)
        pad = max_len - Sp
        cache = jax.tree.map(
            lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            cache)
        last = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        toks = [last]
        pos = Sp
    else:
        # recurrent families: feed the prompt token-by-token
        cache = model.init_cache(cfg, B, max_len) \
            if cfg.family != "encdec" else None
        assert cfg.family in ("rwkv", "griffin"), cfg.family
        last = None
        for t in range(Sp):
            rng, sub = jax.random.split(rng)
            last, _, cache = serve_step(params, cache,
                                        prompt_tokens[:, t:t + 1],
                                        jnp.int32(t), sub)
        toks = [last]
        pos = Sp

    for i in range(max_new - 1):
        rng, sub = jax.random.split(rng)
        last, _, cache = serve_step(params, cache, toks[-1],
                                    jnp.int32(pos), sub)
        toks.append(last)
        pos += 1
    return jnp.concatenate(toks, axis=1)
